"""Deterministic fault injection for the serving plane.

Chaos testing is only useful when a failure is *replayable*: a bug found
by a randomly-timed kill is a bug you can't regress-test. This module
makes every failure a named, counted event on the code path that would
really fail, so a chaos scenario is an ordinary deterministic test:

* production code declares **fault sites** by calling
  :func:`fault_point` at the instants where a real process could die or
  stall — replica batch execution (``"replica.execute"``), each
  compactor phase boundary (``"compactor.begin"`` / ``".seal"`` /
  ``".prepare"`` / ``".commit"``), the checkpoint write/publish windows
  (``"checkpoint.write"`` / ``"checkpoint.publish"``), and the WAL
  record write (``"wal.append"``). With no plan installed the call is a
  cheap no-op (one global read), so the serving fast path is unchanged;
* a test (or ``benchmarks/bench_chaos.py``) installs a
  :class:`FaultPlan` — a list of :class:`FaultSpec` triggers — via
  :func:`fault_scope`. Each spec fires on the Nth *matching* hit of its
  site, optionally filtered by context (``where={"replica": 0}``) and
  thinned by a seeded probability, so the same plan over the same trace
  fires at exactly the same instants on every run (virtual clock
  included — nothing here reads wall time);
* a firing spec either raises :class:`InjectedFault` (``kind="raise"``
  for an in-process failure whose cleanup handlers run, ``kind="crash"``
  for a simulated process death at a phase boundary — sites place crash
  points *outside* their cleanup handlers so the aftermath is exactly a
  kill's, ``kind="torn"`` for a write interrupted mid-record) or returns
  extra latency seconds (``kind="delay"`` — injected straggler time the
  caller charges to its service model).

Every firing is recorded in ``plan.log`` (site, hit number, context),
which doubles as the determinism witness: two runs of the same seeded
plan over the same trace produce identical logs.

>>> plan = FaultPlan(FaultSpec("replica.execute", at=2, where={"replica": 1}))
>>> with fault_scope(plan):
...     fault_point("replica.execute", replica=0)   # no match: replica 0
...     fault_point("replica.execute", replica=1)   # hit 1: armed at 2
...     try:
...         fault_point("replica.execute", replica=1)
...     except InjectedFault as e:
...         print("fired:", e.site)
0.0
0.0
fired: replica.execute
>>> plan.fired
1
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A failure raised by an installed :class:`FaultPlan`.

    ``kind`` tells the instrumented site how to die: ``"raise"`` is an
    ordinary in-process error (cleanup runs), ``"crash"`` simulates a
    process kill at a phase boundary (sites re-raise it past their
    cleanup), ``"torn"`` asks a writer to persist a partial record
    before raising (a mid-``write(2)`` power cut)."""

    def __init__(self, site: str, kind: str = "raise", hit: int = 0):
        super().__init__(f"injected fault at {site!r} (kind={kind}, hit={hit})")
        self.site = site
        self.kind = kind
        self.hit = hit


@dataclass
class FaultSpec:
    """One trigger: fire ``count`` times starting at the ``at``-th
    matching hit of ``site`` (hits are 1-based and counted per spec).

    ``where`` filters by the context keywords the site reports (subset
    match: every listed key must be present and equal). ``p`` < 1 thins
    matching hits through the plan's seeded rng — still deterministic
    for a fixed seed. ``kind="delay"`` makes :func:`fault_point` return
    ``delay_s`` instead of raising (injected straggler latency)."""

    site: str
    at: int = 1
    count: int = 1
    kind: str = "raise"             # "raise" | "crash" | "delay" | "torn"
    delay_s: float = 0.0
    where: Optional[Dict[str, object]] = None
    p: float = 1.0

    def __post_init__(self):
        if self.kind not in ("raise", "crash", "delay", "torn"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at < 1 or self.count < 1:
            raise ValueError("at and count are 1-based and positive")


@dataclass
class FaultPlan:
    """A seeded, replayable schedule of :class:`FaultSpec` triggers.

    Thread-safe: hit counters and the firing log are guarded so faults
    can fire from the front-end's pool threads and the background
    compactor as deterministically as from a single-threaded replay
    (per-spec counting depends only on the sequence of matching hits
    each spec observes, not on cross-site interleaving)."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0
    log: List[dict] = field(default_factory=list)

    def __init__(self, *specs: FaultSpec, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = seed
        self.log = []
        self._hits = [0] * len(self.specs)
        self._rng = np.random.default_rng(seed)
        self._mu = threading.Lock()

    @property
    def fired(self) -> int:
        """How many faults have fired so far."""
        with self._mu:
            return len(self.log)

    def _matches(self, spec: FaultSpec, site: str, ctx: dict) -> bool:
        if spec.site != site:
            return False
        if spec.where:
            return all(k in ctx and ctx[k] == v for k, v in spec.where.items())
        return True

    def hit(self, site: str, **ctx) -> Optional[Tuple[FaultSpec, int]]:
        """Count one hit of ``site``; return the armed ``(spec, hit#)``
        if a spec fires, else None. First matching spec wins."""
        with self._mu:
            for i, spec in enumerate(self.specs):
                if not self._matches(spec, site, ctx):
                    continue
                self._hits[i] += 1
                h = self._hits[i]
                if not (spec.at <= h < spec.at + spec.count):
                    continue
                if spec.p < 1.0 and self._rng.random() >= spec.p:
                    continue
                self.log.append(
                    {"site": site, "kind": spec.kind, "hit": h, **ctx}
                )
                return spec, h
        return None


# One plan active at a time, process-wide: chaos scenarios run serially
# (a test installs a plan around one trace), while the *firing* threads —
# pool workers, the compactor loop — may be many.
_ACTIVE: Optional[FaultPlan] = None


def install_fault_plan(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` process-wide (None clears). Prefer the
    :func:`fault_scope` context manager, which restores on exit."""
    global _ACTIVE
    _ACTIVE = plan


def active_fault_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def fault_scope(*specs_or_plan, seed: int = 0) -> Iterator[FaultPlan]:
    """Install a fault plan for the duration of the block.

    Accepts either a ready :class:`FaultPlan` or :class:`FaultSpec`\\ s
    to build one from. Yields the plan (inspect ``plan.log`` after)."""
    if len(specs_or_plan) == 1 and isinstance(specs_or_plan[0], FaultPlan):
        plan = specs_or_plan[0]
    else:
        plan = FaultPlan(*specs_or_plan, seed=seed)
    prev = _ACTIVE
    install_fault_plan(plan)
    try:
        yield plan
    finally:
        install_fault_plan(prev)


def fault_point(site: str, **ctx) -> float:
    """Declare a fault site. Returns injected extra latency in seconds
    (0.0 normally); raises :class:`InjectedFault` when an installed plan
    fires a ``raise``/``crash``/``torn`` spec here. No-op (and free)
    when no plan is installed."""
    plan = _ACTIVE
    if plan is None:
        return 0.0
    armed = plan.hit(site, **ctx)
    if armed is None:
        return 0.0
    spec, h = armed
    if spec.kind == "delay":
        return spec.delay_s
    raise InjectedFault(site, spec.kind, h)
