"""Straggler mitigation: hedged dispatch with deadline + replica re-issue.

The serving engine dispatches per-shard work through this executor. If a
shard's result misses its deadline, the work is re-issued to the replica
holder (in HARMONY's layout, the dimension-block peers of a vector shard
hold disjoint *columns* of the same rows, so the hedge target is the
next live shard that can recompute the visit after a cheap re-route).

In this single-process container the "nodes" are callables and latency is
simulated; the scheduling logic (deadline, hedge, first-result-wins) is
exactly what a multi-host deployment would run.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class HedgeStats:
    dispatched: int = 0
    hedged: int = 0
    wasted: int = 0                    # hedges whose primary also finished
    hedge_wins: int = 0                # hedges where the replica served first

    @property
    def win_rate(self) -> float:
        """Fraction of fired hedges that actually beat the primary."""
        return self.hedge_wins / self.hedged if self.hedged else 0.0


class HedgingExecutor:
    """Deadline-hedged execution over a set of worker callables.

    Workers are ``fn(task) -> result``; ``latency_fn(worker, task)``
    simulates per-worker service time (tests inject stragglers there).
    """

    def __init__(
        self,
        workers: List[Callable[[Any], Any]],
        deadline_s: float,
        latency_fn: Optional[Callable[[int, Any], float]] = None,
    ):
        self.workers = workers
        self.deadline_s = deadline_s
        self.latency_fn = latency_fn or (lambda w, t: 0.0)
        self.stats = HedgeStats()

    def run(self, task: Any, primary: int, replica: Optional[int] = None) -> Tuple[Any, int]:
        """Returns (result, worker_that_served). Simulated time: if the
        primary's latency exceeds the deadline, the hedge fires and the
        faster of the two serves the request."""
        result, served_by, _ = self.run_timed(task, primary, replica)
        return result, served_by

    def run_timed(
        self, task: Any, primary: int, replica: Optional[int] = None
    ) -> Tuple[Any, int, float]:
        """Hedged dispatch that also reports the effective (simulated)
        latency the request experienced: the primary's latency when it
        beats the deadline, otherwise the faster of primary-finish vs
        deadline + replica-finish. The serving scheduler charges this
        latency to its virtual clock when dispatching batches."""
        self.stats.dispatched += 1
        lat_p = self.latency_fn(primary, task)
        if lat_p <= self.deadline_s or replica is None:
            return self.workers[primary](task), primary, lat_p
        # hedge fires at the deadline
        self.stats.hedged += 1
        lat_r = self.deadline_s + self.latency_fn(replica, task)
        if lat_p <= lat_r:
            self.stats.wasted += 1
            return self.workers[primary](task), primary, lat_p
        self.stats.hedge_wins += 1
        return self.workers[replica](task), replica, lat_r

    def run_ranked(
        self, task: Any, ranked: List[int]
    ) -> Tuple[Any, int, float]:
        """Hedged dispatch over a load-ranked worker list: ``ranked[0]``
        is the router's dispatch choice, ``ranked[1:]`` the remaining
        workers ordered by load estimate. A hedge, if it fires, re-runs
        the task on ``ranked[1]`` — the least-loaded *other* replica
        (i.e. the second-least-loaded overall when the primary was the
        least-loaded) — the cross-replica policy of the serving fleet,
        rather than a node ring position."""
        if not ranked:
            raise ValueError("run_ranked needs at least one worker index")
        replica = ranked[1] if len(ranked) > 1 else None
        return self.run_timed(task, ranked[0], replica)
