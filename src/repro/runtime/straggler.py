"""Straggler mitigation: hedged dispatch with deadline + replica re-issue.

The serving engine dispatches per-shard work through this executor. If a
shard's result misses its deadline, the work is re-issued to the replica
holder (in HARMONY's layout, the dimension-block peers of a vector shard
hold disjoint *columns* of the same rows, so the hedge target is the
next live shard that can recompute the visit after a cheap re-route).

Two execution modes share the same policy and counters:

* **simulated** (:meth:`HedgingExecutor.run_timed` /
  :meth:`~HedgingExecutor.run_ranked`) — latency comes from
  ``latency_fn`` and the hedge *decision* is evaluated analytically; the
  serving scheduler charges the effective latency to its virtual clock.
  This is the deterministic replay path every test pins down.
* **wall-clock** (:meth:`HedgingExecutor.run_wall` /
  :meth:`~HedgingExecutor.run_ranked_wall`) — the primary really runs on
  a worker thread; if no result lands within ``deadline_s`` the task is
  re-issued to the replica worker and the first finisher wins. This is
  what the real-clock front-end (:class:`repro.serve.frontend.ServingFrontend`)
  drives across fleet replicas.

Counters are updated under a lock, so concurrent wall-mode dispatches
from a thread pool keep :class:`HedgeStats` exact.
"""

from __future__ import annotations

import queue as queue_mod
import threading
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple


@dataclass
class HedgeStats:
    dispatched: int = 0
    hedged: int = 0
    wasted: int = 0                    # hedges whose primary also finished
    hedge_wins: int = 0                # hedges where the replica served first

    @property
    def win_rate(self) -> float:
        """Fraction of fired hedges that actually beat the primary."""
        return self.hedge_wins / self.hedged if self.hedged else 0.0


class HedgingExecutor:
    """Deadline-hedged execution over a set of worker callables.

    Workers are ``fn(task) -> result``; ``latency_fn(worker, task)``
    simulates per-worker service time in the simulated mode (tests inject
    stragglers there). ``deadline_s`` is seconds.
    """

    def __init__(
        self,
        workers: List[Callable[[Any], Any]],
        deadline_s: float,
        latency_fn: Optional[Callable[[int, Any], float]] = None,
    ):
        self.workers = workers
        self.deadline_s = deadline_s
        self.latency_fn = latency_fn or (lambda w, t: 0.0)
        self.stats = HedgeStats()
        self._mu = threading.Lock()     # guards stats under wall-mode threads

    def run(self, task: Any, primary: int, replica: Optional[int] = None) -> Tuple[Any, int]:
        """Returns (result, worker_that_served). Simulated time: if the
        primary's latency exceeds the deadline, the hedge fires and the
        faster of the two serves the request."""
        result, served_by, _ = self.run_timed(task, primary, replica)
        return result, served_by

    def run_timed(
        self, task: Any, primary: int, replica: Optional[int] = None
    ) -> Tuple[Any, int, float]:
        """Hedged dispatch that also reports the effective (simulated)
        latency the request experienced: the primary's latency when it
        beats the deadline, otherwise the faster of primary-finish vs
        deadline + replica-finish. The serving scheduler charges this
        latency to its virtual clock when dispatching batches."""
        with self._mu:
            self.stats.dispatched += 1
        lat_p = self.latency_fn(primary, task)
        if lat_p <= self.deadline_s or replica is None:
            return self.workers[primary](task), primary, lat_p
        # hedge fires at the deadline
        with self._mu:
            self.stats.hedged += 1
        lat_r = self.deadline_s + self.latency_fn(replica, task)
        if lat_p <= lat_r:
            with self._mu:
                self.stats.wasted += 1
            return self.workers[primary](task), primary, lat_p
        with self._mu:
            self.stats.hedge_wins += 1
        return self.workers[replica](task), replica, lat_r

    def run_ranked(
        self, task: Any, ranked: List[int]
    ) -> Tuple[Any, int, float]:
        """Hedged dispatch over a load-ranked worker list: ``ranked[0]``
        is the router's dispatch choice, ``ranked[1:]`` the remaining
        workers ordered by load estimate. A hedge, if it fires, re-runs
        the task on ``ranked[1]`` — the least-loaded *other* replica
        (i.e. the second-least-loaded overall when the primary was the
        least-loaded) — the cross-replica policy of the serving fleet,
        rather than a node ring position."""
        if not ranked:
            raise ValueError("run_ranked needs at least one worker index")
        replica = ranked[1] if len(ranked) > 1 else None
        return self.run_timed(task, ranked[0], replica)

    # ------------------------------------------------------- wall-clock mode
    def run_wall(
        self, task: Any, primary: int, replica: Optional[int] = None
    ) -> Tuple[Any, int, bool]:
        """Real-clock hedged dispatch: run the primary on a thread; if it
        produces nothing within ``deadline_s``, re-issue the task to the
        replica and return the first finisher's result.

        Returns ``(result, worker_that_served, hedge_fired)`` —
        ``hedge_fired`` reports whether *this* dispatch hedged (callers
        must not diff the shared counters, which concurrent dispatches
        also move). Loser results are discarded (counted ``wasted`` when
        the primary wins a fired hedge, ``hedge_wins`` when the replica
        does — the same counter semantics as the simulated mode). Worker
        exceptions re-raise in the caller unless the other worker already
        produced a result."""
        with self._mu:
            self.stats.dispatched += 1
        results: "queue_mod.Queue[Tuple[int, Any, Optional[BaseException]]]" = (
            queue_mod.Queue()
        )

        def _run(w: int) -> None:
            try:
                results.put((w, self.workers[w](task), None))
            except BaseException as e:      # noqa: BLE001 - relayed below
                results.put((w, None, e))

        threading.Thread(target=_run, args=(primary,), daemon=True).start()
        try:
            w, res, err = results.get(timeout=self.deadline_s)
            if err is not None:
                raise err
            return res, w, False
        except queue_mod.Empty:
            pass
        if replica is None:                 # nothing to hedge to: wait it out
            w, res, err = results.get()
            if err is not None:
                raise err
            return res, w, False
        with self._mu:
            self.stats.hedged += 1
        threading.Thread(target=_run, args=(replica,), daemon=True).start()
        first_err: Optional[BaseException] = None
        for _ in range(2):                  # first clean result wins
            w, res, err = results.get()
            if err is None:
                with self._mu:
                    if w == primary:
                        self.stats.wasted += 1
                    else:
                        self.stats.hedge_wins += 1
                return res, w, True
            first_err = first_err or err
        raise first_err                     # both workers failed

    def run_ranked_wall(
        self, task: Any, ranked: List[int]
    ) -> Tuple[Any, int, bool]:
        """Wall-clock twin of :meth:`run_ranked`: primary = ``ranked[0]``,
        hedge target = ``ranked[1]`` (the least-loaded other replica)."""
        if not ranked:
            raise ValueError("run_ranked_wall needs at least one worker index")
        replica = ranked[1] if len(ranked) > 1 else None
        return self.run_wall(task, ranked[0], replica)
