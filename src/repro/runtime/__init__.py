from repro.runtime.elastic import ClusterState, replan_on_failure
from repro.runtime.straggler import HedgingExecutor, HedgeStats

__all__ = ["ClusterState", "replan_on_failure", "HedgingExecutor", "HedgeStats"]
