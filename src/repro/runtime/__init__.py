from repro.runtime.elastic import ClusterState, replan_on_failure
from repro.runtime.faults import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    fault_point,
    fault_scope,
    install_fault_plan,
)
from repro.runtime.straggler import HedgingExecutor, HedgeStats

__all__ = [
    "ClusterState",
    "replan_on_failure",
    "HedgingExecutor",
    "HedgeStats",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "fault_point",
    "fault_scope",
    "install_fault_plan",
]
