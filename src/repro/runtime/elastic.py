"""Elastic scaling + fault handling for the ANNS serving path.

The design invariant (DESIGN.md §5): the partition plan is a *pure
function* of (index cluster table, live node set, workload sample) — any
survivor can recompute it after a failure, re-preassign the corpus, and
resume with identical results. ``replan_on_failure`` implements exactly
that; tests assert search results are unchanged (minus capacity) after
killing nodes.

For training, elasticity = checkpoint restore with different-mesh
shardings (see ``repro.checkpoint``); for serving, straggler mitigation =
hedged dispatch (``repro.runtime.straggler``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.config import HarmonyConfig
from repro.core import IVFIndex, PlanDecision, ShardedCorpus, plan_search, preassign


@dataclass
class ClusterState:
    """Mutable view of the serving cluster."""

    n_nodes: int
    live: np.ndarray                    # bool [n_nodes]

    @classmethod
    def fresh(cls, n_nodes: int) -> "ClusterState":
        return cls(n_nodes=n_nodes, live=np.ones(n_nodes, bool))

    def fail(self, node: int):
        self.live[node] = False

    def join(self, node: Optional[int] = None):
        if node is None:
            self.live = np.append(self.live, True)
            self.n_nodes += 1
        else:
            self.live[node] = True

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def live_ids(self) -> np.ndarray:
        """Indices of live nodes (replica routing iterates these)."""
        return np.nonzero(self.live)[0]


def replan_on_failure(
    index: IVFIndex,
    state: ClusterState,
    cfg: Optional[HarmonyConfig] = None,
    probes_sample: Optional[np.ndarray] = None,
) -> tuple[PlanDecision, ShardedCorpus]:
    """Recompute the plan for the surviving node set and re-preassign.

    Deterministic given (index, live set, probes sample): any node can run
    it and arrive at the same layout — no coordinator election needed.
    """
    n = state.n_live
    if n == 0:
        raise RuntimeError("no live nodes")
    decision = plan_search(index, n, cfg or index.cfg, probes_sample=probes_sample)
    corpus = preassign(index, decision.plan)
    return decision, corpus
