"""Version-compatibility shims for the JAX API surface we depend on.

The repo targets the modern ``jax.shard_map`` entry point (with its
``check_vma`` argument); older releases only ship
``jax.experimental.shard_map.shard_map`` (whose equivalent flag is
``check_rep``). Every shard_map call site goes through
:func:`shard_map_compat` so the SPMD engines run on either API.
"""

from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map_compat(
    f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any
) -> Callable:
    """``jax.shard_map`` with replication checking off, on any jax version.

    Tries the public ``jax.shard_map`` (new API, ``check_vma=``) first and
    falls back to ``jax.experimental.shard_map.shard_map`` (old API,
    ``check_rep=``). Both flags disable the same static replication check,
    which our device functions fail structurally (axis-dependent slicing).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # type: ignore
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
