"""Monotone dimension-level pruning: thresholds, prewarm, heap merge.

The invariant everything here preserves (property-tested):

    S_k²(p,q) = Σ_{j≤k} d_j²(p,q) is non-decreasing in k, so once
    S_k² > τ² ≥ (final kth-best distance), p can never enter the top-K.

τ is only ever an *upper bound* on the final kth-best distance — it starts
at the kth-best among the prewarm sample (a subset of real candidates, so
an upper bound) and is tightened monotonically as heaps fill. Pruning is
therefore exact: it changes work, never results.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.index import IVFIndex


@dataclass
class TopKHeap:
    """Vectorized per-query top-K state (scores ascending, -1 padded ids)."""

    scores: np.ndarray   # [NQ, K] float32, +inf padded
    ids: np.ndarray      # [NQ, K] int64, -1 padded

    @classmethod
    def empty(cls, nq: int, k: int) -> "TopKHeap":
        return cls(np.full((nq, k), np.inf, np.float32), np.full((nq, k), -1, np.int64))

    @property
    def tau(self) -> np.ndarray:
        """Current per-query pruning threshold = kth best so far (+inf if
        the heap is not full — can't prune before K real candidates)."""
        return self.scores[:, -1].copy()

    def merge_rows(self, rows: np.ndarray, new_scores: np.ndarray, new_ids: np.ndarray):
        """Merge candidates for a subset of queries.

        rows: [m] query indices; new_scores/new_ids: [m, C].
        """
        if rows.size == 0 or new_scores.shape[1] == 0:
            return
        k = self.scores.shape[1]
        cat_s = np.concatenate([self.scores[rows], new_scores.astype(np.float32)], axis=1)
        cat_i = np.concatenate([self.ids[rows], new_ids.astype(np.int64)], axis=1)
        # stable partial sort per row
        part = np.argpartition(cat_s, kth=k - 1, axis=1)[:, :k]
        take_s = np.take_along_axis(cat_s, part, axis=1)
        take_i = np.take_along_axis(cat_i, part, axis=1)
        order = np.argsort(take_s, axis=1, kind="stable")
        self.scores[rows] = np.take_along_axis(take_s, order, axis=1)
        self.ids[rows] = np.take_along_axis(take_i, order, axis=1)


def exact_scores(
    x: np.ndarray, q: np.ndarray, metric: str = "l2"
) -> np.ndarray:
    """Full-dimension scores, ascending-better. [NQ, N]."""
    if metric == "l2":
        return (
            np.sum(q * q, axis=1)[:, None]
            - 2.0 * (q @ x.T)
            + np.sum(x * x, axis=1)[None, :]
        ).astype(np.float32)
    elif metric == "ip":
        return (-(q @ x.T)).astype(np.float32)
    raise ValueError(metric)


def partial_scores_block(
    x_blk: np.ndarray,
    q_blk: np.ndarray,
    xnorm2_blk: np.ndarray,
    metric: str = "l2",
) -> np.ndarray:
    """One dimension block's contribution d_b² (or -partial dot). [NQ, N].

    Self-contained per block: ‖p‖²_b − 2 p·q|_b + ‖q‖²_b  (L2), or
    −p·q|_b (IP). Summing over blocks reconstructs the exact score.
    """
    if metric == "l2":
        qn = np.sum(q_blk * q_blk, axis=1)[:, None]
        return (qn - 2.0 * (q_blk @ x_blk.T) + xnorm2_blk[None, :]).astype(np.float32)
    elif metric == "ip":
        return (-(q_blk @ x_blk.T)).astype(np.float32)
    raise ValueError(metric)


def prewarm_tau(
    index: IVFIndex,
    q: np.ndarray,
    probes: np.ndarray,
    k: int,
    samples_per_cluster: int = 4,
    metric: str = "l2",
    dead_rows: Optional[np.ndarray] = None,
) -> np.ndarray:
    """PrewarmHeap (Alg. 1, lines 1–5): exactly score a small sample of real
    candidates per probed cluster; the kth-smallest sampled distance is a
    valid initial τ (the sample is a subset of the candidate set, so its
    kth-best upper-bounds the candidate set's kth-best).

    Sampled rows are *not* inserted into result heaps — they are re-scored
    by the main scan, which avoids duplicate ids in merged top-K lists.

    ``dead_rows`` (bool [NB], packed-row tombstones of the mutable data
    plane) excludes dead rows from the sample — a tombstoned vector must
    not tighten τ below the live candidate set's kth-best, or pruning
    would stop being exact.

    Returns tau0 [NQ] float32 (+inf where the sample was smaller than K).
    """
    nq = q.shape[0]
    take = np.minimum(index.sizes, samples_per_cluster)
    sample_rows_per_cluster = [
        np.arange(index.offsets[c], index.offsets[c] + take[c], dtype=np.int64)
        for c in range(index.nlist)
    ]
    all_rows = [
        np.concatenate([sample_rows_per_cluster[c] for c in probes[i]])
        if probes.shape[1]
        else np.zeros((0,), np.int64)
        for i in range(nq)
    ]
    width = max((len(r) for r in all_rows), default=0)
    tau0 = np.full((nq,), np.inf, np.float32)
    if width == 0:
        return tau0
    mat = np.zeros((nq, width), np.int64)
    msk = np.zeros((nq, width), bool)
    for i, rows in enumerate(all_rows):
        mat[i, : len(rows)] = rows
        msk[i, : len(rows)] = True
    if dead_rows is not None:
        msk &= ~dead_rows[mat]
    cand = index.x[mat]                                    # [NQ, W, D]
    if metric == "l2":
        diff = cand - q[:, None, :]
        sc = np.sum(diff * diff, axis=2).astype(np.float32)
    else:
        sc = -np.sum(cand * q[:, None, :], axis=2).astype(np.float32)
    sc = np.where(msk, sc, np.inf)
    kth = np.sort(sc, axis=1)[:, k - 1] if width >= k else np.full((nq,), np.inf)
    counts = msk.sum(axis=1)
    tau0 = np.where(counts >= k, kth, np.inf).astype(np.float32)
    return tau0
