"""Pure-JAX Lloyd k-means for IVF index training.

Matches Faiss's `train` stage (paper Fig. 10 "Train"): k-means over a
training sample, k-means++-style seeding (greedy farthest-point on a
sample for determinism), fixed iteration count, empty-cluster re-seeding.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _pairwise_sq_l2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """[n, d] x [m, d] -> [n, m] squared L2 distances."""
    an = jnp.sum(a * a, axis=1)[:, None]
    bn = jnp.sum(b * b, axis=1)[None, :]
    return an - 2.0 * (a @ b.T) + bn


def _init_centers(x: jnp.ndarray, k: int, key: jax.Array) -> jnp.ndarray:
    """Greedy farthest-point init on a subsample (deterministic given key)."""
    n = x.shape[0]
    idx0 = jax.random.randint(key, (), 0, n)
    first = x[idx0]

    def body(carry, _):
        centers, count = carry
        d = _pairwise_sq_l2(x, centers)          # [n, k]
        # only the first `count` centers are valid
        valid = jnp.arange(centers.shape[0]) < count
        d = jnp.where(valid[None, :], d, jnp.inf)
        mind = jnp.min(d, axis=1)                # [n]
        nxt = jnp.argmax(mind)
        centers = centers.at[count].set(x[nxt])
        return (centers, count + 1), None

    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(first)
    (centers, _), _ = jax.lax.scan(body, (centers0, 1), None, length=k - 1)
    return centers


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_fit(
    x: jnp.ndarray, k: int, iters: int = 12, seed: int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (centers [k, d], assignment [n])."""
    key = jax.random.PRNGKey(seed)
    # subsample for init to bound the O(n·k) greedy pass
    n = x.shape[0]
    sub = min(n, 4096)
    perm = jax.random.permutation(key, n)[:sub]
    centers = _init_centers(x[perm], k, key)

    def step(centers, _):
        d = _pairwise_sq_l2(x, centers)           # [n, k]
        assign = jnp.argmin(d, axis=1)            # [n]
        one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # [n, k]
        counts = jnp.sum(one_hot, axis=0)         # [k]
        sums = one_hot.T @ x                      # [k, d]
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # re-seed empties at the farthest point from its center
        far = jnp.argmax(jnp.min(d, axis=1))
        new = jnp.where((counts > 0)[:, None], new, x[far][None, :])
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    assign = jnp.argmin(_pairwise_sq_l2(x, centers), axis=1)
    return centers, assign


def kmeans_fit_np(x: np.ndarray, k: int, iters: int = 12, seed: int = 0):
    c, a = kmeans_fit(jnp.asarray(x, jnp.float32), k, iters, seed)
    return np.asarray(c), np.asarray(a)
