"""Hybrid retrieval: per-segment BM25 lexical scoring fused with vector
top-k by reciprocal-rank fusion (RRF).

The lexical tier mirrors the vector tier's shape: each sealed segment
lazily builds one immutable :class:`BM25Index` over its metadata text
column (packed-row order, so the same ``dead_rows``/filter bitmaps mask
it), the delta buffer is brute-scored per query, and the per-segment
lexical top-k lists merge by score like vector partials do. Fusion is
rank-based (RRF), so the two tiers never need commensurable scores —
the standard recipe for combining BM25 with dense retrieval.

>>> import numpy as np
>>> bm = BM25Index(["red shoes", "blue shoes", None, "red hat"])
>>> s = bm.scores("red shoes")
>>> bool(s[0] > s[1] > 0), bool(s[2] == 0.0)
(True, True)
>>> v_ids = np.array([[10, 11, 12]])
>>> l_ids = np.array([[12, 13, -1]])
>>> sc, ids = reciprocal_rank_fusion([v_ids, l_ids], k=3)
>>> int(ids[0, 0])     # ranked by both tiers → fused to the top
12
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_TOKEN = re.compile(r"[a-z0-9]+")


def tokenize(text: Optional[str]) -> List[str]:
    """Lowercase alphanumeric tokens ('' / None → no tokens)."""
    return _TOKEN.findall(text.lower()) if text else []


class BM25Index:
    """Okapi BM25 over one row-aligned text column.

    Rows follow the owning corpus's packed order; ``scores`` returns a
    dense [n] array so callers apply the same excluded-row masks they
    already hold for the vector tier. Built once per sealed segment
    (see :func:`segment_bm25`); delta rows are small enough to rebuild
    per search.
    """

    def __init__(self, texts: Sequence[Optional[str]],
                 k1: float = 1.5, b: float = 0.75):
        self.k1, self.b = float(k1), float(b)
        self.n = len(texts)
        self.doc_len = np.zeros(self.n, np.float32)
        postings: Dict[str, Dict[int, int]] = {}
        for r, text in enumerate(texts):
            toks = tokenize(text)
            self.doc_len[r] = len(toks)
            for t in toks:
                tf = postings.setdefault(t, {})
                tf[r] = tf.get(r, 0) + 1
        self.avg_len = float(self.doc_len.mean()) if self.n else 0.0
        # term -> (rows int64[m], tf float32[m])
        self.postings: Dict[str, Tuple[np.ndarray, np.ndarray]] = {
            t: (np.fromiter(tf.keys(), np.int64, len(tf)),
                np.fromiter(tf.values(), np.float32, len(tf)))
            for t, tf in postings.items()
        }

    def memory_bytes(self) -> int:
        """Host-resident bytes of the postings + doc-length structures
        (BM25 is host-tier always; counted by
        :meth:`repro.core.SegmentedIndex.memory_report`)."""
        out = self.doc_len.nbytes
        for rows, tf in self.postings.values():
            out += rows.nbytes + tf.nbytes
        return out

    def scores(self, text: str) -> np.ndarray:
        """BM25 scores [n] (higher = better, 0 = no term match)."""
        out = np.zeros(self.n, np.float32)
        if self.n == 0 or self.avg_len == 0.0:
            return out
        norm = 1.0 - self.b + self.b * self.doc_len / self.avg_len
        for t in tokenize(text):
            post = self.postings.get(t)
            if post is None:
                continue
            rows, tf = post
            df = len(rows)
            idf = np.log(1.0 + (self.n - df + 0.5) / (df + 0.5))
            out[rows] += idf * tf * (self.k1 + 1.0) / (
                tf + self.k1 * norm[rows]
            )
        return out

    def topk(self, text: str, k: int,
             excluded: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
        """(scores desc [≤k], rows [≤k]) of matching, non-excluded rows."""
        sc = self.scores(text)
        if excluded is not None:
            sc = np.where(excluded[: self.n], 0.0, sc)
        rows = np.nonzero(sc > 0.0)[0]
        if rows.size > k:
            part = np.argpartition(-sc[rows], kth=k - 1)[:k]
            rows = rows[part]
        order = np.argsort(-sc[rows], kind="stable")
        rows = rows[order]
        return sc[rows], rows


def segment_bm25(index) -> Optional[BM25Index]:
    """The sealed segment's lexical tier, built lazily from its metadata
    text column and cached on the immutable index (like the int8 tier
    and the filter bitmaps). None when the segment carries no texts."""
    meta = index.meta
    if meta is None or meta.texts is None:
        return None
    bm = index.__dict__.get("_bm25")
    if bm is None:
        bm = BM25Index(meta.texts)
        index.__dict__["_bm25"] = bm
    return bm


def reciprocal_rank_fusion(
    ranked_id_lists: Sequence[np.ndarray],
    k: int,
    k_rrf: float = 60.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fuse per-tier ranked id lists into one top-k by RRF.

    Each input is [NQ, K_t] int64, best-first, -1-padded. A document's
    fused score is Σ_tiers 1/(k_rrf + rank) over the tiers that ranked
    it; ties break toward the lower id (deterministic). Returns
    (scores [NQ, k] float32 *ascending* — negated RRF, so the serving
    convention "smaller is better, +inf pad" holds — and
    ids [NQ, k] int64, -1-padded).
    """
    nq = ranked_id_lists[0].shape[0]
    out_s = np.full((nq, k), np.inf, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    for qi in range(nq):
        fused: Dict[int, float] = {}
        for ids in ranked_id_lists:
            for rank, doc in enumerate(ids[qi]):
                doc = int(doc)
                if doc < 0:
                    continue
                fused[doc] = fused.get(doc, 0.0) + 1.0 / (k_rrf + rank)
        top = sorted(fused.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
        for j, (doc, s) in enumerate(top):
            out_i[qi, j] = doc
            out_s[qi, j] = -s
    return out_s, out_i
