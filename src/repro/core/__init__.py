"""HARMONY core: the paper's contribution.

Public API:

* index: :func:`build_ivf`, :func:`preassign`, :func:`assign_queries`
* planning: :func:`plan_search` (cost model 4.2), :class:`PartitionPlan`
* search: :func:`harmony_search` (staged engine), :func:`search_oracle`
  (single-node baseline/ground truth), :mod:`repro.core.pipeline`
  (TPU-target SPMD ring engine)
"""

from repro.core.index import (
    CompactionPlan,
    DataSnapshot,
    Int8Quant,
    IVFIndex,
    MetadataStore,
    Segment,
    SegmentedIndex,
    ShardedCorpus,
    TAG_MISSING,
    assign_queries,
    build_ivf,
    dim_block_bounds,
    preassign,
    quantize_vectors,
    segment_device_bytes,
)
from repro.core.types import (
    And,
    DataPlane,
    Filter,
    NumRange,
    Or,
    PartitionPlan,
    SearchRequest,
    SearchResult,
    TagIn,
)
from repro.core.planner import plan_search, factorizations, PlanDecision
from repro.core.cost_model import HardwareModel, WorkloadStats, plan_cost, TPU_V5E
from repro.core.search import (
    delta_topk,
    filter_bitmap,
    filter_excluded_rows,
    filtered_assign_queries,
    harmony_search,
    merge_topk,
    search_oracle,
    two_stage_search,
)
from repro.core.fusion import BM25Index, reciprocal_rank_fusion
from repro.core.pruning import TopKHeap, prewarm_tau, partial_scores_block

__all__ = [
    "IVFIndex", "ShardedCorpus", "build_ivf", "preassign", "assign_queries",
    "dim_block_bounds", "PartitionPlan", "SearchResult", "SearchRequest",
    "Filter", "TagIn", "NumRange", "And", "Or", "DataPlane",
    "MetadataStore", "TAG_MISSING",
    "Segment", "SegmentedIndex", "DataSnapshot", "CompactionPlan",
    "Int8Quant", "quantize_vectors", "segment_device_bytes",
    "plan_search", "factorizations", "PlanDecision", "HardwareModel",
    "WorkloadStats", "plan_cost", "TPU_V5E", "harmony_search",
    "search_oracle", "delta_topk", "merge_topk", "two_stage_search",
    "filter_bitmap", "filter_excluded_rows", "filtered_assign_queries",
    "BM25Index", "reciprocal_rank_fusion",
    "TopKHeap", "prewarm_tau", "partial_scores_block",
]
