"""Load-aware routing: cluster → vector-shard assignment (§4.2.2).

Two assignment policies:

* ``round_robin`` — the naive baseline (cluster id mod V). This is what the
  Fig. 9 "w/o balanced load" ablation uses.
* ``load_aware`` — greedy LPT (longest-processing-time) bin packing on
  *expected pair load* (cluster size × query hit rate from a workload
  sample). This is HARMONY's load-aware distribution.

Also provides ring start-offset scheduling: staggering which dimension
block a shard's visit processes first, so late (well-pruned) pipeline
slots rotate across the machine grid (Fig. 5(b)'s deferred-block trick).
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def round_robin_assignment(nlist: int, v_shards: int) -> np.ndarray:
    return (np.arange(nlist) % v_shards).astype(np.int32)


def load_aware_assignment(
    cluster_sizes: np.ndarray,
    cluster_hits: Optional[np.ndarray],
    v_shards: int,
) -> np.ndarray:
    """Greedy LPT on expected load = size × hits (hits default 1)."""
    nlist = len(cluster_sizes)
    hits = np.ones(nlist) if cluster_hits is None else np.asarray(cluster_hits, float)
    load = cluster_sizes.astype(float) * np.maximum(hits, 1e-9)
    order = np.argsort(-load, kind="stable")
    shard_load = np.zeros(v_shards)
    out = np.zeros(nlist, np.int32)
    for c in order:
        v = int(np.argmin(shard_load))
        out[c] = v
        shard_load[v] += load[c]
    return out


def ring_offsets(v_shards: int, d_blocks: int, stagger: bool = True) -> np.ndarray:
    """Start offsets per shard for the dimension ring. Staggered offsets
    spread the expensive slot-0 work across dimension blocks."""
    if not stagger or d_blocks <= 1:
        return np.zeros(v_shards, np.int32)
    return (np.arange(v_shards) % d_blocks).astype(np.int32)


def estimate_cluster_hits(probes: np.ndarray, nlist: int) -> np.ndarray:
    """Per-cluster query hit counts from a probe sample [NQ, P]."""
    probes = probes.reshape(-1)
    return np.bincount(probes[probes >= 0], minlength=nlist).astype(np.float64)


DEFAULT_HOT_FRACTION = 0.1


def workload_concentration(
    hits: np.ndarray, hot_fraction: float = DEFAULT_HOT_FRACTION
) -> float:
    """Hot-cluster concentration of a workload: the share of probe mass on
    the hottest ``ceil(hot_fraction · nlist)`` clusters. 1.0 = all traffic
    on the hot set; ``hot_fraction`` = perfectly uniform. The serving
    scheduler compares this on its live arrival window against the value
    the current plan was built for, and re-plans when the drift exceeds a
    threshold (the Fig. 7 skew-adaptation trigger)."""
    hits = np.asarray(hits, np.float64)
    total = float(hits.sum())
    if total <= 0 or hits.size == 0:
        return 0.0
    n_hot = max(1, int(np.ceil(hot_fraction * hits.size)))
    return float(np.sort(hits)[::-1][:n_hot].sum() / total)
