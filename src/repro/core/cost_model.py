"""§4.2.1 cost model: C(π, Q) = Σ_q C_q(π) + α · I(π).

Costs are wall-time estimates (seconds) from a small hardware model, so
plans are ranked the same way the paper's master node ranks them. The
estimator consumes only lightweight workload statistics available at query
setup time (cluster sizes, per-cluster query hit counts, expected pruning
survival), exactly as §4.2.1 prescribes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.types import PartitionPlan


@dataclass(frozen=True)
class HardwareModel:
    """Per-node rates. Defaults ≈ the paper's testbed (dual-socket Xeon,
    100 Gb/s links). A v5e-pod variant is used by the TPU planner."""

    flops_rate: float = 2.0e11        # effective f32 FLOP/s per node
    net_bw: float = 12.5e9            # bytes/s per link (100 Gb/s)
    net_latency: float = 15e-6        # per-message latency (s)


TPU_V5E = HardwareModel(flops_rate=197e12, net_bw=50e9, net_latency=1e-6)


@dataclass
class WorkloadStats:
    """Lightweight statistics the planner needs.

    cluster_sizes[c]   — rows in IVF cluster c.
    cluster_hits[c]    — how many queries in the (sampled) workload probe c.
    dim                — vector dimensionality.
    nq                 — queries in the sample.
    topk               — K.
    survival           — expected fraction of pairs still alive entering
                         dimension slot j (slot 0 → 1.0); calibrated from
                         observed slice pruning ratios or a default decay.
    """

    cluster_sizes: np.ndarray
    cluster_hits: np.ndarray
    dim: int
    nq: int
    topk: int
    survival: Optional[np.ndarray] = None

    def survival_at(self, d_blocks: int, enable_pruning: bool) -> np.ndarray:
        if not enable_pruning:
            return np.ones(d_blocks)
        if self.survival is not None and len(self.survival) >= d_blocks:
            return np.asarray(self.survival[:d_blocks], np.float64)
        # Default decay matching the paper's Table 3 averages
        # (≈ 1.0, 0.66, 0.34, 0.08 at B=4): survival_j ≈ γ^(j·4/B), γ≈0.51
        j = np.arange(d_blocks) * (4.0 / d_blocks)
        return np.clip(0.51 ** j, 0.05, 1.0)


def per_node_loads(
    plan: PartitionPlan, w: WorkloadStats, enable_pruning: bool = True
) -> np.ndarray:
    """Load(n, π): compute-seconds per node of the V×B grid. Node (v, b)
    computes dimension block b of every probed pair on shard v, discounted
    by expected pruning survival at its (average) pipeline slot."""
    V, B = plan.v_shards, plan.d_blocks
    pairs = w.cluster_sizes * w.cluster_hits      # candidate pairs per cluster
    shard_pairs = np.zeros(V)
    np.add.at(shard_pairs, plan.cluster_to_shard, pairs)
    surv = w.survival_at(B, enable_pruning)
    # staggered ring ⇒ every machine column sees every slot equally often
    mean_surv = float(surv.mean())
    per_block_flops = 2.0 * shard_pairs * (w.dim / B) * mean_surv
    return np.repeat(per_block_flops[:, None], B, axis=1).reshape(-1)


def imbalance(plan: PartitionPlan, w: WorkloadStats, hw: HardwareModel) -> float:
    """I(π): std-dev of per-node load, in seconds."""
    loads = per_node_loads(plan, w) / hw.flops_rate
    return float(np.std(loads))


def plan_cost(
    plan: PartitionPlan,
    w: WorkloadStats,
    hw: HardwareModel = HardwareModel(),
    alpha: float = 1.0,
    enable_pruning: bool = True,
    query_block: int = 32,
) -> dict:
    """Full C(π, Q) with the comp/comm decomposition of §4.2.1.

    Returns a dict with comp/comm/imbalance terms (seconds) and "cost".
    """
    V, B = plan.v_shards, plan.d_blocks
    surv = w.survival_at(B, enable_pruning)
    mean_surv = float(surv.mean())

    pairs_per_cluster = w.cluster_sizes * w.cluster_hits
    total_pairs = float(pairs_per_cluster.sum())

    # --- computation: total pair flops, pruned, spread over the grid's
    # critical path (max-loaded node dominates wall time).
    loads = per_node_loads(plan, w, enable_pruning) / hw.flops_rate
    comp = float(loads.max()) if loads.size else 0.0

    # --- communication:
    # query dispatch: each query ships D floats total regardless of B
    # (paper §4.2.2: total bytes invariant); messages are batched per
    # (query block × machine), not per query.
    n_nodes = max(V * B, 1)
    n_blocks = max(1, -(-w.nq // query_block))
    dispatch_bytes = w.nq * w.dim * 4.0
    dispatch_msgs = n_blocks * n_nodes
    # partial-result hand-off: alive pairs forwarded between B-1 slots
    handoff_pairs = total_pairs * float(surv[1:].sum()) if B > 1 else 0.0
    handoff_bytes = handoff_pairs * 4.0
    # results + per-block threshold sync
    result_bytes = w.nq * w.topk * 12.0 + n_blocks * n_nodes * 4.0 * w.nq / n_blocks
    comm_bytes = dispatch_bytes + handoff_bytes + result_bytes
    # every node has its own link; bytes spread across the cluster's NICs
    comm = comm_bytes / (hw.net_bw * n_nodes) + dispatch_msgs * hw.net_latency

    imb = float(np.std(loads))
    cost = comp + comm + alpha * imb
    return {
        "cost": cost,
        "comp_s": comp,
        "comm_s": comm,
        "imbalance_s": imb,
        "comm_bytes": comm_bytes,
        "mean_survival": mean_surv,
    }
