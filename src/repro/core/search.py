"""End-to-end search paths.

Two engines, both exact w.r.t. the probed candidate set:

* :func:`search_oracle` — single-node Faiss-like IVF scan (the paper's
  baseline and our ground truth for all exactness tests).
* :func:`harmony_search` — the paper's Algorithm 1 as a host-scheduled,
  stage-synchronous engine with **dynamic candidate compaction** between
  dimension stages. This is the CPU-measured reproduction path; the
  TPU-target SPMD path (masked accumulators + Pallas tile-skip) lives in
  ``repro.core.pipeline`` and is validated against the same oracle.

Schedule realized here (per DESIGN.md):

* vector-level pipeline = queries visit their probed vector shards in ring
  order, one shard per stage; top-K heaps tighten τ between stages
  (Fig. 5(a): stage A's results prune stage B's work).
* dimension-level pipeline = within a visit, dimension blocks are processed
  in a per-shard rotated order (``plan.ring_offsets``), partial sums are
  accumulated, and pairs whose running S² exceeds τ are pruned; rows dead
  for every query are compacted away (Fig. 5(b)).
"""

from __future__ import annotations

import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core.index import IVFIndex, ShardedCorpus, assign_queries, dim_block_bounds
from repro.core.pruning import TopKHeap, partial_scores_block, prewarm_tau
from repro.core.types import Filter, PartitionPlan, SearchResult


# ---------------------------------------------------------------------------
# Filter compilation: predicate → packed-row bitmap → probe pushdown
# ---------------------------------------------------------------------------


def filter_bitmap(index: IVFIndex, flt: Filter) -> np.ndarray:
    """Compile a :class:`Filter` to this segment's *allowed* bitmap
    (bool [NB], packed-row order).

    Cached on the immutable segment index keyed by the (hashable) filter
    value, so re-serving the same predicate re-uses the bitmap — the
    filtered analogue of the ``dead_shard_mask`` cache. A corpus without
    metadata allows nothing (absent attributes can't satisfy a
    predicate), matching :meth:`Filter.evaluate` on a missing column."""
    cache = index.__dict__.setdefault("_filter_bitmaps", {})
    bm = cache.get(flt)
    if bm is None:
        if len(cache) >= 64:        # bound the per-segment bitmap cache
            cache.clear()
        if index.meta is None:
            bm = np.zeros(index.nb, bool)
        else:
            bm = flt.evaluate(index.meta.tags, index.meta.nums, index.nb)
        cache[flt] = bm
    return bm


def filter_excluded_rows(
    index: IVFIndex, flt: Optional[Filter],
    dead_rows: Optional[np.ndarray],
) -> Optional[np.ndarray]:
    """Merge a filter's allowed bitmap with the tombstones into one
    *excluded* mask (bool [NB]) — a filter is just a per-query tombstone
    set, so the whole dead-row masking path (oracle member mask, host
    engine's shard remap, executor's host-side gather) applies verbatim.
    Returns None when nothing is excluded (the unfiltered fast path)."""
    if flt is None:
        return dead_rows if dead_rows is not None and dead_rows.any() else None
    excluded = ~filter_bitmap(index, flt)
    if dead_rows is not None:
        excluded = excluded | dead_rows
    return excluded


def filtered_assign_queries(
    index: IVFIndex,
    q: np.ndarray,
    excluded: Optional[np.ndarray],
    nprobe: Optional[int] = None,
) -> np.ndarray:
    """Probe selection with predicate pushdown: clusters whose every row
    is excluded (by the filter and/or tombstones) are dropped from the
    centroid ranking, so low-selectivity filters spend their probe budget
    on clusters that can actually produce candidates.

    Slots that would land on a fully-excluded cluster (fewer live
    clusters than ``nprobe``) are *duplicate-filled* with the query's
    best live cluster instead of a sentinel: every downstream consumer
    (member-set assignment, τ prewarm's per-cluster sampling, the visit
    schedule's ``np.unique``, the executor's row gather) treats
    duplicates as one probe, while a negative sentinel would wrap or
    crash them. Row-level masking stays the source of truth, so this is
    pure work avoidance — never a correctness dependency.

    Selectivity-aware widening: when the allowed fraction of rows falls
    below ``cfg.filter_widen_threshold``, the effective ``nprobe`` scales
    by ~``threshold / selectivity`` (capped at ``filter_widen_cap`` ×,
    clamped to ``nlist``). Candidates thin out linearly with selectivity,
    so a fixed probe budget starves a sel=0.01 filter of candidates long
    before it hurts recall at sel=0.5 — widening spends probes exactly
    where the filter made them cheap. An explicitly passed ``nprobe`` is
    a caller override and is never widened."""
    explicit = nprobe is not None
    nprobe = nprobe or index.cfg.nprobe
    if excluded is None or not excluded.any():
        return assign_queries(index, q, nprobe)
    thr = getattr(index.cfg, "filter_widen_threshold", 0.0)
    sel = float((~excluded).mean())
    if not explicit and thr > 0.0 and 0.0 < sel < thr:
        cap = max(1.0, getattr(index.cfg, "filter_widen_cap", 1.0))
        nprobe = min(index.nlist,
                     int(np.ceil(nprobe * min(cap, thr / sel))))
    live_cluster = np.bincount(
        index.cluster_of[~excluded], minlength=index.nlist
    ) > 0
    qn = np.sum(q * q, axis=1)[:, None]
    cn = np.sum(index.centers * index.centers, axis=1)[None, :]
    d = qn - 2.0 * (q @ index.centers.T) + cn
    d = np.where(live_cluster[None, :], d, np.inf)
    probes = np.argsort(d, axis=1)[:, :nprobe].astype(np.int32)
    picked = np.take_along_axis(d, probes.astype(np.int64), axis=1)
    bad = ~np.isfinite(picked)
    if bad.any():
        probes = np.where(bad, probes[:, :1], probes)
    return probes


# ---------------------------------------------------------------------------
# Oracle (single-node Faiss-like)
# ---------------------------------------------------------------------------


def search_oracle(
    index: IVFIndex,
    q: np.ndarray,
    k: Optional[int] = None,
    nprobe: Optional[int] = None,
    chunk: int = 128,
    dead_rows: Optional[np.ndarray] = None,
    flt: Optional[Filter] = None,
) -> SearchResult:
    """Exact top-k over probed clusters (masked full scan, chunked).

    ``dead_rows`` (bool [NB], packed-row tombstones) excludes deleted /
    superseded rows from the candidate set — the sealed-segment masking
    of the mutable data plane. ``flt`` additionally restricts candidates
    to rows matching the metadata predicate (the filtered ground truth:
    at ``nprobe=nlist`` this is the exact brute-force filtered top-k)."""
    cfg = index.cfg
    k = k or cfg.topk
    if flt is not None:
        dead_rows = filter_excluded_rows(index, flt, dead_rows)
    probes = assign_queries(index, q, nprobe)
    nq = q.shape[0]
    out_s = np.full((nq, k), np.inf, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    t0 = time.perf_counter()
    # corpus norms are query-invariant: materialize once (cached on the
    # index), not inside every query chunk
    xn2 = index.xnorm2 if cfg.metric == "l2" else None
    for lo in range(0, nq, chunk):
        hi = min(nq, lo + chunk)
        member = np.zeros((hi - lo, index.nlist), bool)
        member[np.arange(hi - lo)[:, None], probes[lo:hi]] = True
        mask = member[:, index.cluster_of]                     # [m, NB]
        if dead_rows is not None:
            mask &= ~dead_rows[None, :]
        if cfg.metric == "l2":
            d = (
                np.sum(q[lo:hi] * q[lo:hi], axis=1)[:, None]
                - 2.0 * (q[lo:hi] @ index.x.T)
                + xn2[None, :]
            )
        else:
            d = -(q[lo:hi] @ index.x.T)
        d = np.where(mask, d, np.inf).astype(np.float32)
        part = np.argpartition(d, kth=k - 1, axis=1)[:, :k]
        sc = np.take_along_axis(d, part, axis=1)
        order = np.argsort(sc, axis=1, kind="stable")
        out_s[lo:hi] = np.take_along_axis(sc, order, axis=1)
        out_i[lo:hi] = index.ids[np.take_along_axis(part, order, axis=1)]
        out_i[lo:hi][out_s[lo:hi] == np.inf] = -1
    dt = time.perf_counter() - t0
    return SearchResult(ids=out_i, scores=out_s, stats={"wall_s": dt})


# ---------------------------------------------------------------------------
# Two-stage quantized search (int8 scan → exact fp32 re-rank), host engine
# ---------------------------------------------------------------------------


def two_stage_search(
    index: IVFIndex,
    q: np.ndarray,
    k: Optional[int] = None,
    nprobe: Optional[int] = None,
    probes: Optional[np.ndarray] = None,
    rerank_factor: Optional[int] = None,
    dead_rows: Optional[np.ndarray] = None,
    quant_blocks: Optional[int] = None,
    chunk: int = 128,
) -> SearchResult:
    """Stage 1 scores the probed, live candidate set with the segment's
    sealed int8 codes (quantized L2, int32 dot accumulation) and keeps the
    best ``k·rerank_factor`` rows per query; stage 2 gathers those rows'
    fp32 vectors and rescores them exactly, so every returned score is a
    true fp32 distance.

    Exactness: stage 2 returns the true top-k *of the stage-1 survivor
    set*. Quantization error can only demote a true top-k candidate out of
    the survivor set, never corrupt a returned score — and once
    ``k·rerank_factor`` covers the whole probed candidate set, the result
    is identical to :func:`search_oracle` (asserted in tests). L2 only:
    the shared-grid difference form has no inner-product analogue.
    """
    cfg = index.cfg
    assert cfg.metric == "l2", "int8 two-stage search supports l2 only"
    k = k or cfg.topk
    rerank_factor = rerank_factor or cfg.rerank_factor
    quant = index.int8_quant(quant_blocks or cfg.quant_blocks)
    if probes is None:
        probes = assign_queries(index, q, nprobe)
    nq = q.shape[0]
    kp = min(max(k, k * rerank_factor), index.nb)
    out_s = np.full((nq, k), np.inf, np.float32)
    out_i = np.full((nq, k), -1, np.int64)
    t0 = time.perf_counter()
    q_codes = quant.encode(q)
    xn2 = index.xnorm2
    survivors = 0
    for lo in range(0, nq, chunk):
        hi = min(nq, lo + chunk)
        m = hi - lo
        member = np.zeros((m, index.nlist), bool)
        if probes.shape[1]:
            member[np.arange(m)[:, None], probes[lo:hi]] = True
        mask = member[:, index.cluster_of]                     # [m, NB]
        if dead_rows is not None:
            mask &= ~dead_rows[None, :]
        # stage 1: quantized distances over the masked candidate set
        d8 = np.where(mask, quant.scores(q_codes[lo:hi]), np.inf)
        part = np.argpartition(d8, kth=kp - 1, axis=1)[:, :kp]  # packed rows
        valid = np.isfinite(np.take_along_axis(d8, part, axis=1))
        survivors += int(valid.sum())
        # stage 2: exact fp32 re-rank of the survivors
        qf = q[lo:hi]
        xg = index.x[part]                                     # [m, kp, D]
        d = (
            np.sum(qf * qf, axis=1)[:, None]
            - 2.0 * np.einsum("md,mkd->mk", qf, xg)
            + xn2[part]
        )
        d = np.where(valid, d, np.inf).astype(np.float32)
        if kp > k:
            sel = np.argpartition(d, kth=k - 1, axis=1)[:, :k]
        else:
            sel = np.broadcast_to(np.arange(kp), (m, kp))
        sc = np.take_along_axis(d, sel, axis=1)
        order = np.argsort(sc, axis=1, kind="stable")
        nk = min(k, kp)
        out_s[lo:hi, :nk] = np.take_along_axis(sc, order, axis=1)[:, :nk]
        rows = np.take_along_axis(part, np.take_along_axis(sel, order, axis=1),
                                  axis=1)[:, :nk]
        out_i[lo:hi, :nk] = index.ids[rows]
        out_i[lo:hi][out_s[lo:hi] == np.inf] = -1
    dt = time.perf_counter() - t0
    return SearchResult(
        ids=out_i,
        scores=out_s,
        stats={
            "wall_s": dt,
            "precision": "int8",
            "rerank_k": kp,
            "stage1_survivors": survivors,
        },
    )


# ---------------------------------------------------------------------------
# HARMONY staged engine
# ---------------------------------------------------------------------------


class SearchStats:
    """Structural + timing counters for benchmarks and the roofline model."""

    def __init__(self, d_blocks: int, v_shards: int):
        self.slice_total = np.zeros(d_blocks, np.int64)   # pairs reaching slot j
        self.slice_alive = np.zeros(d_blocks, np.int64)   # pairs computed at slot j
        self.pair_flops = 0                                # pair-level (pruned) flops
        self.row_flops = 0                                 # compacted-matmul flops
        self.dense_flops = 0                               # no-pruning flops
        self.shard_pair_flops = np.zeros(v_shards, np.int64)
        self.comm_bytes = defaultdict(int)
        self.visits = 0
        self.stages = 0
        self.wall_comp_s = 0.0
        self.wall_other_s = 0.0
        # per-(stage, machine) pair-flops — machine (v, b) of the V×B grid
        # owns dimension block b of shard v; the cluster's critical path is
        # max-over-machines per stage (dimension blocks pipeline across
        # machines in steady state, per Fig. 5)
        self.machine_flops = defaultdict(float)   # (stage, v*B+b) → flops
        self.d_blocks = d_blocks
        self.max_pair_buffer = 0         # peak acc elements in any visit

    def parallel_wall_s(self, flops_rate: float = 5e9,
                        net_bw: float = 12.5e9, latency: float = 15e-6) -> float:
        """Critical-path wall time of the modeled cluster: per stage the
        busiest machine's pair-flops / rate, plus the comm model. The
        benchmarks calibrate ``flops_rate`` from a measured single-node
        run so modes are compared on one consistent hardware model."""
        per_stage: Dict[int, float] = defaultdict(float)
        agg: Dict[int, Dict[int, float]] = defaultdict(lambda: defaultdict(float))
        for (stage, machine), fl in self.machine_flops.items():
            agg[stage][machine] += fl
        comp = sum(max(m.values()) for m in agg.values()) / flops_rate if agg else 0.0
        comm = sum(self.comm_bytes.values()) / net_bw + latency * max(self.visits, 1)
        return comp + comm

    def as_dict(self) -> Dict:
        tot = np.maximum(self.slice_total, 1)
        return {
            "slice_pruned_ratio": (1.0 - self.slice_alive / tot).tolist(),
            "pair_flops": int(self.pair_flops),
            "row_flops": int(self.row_flops),
            "dense_flops": int(self.dense_flops),
            "shard_pair_flops": self.shard_pair_flops.tolist(),
            "comm_bytes": dict(self.comm_bytes),
            "visits": self.visits,
            "stages": self.stages,
            "wall_comp_s": self.wall_comp_s,
            "wall_other_s": self.wall_other_s,
            "parallel_wall_s": self.parallel_wall_s(),
            "machine_flops": {f"{k[0]}:{k[1]}": float(v)
                              for k, v in self.machine_flops.items()},
            "max_pair_buffer": int(self.max_pair_buffer),
        }


def _visit_schedule(
    probes: np.ndarray, plan: PartitionPlan
) -> List[List[Tuple[int, np.ndarray]]]:
    """Ring visit order: query i's probed shards, starting from the shard of
    its top-1 probe and walking the ring. Returns per-stage lists of
    (shard, query_indices)."""
    nq = probes.shape[0]
    V = plan.v_shards
    shard_of = plan.cluster_to_shard[probes]               # [NQ, P]
    per_stage: List[Dict[int, List[int]]] = []
    max_stages = 0
    visit_lists: List[np.ndarray] = []
    for i in range(nq):
        shards = shard_of[i]
        start = shards[0]
        uniq = np.unique(shards)
        # ring order from start
        order = np.argsort((uniq - start) % V, kind="stable")
        visit_lists.append(uniq[order])
        max_stages = max(max_stages, len(uniq))
    schedule: List[List[Tuple[int, np.ndarray]]] = []
    for s in range(max_stages):
        by_shard: Dict[int, List[int]] = defaultdict(list)
        for i, visits in enumerate(visit_lists):
            if s < len(visits):
                by_shard[int(visits[s])].append(i)
        schedule.append(
            [(v, np.asarray(qs, np.int64)) for v, qs in sorted(by_shard.items())]
        )
    return schedule


def harmony_search(
    index: IVFIndex,
    corpus: ShardedCorpus,
    q: np.ndarray,
    k: Optional[int] = None,
    nprobe: Optional[int] = None,
    enable_pruning: Optional[bool] = None,
    pipeline: bool = True,
    collect_stats: bool = True,
    dead_rows: Optional[np.ndarray] = None,
    dead_key: Optional[tuple] = None,
    probes: Optional[np.ndarray] = None,
) -> SearchResult:
    """Distributed HARMONY search (host-scheduled reproduction engine).

    ``probes`` (int [nq, nprobe']) — precomputed probe table; skips the
    internal :func:`assign_queries` so a caller that already selected
    probes (filter-aware pushdown/widening in the serving engine) scans
    exactly those clusters.

    ``dead_rows`` (bool [NB] over *packed* index rows) applies the mutable
    data plane's tombstones exactly: dead rows are excluded from the τ
    prewarm sample and masked out of every candidate batch before it can
    enter a heap, so a deleted/superseded id can neither appear in results
    nor tighten pruning below the live kth-best.

    ``dead_key`` — the data plane's ``(generation, dead_version)`` at the
    snapshot this search runs against; lets the corpus cache the
    packed→shard tombstone remap across batches (see
    :meth:`ShardedCorpus.dead_shard_mask`)."""
    cfg = index.cfg
    plan = corpus.plan
    k = k or cfg.topk
    metric = cfg.metric
    if enable_pruning is None:
        enable_pruning = cfg.enable_pruning
    nq, D = q.shape
    V, B = plan.v_shards, plan.d_blocks
    bounds = dim_block_bounds(D, B)
    stats = SearchStats(B, V)

    t_host0 = time.perf_counter()
    if probes is None:
        probes = assign_queries(index, q, nprobe)
    tau0 = (
        prewarm_tau(index, q, probes, k, cfg.prewarm_samples, metric,
                    dead_rows=dead_rows)
        if enable_pruning
        else np.full((nq,), np.inf, np.float32)
    )
    heap = TopKHeap.empty(nq, k)
    schedule = (
        _visit_schedule(probes, plan)
        if pipeline
        else [_all_visits(probes, plan)]
    )
    # remap packed-row tombstones onto the shard layout via the corpus's
    # precomputed permutation (cached across batches when dead_key is the
    # snapshot's (generation, dead_version))
    dead_sh = None
    if dead_rows is not None and dead_rows.any():
        dead_sh = corpus.dead_shard_mask(dead_rows, key=dead_key)
    stats.wall_other_s += time.perf_counter() - t_host0

    for stage in schedule:
        stats.stages += 1
        pending: List[Tuple[np.ndarray, TopKHeap]] = []
        tau_stage = np.minimum(tau0, heap.tau) if enable_pruning else tau0
        for v, qidx in stage:
            local = _process_visit(
                corpus=corpus,
                probes=probes,
                q=q,
                qidx=qidx,
                v=v,
                plan=plan,
                bounds=bounds,
                tau_in=tau_stage[qidx],
                k=k,
                metric=metric,
                enable_pruning=enable_pruning,
                stats=stats,
                stage_idx=stats.stages - 1,
                dead_sh=dead_sh,
            )
            if local is not None:
                pending.append((qidx, local))
                stats.comm_bytes["result_return"] += len(qidx) * k * 12
        # stage barrier: merges become visible to the next stage
        t0 = time.perf_counter()
        for qidx, local in pending:
            heap.merge_rows(qidx, local.scores, local.ids)
        stats.wall_other_s += time.perf_counter() - t0

    # never report an id whose score is +inf (pruned-to-nothing or dead
    # slots) — matches the oracle's -1 convention
    heap.ids[~np.isfinite(heap.scores)] = -1
    res = SearchResult(ids=heap.ids, scores=heap.scores, stats=stats.as_dict())
    return res


def _process_visit(
    corpus: ShardedCorpus,
    probes: np.ndarray,
    q: np.ndarray,
    qidx: np.ndarray,
    v: int,
    plan: PartitionPlan,
    bounds: Sequence[Tuple[int, int]],
    tau_in: np.ndarray,
    k: int,
    metric: str,
    enable_pruning: bool,
    stats: "SearchStats",
    stage_idx: int,
    dead_sh: Optional[np.ndarray] = None,
) -> Optional[TopKHeap]:
    """One (shard, query-group) visit.

    Vector-level pipeline (Alg. 1 VectorPipeline): probed clusters on this
    shard are scanned sequentially in probe-rank order; after each cluster
    batch the *local* heap refines τ, so later batches prune harder.
    Dimension-level pipeline (Alg. 1 DimensionPipeline): within a batch,
    dimension blocks are processed in the shard's rotated ring order with
    monotone partial-sum pruning and dead-row compaction between slices.
    """
    V, B = plan.v_shards, plan.d_blocks
    D = q.shape[1]
    t0 = time.perf_counter()
    cl = probes[qidx]                                      # [m, P]
    on_shard = plan.cluster_to_shard[cl] == v              # [m, P]
    if not on_shard.any():
        stats.wall_other_s += time.perf_counter() - t0
        return None
    # probe-rank-ordered cluster scan: rank r = best rank among group queries
    best_rank: Dict[int, int] = {}
    m, P = cl.shape
    for r in range(P):
        for c in cl[:, r][on_shard[:, r]]:
            best_rank.setdefault(int(c), r)
    ordered = sorted(best_rank, key=lambda c: (best_rank[c], c))
    stats.visits += 1
    local = TopKHeap.empty(len(qidx), k)
    tau_local = tau_in.astype(np.float32).copy()
    qg = q[qidx]
    stats.comm_bytes["query_dispatch"] += qg.size * 4
    stats.wall_other_s += time.perf_counter() - t0

    # staggered ring: base rotation by shard and stage; on top of it, the
    # queries of a visit are split into B sub-groups whose ring starts are
    # rotated per group (Fig. 5(b): Q1 starts D1, Q2 starts D2, ...) — this
    # is what spreads the unprunable first-slot work across all machines.
    offset = (int(plan.ring_offsets[v % V]) + stage_idx) % B

    for c in ordered:
        cv, lo_r, hi_r = corpus.cluster_slices[c]
        assert cv == v
        nrows = hi_r - lo_r
        if nrows == 0:
            continue
        sub_all = np.nonzero((cl == c).any(axis=1) & on_shard.any(axis=1))[0]
        if sub_all.size == 0:
            continue
        for g in range(min(B, len(sub_all))):
            sub = sub_all[g::B]
            if sub.size == 0:
                continue
            order = np.roll(np.arange(B), -((offset + g) % B))
            t0 = time.perf_counter()
            ms = len(sub)
            acc = np.zeros((ms, nrows), np.float32)
            if dead_sh is not None:
                # tombstoned rows enter the visit already pruned: they are
                # compacted away with the other dead pairs and can never
                # reach a heap (exactly the sealed-segment delete mask)
                acc[:, dead_sh[v, lo_r:hi_r]] = np.inf
            live_rows = np.arange(lo_r, hi_r)
            tau_g = tau_local[sub]
            stats.slice_total += ms * nrows   # every pair reaches every slot
            for pos, b in enumerate(order):
                blo, bhi = bounds[b]
                alive_pair = np.isfinite(acc)
                n_alive = int(alive_pair.sum())
                stats.slice_alive[pos] += n_alive
                keep = alive_pair.any(axis=0)
                if not keep.all():
                    acc = acc[:, keep]
                    live_rows = live_rows[keep]
                    alive_pair = alive_pair[:, keep]
                if acc.shape[1] == 0:
                    break
                xr = corpus.x_shard[v, live_rows, blo:bhi]
                xn = corpus.xnorm2_blk[v, b, live_rows]
                part = partial_scores_block(xr, qg[sub][:, blo:bhi], xn, metric)
                acc = np.where(alive_pair, acc + part, np.inf)
                nflop = 2 * n_alive * (bhi - blo)
                stats.pair_flops += nflop
                stats.row_flops += 2 * acc.shape[1] * ms * (bhi - blo)
                stats.shard_pair_flops[v] += nflop
                stats.machine_flops[(stage_idx, v * B + int(b))] += nflop
                if enable_pruning and pos < B - 1:
                    acc = np.where(acc > tau_g[:, None], np.inf, acc)
                    stats.comm_bytes["partial_results"] += int(np.isfinite(acc).sum()) * 4
                stats.comm_bytes["threshold_sync"] += ms * 4
            stats.dense_flops += 2 * nrows * ms * D
            stats.wall_comp_s += time.perf_counter() - t0
            stats.max_pair_buffer = max(stats.max_pair_buffer, ms * nrows)

            t0 = time.perf_counter()
            if acc.shape[1]:
                ids = corpus.ids_shard[v, live_rows]
                local.merge_rows(sub, acc, np.broadcast_to(ids, acc.shape))
                if enable_pruning:
                    tau_local[sub] = np.minimum(tau_local[sub], local.tau[sub])
            stats.wall_other_s += time.perf_counter() - t0
    return local


def _all_visits(probes: np.ndarray, plan: PartitionPlan):
    """Non-pipelined dispatch: every (shard, probing queries) visit in one
    stage — the 'synchronous execution' ablation (Fig. 9)."""
    shard_of = plan.cluster_to_shard[probes]
    out = []
    for v in range(plan.v_shards):
        qs = np.nonzero((shard_of == v).any(axis=1))[0]
        if qs.size:
            out.append((v, qs.astype(np.int64)))
    return out


# ---------------------------------------------------------------------------
# Mutable data plane: delta scan + cross-segment merge
# ---------------------------------------------------------------------------


def delta_topk(
    delta_x: np.ndarray,
    delta_ids: np.ndarray,
    delta_live: np.ndarray,
    q: np.ndarray,
    k: int,
    metric: str = "l2",
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact brute-force top-k over the live rows of a delta buffer.

    The delta is small by construction (the compactor seals it before it
    grows), so a dense scan is the right tool — no clustering, no
    pruning, no approximation. Returns (scores [NQ, k] ascending
    +inf-padded, ids [NQ, k] int64 -1-padded).
    """
    from repro.core.pruning import exact_scores

    nq = q.shape[0]
    live = np.nonzero(delta_live)[0]
    if live.size == 0:
        return (np.full((nq, k), np.inf, np.float32),
                np.full((nq, k), -1, np.int64))
    sc = exact_scores(delta_x[live], q, metric)            # [NQ, n_live]
    ids = delta_ids[live]
    heap = TopKHeap.empty(nq, k)
    heap.merge_rows(np.arange(nq), sc, np.broadcast_to(ids, sc.shape))
    heap.ids[~np.isfinite(heap.scores)] = -1
    return heap.scores, heap.ids


def merge_topk(
    parts: Sequence[Tuple[np.ndarray, np.ndarray]],
    k: int,
    fused: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-segment (scores, ids) top-k lists into one global top-k.

    ``fused=True`` folds each part into a running top-K with the fused
    :func:`repro.kernels.ops.running_topk_update` kernel (the same
    VMEM-resident primitive the SPMD ring uses between chunks) — the
    device-backend path; the default is the host ``TopKHeap`` merge.
    Both return (scores [NQ, k] ascending, ids [NQ, k] int64, -1 where
    +inf).

    The kernel carries ids as int32 (like the whole device pipeline, whose
    resident ``row_ids`` are int32); external ids beyond the int32 range
    fall back to the host merge rather than silently wrapping.
    """
    assert parts
    nq = parts[0][0].shape[0]
    if fused and any(np.abs(ids).max(initial=0) > np.iinfo(np.int32).max
                     for _, ids in parts):
        fused = False
    if fused:
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        run_s = jnp.full((nq, k), jnp.inf, jnp.float32)
        run_i = jnp.full((nq, k), -1, jnp.int32)
        for sc, ids in parts:
            run_s, run_i = kops.running_topk_update(
                jnp.asarray(np.asarray(sc, np.float32)),
                jnp.asarray(np.asarray(ids, np.int32)),
                run_s, run_i, k=k,
            )
        scores = np.asarray(run_s)
        out_i = np.asarray(run_i).astype(np.int64)
    else:
        heap = TopKHeap.empty(nq, k)
        rows = np.arange(nq)
        for sc, ids in parts:
            heap.merge_rows(rows, sc, ids)
        scores, out_i = heap.scores, heap.ids
    out_i = out_i.copy()
    out_i[~np.isfinite(scores)] = -1
    return scores, out_i
