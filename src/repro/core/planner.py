"""Fine-grained query planner (§4.2): pick the partition plan π.

Enumerates factorizations (V, B) of the node count, builds a load-aware
cluster assignment for each, scores them with the §4.2.1 cost model, and
returns the argmin. ``mode`` pins the plan to the paper's baselines:

* ``vector``    → (V=N, B=1)   (Harmony-vector)
* ``dimension`` → (V=1, B=N)   (Harmony-dimension)
* ``harmony``   → cost-model argmin over all factorizations (hybrid)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core.cost_model import HardwareModel, WorkloadStats, plan_cost
from repro.core.index import IVFIndex
from repro.core.router import (
    estimate_cluster_hits,
    load_aware_assignment,
    ring_offsets,
    round_robin_assignment,
    workload_concentration,
)
from repro.core.types import PartitionPlan


def factorizations(n_nodes: int, max_dim_blocks: int) -> List[Tuple[int, int]]:
    """All (V, B) with V·B = n_nodes, B ≤ max_dim_blocks."""
    out = []
    for b in range(1, min(n_nodes, max_dim_blocks) + 1):
        if n_nodes % b == 0:
            out.append((n_nodes // b, b))
    return out


@dataclass
class PlanDecision:
    plan: PartitionPlan
    cost: dict
    candidates: List[Tuple[Tuple[int, int], float]]  # ((V,B), cost) ranking
    # diagnostic: hot-cluster concentration (at the router's
    # DEFAULT_HOT_FRACTION) of the workload sample this plan was built
    # for; uniform ⇒ ≈ DEFAULT_HOT_FRACTION. The serving scheduler keeps
    # its own drift baseline (its hot_fraction may differ) — this field is
    # for logging/benchmark introspection.
    hot_mass: float = 0.0


def make_workload_stats(
    index: IVFIndex,
    probes_sample: Optional[np.ndarray],
    k: int,
    survival: Optional[np.ndarray] = None,
) -> WorkloadStats:
    nlist = index.nlist
    hits = (
        estimate_cluster_hits(probes_sample, nlist)
        if probes_sample is not None
        else np.full(nlist, 1.0)
    )
    nq = int(probes_sample.shape[0]) if probes_sample is not None else 1
    return WorkloadStats(
        cluster_sizes=index.sizes.astype(np.float64),
        cluster_hits=hits,
        dim=index.dim,
        nq=nq,
        topk=k,
        survival=survival,
    )


def plan_search(
    index: IVFIndex,
    n_nodes: int,
    cfg: Optional[HarmonyConfig] = None,
    probes_sample: Optional[np.ndarray] = None,
    hw: HardwareModel = HardwareModel(),
    mode: Optional[str] = None,
    balanced: bool = True,
    stagger: bool = True,
    survival: Optional[np.ndarray] = None,
) -> PlanDecision:
    """Cost-model-driven plan selection."""
    cfg = cfg or index.cfg
    mode = mode or cfg.mode
    w = make_workload_stats(index, probes_sample, cfg.topk, survival)

    if mode == "vector":
        cands = [(n_nodes, 1)]
    elif mode == "dimension":
        cands = [(1, n_nodes)]
    elif mode == "harmony":
        cands = factorizations(n_nodes, cfg.max_dim_blocks)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    scored = []
    best = None
    for V, B in cands:
        assign = (
            load_aware_assignment(w.cluster_sizes, w.cluster_hits, V)
            if balanced
            else round_robin_assignment(index.nlist, V)
        )
        plan = PartitionPlan(
            v_shards=V,
            d_blocks=B,
            cluster_to_shard=assign,
            ring_offsets=ring_offsets(V, B, stagger),
            mode=mode,
        )
        c = plan_cost(plan, w, hw, alpha=cfg.alpha, enable_pruning=cfg.enable_pruning)
        scored.append(((V, B), c["cost"]))
        if best is None or c["cost"] < best[1]["cost"]:
            best = (plan, c)

    assert best is not None
    return PlanDecision(
        plan=best[0],
        cost=best[1],
        candidates=scored,
        hot_mass=workload_concentration(w.cluster_hits),
    )
