"""IVF index build and multi-granularity (vector × dimension) layout.

Build stages mirror the paper's Fig. 10 breakdown:

* **Train** — k-means over the corpus (``repro.core.kmeans``).
* **Add** — assign every base vector to its nearest centroid and pack the
  corpus cluster-contiguously (so probed clusters are contiguous row
  ranges — this is what makes tile-level pruning effective on TPU).
* **Pre-assign** — lay the packed corpus out on the ``v_shards × d_blocks``
  machine grid of a :class:`PartitionPlan`: rows (grouped by cluster) to
  vector shards, dimension blocks to model ranks, and precompute per-block
  squared norms used by the monotone partial-distance recursion.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core.kmeans import kmeans_fit_np
from repro.core.types import PartitionPlan


@dataclass
class IVFIndex:
    """Single-logical-copy IVF index (packed, cluster-sorted)."""

    cfg: HarmonyConfig
    centers: np.ndarray          # [nlist, D]
    x: np.ndarray                # [NB, D] packed cluster-contiguously
    ids: np.ndarray              # [NB] original vector ids of packed rows
    cluster_of: np.ndarray       # [NB] cluster id per packed row (non-decreasing)
    offsets: np.ndarray          # [nlist + 1] row offsets per cluster
    build_times: Dict[str, float]

    @property
    def nb(self) -> int:
        return int(self.x.shape[0])

    @property
    def dim(self) -> int:
        return int(self.x.shape[1])

    @property
    def nlist(self) -> int:
        return int(self.centers.shape[0])

    @property
    def sizes(self) -> np.ndarray:
        return self.offsets[1:] - self.offsets[:-1]

    def cluster_rows(self, c: int) -> Tuple[int, int]:
        return int(self.offsets[c]), int(self.offsets[c + 1])

    @property
    def xnorm2(self) -> np.ndarray:
        """Full-corpus squared norms ‖x‖² [NB], materialized once and
        cached (the oracle and prewarm paths share it)."""
        cached = self.__dict__.get("_xnorm2")
        if cached is None:
            cached = np.sum(self.x * self.x, axis=1)
            self.__dict__["_xnorm2"] = cached
        return cached

    def memory_bytes(self) -> int:
        return sum(a.nbytes for a in (self.centers, self.x, self.ids, self.offsets))


def build_ivf(x: np.ndarray, cfg: HarmonyConfig) -> IVFIndex:
    """Train + Add stages."""
    t0 = time.perf_counter()
    centers, assign = kmeans_fit_np(
        x, cfg.nlist, iters=cfg.kmeans_iters, seed=cfg.kmeans_seed
    )
    t_train = time.perf_counter() - t0

    t0 = time.perf_counter()
    order = np.argsort(assign, kind="stable")
    x_sorted = np.ascontiguousarray(x[order], dtype=np.float32)
    cluster_sorted = assign[order]
    counts = np.bincount(assign, minlength=cfg.nlist)
    offsets = np.zeros((cfg.nlist + 1,), np.int64)
    np.cumsum(counts, out=offsets[1:])
    t_add = time.perf_counter() - t0

    return IVFIndex(
        cfg=cfg,
        centers=centers.astype(np.float32),
        x=x_sorted,
        ids=order.astype(np.int64),
        cluster_of=cluster_sorted.astype(np.int32),
        offsets=offsets,
        build_times={"train": t_train, "add": t_add},
    )


def assign_queries(index: IVFIndex, q: np.ndarray, nprobe: Optional[int] = None) -> np.ndarray:
    """Nearest-``nprobe`` centroids per query (the client-side purple table
    of Fig. 4). Returns [NQ, nprobe] int32 cluster ids."""
    nprobe = nprobe or index.cfg.nprobe
    qn = np.sum(q * q, axis=1)[:, None]
    cn = np.sum(index.centers * index.centers, axis=1)[None, :]
    d = qn - 2.0 * (q @ index.centers.T) + cn
    return np.argsort(d, axis=1)[:, :nprobe].astype(np.int32)


# ---------------------------------------------------------------------------
# Pre-assign: sharded layout on the V × B grid
# ---------------------------------------------------------------------------


def dim_block_bounds(dim: int, d_blocks: int) -> List[Tuple[int, int]]:
    """Contiguous dimension blocks; D is padded implicitly (zero dims do
    not change L2/IP). Block b covers [bounds[b][0], bounds[b][1])."""
    per = -(-dim // d_blocks)  # ceil
    return [(b * per, min(dim, (b + 1) * per)) for b in range(d_blocks)]


@dataclass
class ShardedCorpus:
    """The Pre-assign product: device-grid-resident corpus.

    ``x_shard[v]`` holds shard v's rows padded to ``cap`` with zeros and
    ``valid[v]`` marking real rows. ``xnorm2_blk[v, b]`` is the per-row
    squared norm restricted to dimension block b — the term that makes each
    stage's partial distance self-contained
    (``d_b² = ‖p‖²_b − 2·p·q|_b + ‖q‖²_b``).
    """

    plan: PartitionPlan
    x_shard: np.ndarray          # [V, cap, D] float32
    ids_shard: np.ndarray        # [V, cap] int64, -1 pad
    cluster_shard: np.ndarray    # [V, cap] int32, -1 pad
    valid: np.ndarray            # [V, cap] bool
    xnorm2_blk: np.ndarray       # [V, B, cap] float32
    # host-side lookup: for each cluster, its (shard, start, stop) rows
    cluster_slices: Dict[int, Tuple[int, int, int]]
    preassign_time: float

    @property
    def cap(self) -> int:
        return int(self.x_shard.shape[1])

    def memory_bytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.x_shard,
                self.ids_shard,
                self.cluster_shard,
                self.valid,
                self.xnorm2_blk,
            )
        )


def preassign(index: IVFIndex, plan: PartitionPlan, pad_to: int = 64) -> ShardedCorpus:
    """Distribute clusters to vector shards per ``plan.cluster_to_shard``
    and precompute per-dimension-block norms."""
    t0 = time.perf_counter()
    V, B, D = plan.v_shards, plan.d_blocks, index.dim
    shard_rows: List[List[int]] = [[] for _ in range(V)]
    cluster_slices: Dict[int, Tuple[int, int, int]] = {}
    for c in range(index.nlist):
        v = int(plan.cluster_to_shard[c])
        lo, hi = index.cluster_rows(c)
        start = len(shard_rows[v])
        shard_rows[v].extend(range(lo, hi))
        cluster_slices[c] = (v, start, start + (hi - lo))

    cap = max(1, max(len(r) for r in shard_rows))
    cap = -(-cap // pad_to) * pad_to  # round up for tile alignment

    x_shard = np.zeros((V, cap, D), np.float32)
    ids_shard = np.full((V, cap), -1, np.int64)
    cluster_shard = np.full((V, cap), -1, np.int32)
    valid = np.zeros((V, cap), bool)
    for v in range(V):
        rows = np.asarray(shard_rows[v], np.int64)
        n = len(rows)
        if n:
            x_shard[v, :n] = index.x[rows]
            ids_shard[v, :n] = index.ids[rows]
            cluster_shard[v, :n] = index.cluster_of[rows]
            valid[v, :n] = True

    bounds = dim_block_bounds(D, B)
    xnorm2_blk = np.zeros((V, B, cap), np.float32)
    for b, (lo, hi) in enumerate(bounds):
        seg = x_shard[:, :, lo:hi]
        xnorm2_blk[:, b] = np.sum(seg * seg, axis=2)

    return ShardedCorpus(
        plan=plan,
        x_shard=x_shard,
        ids_shard=ids_shard,
        cluster_shard=cluster_shard,
        valid=valid,
        xnorm2_blk=xnorm2_blk,
        cluster_slices=cluster_slices,
        preassign_time=time.perf_counter() - t0,
    )
