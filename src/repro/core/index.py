"""IVF index build, multi-granularity (vector × dimension) layout, and the
mutable segmented data plane.

Build stages mirror the paper's Fig. 10 breakdown:

* **Train** — k-means over the corpus (``repro.core.kmeans``).
* **Add** — assign every base vector to its nearest centroid and pack the
  corpus cluster-contiguously (so probed clusters are contiguous row
  ranges — this is what makes tile-level pruning effective on TPU).
* **Pre-assign** — lay the packed corpus out on the ``v_shards × d_blocks``
  machine grid of a :class:`PartitionPlan`: rows (grouped by cluster) to
  vector shards, dimension blocks to model ranks, and precompute per-block
  squared norms used by the monotone partial-distance recursion.

Mutability (the streaming-ingest axis) is segment-based, the standard
design of serving-grade ANNS systems (Milvus-style delta/sealed
segments): a :class:`SegmentedIndex` is an ordered set of immutable
*sealed* :class:`Segment`\\ s (each exactly today's packed IVF layout),
one append-only *delta buffer* of fresh vectors, and per-segment
*dead-row* bitmaps (tombstones for deletes and superseded upserts).
Background compaction seals the delta into a new segment or merges
everything into one — the frozen-corpus index of the early PRs is just
the one-sealed-segment, empty-delta special case.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core.kmeans import kmeans_fit_np
from repro.core.types import PartitionPlan


@dataclass
class IVFIndex:
    """Single-logical-copy IVF index (packed, cluster-sorted)."""

    cfg: HarmonyConfig
    centers: np.ndarray          # [nlist, D]
    x: np.ndarray                # [NB, D] packed cluster-contiguously
    ids: np.ndarray              # [NB] original vector ids of packed rows
    cluster_of: np.ndarray       # [NB] cluster id per packed row (non-decreasing)
    offsets: np.ndarray          # [nlist + 1] row offsets per cluster
    build_times: Dict[str, float]
    # per-row metadata (packed order), None when the corpus carries none
    meta: Optional["MetadataStore"] = None

    @property
    def nb(self) -> int:
        return int(self.x.shape[0])

    @property
    def dim(self) -> int:
        return int(self.x.shape[1])

    @property
    def nlist(self) -> int:
        return int(self.centers.shape[0])

    @property
    def sizes(self) -> np.ndarray:
        return self.offsets[1:] - self.offsets[:-1]

    def cluster_rows(self, c: int) -> Tuple[int, int]:
        return int(self.offsets[c]), int(self.offsets[c + 1])

    @property
    def xnorm2(self) -> np.ndarray:
        """Full-corpus squared norms ‖x‖² [NB], materialized once and
        cached (the oracle and prewarm paths share it)."""
        cached = self.__dict__.get("_xnorm2")
        if cached is None:
            cached = np.sum(self.x * self.x, axis=1)
            self.__dict__["_xnorm2"] = cached
        return cached

    def int8_quant(self, d_blocks: Optional[int] = None) -> "Int8Quant":
        """Scalar-quantized int8 tier of this segment's corpus, one grid
        per dimension block. Computed once per ``d_blocks`` granularity and
        cached (segment seal populates the config's canonical granularity
        eagerly; the SPMD executor requests its mesh granularity lazily).
        Checkpoint restore re-attaches persisted codes here so a reload
        never re-derives them."""
        d_blocks = d_blocks or self.cfg.quant_blocks
        cache = self.__dict__.setdefault("_int8_quants", {})
        q = cache.get(d_blocks)
        if q is None:
            q = quantize_vectors(self.x, d_blocks)
            cache[d_blocks] = q
        return q

    def attach_int8_quant(self, quant: "Int8Quant") -> None:
        """Install persisted codes (checkpoint restore path)."""
        cache = self.__dict__.setdefault("_int8_quants", {})
        cache[quant.d_blocks] = quant

    def memory_bytes(self) -> int:
        return sum(a.nbytes for a in (self.centers, self.x, self.ids, self.offsets))


def build_ivf(
    x: np.ndarray, cfg: HarmonyConfig, ext_ids: Optional[np.ndarray] = None,
    meta=None,
) -> IVFIndex:
    """Train + Add stages.

    ``ext_ids`` optionally names each input row with a stable *external*
    id (the ids returned by search); default is the row position —
    exactly the seed behaviour. Segment seals pass the surviving
    external ids through here, so ids stay stable across compactions.

    ``meta`` optionally attaches per-row metadata (any form
    :func:`meta_rows_from_batch` accepts, in *input* row order); it is
    permuted by the same cluster sort as the vectors, so metadata stays
    row-aligned with the packed corpus.
    """
    t0 = time.perf_counter()
    centers, assign = kmeans_fit_np(
        x, cfg.nlist, iters=cfg.kmeans_iters, seed=cfg.kmeans_seed
    )
    t_train = time.perf_counter() - t0

    t0 = time.perf_counter()
    order = np.argsort(assign, kind="stable")
    x_sorted = np.ascontiguousarray(x[order], dtype=np.float32)
    cluster_sorted = assign[order]
    counts = np.bincount(assign, minlength=cfg.nlist)
    offsets = np.zeros((cfg.nlist + 1,), np.int64)
    np.cumsum(counts, out=offsets[1:])
    t_add = time.perf_counter() - t0

    ids = order if ext_ids is None else np.asarray(ext_ids, np.int64)[order]
    store = None
    if meta is not None:
        if isinstance(meta, MetadataStore):
            store = meta.select(order)
        else:
            rows = meta_rows_from_batch(meta, len(x))
            store = meta_rows_to_store(
                None if rows is None else [rows[i] for i in order]
            )
    return IVFIndex(
        cfg=cfg,
        centers=centers.astype(np.float32),
        x=x_sorted,
        ids=ids.astype(np.int64),
        cluster_of=cluster_sorted.astype(np.int32),
        offsets=offsets,
        build_times={"train": t_train, "add": t_add},
        meta=store,
    )


def assign_queries(index: IVFIndex, q: np.ndarray, nprobe: Optional[int] = None) -> np.ndarray:
    """Nearest-``nprobe`` centroids per query (the client-side purple table
    of Fig. 4). Returns [NQ, nprobe] int32 cluster ids."""
    nprobe = nprobe or index.cfg.nprobe
    qn = np.sum(q * q, axis=1)[:, None]
    cn = np.sum(index.centers * index.centers, axis=1)[None, :]
    d = qn - 2.0 * (q @ index.centers.T) + cn
    return np.argsort(d, axis=1)[:, :nprobe].astype(np.int32)


# ---------------------------------------------------------------------------
# Pre-assign: sharded layout on the V × B grid
# ---------------------------------------------------------------------------


def dim_block_bounds(dim: int, d_blocks: int) -> List[Tuple[int, int]]:
    """Contiguous dimension blocks; D is padded implicitly (zero dims do
    not change L2/IP). Block b covers [bounds[b][0], bounds[b][1])."""
    per = -(-dim // d_blocks)  # ceil
    return [(b * per, min(dim, (b + 1) * per)) for b in range(d_blocks)]


# ---------------------------------------------------------------------------
# Scalar-quantized int8 tier (stage 1 of the two-stage search path)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Int8Quant:
    """Per-dimension-block affine int8 codes of one packed corpus.

    Block b has one (scale, zero-point) pair fit to the block's value
    range; a vector dimension j in block b encodes as
    ``round((x_j − zero_b) / scale_b)`` clipped to [−127, 127]. Queries
    are encoded on the *same* grid, so the zero-points cancel in the
    quantized L2 difference and stage-1 scoring is a pure int8×int8
    contraction (see ``kernels/distance_int8.py``).
    """

    codes: np.ndarray   # [NB, D] int8, packed row order of the owning index
    scale: np.ndarray   # [B] float32
    zero: np.ndarray    # [B] float32

    @property
    def d_blocks(self) -> int:
        return int(self.scale.shape[0])

    @property
    def bounds(self) -> List[Tuple[int, int]]:
        return dim_block_bounds(int(self.codes.shape[1]), self.d_blocks)

    def encode(self, x: np.ndarray) -> np.ndarray:
        """Encode fp32 vectors [..., D] on this grid → int8 codes.

        Out-of-range values (queries may fall outside the corpus's value
        range) clip; the corpus itself never clips because the grid was
        fit to its range."""
        x = np.asarray(x, np.float32)
        out = np.empty(x.shape, np.int8)
        for b, (lo, hi) in enumerate(self.bounds):
            q = np.rint((x[..., lo:hi] - self.zero[b]) / self.scale[b])
            out[..., lo:hi] = np.clip(q, -127, 127).astype(np.int8)
        return out

    def decode(self, codes: Optional[np.ndarray] = None) -> np.ndarray:
        """Dequantize codes [..., D] back to fp32 (default: own corpus)."""
        codes = self.codes if codes is None else codes
        out = np.empty(codes.shape, np.float32)
        for b, (lo, hi) in enumerate(self.bounds):
            out[..., lo:hi] = (
                codes[..., lo:hi].astype(np.float32) * self.scale[b]
                + self.zero[b]
            )
        return out

    def code_norms2(self, codes: Optional[np.ndarray] = None) -> np.ndarray:
        """Σ_b s_b²·Σ_j code², the pre-scaled norm term of the quantized
        L2 form (cached for the corpus codes)."""
        if codes is None:
            cached = self.__dict__.get("_cnorm2")
            if cached is not None:
                return cached
            codes = self.codes
            caching = True
        else:
            caching = False
        out = np.zeros(codes.shape[:-1], np.float32)
        for b, (lo, hi) in enumerate(self.bounds):
            blk = codes[..., lo:hi].astype(np.int32)
            out += (self.scale[b] ** 2) * np.sum(blk * blk, axis=-1).astype(
                np.float32
            )
        if caching:
            object.__setattr__(self, "_cnorm2", out)
        return out

    def scores(self, q_codes: np.ndarray, rows: Optional[np.ndarray] = None
               ) -> np.ndarray:
        """Quantized-L2 distances d̂²[m, n] between encoded queries
        [M, D] and corpus rows (all, or the given packed rows). Host
        oracle of the int8 kernel — int32 dot accumulation, f32 combine."""
        p = self.codes if rows is None else self.codes[rows]
        pn2 = self.code_norms2() if rows is None else self.code_norms2(p)
        qn2 = self.code_norms2(q_codes)
        acc = qn2[:, None] + pn2[None, :]
        for b, (lo, hi) in enumerate(self.bounds):
            dot = q_codes[:, lo:hi].astype(np.int32) @ p[:, lo:hi].astype(
                np.int32
            ).T
            acc -= (2.0 * self.scale[b] ** 2) * dot.astype(np.float32)
        return acc.astype(np.float32)

    def memory_bytes(self) -> int:
        return self.codes.nbytes + self.scale.nbytes + self.zero.nbytes


def quantize_vectors(x: np.ndarray, d_blocks: int) -> Int8Quant:
    """Fit one affine int8 grid per dimension block to ``x`` [NB, D] and
    encode it. The grid covers the block's [min, max] exactly, so the
    corpus itself never clips; scale has a floor so constant blocks stay
    well-defined."""
    x = np.asarray(x, np.float32)
    bounds = dim_block_bounds(int(x.shape[1]), d_blocks)
    scale = np.ones(d_blocks, np.float32)
    zero = np.zeros(d_blocks, np.float32)
    codes = np.empty(x.shape, np.int8)
    for b, (lo, hi) in enumerate(bounds):
        blk = x[:, lo:hi]
        mn = float(blk.min()) if blk.size else 0.0
        mx = float(blk.max()) if blk.size else 0.0
        zero[b] = 0.5 * (mn + mx)
        scale[b] = max((mx - mn) / 254.0, 1e-8)
        q = np.rint((blk - zero[b]) / scale[b])
        codes[:, lo:hi] = np.clip(q, -127, 127).astype(np.int8)
    return Int8Quant(codes=codes, scale=scale, zero=zero)


@dataclass
class ShardedCorpus:
    """The Pre-assign product: device-grid-resident corpus.

    ``x_shard[v]`` holds shard v's rows padded to ``cap`` with zeros and
    ``valid[v]`` marking real rows. ``xnorm2_blk[v, b]`` is the per-row
    squared norm restricted to dimension block b — the term that makes each
    stage's partial distance self-contained
    (``d_b² = ‖p‖²_b − 2·p·q|_b + ‖q‖²_b``).
    """

    plan: PartitionPlan
    x_shard: np.ndarray          # [V, cap, D] float32
    ids_shard: np.ndarray        # [V, cap] int64, -1 pad
    cluster_shard: np.ndarray    # [V, cap] int32, -1 pad
    valid: np.ndarray            # [V, cap] bool
    xnorm2_blk: np.ndarray       # [V, B, cap] float32
    # host-side lookup: for each cluster, its (shard, start, stop) rows
    cluster_slices: Dict[int, Tuple[int, int, int]]
    # packed-row → shard-layout permutation: packed row p lives at
    # (packed_shard[p], packed_row[p]) in the shard arrays
    packed_shard: np.ndarray     # [NB] int32
    packed_row: np.ndarray       # [NB] int32
    preassign_time: float

    @property
    def cap(self) -> int:
        return int(self.x_shard.shape[1])

    def dead_shard_mask(
        self, dead_rows: np.ndarray, key: Optional[tuple] = None
    ) -> np.ndarray:
        """Remap packed-row tombstones [NB] to the shard layout [V, cap].

        O(#dead) via the precomputed permutation — no per-cluster Python
        loop. With ``key`` (the data plane's ``(generation,
        dead_version)``) the result is cached single-entry: repeated
        batches between mutations reuse the mask, and any tombstone flip
        or generation swap changes the key, so stale masks can never be
        served. Callers without a stable key get a fresh mask."""
        cache = self.__dict__.get("_dead_mask_cache")
        if key is not None and cache is not None and cache[0] == key:
            return cache[1]
        mask = np.zeros((self.x_shard.shape[0], self.cap), bool)
        rows = np.nonzero(dead_rows)[0]
        mask[self.packed_shard[rows], self.packed_row[rows]] = True
        if key is not None:
            self.__dict__["_dead_mask_cache"] = (key, mask)
        return mask

    def memory_bytes(self) -> int:
        return sum(
            a.nbytes
            for a in (
                self.x_shard,
                self.ids_shard,
                self.cluster_shard,
                self.valid,
                self.xnorm2_blk,
            )
        )


def preassign(index: IVFIndex, plan: PartitionPlan, pad_to: int = 64) -> ShardedCorpus:
    """Distribute clusters to vector shards per ``plan.cluster_to_shard``
    and precompute per-dimension-block norms."""
    t0 = time.perf_counter()
    V, B, D = plan.v_shards, plan.d_blocks, index.dim
    shard_rows: List[List[int]] = [[] for _ in range(V)]
    cluster_slices: Dict[int, Tuple[int, int, int]] = {}
    for c in range(index.nlist):
        v = int(plan.cluster_to_shard[c])
        lo, hi = index.cluster_rows(c)
        start = len(shard_rows[v])
        shard_rows[v].extend(range(lo, hi))
        cluster_slices[c] = (v, start, start + (hi - lo))

    cap = max(1, max(len(r) for r in shard_rows))
    cap = -(-cap // pad_to) * pad_to  # round up for tile alignment

    x_shard = np.zeros((V, cap, D), np.float32)
    ids_shard = np.full((V, cap), -1, np.int64)
    cluster_shard = np.full((V, cap), -1, np.int32)
    valid = np.zeros((V, cap), bool)
    packed_shard = np.full(index.nb, -1, np.int32)
    packed_row = np.full(index.nb, -1, np.int32)
    for v in range(V):
        rows = np.asarray(shard_rows[v], np.int64)
        n = len(rows)
        if n:
            x_shard[v, :n] = index.x[rows]
            ids_shard[v, :n] = index.ids[rows]
            cluster_shard[v, :n] = index.cluster_of[rows]
            valid[v, :n] = True
            packed_shard[rows] = v
            packed_row[rows] = np.arange(n, dtype=np.int32)

    bounds = dim_block_bounds(D, B)
    xnorm2_blk = np.zeros((V, B, cap), np.float32)
    for b, (lo, hi) in enumerate(bounds):
        seg = x_shard[:, :, lo:hi]
        xnorm2_blk[:, b] = np.sum(seg * seg, axis=2)

    return ShardedCorpus(
        plan=plan,
        x_shard=x_shard,
        ids_shard=ids_shard,
        cluster_shard=cluster_shard,
        valid=valid,
        xnorm2_blk=xnorm2_blk,
        cluster_slices=cluster_slices,
        packed_shard=packed_shard,
        packed_row=packed_row,
        preassign_time=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Per-row metadata (filtered / hybrid search)
# ---------------------------------------------------------------------------

# fill value for tag columns a row never carried (a merged segment unions
# the columns of its sources) — a predicate only matches it if the caller
# filters for this exact sentinel
TAG_MISSING = np.iinfo(np.int64).min


@dataclass(frozen=True)
class MetadataStore:
    """Columnar per-row metadata aligned with one packed corpus.

    ``tags[name][r]`` / ``nums[name][r]`` are row r's int tag / float
    numeric attributes; ``texts[r]`` is its lexical document (or None).
    Rows follow the owning index's packed order, so a
    :class:`repro.core.types.Filter` evaluates straight to a packed-row
    bitmap that plugs into the ``dead_rows`` masking path. Missing values
    are :data:`TAG_MISSING` / NaN / None — none of which satisfy a
    ``TagIn`` / ``NumRange`` predicate on the column.
    """

    tags: Dict[str, np.ndarray]                 # name -> [NB] int64
    nums: Dict[str, np.ndarray]                 # name -> [NB] float32
    texts: Optional[Tuple[Optional[str], ...]] = None   # [NB] or None

    @property
    def n(self) -> int:
        for col in self.tags.values():
            return int(col.shape[0])
        for col in self.nums.values():
            return int(col.shape[0])
        return 0 if self.texts is None else len(self.texts)

    def row(self, r: int) -> dict:
        """Row r as a plain per-row dict (python-native values)."""
        out = {}
        for k, col in self.tags.items():
            if col[r] != TAG_MISSING:
                out[k] = int(col[r])
        for k, col in self.nums.items():
            if not np.isnan(col[r]):
                out[k] = float(col[r])
        if self.texts is not None and self.texts[r] is not None:
            out["text"] = self.texts[r]
        return out

    def select(self, rows: np.ndarray) -> "MetadataStore":
        """Sub-store of the given packed rows (gather/permutation)."""
        return MetadataStore(
            tags={k: col[rows] for k, col in self.tags.items()},
            nums={k: col[rows] for k, col in self.nums.items()},
            texts=None if self.texts is None
            else tuple(self.texts[int(r)] for r in rows),
        )

    def memory_bytes(self) -> int:
        out = sum(c.nbytes for c in self.tags.values())
        out += sum(c.nbytes for c in self.nums.values())
        if self.texts is not None:
            out += sum(len(t) for t in self.texts if t)
        return out


def meta_rows_from_batch(meta, n: int) -> Optional[List[Optional[dict]]]:
    """Normalize a batch ``meta`` argument to per-row dicts.

    Accepts a dict of columns (each an [n] array/list; a ``"text"``
    column of strings feeds the lexical scorer), a list of per-row
    dicts, or None. Values become python natives so rows can be
    journaled / JSON-encoded verbatim."""
    if meta is None:
        return None
    if isinstance(meta, dict):
        rows: List[Optional[dict]] = [{} for _ in range(n)]
        for name, col in meta.items():
            vals = list(col)
            assert len(vals) == n, (name, len(vals), n)
            for i, v in enumerate(vals):
                if v is None:
                    continue
                if isinstance(v, str):
                    rows[i][name] = v
                elif isinstance(v, (bool, int, np.integer)):
                    rows[i][name] = int(v)
                else:
                    rows[i][name] = float(v)
        return rows
    rows = [None if r is None else dict(r) for r in meta]
    assert len(rows) == n, (len(rows), n)
    return rows


def meta_rows_to_store(
    rows: Optional[Sequence[Optional[dict]]],
) -> Optional[MetadataStore]:
    """Per-row dicts → columnar store (None when no row carries any).

    Column typing is by value: all-integral → tag column, otherwise
    numeric; the ``"text"`` column (strings) becomes ``texts``."""
    if rows is None or not any(r for r in rows):
        return None
    n = len(rows)
    cols: Dict[str, list] = {}
    for i, r in enumerate(rows):
        if not r:
            continue
        for k, v in r.items():
            cols.setdefault(k, [None] * n)[i] = v
    tags, nums, texts = {}, {}, None
    for name, vals in cols.items():
        if any(isinstance(v, str) for v in vals if v is not None):
            assert name == "text", f"string column must be named 'text': {name}"
            texts = tuple(vals)
            continue
        if all(isinstance(v, (bool, int, np.integer))
               for v in vals if v is not None):
            tags[name] = np.asarray(
                [TAG_MISSING if v is None else int(v) for v in vals], np.int64
            )
        else:
            nums[name] = np.asarray(
                [np.nan if v is None else float(v) for v in vals], np.float32
            )
    return MetadataStore(tags=tags, nums=nums, texts=texts)


# ---------------------------------------------------------------------------
# Mutable segmented data plane
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    """One immutable sealed segment: a packed IVF index whose ``ids`` are
    stable external ids. Row r of ``index.x`` is addressed everywhere as
    ``(seg_id, r)``; deletions never rewrite a sealed segment — they flip
    a bit in the owning :class:`SegmentedIndex`'s dead-row bitmap."""

    seg_id: int
    index: IVFIndex

    @property
    def nb(self) -> int:
        return self.index.nb


def segment_device_bytes(seg: "Segment", precision: str = "fp32",
                         d_blocks: int = 1) -> int:
    """Bytes the SPMD executor keeps device-resident for one sealed
    segment at ``precision`` — the packed corpus rows (int8 codes or
    fp32), per-dimension-block norms, and the packed cluster/row id
    columns. This is the currency of the placement budget: a
    ``device``-tier segment costs this much HBM, a ``host``-tier segment
    costs zero (its rows stream through the gather path per batch)."""
    idx = seg.index
    d = int(idx.x.shape[1])
    per_row = (d if precision == "int8" else 4 * d) + 4 * d_blocks + 8
    return idx.nb * per_row


@dataclass(frozen=True)
class CompactionPlan:
    """Consistent snapshot handed to the (off-path, lock-free) seal step.

    ``ids``/``x`` are the live rows of the structures being compacted
    (delta buffer + ``merge_seg_ids`` sealed segments), sorted by external
    id — so a full merge is bit-identical to ``build_ivf`` over the live
    set. ``carry_seg_ids`` keep serving untouched through the swap."""

    base_generation: int
    merge_seg_ids: Tuple[int, ...]
    carry_seg_ids: Tuple[int, ...]
    ids: np.ndarray                 # [n] int64, sorted ascending
    x: np.ndarray                   # [n, D] float32
    # per-row metadata dicts aligned with ids/x (None when no row has any)
    meta: Optional[Tuple[Optional[dict], ...]] = None


class SegmentedIndex:
    """Mutable segmented vector index: sealed segments + delta + tombstones.

    The single shared data plane of the serving stack — every replica's
    :class:`repro.serve.engine.HarmonyServer` holds a reference to the
    same object, so one ``upsert``/``delete`` is immediately visible
    fleet-wide, and a compaction *commit* (generation bump) tells every
    replica to adopt the new segment set.

    Thread model: all mutation happens under ``_mu``; readers take a
    :meth:`snapshot` (cheap — tuple of immutable segments plus copies of
    the dead bitmaps and delta state, taken under the lock) and search
    lock-free on a true point-in-time view. Delta rows are append-only
    (an upsert of an existing id appends a new row and kills the old one
    — rows are never rewritten in place, so a reader can never observe a
    torn vector).

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> rng = np.random.default_rng(0)
    >>> x = rng.standard_normal((64, 4)).astype(np.float32)
    >>> cfg = HarmonyConfig(dim=4, nlist=4, nprobe=4, topk=3, kmeans_iters=2)
    >>> si = SegmentedIndex.build(x, cfg)
    >>> si.n_segments, si.delta_len, si.nb_live
    (1, 0, 64)
    >>> si.upsert([64], x[:1] + 1.0)
    >>> si.delete([0, 1])
    2
    >>> si.delta_len, si.nb_live, sorted(si.dead_count_by_segment().values())
    (1, 63, [2])
    >>> si.compact_inline(merge_all=True)       # one-shot, serving paused
    >>> si.generation, si.n_segments, si.delta_len, si.nb_live
    (1, 1, 0, 63)
    """

    def __init__(self, cfg: HarmonyConfig, segments: Sequence[Segment] = ()):
        self.cfg = cfg
        self._mu = threading.RLock()
        self.segments: Tuple[Segment, ...] = tuple(segments)
        self.generation = 0
        # monotone counter of sealed-row tombstone flips — deletes do NOT
        # bump generation, so (generation, dead_version) is the cache key
        # for anything derived from the dead bitmaps
        self.dead_version = 0
        self._next_seg_id = 1 + max((s.seg_id for s in self.segments), default=-1)
        # sealed-row tombstones: seg_id -> bool [nb] (True = dead)
        self._dead_rows: Dict[int, np.ndarray] = {
            s.seg_id: np.zeros(s.nb, bool) for s in self.segments
        }
        # location maps: external id -> (seg_id, row) | delta row
        self._loc: Dict[int, Tuple[int, int]] = {}
        for s in self.segments:
            for r, i in enumerate(s.index.ids):
                self._loc[int(i)] = (s.seg_id, r)
        # append-only delta buffer (doubled on growth; old buffers stay
        # valid for readers that snapshotted them)
        self._delta_x = np.zeros((0, cfg.dim), np.float32)
        self._delta_ids = np.zeros((0,), np.int64)
        self._delta_live = np.zeros((0,), bool)
        self._delta_meta: List[Optional[dict]] = []   # row n -> meta dict
        self._delta_len = 0
        self._delta_pos: Dict[int, int] = {}
        self._journal: Optional[List[tuple]] = None     # ops during compaction
        self.op_count = 0               # total accepted upsert/delete rows
        # optional durability hook (repro.checkpoint.wal.WriteAheadLog):
        # when attached, every accepted write is journaled+fsynced before
        # the call returns; wal_seq is the watermark of the last durable
        # record (persisted in checkpoints, the replay cut on recovery)
        self._wal = None
        self.wal_seq = 0
        # memory-hierarchy tier per sealed segment: seg_id -> "device" |
        # "host" (absent = "device"). placement_version bumps on every
        # set_tiers so serving replicas re-sync executor residency
        # without a generation swap (results are tier-invariant, so the
        # query cache stays valid across a move)
        self._tier: Dict[int, str] = {}
        self.placement_version = 0
        # per-segment cluster-hotness EWMA (probe mass per sealed
        # cluster), fed by the serving layer via note_probes — the
        # placement policy's promote/demote signal
        self.hotness_alpha = 0.25
        self._hotness: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------- builders
    @classmethod
    def build(
        cls, x: np.ndarray, cfg: HarmonyConfig,
        ids: Optional[np.ndarray] = None,
    ) -> "SegmentedIndex":
        """Build a one-sealed-segment index (the static special case)."""
        return cls.from_static(build_ivf(np.asarray(x, np.float32), cfg, ids))

    @classmethod
    def from_static(cls, index: IVFIndex) -> "SegmentedIndex":
        """Wrap an already-built :func:`build_ivf` index as generation 0."""
        return cls(index.cfg, [Segment(seg_id=0, index=index)])

    # ------------------------------------------------------------ properties
    @property
    def dim(self) -> int:
        return self.cfg.dim

    @property
    def nlist(self) -> int:
        """Cluster count of the *plan/routing* cluster space (the config's
        nlist; small sealed segments may carry fewer centroids)."""
        return self.cfg.nlist

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def delta_len(self) -> int:
        """Live rows currently in the delta buffer."""
        with self._mu:
            return int(self._delta_live[: self._delta_len].sum())

    @property
    def nb_live(self) -> int:
        """Total live vectors (sealed minus tombstoned, plus delta)."""
        with self._mu:
            return len(self._loc) + len(self._delta_pos)

    def live_sizes(self, seg: Segment) -> np.ndarray:
        """Tombstone-aware per-cluster sizes of one sealed segment (what
        load-aware planning should balance — dead rows carry no work)."""
        with self._mu:
            alive = ~self._dead_rows[seg.seg_id]
        return np.bincount(
            seg.index.cluster_of[alive], minlength=seg.index.nlist
        ).astype(np.int64)

    def dead_count_by_segment(self) -> Dict[int, int]:
        with self._mu:
            return {sid: int(d.sum()) for sid, d in self._dead_rows.items()}

    def memory_bytes(self) -> int:
        """Total resident bytes across both tiers: sealed segments
        (including metadata columns, cached BM25 postings and cached int8
        codes), dead bitmaps, and the delta buffer. For the per-tier
        split the placement budget works against, see
        :meth:`memory_report`."""
        rep = self.memory_report()
        return rep["host_bytes"] + rep["device_bytes"]

    def _segment_host_bytes_locked(self, seg: Segment) -> int:
        """Host-resident bytes of one sealed segment: the fp32 corpus and
        build artifacts always live host-side (the re-rank source and the
        compaction/checkpoint source of truth), plus metadata columns,
        lazily-built BM25 postings, and cached int8 codes."""
        idx = seg.index
        out = sum(a.nbytes
                  for a in (idx.centers, idx.x, idx.ids, idx.offsets,
                            idx.cluster_of))
        if idx.meta is not None:
            out += idx.meta.memory_bytes()
        bm = idx.__dict__.get("_bm25")
        if bm is not None:
            out += bm.memory_bytes()
        for quant in idx.__dict__.get("_int8_quants", {}).values():
            out += quant.memory_bytes()
        return out

    def memory_report(self, precision: str = "fp32",
                      d_blocks: int = 1) -> Dict[str, int]:
        """Per-tier byte accounting — what actually lives in HBM vs host
        RAM. ``device_bytes`` counts, for every ``device``-tier segment,
        the arrays the SPMD executor keeps resident at ``precision``
        (:func:`segment_device_bytes`); everything else — fp32 corpora,
        metadata, BM25 postings, int8 codes, dead bitmaps, the delta
        buffer — is ``host_bytes``. The placement budget and
        ``bench_memory`` both read this."""
        with self._mu:
            device = 0
            host = sum(d.nbytes for d in self._dead_rows.values())
            host += (self._delta_x.nbytes + self._delta_ids.nbytes
                     + self._delta_live.nbytes)
            for s in self.segments:
                host += self._segment_host_bytes_locked(s)
                if self._tier.get(s.seg_id, "device") == "device":
                    device += segment_device_bytes(s, precision, d_blocks)
            return {"device_bytes": device, "host_bytes": host,
                    "total_bytes": device + host}

    # ------------------------------------------------------ tier placement
    def tier_of(self, seg_id: int) -> str:
        """Current tier of a sealed segment ("device" unless demoted)."""
        with self._mu:
            return self._tier.get(int(seg_id), "device")

    def tiers(self) -> Dict[int, str]:
        """seg_id -> tier for every sealed segment (point-in-time copy)."""
        with self._mu:
            return {s.seg_id: self._tier.get(s.seg_id, "device")
                    for s in self.segments}

    def set_tiers(self, tiers: Dict[int, str]) -> int:
        """Install a placement (seg_id -> "device"|"host") and bump
        ``placement_version`` so every serving replica re-syncs executor
        residency on its next batch. Unknown seg ids are ignored; omitted
        segments keep their current tier. Returns the new version.

        Tier moves never change search results (the host tier streams
        the exact same packed rows through the same kernels), so unlike
        a generation swap this does NOT invalidate query caches."""
        live = {s.seg_id for s in self.segments}
        with self._mu:
            for sid, tier in tiers.items():
                if tier not in ("device", "host"):
                    raise ValueError(f"unknown tier {tier!r}")
                if int(sid) in live:
                    self._tier[int(sid)] = tier
            self.placement_version += 1
            return self.placement_version

    def note_probes(self, seg_id: int, probes: np.ndarray) -> None:
        """Fold one batch's probe selection for segment ``seg_id`` into
        its cluster-hotness EWMA (the placement policy's promote/demote
        signal). Padding entries (< 0) are ignored."""
        seg = next((s for s in self.segments if s.seg_id == seg_id), None)
        if seg is None:
            return
        flat = np.asarray(probes).ravel()
        flat = flat[(flat >= 0) & (flat < seg.index.nlist)]
        counts = np.bincount(flat, minlength=seg.index.nlist)
        with self._mu:
            h = self._hotness.get(seg_id)
            if h is None or len(h) != seg.index.nlist:
                h = np.zeros(seg.index.nlist, np.float64)
                self._hotness[seg_id] = h
            a = self.hotness_alpha
            h *= (1.0 - a)
            h += a * counts

    def hotness(self, seg_id: int) -> np.ndarray:
        """Cluster-hotness EWMA of one segment (zeros until probed)."""
        seg = next((s for s in self.segments if s.seg_id == seg_id), None)
        nlist = seg.index.nlist if seg is not None else 0
        with self._mu:
            h = self._hotness.get(int(seg_id))
            return h.copy() if h is not None else np.zeros(nlist, np.float64)

    def segment_hotness(self) -> Dict[int, float]:
        """seg_id -> total probe mass EWMA (the per-segment heat the
        placement policy ranks by)."""
        with self._mu:
            return {s.seg_id: float(self._hotness[s.seg_id].sum())
                    if s.seg_id in self._hotness else 0.0
                    for s in self.segments}

    def has(self, ext_id: int) -> bool:
        """Is ``ext_id`` live (reachable by search)?"""
        with self._mu:
            return int(ext_id) in self._loc or int(ext_id) in self._delta_pos

    @property
    def compaction_in_flight(self) -> bool:
        """Is a begin→commit compaction cycle currently open? (The crash-
        recovery path rolls an orphaned one back — see
        :meth:`repro.serve.compactor.Compactor.recover`.)"""
        with self._mu:
            return self._journal is not None

    # ----------------------------------------------------------- durability
    def attach_wal(self, wal) -> None:
        """Journal every subsequently accepted write to ``wal`` (a
        :class:`repro.checkpoint.wal.WriteAheadLog`), inside the same
        critical section that applies it — so WAL order is apply order
        and a write is acknowledged only once durable. A WAL append that
        raises (disk error, injected torn write) propagates to the
        writer: the op was **not** acknowledged and recovery will not
        replay it. Pass ``None`` to detach."""
        with self._mu:
            self._wal = wal

    # -------------------------------------------------------------- writes
    def _kill_locked(self, ext_id: int) -> bool:
        """Remove ``ext_id``'s current live copy (sealed tombstone or delta
        mask). Returns True if a copy existed."""
        loc = self._loc.pop(ext_id, None)
        if loc is not None:
            self._dead_rows[loc[0]][loc[1]] = True
            self.dead_version += 1
            return True
        row = self._delta_pos.pop(ext_id, None)
        if row is not None:
            self._delta_live[row] = False
            return True
        return False

    def _append_delta_locked(self, ext_id: int, vec: np.ndarray,
                             meta_row: Optional[dict] = None) -> None:
        n = self._delta_len
        if n == len(self._delta_x):
            cap = max(64, 2 * len(self._delta_x))
            for name in ("_delta_x", "_delta_ids", "_delta_live"):
                old = getattr(self, name)
                shape = (cap,) + old.shape[1:]
                new = np.zeros(shape, old.dtype)
                new[:n] = old[:n]
                setattr(self, name, new)    # readers keep their old buffer
        self._delta_x[n] = vec
        self._delta_ids[n] = ext_id
        self._delta_live[n] = True
        self._delta_meta.append(meta_row or None)
        self._delta_len = n + 1
        self._delta_pos[ext_id] = n

    def upsert(self, ids: Sequence[int], vecs: np.ndarray, meta=None) -> None:
        """Insert-or-replace vectors under stable external ids. The newest
        version wins immediately: any older copy (sealed or delta) is
        tombstoned in the same critical section.

        ``meta`` optionally attaches per-row metadata (any form
        :func:`meta_rows_from_batch` accepts); replacing a row replaces
        its metadata wholesale (omitting ``meta`` clears it).

        Ids are int64 end-to-end on the host backend; the device
        (``spmd``) pipeline carries ids as int32, so keep external ids
        within int32 range when serving through it."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        ids = np.asarray(ids, np.int64).reshape(-1)
        assert vecs.shape == (len(ids), self.dim), (vecs.shape, len(ids))
        meta_rows = meta_rows_from_batch(meta, len(ids))
        with self._mu:
            for r, (i, v) in enumerate(zip(ids, vecs)):
                i = int(i)
                self._kill_locked(i)
                self._append_delta_locked(
                    i, v, None if meta_rows is None else meta_rows[r]
                )
            self.op_count += len(ids)
            if self._journal is not None:
                self._journal.append(
                    ("upsert", ids.copy(), vecs.copy(), meta_rows)
                )
            if self._wal is not None:
                self.wal_seq = self._wal.append_upsert(ids, vecs, meta_rows)

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone external ids. Returns how many were actually live."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        with self._mu:
            removed = sum(1 for i in ids if self._kill_locked(int(i)))
            self.op_count += len(ids)
            if self._journal is not None:
                self._journal.append(("delete", ids.copy()))
            if self._wal is not None:
                self.wal_seq = self._wal.append_delete(ids)
            return removed

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> "DataSnapshot":
        """Point-in-time read view for one search: immutable sealed
        segments plus *copies* of the dead bitmaps and the delta's live
        id/row state. The bitmap copy matters: an upsert of a sealed id
        flips its dead bit and appends the new delta row as one atomic
        write — a reader sharing the live bitmap could observe the
        tombstone half without the new row and lose the id entirely."""
        with self._mu:
            n = self._delta_len
            return DataSnapshot(
                generation=self.generation,
                segments=self.segments,
                dead_rows={sid: d.copy() for sid, d in self._dead_rows.items()},
                delta_ids=self._delta_ids[:n].copy(),
                delta_x=self._delta_x[:n],          # append-only: rows ≤ n frozen
                delta_live=self._delta_live[:n].copy(),
                dead_version=self.dead_version,
                delta_meta=tuple(self._delta_meta[:n]),
                tiers={s.seg_id: self._tier.get(s.seg_id, "device")
                       for s in self.segments},
                placement_version=self.placement_version,
            )

    def live_vectors(self) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, x) of every live vector, sorted by external id — the
        brute-force-oracle and from-scratch-rebuild reference set."""
        with self._mu:
            parts_i, parts_x = [], []
            for s in self.segments:
                alive = ~self._dead_rows[s.seg_id]
                parts_i.append(s.index.ids[alive])
                parts_x.append(s.index.x[alive])
            n = self._delta_len
            live = self._delta_live[:n]
            parts_i.append(self._delta_ids[:n][live])
            parts_x.append(self._delta_x[:n][live])
        ids = np.concatenate(parts_i) if parts_i else np.zeros(0, np.int64)
        x = (np.concatenate(parts_x) if parts_x
             else np.zeros((0, self.dim), np.float32))
        order = np.argsort(ids, kind="stable")
        return ids[order], np.ascontiguousarray(x[order])

    # ----------------------------------------------------------- compaction
    def begin_compaction(self, merge_all: bool = False,
                         merge_seg_ids: Optional[Sequence[int]] = None
                         ) -> CompactionPlan:
        """Open a compaction: snapshot the rows to re-seal and start
        journaling writes so the (long) seal step can run off the serving
        path. Exactly one compaction may be in flight."""
        with self._mu:
            if self._journal is not None:
                raise RuntimeError("a compaction is already in flight")
            if merge_seg_ids is None:
                merge_seg_ids = ([s.seg_id for s in self.segments]
                                 if merge_all else [])
            merge_seg_ids = tuple(int(s) for s in merge_seg_ids)
            carry = tuple(s.seg_id for s in self.segments
                          if s.seg_id not in merge_seg_ids)
            parts_i, parts_x, meta_rows = [], [], []
            for s in self.segments:
                if s.seg_id not in merge_seg_ids:
                    continue
                alive = ~self._dead_rows[s.seg_id]
                parts_i.append(s.index.ids[alive])
                parts_x.append(s.index.x[alive].copy())
                if s.index.meta is not None:
                    store = s.index.meta.select(np.nonzero(alive)[0])
                    meta_rows.extend(store.row(r) for r in range(store.n))
                else:
                    meta_rows.extend([None] * int(alive.sum()))
            n = self._delta_len
            live = self._delta_live[:n]
            parts_i.append(self._delta_ids[:n][live].copy())
            parts_x.append(self._delta_x[:n][live].copy())
            meta_rows.extend(self._delta_meta[r] for r in np.nonzero(live)[0])
            ids = np.concatenate(parts_i)
            x = (np.concatenate(parts_x) if ids.size
                 else np.zeros((0, self.dim), np.float32))
            order = np.argsort(ids, kind="stable")
            self._journal = []
            return CompactionPlan(
                base_generation=self.generation,
                merge_seg_ids=merge_seg_ids,
                carry_seg_ids=carry,
                ids=ids[order],
                x=np.ascontiguousarray(x[order]),
                meta=(tuple(meta_rows[i] for i in order)
                      if any(r for r in meta_rows) else None),
            )

    def seal(self, plan: CompactionPlan) -> List[Segment]:
        """Heavy step (k-means + pack), run OUTSIDE the lock: seal the
        plan's rows into new segment(s). A full merge re-trains with the
        config's exact settings, so the result is bit-identical to
        ``build_ivf`` over the live set."""
        if plan.ids.size == 0:
            return []
        n = int(plan.ids.size)
        nlist = max(1, min(self.cfg.nlist, n))
        seg_cfg = self.cfg.replace(
            nlist=nlist, nprobe=min(self.cfg.nprobe, nlist)
        )
        with self._mu:
            seg_id = self._next_seg_id
            self._next_seg_id += 1
        index = build_ivf(plan.x, seg_cfg, ext_ids=plan.ids, meta=plan.meta)
        # quantize at seal (off the serving path): the int8 tier of the
        # two-stage search is part of the sealed artifact, so a precision
        # switch or checkpoint save never recomputes it mid-serving
        index.int8_quant(self.cfg.quant_blocks)
        return [Segment(seg_id=seg_id, index=index)]

    def abort_compaction(self) -> None:
        with self._mu:
            self._journal = None

    def commit_compaction(self, plan: CompactionPlan,
                          new_segments: Sequence[Segment]) -> int:
        """Atomically install the sealed segments and replay the writes
        that arrived during the seal. Bumps ``generation`` (replicas adopt
        on their next batch, or eagerly via the compactor). Returns the
        new generation."""
        # precompute the new segments' location entries OUTSIDE the lock
        # (they're immutable): the critical section must stay O(journal),
        # not O(corpus), or readers' snapshot() calls would stall behind
        # a large merge — the very thing the swap protocol forbids
        new_loc: Dict[int, Tuple[int, int]] = {}
        for s in new_segments:
            for r, i in enumerate(s.index.ids):
                new_loc[int(i)] = (s.seg_id, r)
        with self._mu:
            if self._journal is None:
                raise RuntimeError("no compaction in flight")
            if self.generation != plan.base_generation:
                self._journal = None
                raise RuntimeError("concurrent generation change")
            carry = [s for s in self.segments if s.seg_id in plan.carry_seg_ids]
            self.segments = tuple(carry) + tuple(new_segments)
            self._dead_rows = {
                sid: d for sid, d in self._dead_rows.items()
                if sid in plan.carry_seg_ids
            }
            for s in new_segments:
                self._dead_rows[s.seg_id] = np.zeros(s.nb, bool)
            # tier/hotness state of merged-away segments dies with them;
            # new seals start device-tier (the placement policy demotes
            # them on its next cycle if the budget says so)
            keep = set(plan.carry_seg_ids)
            self._tier = {sid: t for sid, t in self._tier.items()
                          if sid in keep}
            self._hotness = {sid: h for sid, h in self._hotness.items()
                             if sid in keep}
            # rebuild location maps: carried entries survive, merged /
            # delta entries now point at the new sealed rows. The two
            # common shapes stay cheap under the lock: a full merge is an
            # O(1) dict swap, a delta-only seal an O(delta) update;
            # partial merges pay one pass over the carried entries.
            if not plan.carry_seg_ids:
                self._loc = new_loc
            elif plan.merge_seg_ids:
                self._loc = {i: l for i, l in self._loc.items()
                             if l[0] in plan.carry_seg_ids}
                self._loc.update(new_loc)
            else:
                self._loc.update(new_loc)   # sealed entries all carried
            self._delta_x = np.zeros((0, self.cfg.dim), np.float32)
            self._delta_ids = np.zeros((0,), np.int64)
            self._delta_live = np.zeros((0,), bool)
            self._delta_meta = []
            self._delta_len = 0
            self._delta_pos = {}
            ops, self._journal = self._journal, None
            self.generation += 1
            # replay the journal onto the new structures (idempotent kills
            # + fresh delta appends — ops were counted when first applied)
            for op in ops:
                if op[0] == "upsert":
                    _, ids, vecs, meta_rows = op
                    for r, (i, v) in enumerate(zip(ids, vecs)):
                        self._kill_locked(int(i))
                        self._append_delta_locked(
                            int(i), v,
                            None if meta_rows is None else meta_rows[r],
                        )
                else:
                    for i in op[1]:
                        self._kill_locked(int(i))
            return self.generation

    def compact_inline(self, merge_all: bool = False) -> None:
        """Synchronous begin→seal→commit (tests / offline tools; live
        serving uses :class:`repro.serve.compactor.Compactor`, which
        interleaves replica preparation before the commit)."""
        plan = self.begin_compaction(merge_all=merge_all)
        try:
            segs = self.seal(plan)
        except BaseException:
            self.abort_compaction()
            raise
        self.commit_compaction(plan, segs)


@dataclass(frozen=True)
class DataSnapshot:
    """One search's point-in-time view of a :class:`SegmentedIndex`."""

    generation: int
    segments: Tuple[Segment, ...]
    dead_rows: Dict[int, np.ndarray]    # seg_id -> bool [nb] (point-in-time copy)
    delta_ids: np.ndarray               # [n] int64
    delta_x: np.ndarray                 # [n, D] float32 (frozen rows)
    delta_live: np.ndarray              # [n] bool
    dead_version: int = 0               # tombstone-flip counter at snapshot
    delta_meta: Tuple[Optional[dict], ...] = ()   # [n] per-row meta dicts
    # seg_id -> "device" | "host" at snapshot time, and the placement
    # version it reflects (replicas re-sync executors when it moves)
    tiers: Dict[int, str] = None        # type: ignore[assignment]
    placement_version: int = 0

    @property
    def delta_count(self) -> int:
        return int(self.delta_live.sum())
