"""Core datatypes: partition plans, routing tables, search results."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class PartitionPlan:
    """A HARMONY partition plan π.

    The machine grid is ``v_shards × d_blocks`` (vector-based × dimension-
    based). ``cluster_to_shard[c]`` maps IVF cluster c to a vector shard —
    the load-aware part of the plan. ``ring_offset[g]`` staggers the
    dimension-ring start of query group g (the paper's "defer hot blocks to
    late stages" scheduling).
    """

    v_shards: int
    d_blocks: int
    cluster_to_shard: np.ndarray            # [nlist] int32
    ring_offsets: Optional[np.ndarray] = None   # [v_shards] int32, default zeros
    mode: str = "harmony"                   # harmony | vector | dimension

    def __post_init__(self):
        assert self.cluster_to_shard.ndim == 1
        if self.ring_offsets is None:
            object.__setattr__(
                self, "ring_offsets", np.zeros((self.v_shards,), np.int32)
            )

    @property
    def n_nodes(self) -> int:
        return self.v_shards * self.d_blocks


@dataclass
class SearchResult:
    ids: np.ndarray                         # [NQ, K] int64 (original vector ids, -1 pad)
    scores: np.ndarray                      # [NQ, K] float32 (ascending; sq-L2 or -IP)
    stats: dict = field(default_factory=dict)
