"""Core datatypes: partition plans, filters, requests, results.

This module is the serving surface's vocabulary: a query is a
:class:`SearchRequest` (vector + per-request knobs), an answer is a
:class:`SearchResult`, a predicate is a :class:`Filter` expression tree,
and every layer that accepts writes implements :class:`DataPlane`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class PartitionPlan:
    """A HARMONY partition plan π.

    The machine grid is ``v_shards × d_blocks`` (vector-based × dimension-
    based). ``cluster_to_shard[c]`` maps IVF cluster c to a vector shard —
    the load-aware part of the plan. ``ring_offset[g]`` staggers the
    dimension-ring start of query group g (the paper's "defer hot blocks to
    late stages" scheduling).
    """

    v_shards: int
    d_blocks: int
    cluster_to_shard: np.ndarray            # [nlist] int32
    ring_offsets: Optional[np.ndarray] = None   # [v_shards] int32, default zeros
    mode: str = "harmony"                   # harmony | vector | dimension

    def __post_init__(self):
        assert self.cluster_to_shard.ndim == 1
        if self.ring_offsets is None:
            object.__setattr__(
                self, "ring_offsets", np.zeros((self.v_shards,), np.int32)
            )

    @property
    def n_nodes(self) -> int:
        return self.v_shards * self.d_blocks


# --------------------------------------------------------------- filters
class Filter:
    """Predicate over per-row metadata, pushed down into the index scan.

    A filter is a small expression tree over tag columns (int64) and
    numeric columns (float32): :class:`TagIn`, :class:`NumRange`, composed
    with :class:`And` / :class:`Or` (or the ``&`` / ``|`` operators).
    Evaluation is vectorized — :meth:`evaluate` maps a segment's columnar
    metadata to a boolean *allowed* mask over its rows, which the engine
    complements and merges into the tombstone (``dead_rows``) masking path.

    Every concrete filter is a frozen, hashable dataclass, so a filter
    value doubles as a cache key for its compiled per-segment bitmaps.

    >>> import numpy as np
    >>> f = TagIn("color", (1, 3)) & NumRange("price", 10.0, 20.0)
    >>> tags = {"color": np.array([1, 2, 3, 3])}
    >>> nums = {"price": np.array([15.0, 15.0, 5.0, 12.0], np.float32)}
    >>> f.evaluate(tags, nums, 4).tolist()
    [True, False, False, True]
    >>> (TagIn("color", (2,)) | NumRange("price", hi=6.0)).evaluate(
    ...     tags, nums, 4).tolist()
    [False, True, True, False]
    """

    def evaluate(
        self,
        tags: Dict[str, np.ndarray],
        nums: Dict[str, np.ndarray],
        n: int,
    ) -> np.ndarray:
        """Boolean allowed-mask [n] over rows with the given columns.

        A referenced column that a segment doesn't carry matches no row
        (absent metadata can't satisfy a predicate on it)."""
        raise NotImplementedError

    def __and__(self, other: "Filter") -> "And":
        return And((self, other))

    def __or__(self, other: "Filter") -> "Or":
        return Or((self, other))


@dataclass(frozen=True)
class TagIn(Filter):
    """``column ∈ values`` over an int tag column."""

    column: str
    values: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "values", tuple(sorted(int(v) for v in self.values))
        )

    def evaluate(self, tags, nums, n):
        col = tags.get(self.column)
        if col is None:
            return np.zeros(n, bool)
        return np.isin(col[:n], np.asarray(self.values, np.int64))


@dataclass(frozen=True)
class NumRange(Filter):
    """``lo ≤ column ≤ hi`` over a float numeric column (bounds
    inclusive; omit one for a half-open range)."""

    column: str
    lo: float = -np.inf
    hi: float = np.inf

    def __post_init__(self):
        object.__setattr__(self, "lo", float(self.lo))
        object.__setattr__(self, "hi", float(self.hi))

    def evaluate(self, tags, nums, n):
        col = nums.get(self.column)
        if col is None:
            return np.zeros(n, bool)
        col = col[:n]
        return (col >= self.lo) & (col <= self.hi)


@dataclass(frozen=True)
class And(Filter):
    """Conjunction of clauses."""

    clauses: Tuple[Filter, ...]

    def __post_init__(self):
        object.__setattr__(self, "clauses", tuple(self.clauses))

    def evaluate(self, tags, nums, n):
        out = np.ones(n, bool)
        for c in self.clauses:
            out &= c.evaluate(tags, nums, n)
        return out


@dataclass(frozen=True)
class Or(Filter):
    """Disjunction of clauses."""

    clauses: Tuple[Filter, ...]

    def __post_init__(self):
        object.__setattr__(self, "clauses", tuple(self.clauses))

    def evaluate(self, tags, nums, n):
        out = np.zeros(n, bool)
        for c in self.clauses:
            out |= c.evaluate(tags, nums, n)
        return out


# ------------------------------------------------------- request / result
@dataclass
class SearchRequest:
    """One search: the vector plus every per-request knob.

    This is the canonical request shape across the whole serving surface
    (``ServingFrontend.submit`` / ``ServingScheduler.submit`` /
    ``HarmonyServer.search_batch``); bare ``np.ndarray`` queries are
    still accepted everywhere and auto-wrapped (with a
    ``DeprecationWarning``) for pre-request-API call sites.

    * ``vector`` — [D] (or [NQ, D] for batch entry points) float32.
    * ``k`` — top-k override (None → the serving default).
    * ``filter`` — a :class:`Filter` metadata predicate, or None.
    * ``hybrid_text`` — lexical query text; when set, BM25 scores are
      fused with the vector top-k by reciprocal-rank fusion
      (:mod:`repro.core.fusion`).
    * ``precision`` — "fp32" | "int8" override, or None for the server's
      configured tier.
    * ``deadline`` — absolute clock time after which the caller no longer
      wants an answer. Enforced per request by the scheduler and
      front-end: a request whose deadline passed before dispatch is shed
      with the sentinel degradation path (ids -1, +inf scores, counted in
      ``stats.expired_requests``) instead of executed; cache hits honor
      it trivially (they complete at arrival).
    """

    vector: np.ndarray
    k: Optional[int] = None
    filter: Optional[Filter] = None
    hybrid_text: Optional[str] = None
    precision: Optional[str] = None
    deadline: Optional[float] = None

    def options_key(self):
        """Hashable grouping key: requests with equal keys may be batched
        and executed together (the batch shares one filter/hybrid/precision
        context)."""
        return (self.filter, self.hybrid_text, self.precision)


@dataclass
class SearchResult:
    ids: np.ndarray                         # [NQ, K] int64 (original vector ids, -1 pad)
    scores: np.ndarray                      # [NQ, K] float32 (ascending; sq-L2 or -IP)
    stats: dict = field(default_factory=dict)


# ------------------------------------------------------------- data plane
class DataPlane:
    """The one write surface every serving layer exposes.

    ``upsert(ids, vecs, meta=None)`` / ``delete(ids)`` used to be
    copy-pasted forwarders on the engine, scheduler target, fleet, and
    frontend, each with its own drifting docstring. They are now all this
    mixin: a subclass implements ``_data_plane()`` (returning the next
    layer down — ultimately a :class:`repro.core.SegmentedIndex`) and
    optionally ``_note_write(kind, n)`` for its own accounting.

    Semantics (identical at every layer): ``upsert`` inserts or replaces
    whole rows by external id — ``meta`` is an optional dict of metadata
    columns (int columns become tags, float columns numerics, a ``"text"``
    entry of strings feeds the lexical scorer); ``delete`` tombstones ids
    and returns how many were actually live. Writes are immediately
    visible to subsequent searches.

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> from repro.core import SegmentedIndex
    >>> class Plane(DataPlane):
    ...     def __init__(self, data):
    ...         self.data, self.writes = data, 0
    ...     def _data_plane(self):
    ...         return self.data
    ...     def _note_write(self, kind, n):
    ...         self.writes += n
    >>> p = Plane(SegmentedIndex(HarmonyConfig(dim=4, nlist=2), ()))
    >>> p.upsert([7, 8], np.ones((2, 4), np.float32), meta={"tag": [1, 2]})
    >>> p.delete([7, 99])
    1
    >>> p.writes
    4
    """

    def _data_plane(self):
        """The layer writes forward to (override)."""
        raise NotImplementedError

    def _root_data_plane(self):
        """Follow ``_data_plane()`` to the bottom of the stack — ultimately
        the shared :class:`repro.core.SegmentedIndex`. The serving-side
        query cache reads its ``(generation, op_count)`` epoch here, so
        writes and compaction commits invalidate cached answers no matter
        which layer performed them (frontend, scheduler target, fleet, or
        the plane directly)."""
        obj = self
        for _ in range(8):                  # defensive depth bound
            if not isinstance(obj, DataPlane):
                break
            obj = obj._data_plane()
        return obj

    def _note_write(self, kind: str, n: int) -> None:
        """Accounting hook: ``kind`` is "upsert" | "delete", ``n`` the
        number of id rows the caller passed (the historical counter
        semantics — ``delete`` still *returns* the actually-live count).
        Default: no-op."""

    def upsert(self, ids, vecs, meta=None) -> None:
        n = len(np.asarray(ids, np.int64).reshape(-1))
        self._data_plane().upsert(ids, vecs, meta)
        self._note_write("upsert", n)

    def delete(self, ids) -> int:
        n = len(np.asarray(ids, np.int64).reshape(-1))
        removed = self._data_plane().delete(ids)
        self._note_write("delete", n)
        return removed
