"""Flexible pipelined execution engine — TPU-target SPMD path (§4.3).

The paper's MPI pipeline (Fig. 5(b)) maps onto the device mesh as a
**dimension ring**: the mesh is (``pod`` ×) ``data`` × ``model``; device
(v, b) owns dimension block b of vector shard v. Query groups' partial
accumulators rotate around the ``model`` axis with ``lax.ppermute`` — at
ring stage t, device (v, b) scores dimension block b for query group
(b − t − offset_v) mod B, adds into the received accumulator, prunes
against the group's travelling τ, and forwards. After B stages every
group has visited every dimension block. ``offset_v`` staggers ring
starts across shards (the paper's load-aware deferred-block schedule).

Billion-scale feasibility: a shard's rows are streamed in chunks
(``lax.scan``), each chunk running one full dimension ring; a per-group
running top-K (and its τ = kth best) tightens between chunks — the
vector-level pipeline of Fig. 5(a). Accumulator memory is O(QG × chunk),
not O(QG × cap).

Exactness: identical guarantees to the host engine — pruning uses monotone
partial sums against a valid upper bound τ; results equal the oracle's
top-k over probed clusters.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_compat
from repro.core.index import IVFIndex, ShardedCorpus, dim_block_bounds
from repro.kernels import ops as kops


@dataclass(frozen=True)
class SpmdConfig:
    """Static geometry of the SPMD search step."""

    v_shards: int          # data-axis size (vector shards per pod)
    d_blocks: int          # model-axis size (dimension blocks)
    n_pods: int = 1        # pod-axis size (corpus super-shards)
    qb: int = 64           # queries per step (per pod; replicated over pods)
    cap: int = 1024        # padded rows per shard
    dim: int = 128         # padded to d_blocks * db
    nprobe: int = 8
    k: int = 10
    chunk: int = 512       # candidate rows scored per ring pass
    metric: str = "l2"
    prune: bool = True
    x_dtype: str = "float32"    # bf16 halves corpus HBM traffic (accum stays f32)
    precision: str = "fp32"     # "int8" → quantized stage-1 scoring tier
    use_pallas: bool = True     # False → pure-jnp scoring (dry-run / CPU bench)
    tile_m: int = 128
    tile_n: int = 128
    tile_k: int = 128
    axis_pod: str = "pod"
    axis_data: str = "data"
    axis_model: str = "model"

    @property
    def qg(self) -> int:
        assert self.qb % self.d_blocks == 0, (self.qb, self.d_blocks)
        return self.qb // self.d_blocks

    @property
    def db(self) -> int:
        assert self.dim % self.d_blocks == 0, (self.dim, self.d_blocks)
        return self.dim // self.d_blocks

    @property
    def n_chunks(self) -> int:
        assert self.cap % self.chunk == 0, (self.cap, self.chunk)
        return self.cap // self.chunk

    def __post_init__(self):
        assert self.precision in ("fp32", "int8"), self.precision
        if self.precision == "int8":
            # the shared-grid quantized difference form is L2-only
            assert self.metric == "l2", (self.precision, self.metric)


# ---------------------------------------------------------------------------
# Host-side input packaging
# ---------------------------------------------------------------------------


def build_corpus_arrays(corpus: ShardedCorpus, scfg: SpmdConfig,
                        quant: Optional["Int8Quant"] = None):
    """Pack the sharded corpus into the step's device-resident arrays.

    These are the batch-invariant inputs — the serving executor uploads
    them to the mesh ONCE and reuses them across every served batch.

    Shapes (global, to be sharded by the step's in_shardings):
      x_blocks   [V, cap, D_pad]      f32 | int8 codes  (rows→data, dims→model)
      xn2_blocks [B, V, cap]          f32   (block norms; B→model, V→data)
      cluster_ids[V, cap]             i32
      row_ids    [V, cap]             i32
      scale2     [B]                  f32   (int8 only: s² per dim block)

    With ``precision="int8"`` the resident corpus is the 1-byte codes of a
    per-dimension-block affine grid (4× smaller than fp32), ``xn2_blocks``
    carries the pre-scaled s²·Σcode² norms, and the grid's (scale, zero)
    come from ``quant`` — the segment's seal-time :class:`Int8Quant` —
    when its blocking matches this mesh, else are fit to this layout.
    Padded rows *and* padded dims are encoded as literal 0.0 on the same
    grid queries use, so padding contributes exactly 0 to every distance.
    """
    V, B = scfg.v_shards, scfg.d_blocks
    cap, D = scfg.cap, scfg.dim
    assert corpus.plan.v_shards == V
    xs = corpus.x_shard
    assert xs.shape[1] <= cap, (xs.shape, cap)

    cluster_ids = np.full((V, cap), -1, np.int32)
    cluster_ids[:, : xs.shape[1]] = corpus.cluster_shard
    row_ids = np.full((V, cap), -1, np.int32)
    row_ids[:, : xs.shape[1]] = corpus.ids_shard.astype(np.int32)

    if scfg.precision == "int8":
        xf = np.zeros((V, cap, D), np.float32)
        xf[:, : xs.shape[1], : xs.shape[2]] = xs
        bounds = dim_block_bounds(D, B)
        scale, zero = _mesh_quant_grid(xs, corpus.valid, scfg, quant)
        codes = np.empty((V, cap, D), np.int8)
        xn2_blocks = np.zeros((B, V, cap), np.float32)
        for b, (lo, hi) in enumerate(bounds):
            qb = np.rint((xf[:, :, lo:hi] - zero[b]) / scale[b])
            cb = np.clip(qb, -127, 127).astype(np.int8)
            codes[:, :, lo:hi] = cb
            c32 = cb.astype(np.int32)
            xn2_blocks[b] = (scale[b] ** 2) * np.sum(c32 * c32, axis=2)
        return dict(
            x_blocks=codes,
            xn2_blocks=xn2_blocks,
            cluster_ids=cluster_ids,
            row_ids=row_ids,
            scale2=(scale.astype(np.float32) ** 2),
            # host-only: the grid queries must be encoded on (callers pop
            # this before uploading the dict to the mesh)
            quant_grid=(scale, zero),
        )

    import ml_dtypes

    xdt = np.float32 if scfg.x_dtype == "float32" else ml_dtypes.bfloat16
    x_blocks = np.zeros((V, cap, D), xdt)
    x_blocks[:, : xs.shape[1], : xs.shape[2]] = xs.astype(xdt)

    xn2_blocks = np.zeros((B, V, cap), np.float32)
    if xdt is np.float32 and corpus.xnorm2_blk.shape[1] == B:
        # reuse the per-block norms preassign already materialized (zero
        # padding — rows or dims — does not change block norms)
        xn2_blocks[:, :, : xs.shape[1]] = np.moveaxis(corpus.xnorm2_blk, 0, 1)
    else:
        # dtype cast (or a different block split) changes the norms
        bounds = dim_block_bounds(D, B)
        for b, (lo, hi) in enumerate(bounds):
            seg = x_blocks[:, :, lo:hi]
            xn2_blocks[b] = np.sum(seg * seg, axis=2)
    return dict(
        x_blocks=x_blocks,
        xn2_blocks=xn2_blocks,
        cluster_ids=cluster_ids,
        row_ids=row_ids,
    )


def _mesh_quant_grid(xs: np.ndarray, valid: np.ndarray, scfg: SpmdConfig,
                     quant: Optional["Int8Quant"]):
    """(scale [B], zero [B]) for this mesh's dimension blocking.

    Reuses the seal-time grid when its per-block dim ranges coincide with
    the mesh blocking (the common case: ``quant_blocks == d_blocks`` and
    minimal dim padding); otherwise fits a fresh grid to the shard
    layout's valid rows — a deterministic function of the corpus, so
    every replica derives identical codes."""
    B, db = scfg.d_blocks, scfg.db
    if (quant is not None and quant.d_blocks == B
            and -(-quant.codes.shape[1] // B) == db):
        return quant.scale.copy(), quant.zero.copy()
    scale = np.ones(B, np.float32)
    zero = np.zeros(B, np.float32)
    rows = xs[valid[:, : xs.shape[1]]] if valid.size else xs.reshape(-1, xs.shape[2])
    for b, (lo, hi) in enumerate(dim_block_bounds(scfg.dim, B)):
        blk = rows[:, lo:min(hi, rows.shape[1])]
        mn = float(blk.min()) if blk.size else 0.0
        mx = float(blk.max()) if blk.size else 0.0
        zero[b] = 0.5 * (mn + mx)
        scale[b] = max((mx - mn) / 254.0, 1e-8)
    return scale, zero


def build_query_arrays(
    q: np.ndarray, scfg: SpmdConfig, probes: np.ndarray, tau0: np.ndarray,
    quant_grid: Optional[Tuple[np.ndarray, np.ndarray]] = None,
):
    """Pack one query batch into the step's per-batch arrays, padded to the
    static ``scfg.qb`` shape.

      queries    [QB, D_pad]          f32 | int8 codes   (dims→model)
      probes     [QB, P]              i32   (replicated)
      tau0       [QB]                 f32   (replicated)

    With ``precision="int8"``, queries are encoded on the corpus's grid
    (``quant_grid`` = (scale [B], zero [B]) of the resident codes) —
    out-of-range query values clip, padded rows/dims encode literal 0.0
    exactly like the corpus padding, so padding cancels in the quantized
    difference."""
    qb, D = scfg.qb, scfg.dim
    queries = np.zeros((qb, D), np.float32)
    nq = min(q.shape[0], qb)
    queries[:nq, : q.shape[1]] = q[:nq]
    if scfg.precision == "int8":
        assert quant_grid is not None, "int8 queries need the corpus grid"
        scale, zero = quant_grid
        codes = np.empty((qb, D), np.int8)
        for b, (lo, hi) in enumerate(dim_block_bounds(D, scfg.d_blocks)):
            c = np.rint((queries[:, lo:hi] - zero[b]) / scale[b])
            codes[:, lo:hi] = np.clip(c, -127, 127).astype(np.int8)
        queries = codes
    probes_pad = np.zeros((qb, probes.shape[1]), np.int32)
    probes_pad[:nq] = probes[:nq]
    probes_pad[nq:] = -2                      # match nothing
    tau_pad = np.full((qb,), -np.inf, np.float32)
    tau_pad[:nq] = tau0[:nq]
    return dict(queries=queries, probes=probes_pad, tau0=tau_pad)


def build_spmd_inputs(
    index: IVFIndex, corpus: ShardedCorpus, q: np.ndarray, scfg: SpmdConfig,
    probes: np.ndarray, tau0: np.ndarray,
):
    """Corpus + query-batch packing in one call (one-shot example path)."""
    quant = (index.int8_quant(scfg.d_blocks)
             if scfg.precision == "int8" else None)
    corpus_arrays = build_corpus_arrays(corpus, scfg, quant=quant)
    grid = corpus_arrays.pop("quant_grid", None)
    return {
        **corpus_arrays,
        **build_query_arrays(q, scfg, probes, tau0, quant_grid=grid),
    }


def corpus_shardings(scfg: SpmdConfig, mesh: Mesh):
    """NamedShardings of the batch-invariant (device-resident) arrays."""
    ap = scfg.axis_pod if scfg.n_pods > 1 else None
    ad, am = scfg.axis_data, scfg.axis_model
    # the pod axis shards extra vector shards: x arrays carry a leading pod dim
    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    if scfg.n_pods > 1:
        out = dict(
            x_blocks=ns(ap, ad, None, am),
            xn2_blocks=ns(ap, am, ad, None),
            cluster_ids=ns(ap, ad, None),
            row_ids=ns(ap, ad, None),
        )
    else:
        out = dict(
            x_blocks=ns(ad, None, am),
            xn2_blocks=ns(am, ad, None),
            cluster_ids=ns(ad, None),
            row_ids=ns(ad, None),
        )
    if scfg.precision == "int8":
        out["scale2"] = ns(am)      # one s² per dimension block
    return out


def query_shardings(scfg: SpmdConfig, mesh: Mesh):
    """NamedShardings of the per-batch arrays."""
    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    return dict(
        queries=ns(None, scfg.axis_model),
        probes=ns(None, None),
        tau0=ns(None),
    )


def input_shardings(scfg: SpmdConfig, mesh: Mesh):
    return {**corpus_shardings(scfg, mesh), **query_shardings(scfg, mesh)}


def input_specs(scfg: SpmdConfig):
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    V, B, cap, D = scfg.v_shards, scfg.d_blocks, scfg.cap, scfg.dim
    lead = (scfg.n_pods,) if scfg.n_pods > 1 else ()
    f32, i32 = jnp.float32, jnp.int32
    int8 = scfg.precision == "int8"
    xdt = jnp.int8 if int8 else jnp.dtype(scfg.x_dtype)
    out = dict(
        x_blocks=jax.ShapeDtypeStruct(lead + (V, cap, D), xdt),
        xn2_blocks=jax.ShapeDtypeStruct(lead + (B, V, cap), f32),
        cluster_ids=jax.ShapeDtypeStruct(lead + (V, cap), i32),
        row_ids=jax.ShapeDtypeStruct(lead + (V, cap), i32),
        queries=jax.ShapeDtypeStruct((scfg.qb, D), jnp.int8 if int8 else f32),
        probes=jax.ShapeDtypeStruct((scfg.qb, scfg.nprobe), i32),
        tau0=jax.ShapeDtypeStruct((scfg.qb,), f32),
    )
    if int8:
        out["scale2"] = jax.ShapeDtypeStruct((B,), f32)
    return out


# ---------------------------------------------------------------------------
# The SPMD step
# ---------------------------------------------------------------------------


def _score_chunk_update(scfg: SpmdConfig, x_c, xn2_c, qrows, qn2, acc, tau):
    """One (group, chunk, block) partial update — Pallas or jnp ref."""
    if scfg.use_pallas:
        out, skip = kops.partial_distance_update(
            x_c, xn2_c, qrows, qn2, acc, tau,
            prune=scfg.prune, metric=scfg.metric,
            tile_m=scfg.tile_m, tile_n=scfg.tile_n, tile_k=scfg.tile_k,
        )
        return out, skip.sum(), skip.size
    from repro.kernels import ref

    out = ref.partial_distance_update_ref(
        x_c, xn2_c, qrows, qn2, acc, tau, prune=scfg.prune, metric=scfg.metric
    )
    skip = kops._tile_skip_map(acc, scfg.tile_m, scfg.tile_n)
    return out, skip.sum(), skip.size


def _score_chunk_update_int8(scfg: SpmdConfig, x_c, xn2_c, qrows, qn2, s2,
                             acc, tau):
    """int8 variant: codes in, int32 MXU accumulation, f32 combine."""
    if scfg.use_pallas:
        out, skip = kops.int8_partial_distance_update(
            x_c, xn2_c, qrows, qn2, s2, acc, tau,
            prune=scfg.prune,
            tile_m=scfg.tile_m, tile_n=scfg.tile_n, tile_k=scfg.tile_k,
        )
        return out, skip.sum(), skip.size
    from repro.kernels import ref

    out = ref.int8_partial_distance_update_ref(
        x_c, xn2_c, qrows, qn2, s2, acc, tau, prune=scfg.prune
    )
    skip = kops._tile_skip_map(acc, scfg.tile_m, scfg.tile_n)
    return out, skip.sum(), skip.size


def gather_local_candidates(rows, x_blk, xn2_blk, cluster_ids, row_ids):
    """Device-side gather of probed-cluster candidates into a padded static
    buffer (the serving executor's per-batch candidate set).

    ``rows`` [cap_b] int32 indexes this shard's resident rows; -1 = pad.
    Pad slots re-read row 0 but get cluster id -1, so they match no probe
    and their accumulator stays +inf (excluded exactly like corpus padding).
    """
    cap_full = x_blk.shape[0]
    keep = rows >= 0
    safe = jnp.clip(rows, 0, cap_full - 1)
    x_c = jnp.take(x_blk, safe, axis=0)
    xn2_c = jnp.where(keep, jnp.take(xn2_blk, safe, axis=0), 0.0)
    cl_c = jnp.where(keep, jnp.take(cluster_ids, safe, axis=0), -1)
    id_c = jnp.where(keep, jnp.take(row_ids, safe, axis=0), -1)
    return x_c, xn2_c, cl_c, id_c


def gather_host_candidates(arrays: dict, rows: np.ndarray) -> dict:
    """Host-side analogue of :func:`gather_local_candidates` for
    host-tier (cold) segments: gather the probed clusters' rows out of
    the host-resident packed corpus into per-batch candidate arrays
    ready to stream to the mesh.

    ``arrays`` is :func:`build_corpus_arrays`'s dict kept host-side
    (int8 codes stream 4× less PCIe traffic than fp32 rows — the cold
    tier's preferred precision); ``rows`` [V, cap_b] int32 indexes each
    shard's packed rows, -1 = pad. Pad slots re-read row 0 but get
    cluster id -1 and zero norms, so — exactly like the device-side
    gather — they match no probe and never enter a top-K.

    Returns ``dict(x_c [V, cap_b, D], xn2_c [B, V, cap_b],
    cl_c [V, cap_b], id_c [V, cap_b])`` with the same dtypes, block
    grids and axis layout the resident path uses, so the streamed step
    runs the identical ring kernels over them.
    """
    x_blocks, xn2_blocks = arrays["x_blocks"], arrays["xn2_blocks"]
    cl, rid = arrays["cluster_ids"], arrays["row_ids"]
    V = cl.shape[0]
    keep = rows >= 0
    safe = np.where(keep, rows, 0)
    vi = np.arange(V)[:, None]
    x_c = np.ascontiguousarray(x_blocks[vi, safe])
    xn2_c = np.where(keep[None], xn2_blocks[:, vi, safe], 0.0).astype(np.float32)
    cl_c = np.where(keep, cl[vi, safe], -1).astype(np.int32)
    id_c = np.where(keep, rid[vi, safe], -1).astype(np.int32)
    return dict(x_c=x_c, xn2_c=xn2_c, cl_c=cl_c, id_c=id_c)


def ring_chunk_search(scfg: SpmdConfig, x_blk, xn2_blk, cluster_ids, row_ids,
                      q_blk, probes, tau0, scale2=None):
    """Per-device ring search core (call under shard_map).

    Inputs are this device's local, already-squeezed arrays:
      x_blk [cap, db], xn2_blk [cap], cluster_ids/row_ids [cap],
      q_blk [qb, db], probes [qb, P], tau0 [qb].
    Runs the chunked dimension-ring scan (Pallas partial-distance with
    tile-granular early-stop, ppermute rotation, running top-K with τ
    tightening between chunks) and merges results across the mesh axes.
    Returns replicated (scores [qb, K], ids [qb, K], stats [2]).

    ``precision="int8"``: x_blk/q_blk carry int8 codes, xn2_blk the
    pre-scaled s²·Σcode² norms, and ``scale2`` this device's scalar s².
    The ring then computes *quantized* L2 — still monotone over dimension
    blocks, so the travelling-τ pruning and running top-K stay exact
    within the quantized metric (the fp32 re-rank happens host-side in
    the executor).
    """
    B, QG, K = scfg.d_blocks, scfg.qg, scfg.k
    chunk, n_chunks = scfg.chunk, scfg.n_chunks

    b_idx = jax.lax.axis_index(scfg.axis_model)
    v_idx = jax.lax.axis_index(scfg.axis_data)
    offset = v_idx % B
    g_home = (b_idx - offset) % B          # resident group of this device

    # per-group local state: this device accumulates results for g_home
    probes_home = jax.lax.dynamic_slice_in_dim(probes, g_home * QG, QG, 0)
    tau_home0 = jax.lax.dynamic_slice_in_dim(tau0, g_home * QG, QG, 0)

    run_scores0 = jnp.full((QG, K), jnp.inf, jnp.float32)
    run_ids0 = jnp.full((QG, K), -1, jnp.int32)

    perm = [(i, (i + 1) % B) for i in range(B)]

    def outer(carry, c):
        run_scores, run_ids, skip_cnt, tile_cnt = carry
        row0 = c * chunk
        x_c = jax.lax.dynamic_slice_in_dim(x_blk, row0, chunk, 0)
        xn2_c = jax.lax.dynamic_slice_in_dim(xn2_blk, row0, chunk, 0)
        cl_c = jax.lax.dynamic_slice_in_dim(cluster_ids, row0, chunk, 0)
        id_c = jax.lax.dynamic_slice_in_dim(row_ids, row0, chunk, 0)

        # init acc for home group: 0 where probed, +inf otherwise
        mask = (probes_home[:, :, None] == cl_c[None, None, :]).any(axis=1)
        tau_home = jnp.minimum(tau_home0, run_scores[:, -1])
        acc0 = jnp.where(mask, 0.0, jnp.inf).astype(jnp.float32)

        def ring(rc, t):
            acc, tau_g, sk, tc = rc
            g = (b_idx - t - offset) % B
            qrows = jax.lax.dynamic_slice_in_dim(q_blk, g * QG, QG, 0)
            if scfg.precision == "int8":
                s2 = scale2.reshape(())
                # int32 code norms are exact; one f32 scale at the end
                qn2 = s2 * jnp.sum(
                    qrows.astype(jnp.int32) ** 2, axis=1
                ).astype(jnp.float32)
                acc, s_cnt, t_cnt = _score_chunk_update_int8(
                    scfg, x_c, xn2_c, qrows, qn2, s2, acc, tau_g
                )
            else:
                qn2 = jnp.sum(qrows.astype(jnp.float32) ** 2, axis=1)
                acc, s_cnt, t_cnt = _score_chunk_update(
                    scfg, x_c, xn2_c, qrows, qn2, acc, tau_g
                )
            if B > 1:
                acc = jax.lax.ppermute(acc, scfg.axis_model, perm)
                tau_g = jax.lax.ppermute(tau_g, scfg.axis_model, perm)
            return (acc, tau_g, sk + s_cnt, tc + t_cnt), None

        (acc, _, skip_cnt, tile_cnt), _ = jax.lax.scan(
            ring, (acc0, tau_home, skip_cnt, tile_cnt), jnp.arange(B)
        )
        # after B stages (and B ppermutes) the accumulator is home again;
        # merge the chunk into the running top-K (fused VMEM-resident kernel
        # on the Pallas path, concat+sort on the jnp path)
        id_b = jnp.broadcast_to(id_c[None, :], acc.shape)
        if scfg.use_pallas:
            run_scores, run_ids = kops.running_topk_update(
                acc, id_b, run_scores, run_ids, k=K
            )
        else:
            cat_s = jnp.concatenate([run_scores, acc], axis=1)
            cat_i = jnp.concatenate([run_ids, id_b], axis=1)
            neg, pos = jax.lax.top_k(-cat_s, K)
            run_scores = -neg
            run_ids = jnp.take_along_axis(cat_i, pos, axis=1)
        return (run_scores, run_ids, skip_cnt, tile_cnt), None

    (run_scores, run_ids, skip_cnt, tile_cnt), _ = jax.lax.scan(
        outer,
        (run_scores0, run_ids0, jnp.int32(0), jnp.int32(0)),
        jnp.arange(n_chunks),
    )

    # ---- gather groups across the model axis and restore group order
    gs = jax.lax.all_gather(run_scores, scfg.axis_model)   # [B, QG, K]
    gi = jax.lax.all_gather(run_ids, scfg.axis_model)
    src = (jnp.arange(B) + offset) % B                     # group g ← device g+offset
    gs = jnp.take(gs, src, axis=0).reshape(scfg.qb, K)
    gi = jnp.take(gi, src, axis=0).reshape(scfg.qb, K)

    # ---- merge across vector shards (data axis)
    if scfg.v_shards > 1:
        as_ = jax.lax.all_gather(gs, scfg.axis_data)       # [V, QB, K]
        ai = jax.lax.all_gather(gi, scfg.axis_data)
        as_ = jnp.moveaxis(as_, 0, 1).reshape(scfg.qb, -1)
        ai = jnp.moveaxis(ai, 0, 1).reshape(scfg.qb, -1)
        neg, pos = jax.lax.top_k(-as_, K)
        gs = -neg
        gi = jnp.take_along_axis(ai, pos, axis=1)

    # ---- merge across pods (corpus super-shards)
    if scfg.n_pods > 1:
        ps = jax.lax.all_gather(gs, scfg.axis_pod)
        pi = jax.lax.all_gather(gi, scfg.axis_pod)
        ps = jnp.moveaxis(ps, 0, 1).reshape(scfg.qb, -1)
        pi = jnp.moveaxis(pi, 0, 1).reshape(scfg.qb, -1)
        neg, pos = jax.lax.top_k(-ps, K)
        gs = -neg
        gi = jnp.take_along_axis(pi, pos, axis=1)

    stats = jnp.stack(
        [
            jax.lax.psum(skip_cnt, scfg.axis_model),
            jax.lax.psum(tile_cnt, scfg.axis_model),
        ]
    )
    stats = jax.lax.psum(stats, scfg.axis_data)
    if scfg.n_pods > 1:
        stats = jax.lax.psum(stats, scfg.axis_pod)
    return gs, gi, stats


def make_device_fn(scfg: SpmdConfig):
    """The per-device body, to be wrapped in shard_map: squeeze the leading
    sharded axes and run the ring search core over the full resident shard."""

    def device_fn(x_blk, xn2_blk, cluster_ids, row_ids, *rest):
        # shapes (per device):
        #   x_blk [1(,1), cap, db]  xn2_blk [1(,1)?, ...] — squeeze leading axes
        if scfg.precision == "int8":
            scale2, q_blk, probes, tau0 = rest
        else:
            scale2, (q_blk, probes, tau0) = None, rest
        x_blk = x_blk.reshape(scfg.cap, scfg.db)
        xn2_blk = xn2_blk.reshape(scfg.cap)
        cluster_ids = cluster_ids.reshape(scfg.cap)
        row_ids = row_ids.reshape(scfg.cap)
        q_blk = q_blk.reshape(scfg.qb, scfg.db)
        return ring_chunk_search(
            scfg, x_blk, xn2_blk, cluster_ids, row_ids, q_blk, probes, tau0,
            scale2=scale2,
        )

    return device_fn


def make_spmd_search(scfg: SpmdConfig, mesh: Mesh):
    """jit(shard_map(...)) search step over the mesh. Returns a callable
    (and the in_shardings dict for dry-run lowering)."""
    dev = make_device_fn(scfg)
    if scfg.n_pods > 1:
        corpus_specs = (
            P(scfg.axis_pod, scfg.axis_data, None, scfg.axis_model),
            P(scfg.axis_pod, scfg.axis_model, scfg.axis_data, None),
            P(scfg.axis_pod, scfg.axis_data, None),
            P(scfg.axis_pod, scfg.axis_data, None),
        )
    else:
        corpus_specs = (
            P(scfg.axis_data, None, scfg.axis_model),
            P(scfg.axis_model, scfg.axis_data, None),
            P(scfg.axis_data, None),
            P(scfg.axis_data, None),
        )
    if scfg.precision == "int8":
        corpus_specs = corpus_specs + (P(scfg.axis_model),)   # scale2 [B]
    in_specs = corpus_specs + (
        P(None, scfg.axis_model),
        P(None, None),
        P(None),
    )
    out_specs = (P(), P(), P())

    fn = shard_map_compat(
        dev, mesh=mesh, in_specs=in_specs, out_specs=out_specs
    )
    return jax.jit(fn)
