from repro.data.vectors import (
    VectorDataset,
    make_dataset,
    make_queries,
    brute_force_topk,
    recall_at_k,
)
from repro.data.tokens import TokenPipeline

__all__ = [
    "VectorDataset",
    "make_dataset",
    "make_queries",
    "brute_force_topk",
    "recall_at_k",
    "TokenPipeline",
]
