"""Synthetic vector corpora for ANNS experiments.

The container is offline, so the paper's open datasets (Sift1M, Msong, …)
are replaced by Gaussian-mixture corpora with the same controllable
properties the paper varies: size NB, dimensionality D, cluster count, and
*query skew* (fraction of queries hitting a small set of hot clusters —
the paper's Fig. 7 manipulates exactly this).

Everything is deterministic given a seed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp


@dataclass
class VectorDataset:
    """A corpus plus generation metadata."""

    x: np.ndarray                  # [NB, D] float32 base vectors
    centers: np.ndarray            # [C, D] mixture centers used for generation
    labels: np.ndarray             # [NB] generating component of each vector
    seed: int

    @property
    def nb(self) -> int:
        return int(self.x.shape[0])

    @property
    def dim(self) -> int:
        return int(self.x.shape[1])


def make_dataset(
    nb: int = 20_000,
    dim: int = 64,
    n_components: int = 32,
    spread: float = 0.25,
    seed: int = 0,
    component_weights: Optional[np.ndarray] = None,
) -> VectorDataset:
    """Gaussian-mixture corpus. ``spread`` controls intra-cluster stddev
    relative to unit-norm centers (small spread → easy pruning, like Star;
    large spread → hard pruning, like Glove — paper Table 3's variance)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_components, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    if component_weights is None:
        component_weights = np.full((n_components,), 1.0 / n_components)
    component_weights = np.asarray(component_weights, dtype=np.float64)
    component_weights = component_weights / component_weights.sum()
    labels = rng.choice(n_components, size=nb, p=component_weights)
    # Per-dim noise scaled by 1/sqrt(dim): total noise norm ≈ `spread`
    # regardless of D, so cluster contrast (inter-center distance ≈ √2 vs
    # intra-cluster spread) matches real embedding corpora at any dim.
    # Per-point lognormal radius gives the smooth distance continuum real
    # corpora show (varying local density) — without it distances are
    # bi-level (χ² concentration) and pruning curves look nothing like the
    # paper's Table 3.
    radius = spread * np.exp(0.5 * rng.normal(size=(nb, 1)))
    noise = (radius / np.sqrt(dim)) * rng.normal(size=(nb, dim))
    x = centers[labels] + noise.astype(np.float32)
    return VectorDataset(x=x.astype(np.float32), centers=centers, labels=labels, seed=seed)


def make_queries(
    ds: VectorDataset,
    nq: int = 256,
    skew: float = 0.0,
    hot_fraction: float = 0.125,
    noise: float = 0.25,
    seed: int = 1,
    tail_fraction: float = 0.0,
) -> np.ndarray:
    """Queries drawn near corpus components.

    ``skew`` ∈ [0,1]: probability mass routed to the ``hot_fraction`` hottest
    components. skew=0 → uniform workload; skew→1 → all queries hit a few
    components (paper Fig. 7's imbalanced loads).
    """
    rng = np.random.default_rng(seed)
    c = ds.centers.shape[0]
    n_hot = max(1, int(round(hot_fraction * c)))
    p = np.full((c,), (1.0 - skew) / c, dtype=np.float64)
    p[:n_hot] += skew / n_hot
    p /= p.sum()
    comp = rng.choice(c, size=nq, p=p)
    # Queries are perturbed *corpus points* of the chosen component (the
    # standard held-out-sample methodology of Sift1M etc.), not component
    # centers — centers sit at the densest spot and make pruning look
    # artificially weak.
    # ``tail_fraction``>0 draws sources from the furthest-from-center
    # fraction of each component — boundary queries whose true neighbors
    # straddle several IVF lists, giving the gradual recall-vs-nprobe
    # curves of real corpora.
    radius = np.linalg.norm(ds.x - ds.centers[ds.labels], axis=1)
    q = np.empty((nq, ds.dim), np.float32)
    for i, ci in enumerate(comp):
        rows = np.nonzero(ds.labels == ci)[0]
        if len(rows) == 0:
            rows = np.arange(ds.nb)
        if tail_fraction > 0:
            order = rows[np.argsort(radius[rows])]
            n_tail = max(1, int(tail_fraction * len(rows)))
            rows = order[-n_tail:]
        src = rows[rng.integers(len(rows))]
        q[i] = ds.x[src]
    jitter = (noise / np.sqrt(ds.dim)) * rng.normal(size=(nq, ds.dim))
    q = q + jitter.astype(np.float32)
    return q.astype(np.float32)


def brute_force_topk(
    x: np.ndarray, q: np.ndarray, k: int, metric: str = "l2"
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact top-k ground truth. Returns (indices [NQ,k], scores [NQ,k]).

    Scores are squared-L2 (ascending) or negative inner product (so that
    smaller is always better, matching the search engine's convention).
    """
    xj = jnp.asarray(x)
    qj = jnp.asarray(q)
    if metric == "l2":
        d = (
            jnp.sum(qj * qj, axis=1)[:, None]
            - 2.0 * qj @ xj.T
            + jnp.sum(xj * xj, axis=1)[None, :]
        )
    elif metric == "ip":
        d = -(qj @ xj.T)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    import jax

    neg, idx = jax.lax.top_k(-d, k)  # top_k is max-k; negate for min-k
    return np.asarray(idx), np.asarray(-neg)


def recall_at_k(pred_idx: np.ndarray, true_idx: np.ndarray) -> float:
    """Standard recall@k: |pred ∩ true| / k averaged over queries."""
    assert pred_idx.shape == true_idx.shape
    nq, k = pred_idx.shape
    hits = 0
    for i in range(nq):
        hits += len(set(pred_idx[i].tolist()) & set(true_idx[i].tolist()))
    return hits / (nq * k)
