"""Deterministic synthetic token pipeline for LM training.

Produces sharded `(tokens, targets)` batches without touching disk or
network. The stream is a stateless function of (seed, step, position), so:

* every data-parallel host slices the same logical global batch — the
  pipeline is *elastic* (resuming with a different DP size yields the same
  global stream), and
* restart-after-failure is exact: the step index is the only state.

Sequences are Zipf-distributed token ids with short-range structure
(a copy-and-shift process) so a small LM has learnable signal — loss drops
measurably within a few hundred steps, which examples/train_lm.py asserts.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def global_batch_at(self, step: int) -> np.ndarray:
        """Full global batch [global_batch, seq_len+1] of int32 (inputs+shifted)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        b, s = self.global_batch, self.seq_len + 1
        # Zipfian marginals, clipped to vocab.
        raw = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tok = np.minimum(raw, self.vocab_size - 1).astype(np.int32)
        # Inject copy structure: second half repeats first half for a subset
        # of rows — gives the model an in-context pattern to learn.
        half = s // 2
        copy_rows = rng.random(b) < 0.5
        tok[copy_rows, half : 2 * half] = tok[copy_rows, :half]
        return tok

    def shard_at(self, step: int, dp_rank: int, dp_size: int) -> np.ndarray:
        """This host's slice of the global batch (contiguous row block)."""
        assert self.global_batch % dp_size == 0, (self.global_batch, dp_size)
        per = self.global_batch // dp_size
        g = self.global_batch_at(step)
        return g[dp_rank * per : (dp_rank + 1) * per]

    def batch_for_step(self, step: int, dp_rank: int = 0, dp_size: int = 1):
        """Returns dict(tokens=[b, S], targets=[b, S]) for the step."""
        chunk = self.shard_at(step, dp_rank, dp_size)
        return {"tokens": chunk[:, :-1], "targets": chunk[:, 1:]}
