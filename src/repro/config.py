"""Configuration dataclasses for the HARMONY framework.

Two config families:

* :class:`HarmonyConfig` — the paper's ANNS system (index, partition plan
  search space, cost-model weights, pruning/pipeline switches).
* :class:`ModelConfig` — the assigned LM architecture pool (dense / MoE /
  SSM / hybrid / audio / VLM backbones) plus training/serving knobs.

Everything is a frozen dataclass so configs are hashable and can key jit
caches. ``repro.configs`` registers one ModelConfig per assigned arch.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# ANNS (the paper's own system)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HarmonyConfig:
    """Config for the HARMONY distributed ANNS engine."""

    dim: int = 128                  # vector dimensionality D
    nlist: int = 64                 # number of IVF clusters
    nprobe: int = 8                 # probed clusters per query
    topk: int = 10                  # K of top-K search
    metric: str = "l2"              # "l2" | "ip" (inner product / cosine on normalized)

    # Partition plan search space: factorizations (B_vec, B_dim) of n_devices.
    max_dim_blocks: int = 8         # upper bound on B_dim the planner may pick
    alpha: float = 1.0              # imbalance weight α in C(π,Q)

    # Pipeline / pruning switches (Mode in the paper's CLI):
    #   "harmony" (hybrid adaptive), "vector", "dimension"
    mode: str = "harmony"
    enable_pruning: bool = True
    prewarm_samples: int = 4        # vectors per probed cluster used to seed τ
    query_block: int = 32           # vector-level pipeline batch size

    # Kernel tiling (MXU-aligned on TPU; interpret-mode on CPU).
    tile_n: int = 128               # candidate tile
    tile_q: int = 128               # query tile
    tile_d: int = 128               # dimension-block inner tile

    # Two-stage int8 search tier (precision="int8"):
    quant_blocks: int = 4           # dimension blocks per int8 scale/zero grid
    rerank_factor: int = 4          # stage-1 keeps k·rerank_factor candidates

    # Selectivity-aware probe widening for filtered search: when the
    # allowed fraction of live rows drops below ``filter_widen_threshold``,
    # nprobe scales by ~threshold/selectivity (candidates thin out
    # linearly with selectivity, so the probe budget must widen to keep
    # recall) up to ``filter_widen_cap`` × nprobe. 0 disables widening.
    filter_widen_threshold: float = 0.2
    filter_widen_cap: float = 4.0

    # k-means training
    kmeans_iters: int = 12
    kmeans_seed: int = 0

    def replace(self, **kw) -> "HarmonyConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# LM architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    # d_ff of each expert is ModelConfig.d_ff when MoE is enabled.
    router_jitter: float = 0.0
    load_balance_loss: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture. Field names follow the assignment table."""

    name: str
    family: str                    # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads

    # attention flavor
    qkv_bias: bool = False                   # qwen1.5
    rope_theta: float = 10000.0
    rope_style: str = "rope"                 # rope | mrope (qwen2-vl) | none
    sliding_window: int = 0                  # >0 → local attention window
    local_global_ratio: int = 0              # gemma3: N local layers per 1 global
    attn_logit_softcap: float = 0.0

    # mlp flavor
    mlp: str = "swiglu"                      # swiglu | gelu
    # norms
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embed: bool = False                # gemma-style sqrt(d) embed scale

    # MoE
    moe: MoEConfig = field(default_factory=MoEConfig)

    # SSM / hybrid
    ssm_state: int = 0                       # mamba2 state size (zamba2)
    ssm_conv: int = 4
    ssm_expand: int = 2
    xlstm_slstm_every: int = 0               # xlstm: 1-in-N blocks are sLSTM
    hybrid_attn_every: int = 0               # zamba2: shared attn block period

    # modality frontend stubs
    frontend: str = "none"                   # none | audio_frames | vision_patches
    encoder_only: bool = False               # hubert

    # precision / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"                 # adamw | adafactor (1T-scale)
    remat: bool = True
    fsdp_params: bool = False                # shard params over data axis too
    # layers folded into one scan step (pattern unit for mixed stacks)
    scan_unit: int = 1

    # which of the 4 assigned shapes apply (see DESIGN.md skip policy)
    supports_decode: bool = True
    supports_long_context: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """Shape cells that apply to an arch per DESIGN.md's skip policy."""
    out = []
    for s in SHAPES:
        if s.kind == "decode" and (cfg.encoder_only or not cfg.supports_decode):
            continue
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(s)
    return tuple(out)
