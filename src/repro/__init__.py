"""repro: HARMONY distributed ANNS + multi-arch LM framework on JAX/TPU.

The paper's primary contribution lives in ``repro.core`` (multi-granularity
partitioning, monotonic dimension-level pruning, cost-model planner, ring
pipeline). Substrates: ``repro.models``, ``repro.train``, ``repro.serve``,
``repro.data``, ``repro.checkpoint``, ``repro.runtime``, ``repro.sharding``,
``repro.kernels`` (Pallas), ``repro.launch`` (mesh / dry-run / drivers).
"""

__version__ = "0.1.0"
