"""Sharding rules: logical-parameter-name → mesh PartitionSpec.

TP over ``model``; DP (+FSDP where ``cfg.fsdp_params``) over ``data`` and
``pod``; MoE experts over ``data`` (EP). Decode caches shard batch over
(pod, data) and KV-heads over ``model`` when divisible, else the sequence
axis (GSPMD then lowers the softmax statistics to cross-shard reduces —
flash-decode); batch-1 long-context cells shard the sequence axis over
every available mesh axis.

All rules operate on *trailing* dims — leading unit/local stacking axes
are padded with None automatically.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeSpec


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _pad(spec: Sequence, ndim: int) -> P:
    spec = list(spec)
    assert len(spec) <= ndim, (spec, ndim)
    return P(*([None] * (ndim - len(spec)) + spec))


def _sanitize(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (jit in_shardings
    requires divisible argument dims — e.g. hubert's vocab of 504)."""
    out = []
    for dim, s in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(s if dim % size == 0 else None)
    return P(*out)


def _ns(mesh: Mesh, spec: P, shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(mesh, _sanitize(spec, shape, mesh))


def _base_param_spec(name: str, parent: str, ndim: int, cfg: ModelConfig):
    """Trailing-dims spec for one parameter leaf."""
    fsdp = "data" if cfg.fsdp_params else None
    if parent == "moe":
        if name in ("w1", "w3"):
            return ("data", None, "model")
        if name == "w2":
            return ("data", "model", None)
        if name == "router":
            return (None, None)
    if name == "embed":
        # tied embeddings double as the LM head → vocab must be sharded so
        # logits come out vocab-sharded; untied tables shard d_model.
        return ("model", None) if cfg.tie_embeddings else (None, "model")
    if name == "lm_head":
        return (fsdp, "model")
    if name in ("wq", "wk", "wv", "w1", "w3", "w_up", "w_in"):
        return (fsdp, "model")
    if name in ("wo", "w2", "w_down"):
        return ("model", fsdp)
    if name in ("bq", "bk", "bv"):
        return ("model",)
    if name == "conv":
        return (None, "model")
    if name == "r":                      # sLSTM recurrent kernel [H, hd, 4hd]
        return (None, None, "model")
    # norms, gates, scalars (ln*, norm, A_log, D, dt_bias, final_norm, w_if)
    return ()


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh):
    """Pytree (matching params) of NamedSharding. ``params_shape`` may be
    the real params or a jax.eval_shape result."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        # adafactor factored stats mirror their parameter's spec minus a dim
        if name in ("vr", "vc"):
            pname = names[-2]
            pparent = names[-3] if len(names) > 2 else ""
            base = list(_base_param_spec(pname, pparent, leaf.ndim + 1, cfg))
            full = [None] * (leaf.ndim + 1 - len(base)) + base
            spec = full[:-1] if name == "vr" else full[:-2] + full[-1:]
            return _ns(mesh, P(*spec), leaf.shape)
        if name == "v" and parent not in ("", "moe"):
            # unfactored adafactor slot: mirror the param itself
            pname, pparent = names[-2], names[-3] if len(names) > 2 else ""
            base = _base_param_spec(pname, pparent, leaf.ndim, cfg)
            return _ns(mesh, _pad(base, leaf.ndim), leaf.shape)
        base = _base_param_spec(name, parent, leaf.ndim, cfg)
        return _ns(mesh, _pad(base, leaf.ndim), leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def opt_shardings(opt_shape, params_shape, cfg: ModelConfig, mesh: Mesh):
    """AdamW mu/nu mirror params; adafactor handled by name rules above."""
    rep = NamedSharding(mesh, P())

    def leaf_spec(path, leaf):
        names = _path_names(path)
        if names[-1] in ("step", "gnorm"):
            return rep
        # strip the leading "mu"/"nu"/"v" container and apply param rules
        name = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        if name in ("vr", "vc"):
            pname = names[-2]
            pparent = names[-3] if len(names) > 2 else ""
            base = list(_base_param_spec(pname, pparent, leaf.ndim + 1, cfg))
            full = [None] * (leaf.ndim + 1 - len(base)) + base
            spec = full[:-1] if name == "vr" else full[:-2] + full[-1:]
            return _ns(mesh, P(*spec), leaf.shape)
        if name == "v":
            pname = names[-2]
            pparent = names[-3] if len(names) > 2 else ""
            base = _base_param_spec(pname, pparent, leaf.ndim, cfg)
            return _ns(mesh, _pad(base, leaf.ndim), leaf.shape)
        base = _base_param_spec(name, parent, leaf.ndim, cfg)
        return _ns(mesh, _pad(base, leaf.ndim), leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, opt_shape)


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """Shardings for the train/prefill input batch dict."""
    ba = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    bspec = ba if shape.global_batch % bsz == 0 and shape.global_batch >= bsz else None

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    out = {"tokens": ns(bspec, None), "targets": ns(bspec, None)}
    if cfg.frontend == "audio_frames":
        out = {"frames": ns(bspec, None, None), "targets": ns(bspec, None),
               "loss_mask": ns(bspec, None)}
    if cfg.rope_style == "mrope":
        out["positions"] = ns(None, bspec, None)
    return out


def cache_shardings(cfg: ModelConfig, cache_shape, shape: ShapeSpec, mesh: Mesh):
    """Shardings for the decode cache pytree (from jax.eval_shape)."""
    ba = batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    B = shape.global_batch
    b_ok = B % bsz == 0 and B >= bsz
    model_size = mesh.shape["model"]
    all_axes = ba + ("model",)

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v") and nd >= 4:
            # [..., B, S, KV, hd]
            lead = nd - 4
            KV = leaf.shape[-2]
            S = leaf.shape[-3]
            if b_ok:
                bs = ba
                kv_spec = "model" if KV % model_size == 0 else None
                s_spec = None if kv_spec else ("model" if S % model_size == 0 else None)
            else:
                bs = None
                # batch-1 long context: shard the sequence over everything
                s_spec = all_axes if S % (bsz * model_size) == 0 else "model"
                kv_spec = None
            spec = [None] * lead + [bs, s_spec, kv_spec, None]
            return NamedSharding(mesh, P(*spec))
        if name == "pos" and nd >= 2:
            lead = nd - 2
            return NamedSharding(mesh, P(*([None] * lead + [ba if b_ok else None, None])))
        # recurrent states: find the batch dim == B, shard trailing big dims
        if nd >= 3:
            # heuristics per state kind
            shape_l = leaf.shape
            spec = [None] * nd
            try:
                bdim = next(i for i, s in enumerate(shape_l) if s == B and i >= 1)
            except StopIteration:
                bdim = None
            if b_ok and bdim is not None:
                spec[bdim] = ba
            # shard the largest trailing dim over model if divisible
            for i in range(nd - 1, max(nd - 3, 0), -1):
                if i != bdim and shape_l[i] % model_size == 0 and shape_l[i] >= model_size:
                    spec[i] = "model"
                    break
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)
