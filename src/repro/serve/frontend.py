"""Real-clock asynchronous serving front-end — live traffic through the
same admission queue, adaptive batch former, and deadline/shed accounting
that the virtual-clock scheduler replays deterministically.

This is the ROADMAP's "real-clock front-end": BatANN-style, an async
driver that overlaps replica execution for real instead of only on the
simulated clock. The split of responsibilities:

* :class:`ServingFrontend` (here) — owns the wall clock
  (:class:`repro.serve.clock.MonotonicClock`), a bounded admission queue,
  the batch-forming triggers (the *same* ``next_fire`` policy the
  scheduler uses: size / deadline / capacity), a dispatcher thread that
  fires due batches, and a thread pool that executes up to
  ``max_inflight`` batches concurrently;
* the :class:`repro.serve.scheduler.DispatchTarget` — owns running one
  batch (``execute_wall``): a :class:`~repro.serve.scheduler.SingleServerTarget`
  serializes on its server; a :class:`repro.serve.fleet.ReplicaFleet`
  routes by live load estimates and runs the batch on the chosen replica
  concurrently with other in-flight batches (per-replica locks, atomic
  EWMA accounting, optional wall-clock straggler hedging).

Requests are submitted live — :meth:`ServingFrontend.submit` returns a
``concurrent.futures.Future`` resolving to a
:class:`~repro.serve.scheduler.RequestResult`; :meth:`~ServingFrontend.asubmit`
is the asyncio twin. Backpressure sheds by failing the future with
:class:`ShedError` (and counting it), never by blocking the submitter.

The virtual-clock replay (:class:`~repro.serve.scheduler.ServingScheduler`)
remains the test oracle for the shared queue/deadline/shed logic —
``tests/test_virtual_clock_goldens.py`` pins it bit-for-bit.

>>> import numpy as np
>>> from repro.config import HarmonyConfig
>>> from repro.core import build_ivf
>>> from repro.serve import HarmonyServer, SchedulerConfig, ServingFrontend
>>> rng = np.random.default_rng(0)
>>> x = rng.standard_normal((256, 8)).astype(np.float32)
>>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3, kmeans_iters=2)
>>> srv = HarmonyServer(build_ivf(x, cfg), n_nodes=2)
>>> with ServingFrontend(srv, SchedulerConfig(max_batch=4, max_wait_s=1e-3),
...                      k=3) as fe:
...     futs = fe.submit_many(x[:8])            # live submission
...     ids = [f.result(timeout=30).ids for f in futs]
>>> len(ids), ids[0].shape
(8, (3,))
>>> fe.stats.admitted, fe.stats.shed
(8, 0)
"""

from __future__ import annotations

import asyncio
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Deque, List, Optional, Tuple

import numpy as np

import time
import warnings

from repro.core.types import DataPlane, SearchRequest
from repro.serve.cache import QueryCache, build_query_cache
from repro.serve.clock import Clock, MonotonicClock
from repro.serve.scheduler import (
    DispatchTarget,
    Request,
    RequestResult,
    SchedulerConfig,
    SingleServerTarget,
    SkewMonitor,
    next_fire,
)


class ShedError(RuntimeError):
    """A request was rejected by admission control (bounded queue full).

    Delivered through the submitted future — ``future.result()`` (or
    ``await asubmit(...)``) raises it; the request was counted in
    ``stats.shed`` and never queued."""


class ServingFrontend(DataPlane):
    """Live (wall-clock) admission-controlled serving front-end.

    Parameters mirror :class:`~repro.serve.scheduler.ServingScheduler`:
    pass a ``HarmonyServer`` (wrapped in a ``SingleServerTarget``) or any
    ``DispatchTarget`` — in particular a
    :class:`repro.serve.fleet.ReplicaFleet`, whose replicas then execute
    concurrently on the front-end's thread pool.

    ``max_inflight`` bounds concurrently executing batches (default: the
    target's ``parallelism`` — 1 for a single server, the live replica
    count for a fleet). ``service_time_fn(n_queries) -> seconds`` (single
    server only) pads each batch's wall to a service model by sleeping —
    used by benchmarks/tests to model remote-replica service time on one
    box; fleets take the per-replica model in their own constructor.

    Lifecycle: the dispatcher thread starts immediately; use as a context
    manager or call :meth:`shutdown`. :meth:`drain` blocks until queue and
    in-flight batches are empty (firing still-queued batches immediately
    rather than waiting out their deadlines).

    All timestamps are seconds on ``clock`` (default
    :class:`~repro.serve.clock.MonotonicClock`, epoch ≈ construction
    time); ``stats`` durations are milliseconds (see
    :meth:`repro.serve.engine.ServeStats.summary`).
    """

    def __init__(
        self,
        server,
        cfg: Optional[SchedulerConfig] = None,
        k: Optional[int] = None,
        max_inflight: Optional[int] = None,
        service_time_fn=None,
        clock: Optional[Clock] = None,
        on_batch=None,
    ):
        self.cfg = cfg or SchedulerConfig()
        if isinstance(server, DispatchTarget):
            if service_time_fn is not None:
                raise ValueError(
                    "service_time_fn belongs to the target when a "
                    "DispatchTarget is passed (construct it with one)"
                )
            self.target = server
        else:
            self.target = SingleServerTarget(
                server, service_time_fn=service_time_fn
            )
        self.server = getattr(self.target, "server", self.target)
        self.stats = self.target.stats
        self.clock: Clock = clock or MonotonicClock()
        self.k = k or self.target.default_k
        self.max_batch = self.cfg.max_batch or self.target.default_max_batch
        assert self.max_batch >= 1
        self.max_inflight = int(max_inflight or self.target.parallelism)
        assert self.max_inflight >= 1
        self.on_batch = on_batch
        self.target.configure(self.cfg, self.k)
        self._skew = SkewMonitor(self.cfg, self.target)
        self._skew_mu = threading.Lock()

        # semantic cache + in-flight coalescing (repro.serve.cache):
        # inert when cfg.cache is None/disabled. Followers of an in-flight
        # leader never enter the queue — they attach to its execution and
        # resolve when it completes.
        self.cache = build_query_cache(self.cfg, self.target, self.stats)
        self._coalesce = self.cache is not None and self.cfg.cache.coalesce
        self._leaders: dict = {}                   # cache key -> leader rid
        self._followers: dict = {}                 # leader rid -> [(Request, Future)]
        self._rid_key: dict = {}                   # leader rid -> cache key

        self._mu = threading.Condition()
        self.queue: Deque[Request] = deque()       # same shape the shared
        self._futures: dict = {}                   # next_fire policy reads
        self._inflight = 0
        self._closing = False
        self._draining = 0
        self._next_id = 0
        self._batch_id = 0
        self._served = 0
        self.first_arrival_s: Optional[float] = None
        self.last_done_s = 0.0
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_inflight, thread_name_prefix="harmony-serve"
        )
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="harmony-dispatch", daemon=True
        )
        self._dispatcher.start()

    # ---------------------------------------------------------------- admit
    def submit(self, query) -> "Future[RequestResult]":
        """Offer one request at the current wall time. ``query`` is a
        :class:`repro.core.SearchRequest` (the canonical shape — its
        filter/hybrid/precision/k ride with the request) or a bare [D]
        array, auto-wrapped with a ``DeprecationWarning``. Returns a
        future that resolves to its :class:`RequestResult` — or raises
        :class:`ShedError` from the future if backpressure shed it.
        Raises ``RuntimeError`` immediately if the front-end is shut
        down."""
        if not isinstance(query, SearchRequest):
            warnings.warn(
                "submitting a bare ndarray is deprecated; pass a "
                "repro.core.SearchRequest",
                DeprecationWarning, stacklevel=2,
            )
            query = SearchRequest(vector=np.asarray(query))
        fut: "Future[RequestResult]" = Future()
        shed_exc = None
        ready: Optional[RequestResult] = None
        with self._mu:
            if self._closing:
                raise RuntimeError("ServingFrontend is shut down")
            arrival_s = self.clock.now()
            self.stats.offered += 1
            rid = self._next_id
            self._next_id += 1
            if self.first_arrival_s is None:
                self.first_arrival_s = arrival_s
            vec = np.asarray(query.vector)
            k_r = query.k or self.k
            options = (query.filter, query.hybrid_text, query.precision)
            key = (QueryCache.request_key(vec, k_r, options)
                   if self.cache is not None else None)
            hit = None
            leader = (self._leaders.get(key)
                      if self._coalesce and key is not None else None)
            if query.deadline is not None and arrival_s > query.deadline:
                # deadline already blown: sentinel degradation (PR 7
                # shape), never queued — checked before the cache so even
                # a cached answer is refused
                self.stats.expired_requests += 1
                ready = RequestResult(
                    req_id=rid,
                    ids=np.full(k_r, -1, np.int64),
                    scores=np.full(k_r, np.inf, np.float32),
                    arrival_s=arrival_s, dispatch_s=arrival_s,
                    done_s=arrival_s, batch_id=-1,
                )
            elif (self.cache is not None and (hit := self.cache.lookup(
                    vec, k_r, options, arrival_s)) is not None):
                self._served += 1
                self.last_done_s = max(self.last_done_s, arrival_s)
                self.stats.queue_wait_ms.append(0.0)
                self.stats.request_latency_ms.append(0.0)
                ready = RequestResult(
                    req_id=rid, ids=hit.ids, scores=hit.scores,
                    arrival_s=arrival_s, dispatch_s=arrival_s,
                    done_s=arrival_s, batch_id=-1,
                )
            elif leader is not None:
                # coalesce: attach to the in-flight/queued duplicate's
                # execution instead of enqueueing again
                self.stats.coalesced += 1
                self._followers.setdefault(leader, []).append((Request(
                    rid, vec, arrival_s,
                    k=query.k, filter=query.filter,
                    hybrid_text=query.hybrid_text, precision=query.precision,
                    deadline=query.deadline,
                ), fut))
            elif (self.cfg.queue_capacity
                    and len(self.queue) >= self.cfg.queue_capacity):
                self.stats.shed += 1
                shed_exc = ShedError(
                    f"request {rid} shed: queue at capacity "
                    f"{self.cfg.queue_capacity}"
                )
            else:
                self.queue.append(Request(
                    rid, vec, arrival_s,
                    k=query.k, filter=query.filter,
                    hybrid_text=query.hybrid_text, precision=query.precision,
                    deadline=query.deadline,
                ))
                self._futures[rid] = fut
                self.stats.admitted += 1
                if self._coalesce and key is not None:
                    self._leaders[key] = rid
                    self._rid_key[rid] = key
                self._mu.notify_all()
        if shed_exc is not None:
            fut.set_exception(shed_exc)
        elif ready is not None:
            fut.set_result(ready)
        return fut

    def submit_many(self, queries) -> List["Future[RequestResult]"]:
        """Submit a sequence of single-query requests (arrays or
        :class:`SearchRequest`); one future each (shed requests come back
        as already-failed futures)."""
        return [self.submit(q) for q in queries]

    async def asubmit(self, query) -> RequestResult:
        """asyncio twin of :meth:`submit`: ``await`` the result directly
        (raises :class:`ShedError` if admission shed the request)."""
        return await asyncio.wrap_future(self.submit(query))

    # ----------------------------------------------------------- mutation
    # upsert()/delete() come from the DataPlane mixin and forward to the
    # dispatch target. Thread-safe against in-flight batches — a
    # dispatched batch keeps its snapshot; the write is visible to every
    # batch dispatched after the call returns.
    def _data_plane(self):
        return self.target

    # ----------------------------------------------------------- dispatcher
    def _due(self, now: float) -> Tuple[float, str]:
        """When may the queued requests dispatch, and why — the
        scheduler's shared :func:`~repro.serve.scheduler.next_fire`
        policy verbatim. The virtual scheduler gates on
        ``target.next_free_s()``; here the in-flight bound plays that
        role (checked by the caller), so the free-time argument is 0.
        While draining/closing, still-queued requests fire immediately
        instead of waiting out their deadline (trigger classification
        unchanged)."""
        fire_s, trigger = next_fire(self.queue, self.cfg, self.max_batch, 0.0)
        if self._closing or self._draining:
            return now, trigger
        return fire_s, trigger

    def _dispatch_loop(self) -> None:
        while True:
            with self._mu:
                while not self.queue and not self._closing:
                    self._mu.wait()
                if not self.queue:          # closing and drained
                    break
                now = self.clock.now()
                fire_s, trigger = self._due(now)
                if fire_s > now:
                    self._mu.wait(timeout=min(fire_s - now, 0.05))
                    continue
                if self._inflight >= self.max_inflight:
                    self._mu.wait(timeout=0.05)
                    continue
                batch = [
                    self.queue.popleft()
                    for _ in range(min(len(self.queue), self.max_batch))
                ]
                futs = [self._futures.pop(r.req_id) for r in batch]
                self._inflight += 1
                bid = self._batch_id
                self._batch_id += 1
                dispatch_s = now
            try:
                self._pool.submit(
                    self._run_batch, batch, futs, dispatch_s, trigger, bid
                )
            except RuntimeError:            # pool torn down mid-close
                with self._mu:
                    self._inflight -= 1
                    fols = self._detach_followers(batch)
                    self._mu.notify_all()
                for fut in futs:
                    fut.cancel()
                for fl in fols:
                    for _, f in fl:
                        f.cancel()

    def _detach_followers(self, batch) -> List[list]:
        """Pop each batch request's coalesced followers and release its
        leader registration (call under ``self._mu``). Returns one
        ``[(Request, Future), ...]`` list per batch row. After this, new
        duplicates start a fresh leader — no follower can attach to an
        already-completed execution."""
        fols = []
        for req in batch:
            key = self._rid_key.pop(req.req_id, None)
            if key is not None and self._leaders.get(key) == req.req_id:
                del self._leaders[key]
            fols.append(self._followers.pop(req.req_id, []))
        return fols

    def _sentinel(self, rid: int, k: int, arrival_s: float, stamp_s: float,
                  bid: int) -> RequestResult:
        return RequestResult(
            req_id=rid,
            ids=np.full(k, -1, np.int64),
            scores=np.full(k, np.inf, np.float32),
            arrival_s=arrival_s, dispatch_s=stamp_s, done_s=stamp_s,
            batch_id=bid,
        )

    def _run_batch(self, batch, futs, dispatch_s: float, trigger: str,
                   bid: int):
        # per-request deadline enforcement at dispatch: a request whose
        # absolute deadline passed while it queued degrades to the
        # sentinel shape (PR 7), never executes. Its coalesced followers
        # (who wanted the same answer) degrade with it.
        expired, exp_futs = [], []
        live, live_futs = [], []
        for req, fut in zip(batch, futs):
            if req.deadline is not None and dispatch_s > req.deadline:
                expired.append(req)
                exp_futs.append(fut)
            else:
                live.append(req)
                live_futs.append(fut)
        if expired:
            with self._mu:
                exp_fols = (self._detach_followers(expired)
                            if self._coalesce else [[] for _ in expired])
                self.stats.expired_requests += (
                    len(expired) + sum(len(f) for f in exp_fols)
                )
            for req, fut, fols in zip(expired, exp_futs, exp_fols):
                fut.set_result(self._sentinel(
                    req.req_id, req.k or self.k, req.arrival_s, dispatch_s,
                    bid,
                ))
                for freq, ffut in fols:
                    ffut.set_result(self._sentinel(
                        freq.req_id, freq.k or self.k, freq.arrival_s,
                        dispatch_s, bid,
                    ))
        batch, futs = live, live_futs
        if not batch:
            with self._mu:
                self._inflight -= 1
                self._mu.notify_all()
            if self.on_batch is not None:
                try:
                    self.on_batch(bid, self)
                except Exception as e:
                    warnings.warn(
                        f"on_batch callback failed on batch {bid}: {e!r}"
                    )
            return
        # epoch read before execution: cache entries from this batch are
        # stamped pre-execute, so a concurrent write that lands mid-batch
        # makes them count as already-stale (conservative)
        pre_epoch = self.cache.epoch() if self.cache is not None else None
        row_ids = row_scores = None
        err = None
        try:
            oldest_s = min(req.arrival_s for req in batch)
            # partition by request options (filter/hybrid/precision/k):
            # each group shares one execution context; the knob-free batch
            # is one group and one positional execute_wall call — the
            # pre-request-API behaviour
            groups = {}
            for row, req in enumerate(batch):
                groups.setdefault(req.options_key(), []).append(row)

            def _run_all():
                ids_out = [None] * len(batch)
                scores_out = [None] * len(batch)
                d_max = self.clock.now()
                for key, rows in groups.items():
                    queries = np.stack([batch[r].query for r in rows])
                    if key is None:
                        res, g_done = self.target.execute_wall(
                            queries, self.k, bid, self.clock
                        )
                    else:
                        res, g_done = self.target.execute_wall(
                            queries, key[0] or self.k, bid, self.clock,
                            key[1:],
                        )
                    d_max = max(d_max, g_done)
                    for i, r in enumerate(rows):
                        ids_out[r] = res.ids[i]
                        scores_out[r] = res.scores[i]
                return ids_out, scores_out, d_max

            # searches are idempotent reads: a batch whose dispatch raises
            # (replica crash past the fleet's own failover, torn target) is
            # re-issued with linear backoff while the oldest request's age
            # stays inside the per-request deadline budget
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    row_ids, row_scores, done_s = _run_all()
                    err = None
                    break
                except Exception as e:      # noqa: BLE001 - bounded retry
                    err = e
                    if attempt >= self.cfg.max_retries:
                        break
                    backoff = self.cfg.retry_backoff_s * (attempt + 1)
                    if (self.cfg.request_deadline_s > 0
                            and (self.clock.now() - oldest_s) + backoff
                            > self.cfg.request_deadline_s):
                        break   # budget spent: fail now, not later
                    with self._mu:
                        self.stats.retried_batches += 1
                    self.clock.sleep(backoff)
        except BaseException as e:          # noqa: BLE001 - relayed to futures
            err = e
        if err is not None:
            done_s = self.clock.now()
        if err is None and self.cache is not None:
            # store served answers before followers detach, so the next
            # duplicate (no longer coalescible) exact-hits instead
            for row, req in enumerate(batch):
                self.cache.insert(
                    req.query, req.k or self.k,
                    (req.filter, req.hybrid_text, req.precision),
                    row_ids[row], row_scores[row], done_s, epoch=pre_epoch,
                )
        with self._mu:
            self._inflight -= 1
            # followers resolve with their leader (success or error) —
            # detaching under the same lock submit() attaches with means
            # no follower can be orphaned
            fols = (self._detach_followers(batch)
                    if self._coalesce else [[] for _ in batch])
            n_fols = sum(len(f) for f in fols)
            if err is not None:
                # the batch is answered (with an error), the front-end
                # keeps serving — degradation, not collapse
                self.stats.failed_batches += 1
                self.stats.failed_requests += len(batch) + n_fols
            if err is None:
                if trigger == "full":
                    self.stats.full_batches += 1
                elif trigger == "capacity":
                    self.stats.capacity_batches += 1
                else:
                    self.stats.deadline_batches += 1
                for row, req in enumerate(batch):
                    self.stats.queue_wait_ms.append(
                        (dispatch_s - req.arrival_s) * 1e3
                    )
                    self.stats.request_latency_ms.append(
                        (done_s - req.arrival_s) * 1e3
                    )
                    for freq, _ffut in fols[row]:
                        # a follower may have attached after dispatch —
                        # it never queued, so its wait clamps at 0
                        self.stats.queue_wait_ms.append(
                            max(dispatch_s - freq.arrival_s, 0.0) * 1e3
                        )
                        self.stats.request_latency_ms.append(
                            max(done_s - freq.arrival_s, 0.0) * 1e3
                        )
                self._served += len(batch) + n_fols
                self.last_done_s = max(self.last_done_s, done_s)
            self._mu.notify_all()
        # complete futures outside the lock: done-callbacks run inline
        if err is not None:
            for fut in futs:
                fut.set_exception(err)
            for fl in fols:
                for _, ffut in fl:
                    ffut.set_exception(err)
        else:
            for row, (req, fut) in enumerate(zip(batch, futs)):
                fut.set_result(
                    RequestResult(
                        req_id=req.req_id,
                        ids=row_ids[row],
                        scores=row_scores[row],
                        arrival_s=req.arrival_s,
                        dispatch_s=dispatch_s,
                        done_s=done_s,
                        batch_id=bid,
                    )
                )
                for freq, ffut in fols[row]:
                    ffut.set_result(
                        RequestResult(
                            req_id=freq.req_id,
                            ids=row_ids[row],
                            scores=row_scores[row],
                            arrival_s=freq.arrival_s,
                            dispatch_s=dispatch_s,
                            done_s=done_s,
                            batch_id=bid,
                        )
                    )
            try:
                with self._skew_mu:         # serialized hot-mass check
                    self._skew.after_batch()
            except Exception as e:          # results already delivered —
                warnings.warn(              # surface, don't lose, the error
                    f"skew-replan check failed on batch {bid}: {e!r}"
                )
        if self.on_batch is not None:
            try:
                self.on_batch(bid, self)
            except Exception as e:
                warnings.warn(f"on_batch callback failed on batch {bid}: {e!r}")

    # ------------------------------------------------------------ lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is empty and no batch is in flight,
        firing still-queued batches immediately. Returns False if
        ``timeout`` (seconds) expired first. The timeout is measured on
        real time (``time.monotonic``), not ``self.clock`` — waiting is
        real even if a non-wall clock was injected."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._mu:
            self._draining += 1
            self._mu.notify_all()
            try:
                while self.queue or self._inflight:
                    wait_s = 0.05
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            return False
                        wait_s = min(wait_s, remaining)
                    self._mu.wait(timeout=wait_s)
                return True
            finally:
                self._draining -= 1
                self._mu.notify_all()

    def shutdown(self, wait: bool = True,
                 timeout: Optional[float] = None) -> bool:
        """Graceful stop: refuse new submissions, then (``wait=True``)
        drain queued and in-flight work before tearing the pool down.
        With ``wait=False``, queued requests are cancelled and in-flight
        batches finish in the background. If ``timeout`` expires while
        draining, remaining in-flight batches are likewise left to finish
        in the background rather than blocking past the timeout.
        Idempotent.

        Returns ``True`` once everything is down (work resolved, the
        dispatcher thread joined) — the same contract as
        :meth:`repro.serve.compactor.Compactor.stop`. ``False`` means
        something was left running in the background: an unexpired drain
        timeout, or a dispatcher thread that outlived its join (also
        recorded in ``stats.shutdown_leaks``)."""
        drained = True
        with self._mu:
            already = self._closing
            self._closing = True
            if not wait:
                dropped = []
                for r in self.queue:
                    dropped.append(self._futures.pop(r.req_id, None))
                    # queued leaders take their coalesced followers down
                    # with them (in-flight leaders still resolve theirs)
                    for fl in self._detach_followers([r]):
                        dropped.extend(f for _, f in fl)
                self.queue.clear()
            self._mu.notify_all()
        if not wait:
            for fut in dropped:
                if fut is not None:
                    fut.cancel()
        elif not already:
            drained = self.drain(timeout)
        self._dispatcher.join(timeout=5.0)
        leaked = self._dispatcher.is_alive()
        if leaked:
            with self._mu:
                self.stats.shutdown_leaks += 1
        self._pool.shutdown(wait=wait and drained)
        return drained and not leaked

    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(wait=exc_type is None)

    # ------------------------------------------------------------ reporting
    @property
    def makespan_s(self) -> float:
        """First arrival → last completion, in wall seconds."""
        if self.first_arrival_s is None:
            return 0.0
        return max(self.last_done_s - self.first_arrival_s, 0.0)

    @property
    def served_qps(self) -> float:
        """Served requests per wall second of makespan."""
        return self._served / self.makespan_s if self.makespan_s > 0 else 0.0

    def summary(self) -> dict:
        """Admission/latency digest (`ServeStats.summary` keys — ms/counts)
        plus the front-end's wall-clock view: ``served`` requests,
        ``makespan_s`` (seconds), ``served_qps`` (requests per wall
        second), and the in-flight bound."""
        return {
            **self.stats.summary(),
            "served": self._served,
            "makespan_s": self.makespan_s,
            "served_qps": self.served_qps,
            "max_inflight": self.max_inflight,
        }
