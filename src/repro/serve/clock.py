"""Clock abstraction for the serving stack.

The scheduler's queue/deadline/shed logic is clock-agnostic: it asks
"what time is it" and (in the real-clock front-end) "wait until t".
Factoring that question behind a protocol lets the *same* admission
queue, batch former, and deadline accounting run in two modes:

* :class:`VirtualClock` — time is driven externally by request arrival
  timestamps; nothing ever sleeps. This is the deterministic replay
  harness (:class:`repro.serve.scheduler.ServingScheduler`) used by every
  test and virtual benchmark: batch composition and every counter depend
  only on the trace.
* :class:`MonotonicClock` — wall time from ``time.monotonic()``,
  rebased to 0 at construction so timestamps are small and directly
  comparable with virtual-clock traces. This is what the live
  front-end (:class:`repro.serve.frontend.ServingFrontend`) runs on.

Both expose seconds as ``float``; all serving timestamps in this repo
are seconds since the clock's epoch (first arrival ≈ 0).

>>> c = VirtualClock()
>>> c.now()
0.0
>>> c.advance_to(1.5); c.now()
1.5
>>> c.advance_to(1.0); c.now()   # virtual time never goes backwards
1.5
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Minimal clock surface the serving stack depends on."""

    def now(self) -> float:
        """Current time in seconds since the clock's epoch."""
        ...

    def sleep(self, dt: float) -> None:
        """Block for ``dt`` seconds (no-op on a virtual clock)."""
        ...


class VirtualClock:
    """Externally-driven simulation clock (the replay test oracle).

    ``now()`` returns the largest timestamp ever passed to
    :meth:`advance_to` — the scheduler advances it with each arrival
    timestamp, so replaying the same trace always produces the same
    virtual timeline. ``sleep`` is a no-op: virtual time only moves via
    the trace.
    """

    def __init__(self, start_s: float = 0.0):
        self.now_s = float(start_s)

    def now(self) -> float:
        return self.now_s

    def advance_to(self, t: float) -> None:
        """Move time forward to ``t`` (monotone: earlier t is ignored)."""
        if t > self.now_s:
            self.now_s = float(t)

    def sleep(self, dt: float) -> None:     # pragma: no cover - trivial
        pass


class MonotonicClock:
    """Wall clock over ``time.monotonic()``, epoch-rebased to 0.

    >>> c = MonotonicClock()
    >>> t0 = c.now(); c.sleep(0.001); c.now() >= t0
    True
    """

    def __init__(self):
        self._epoch = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._epoch

    def advance_to(self, t: float) -> None:
        """No-op: wall time advances itself (kept so scheduler code can
        drive either clock uniformly)."""

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)
