"""Asynchronous admission-controlled serving scheduler (the BatANN-style
dispatch layer on top of the HARMONY core).

The paper's throughput claims are won in this layer: requests arrive as
single queries with timestamps; the scheduler

* **admits** them into a bounded queue (backpressure: arrivals beyond the
  bound are shed and counted, never silently dropped);
* **forms batches adaptively** — a batch fires when either the size
  threshold (``max_batch``, default the engine's ``query_block``) is
  reached or the oldest queued request has waited ``max_wait_s`` (the
  deadline trigger that caps tail latency under slow arrivals);
* **routes skew-aware** — the hot-cluster concentration of the live
  arrival window (:func:`repro.core.router.workload_concentration` over
  :func:`estimate_cluster_hits`) is compared against the concentration the
  current plan was built for; drift past ``replan_drift`` triggers a
  cost-model re-plan (Fig. 7's skew adaptation, now online);
* **hedges stragglers** — batch dispatch optionally goes through
  :class:`repro.runtime.straggler.HedgingExecutor`, whose simulated
  effective latency is charged to the scheduler's virtual clock.

Time model: the scheduler runs on a *virtual clock* driven by request
arrival timestamps — the standard single-process simulation methodology
used by the benchmarks (see ``benchmarks/common.py``). Batch service time
is the measured ``search_batch`` wall by default, or an injected
``service_time_fn`` (tests use this to force deterministic backlog). The
queue/deadline/shed logic is exactly what a multi-host front-end would
run on real clocks.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.router import (
    DEFAULT_HOT_FRACTION,
    estimate_cluster_hits,
    workload_concentration,
)
from repro.runtime.straggler import HedgingExecutor


@dataclass(frozen=True)
class SchedulerConfig:
    """Knobs of the admission-controlled batch former."""

    max_batch: int = 0              # size trigger; 0 → server cfg.query_block
    max_wait_s: float = 2e-3        # deadline trigger for the oldest request
    queue_capacity: int = 0         # backpressure bound; 0 → unbounded
    replan_drift: float = 0.0       # hot-mass drift threshold; 0 → off
    hot_fraction: float = DEFAULT_HOT_FRACTION
    skew_window: int = 1024         # probe rows of the live arrival window
    min_batches_between_replans: int = 4
    hedge_deadline_s: float = 0.0   # straggler hedging; 0 → off
    backend: str = ""               # batch execution backend: "" → server
                                    # default; "host" | "spmd" to force


@dataclass
class Request:
    req_id: int
    query: np.ndarray               # [D]
    arrival_s: float


@dataclass
class RequestResult:
    req_id: int
    ids: np.ndarray                 # [K]
    scores: np.ndarray              # [K]
    arrival_s: float
    dispatch_s: float
    done_s: float
    batch_id: int

    @property
    def queue_wait_s(self) -> float:
        return self.dispatch_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s


class ServingScheduler:
    """Admission-controlled adaptive batcher over a ``HarmonyServer``.

    Usage: either drive it incrementally (``submit`` per arrival, then
    ``flush``) or replay a whole trace with :meth:`run_trace`. Arrival
    timestamps must be non-decreasing. ``on_batch(batch_idx, scheduler)``
    is invoked after every dispatched batch — tests use it to kill nodes
    mid-stream (the elastic invariant extends to scheduled serving).
    """

    def __init__(
        self,
        server,
        cfg: Optional[SchedulerConfig] = None,
        k: Optional[int] = None,
        service_time_fn: Optional[Callable[[int], float]] = None,
        latency_fn: Optional[Callable[[int, object], float]] = None,
        on_batch: Optional[Callable[[int, "ServingScheduler"], None]] = None,
    ):
        self.server = server
        self.cfg = cfg or SchedulerConfig()
        self.k = k or server.cfg.topk
        self.max_batch = self.cfg.max_batch or server.cfg.query_block
        assert self.max_batch >= 1
        self.service_time_fn = service_time_fn
        self.on_batch = on_batch
        self.queue: Deque[Request] = deque()
        self.done: List[RequestResult] = []
        self.busy_until = 0.0
        self.first_arrival_s: Optional[float] = None
        self._next_id = 0
        self._batch_id = 0
        self._batches_since_replan = 0
        # skew baseline: hot-mass of the workload the current plan was
        # built for (set lazily; re-synced after ANY re-plan, including
        # fail_node / replan_every ones done behind the scheduler's back)
        self._plan_hot: Optional[float] = None
        self._seen_replans = server.stats.replans
        if (self.cfg.backend or getattr(server, "backend", "host")) == "spmd":
            # pre-compile the executor's bucket ladder so no in-trace
            # dispatch charges a jit compile to the virtual clock (which
            # would distort queue-wait/shed statistics by seconds)
            server.executor.warmup(k=self.k)
        self._hedge: Optional[HedgingExecutor] = None
        if self.cfg.hedge_deadline_s > 0:
            # one worker slot per cluster node; every worker executes the
            # same search primitive, so the hedge target's answer is the
            # primary's answer (HARMONY's replica layout recomputes visits)
            self._hedge = HedgingExecutor(
                workers=[self._exec_task] * server.cluster.n_nodes,
                deadline_s=self.cfg.hedge_deadline_s,
                latency_fn=latency_fn or (lambda w, t: 0.0),
            )

    # ---------------------------------------------------------------- admit
    def submit(self, query: np.ndarray, arrival_s: float) -> int:
        """Offer one request. Returns its req_id, or -1 if shed by
        backpressure. Fires any batches due before ``arrival_s`` first.

        req_ids are consumed by shed requests too, so a served request's
        req_id is always its submission (trace) position — results map
        back to the trace even after shedding."""
        self.advance(arrival_s)
        stats = self.server.stats
        stats.offered += 1
        rid = self._next_id
        self._next_id += 1
        if self.first_arrival_s is None:
            self.first_arrival_s = arrival_s
        if self.cfg.queue_capacity and len(self.queue) >= self.cfg.queue_capacity:
            stats.shed += 1
            return -1
        self.queue.append(Request(rid, np.asarray(query), arrival_s))
        stats.admitted += 1
        return rid

    # ------------------------------------------------------------ batch form
    def _next_fire(self) -> Tuple[float, str]:
        """(virtual time at which the next batch can dispatch, trigger)."""
        if len(self.queue) >= self.max_batch:
            ready = self.queue[self.max_batch - 1].arrival_s
            trigger = "full"
        else:
            ready = self.queue[0].arrival_s + self.cfg.max_wait_s
            trigger = "deadline"
            if (self.cfg.queue_capacity
                    and len(self.queue) >= self.cfg.queue_capacity
                    and self.queue[-1].arrival_s < ready):
                # queue at its bound with the size trigger unreachable:
                # fire as soon as the server frees up instead of shedding
                # behind an idle server until the deadline
                ready = self.queue[-1].arrival_s
                trigger = "capacity"
        return max(ready, self.busy_until), trigger

    def advance(self, now: float):
        """Fire every batch whose dispatch time is ≤ ``now``."""
        while self.queue:
            dispatch_s, trigger = self._next_fire()
            if dispatch_s > now:
                break
            self._dispatch(dispatch_s, trigger)

    def flush(self) -> List[RequestResult]:
        """Drain the queue (deadlines fire naturally on the virtual clock)
        and return all results in request order."""
        self.advance(math.inf)
        return sorted(self.done, key=lambda r: r.req_id)

    # -------------------------------------------------------------- dispatch
    def _exec_task(self, task):
        queries, k = task
        return self.server.search_batch(
            queries, k, backend=self.cfg.backend or None
        )

    def _dispatch(self, dispatch_s: float, trigger: str):
        batch = [self.queue.popleft()
                 for _ in range(min(len(self.queue), self.max_batch))]
        queries = np.stack([r.query for r in batch])
        stats = self.server.stats

        t0 = time.perf_counter()
        sim_lat = 0.0
        if self._hedge is not None:
            # elastic scale-up (join_node) grows the cluster after init;
            # keep one worker slot per node so live indices stay valid
            while len(self._hedge.workers) < self.server.cluster.n_nodes:
                self._hedge.workers.append(self._exec_task)
            live = np.nonzero(self.server.cluster.live)[0]
            primary = int(live[self._batch_id % len(live)])
            replica = int(live[(self._batch_id + 1) % len(live)]) if len(live) > 1 else None
            hedged_before = self._hedge.stats.hedged
            res, _, sim_lat = self._hedge.run_timed((queries, self.k), primary, replica)
            if self._hedge.stats.hedged > hedged_before:
                stats.hedged_batches += 1
        else:
            res = self.server.search_batch(
                queries, self.k, backend=self.cfg.backend or None
            )
        wall = time.perf_counter() - t0
        service_s = (
            self.service_time_fn(len(batch)) if self.service_time_fn else wall
        ) + sim_lat
        done_s = dispatch_s + service_s
        self.busy_until = done_s

        if trigger == "full":
            stats.full_batches += 1
        elif trigger == "capacity":
            stats.capacity_batches += 1
        else:
            stats.deadline_batches += 1
        for row, req in enumerate(batch):
            stats.queue_wait_ms.append((dispatch_s - req.arrival_s) * 1e3)
            stats.request_latency_ms.append((done_s - req.arrival_s) * 1e3)
            self.done.append(
                RequestResult(
                    req_id=req.req_id,
                    ids=res.ids[row],
                    scores=res.scores[row],
                    arrival_s=req.arrival_s,
                    dispatch_s=dispatch_s,
                    done_s=done_s,
                    batch_id=self._batch_id,
                )
            )
        self._batch_id += 1
        self._batches_since_replan += 1
        self._maybe_replan_on_skew()
        if self.on_batch is not None:
            self.on_batch(self._batch_id - 1, self)

    # ------------------------------------------------------- skew adaptation
    def _window_hot_mass(self) -> Optional[float]:
        # walk the probe history from the newest batch back, taking only
        # enough arrays to cover the window (not the whole history)
        take, rows = [], 0
        for p in reversed(self.server._recent_probes):
            take.append(p)
            rows += p.shape[0]
            if rows >= self.cfg.skew_window:
                break
        if not take:
            return None
        window = np.concatenate(take[::-1], axis=0)[-self.cfg.skew_window:]
        hits = estimate_cluster_hits(window, self.server.index.nlist)
        return workload_concentration(hits, self.cfg.hot_fraction)

    def _maybe_replan_on_skew(self):
        if self.cfg.replan_drift <= 0:
            return
        if self.server.stats.replans != self._seen_replans:
            # the plan was rebuilt elsewhere (fail_node, replan_every):
            # re-baseline on the window that plan saw
            self._seen_replans = self.server.stats.replans
            self._plan_hot = self._window_hot_mass()
            self._batches_since_replan = 0
            return
        if self._plan_hot is None:
            # the initial plan was built from a uniform workload prior
            self._plan_hot = workload_concentration(
                np.ones(self.server.index.nlist), self.cfg.hot_fraction
            )
        if self._batches_since_replan < self.cfg.min_batches_between_replans:
            return
        hot = self._window_hot_mass()
        if hot is None:
            return
        if abs(hot - self._plan_hot) > self.cfg.replan_drift:
            self.server.refresh_plan()
            self.server.stats.skew_replans += 1
            self._plan_hot = hot
            self._seen_replans = self.server.stats.replans
            self._batches_since_replan = 0

    # ---------------------------------------------------------------- replay
    def run_trace(
        self, trace: Sequence[Tuple[float, np.ndarray]]
    ) -> List[RequestResult]:
        """Replay a whole (arrival_s, query)-trace and drain. Returns served
        results ordered by req_id; shed requests have no result (compare
        ``server.stats.shed``)."""
        for arrival_s, q in trace:
            self.submit(q, arrival_s)
        return self.flush()

    # ------------------------------------------------------------- reporting
    @property
    def makespan_s(self) -> float:
        """First arrival → last completion on the virtual clock."""
        if self.first_arrival_s is None:
            return 0.0
        return max(self.busy_until - self.first_arrival_s, 0.0)

    @property
    def served_qps(self) -> float:
        return len(self.done) / self.makespan_s if self.makespan_s > 0 else 0.0
