"""Asynchronous admission-controlled serving scheduler (the BatANN-style
dispatch layer on top of the HARMONY core).

The paper's throughput claims are won in this layer: requests arrive as
single queries with timestamps; the scheduler

* **admits** them into a bounded queue (backpressure: arrivals beyond the
  bound are shed and counted, never silently dropped);
* **forms batches adaptively** — a batch fires when either the size
  threshold (``max_batch``, default the engine's ``query_block``) is
  reached or the oldest queued request has waited ``max_wait_s`` (the
  deadline trigger that caps tail latency under slow arrivals);
* **routes skew-aware** — the hot-cluster concentration of the live
  arrival window (:func:`repro.core.router.workload_concentration` over
  :func:`estimate_cluster_hits`) is compared against the concentration the
  current plan was built for; drift past ``replan_drift`` triggers a
  cost-model re-plan (Fig. 7's skew adaptation, now online);
* **hedges stragglers** — batch dispatch optionally goes through
  :class:`repro.runtime.straggler.HedgingExecutor`, whose simulated
  effective latency is charged to the scheduler's virtual clock.

Batch formation is decoupled from execution: ``_dispatch`` hands every
formed batch to a pluggable :class:`DispatchTarget` —
:class:`SingleServerTarget` (one ``HarmonyServer``, built automatically
when the scheduler is handed a server) or
:class:`repro.serve.fleet.ReplicaFleet` (N replicas behind the same
admission queue, load-aware routing + cross-replica hedging).

Time model: the clock is factored behind :class:`repro.serve.clock.Clock`.
``ServingScheduler`` itself always runs the **virtual-clock replay**
(:class:`~repro.serve.clock.VirtualClock` driven by request arrival
timestamps) — the standard single-process simulation methodology used by
the benchmarks (see ``benchmarks/common.py``) and the repo's
deterministic test oracle (``tests/test_virtual_clock_goldens.py`` pins
its counters bit-for-bit). Batch service time is the measured
``search_batch`` wall by default, or an injected ``service_time_fn``
(tests use this to force deterministic backlog). The *same*
queue/deadline/shed logic runs against the wall clock in
:class:`repro.serve.frontend.ServingFrontend`, which dispatches formed
batches to a thread pool so fleet replicas overlap in real time.
"""

from __future__ import annotations

import math
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.router import (
    DEFAULT_HOT_FRACTION,
    estimate_cluster_hits,
    workload_concentration,
)
from repro.core.types import DataPlane, Filter, SearchRequest
from repro.runtime.straggler import HedgingExecutor
from repro.serve.cache import CacheConfig, build_query_cache, vec_bytes
from repro.serve.clock import Clock, VirtualClock


def options_kwargs(options) -> dict:
    """Expand a request-options tuple (``SearchRequest.options_key()``:
    filter, hybrid_text, precision) into ``search_batch`` keywords. None
    (the no-options fast path) expands to nothing."""
    if options is None:
        return {}
    flt, hybrid_text, precision = options
    return {"flt": flt, "hybrid_text": hybrid_text, "precision": precision}


@dataclass(frozen=True)
class SchedulerConfig:
    """Knobs of the admission-controlled batch former.

    Shared by the virtual-clock :class:`ServingScheduler` and the
    real-clock :class:`repro.serve.frontend.ServingFrontend` — the same
    config replayed virtually is the test oracle for a live run.

    All durations are **seconds**.

    >>> cfg = SchedulerConfig(max_batch=16, max_wait_s=2e-3,
    ...                       queue_capacity=64)
    >>> cfg.max_batch, cfg.queue_capacity
    (16, 64)
    """

    max_batch: int = 0              # size trigger; 0 → server cfg.query_block
    max_wait_s: float = 2e-3        # deadline trigger for the oldest request
    queue_capacity: int = 0         # backpressure bound; 0 → unbounded
    replan_drift: float = 0.0       # hot-mass drift threshold; 0 → off
    hot_fraction: float = DEFAULT_HOT_FRACTION
    skew_window: int = 1024         # probe rows of the live arrival window
    min_batches_between_replans: int = 4
    hedge_deadline_s: float = 0.0   # straggler hedging; 0 → off
    backend: str = ""               # batch execution backend: "" → server
                                    # default; "host" | "spmd" to force
    # graceful degradation (searches are idempotent reads, so re-issuing
    # a failed batch is always safe): a batch whose dispatch raises is
    # retried up to max_retries times with linear backoff, as long as the
    # oldest request's age stays inside request_deadline_s (0 → no
    # deadline budget). With max_retries=0 (default) failures propagate
    # exactly as before; with retries enabled, an exhausted batch
    # *degrades* instead of raising — placeholder results (ids -1,
    # +inf scores) and failed_batches/failed_requests counters.
    max_retries: int = 0
    retry_backoff_s: float = 1e-3
    request_deadline_s: float = 0.0
    # semantic cache + request coalescing in front of admission
    # (repro.serve.cache). None or CacheConfig(enabled=False) — the
    # default — keeps every admission path byte-identical to a cache-less
    # build (the virtual-clock goldens pin this).
    cache: Optional[CacheConfig] = None


@dataclass
class Request:
    """One admitted query with its arrival timestamp (seconds) and the
    per-request knobs carried in from its :class:`SearchRequest` (all
    None for pre-request-API submissions — the zero-overhead default)."""

    req_id: int
    query: np.ndarray               # [D]
    arrival_s: float
    k: Optional[int] = None
    filter: Optional[Filter] = None
    hybrid_text: Optional[str] = None
    precision: Optional[str] = None
    deadline: Optional[float] = None    # absolute; enforced at dispatch

    def options_key(self):
        """Grouping key for batch execution (see
        :meth:`repro.core.types.SearchRequest.options_key`), with the
        per-request ``k`` folded in. ``None`` for a knob-free request —
        the batch path that stays byte-identical to the pre-filter API."""
        if (self.k is None and self.filter is None
                and self.hybrid_text is None and self.precision is None):
            return None
        return (self.k, self.filter, self.hybrid_text, self.precision)


@dataclass
class RequestResult:
    """Per-request outcome: top-K ids/scores plus the three timeline
    points (all seconds on the scheduler's clock): ``arrival_s`` →
    ``dispatch_s`` (batch formed and handed to the target) → ``done_s``
    (batch completed)."""

    req_id: int
    ids: np.ndarray                 # [K]
    scores: np.ndarray              # [K]
    arrival_s: float
    dispatch_s: float
    done_s: float
    batch_id: int

    @property
    def queue_wait_s(self) -> float:
        return self.dispatch_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s


class DispatchTarget(DataPlane):
    """Execution side of the scheduler: where formed batches go.

    The scheduler owns admission, batch formation, and the clock;
    a target owns *running* the batch (which engine, which replica, which
    hedge policy) and reports the completion time back. Implementations:
    :class:`SingleServerTarget` here and
    :class:`repro.serve.fleet.ReplicaFleet`.

    The write surface (``upsert``/``delete``) is the shared
    :class:`repro.core.types.DataPlane` mixin — implementations point
    ``_data_plane()`` at the next layer down.

    The target also exposes the thin server-shaped surface the
    scheduler's skew adaptation needs (``stats`` for accounting,
    ``window_probes``/``nlist``/``refresh_plan``/``replans`` for the
    hot-mass drift trigger, ``default_max_batch``/``default_k`` for
    config defaults).
    """

    stats = None                    # ServeStats: admission/queue accounting

    def configure(self, cfg: SchedulerConfig, k: int) -> None:
        """Bind the scheduler's config (backend override, hedge deadline)
        and pre-warm compiled paths so no in-trace dispatch charges a jit
        compile to the virtual clock."""

    def next_free_s(self) -> float:
        """Earliest virtual time the target can start another batch."""
        raise NotImplementedError

    def execute(
        self, queries: np.ndarray, k: int, dispatch_s: float, batch_id: int,
        options=None,
    ):
        """Run one formed batch; returns ``(result, done_s)`` where
        ``done_s`` is the completion time on the virtual clock.
        ``options`` is a request-options tuple (filter, hybrid_text,
        precision) shared by the whole batch, or None (see
        :func:`options_kwargs`) — the scheduler only passes it when a
        batch actually carries options, so positional implementations
        predating the request API keep working."""
        raise NotImplementedError

    def execute_wall(
        self, queries: np.ndarray, k: int, batch_id: int, clock: Clock,
        options=None,
    ):
        """Real-clock batch execution for the live front-end: run the
        batch NOW and return ``(result, done_s)`` with ``done_s`` read
        from ``clock`` at completion.

        Default: delegate to :meth:`execute` with the current wall time
        as the dispatch stamp and re-stamp completion from the clock —
        correct for stub/virtual targets whose ``execute`` is synchronous;
        real targets override for thread-safe accounting and wall-enforced
        service models."""
        if options is None:
            res, _ = self.execute(queries, k, clock.now(), batch_id)
        else:
            res, _ = self.execute(queries, k, clock.now(), batch_id, options)
        return res, clock.now()

    def prefetch(self, queries: np.ndarray) -> None:
        """Advisory lookahead: the scheduler peeks the requests that will
        form the *next* batch and offers their vectors before running the
        current one, so a target serving host-tier segments can overlap
        their candidate upload with the in-flight batch's compute
        (:meth:`repro.serve.engine.HarmonyServer.prefetch_batch`). A
        wrong or ignored prefetch costs nothing but the hint. Default:
        no-op."""

    # --- skew-adaptation surface -----------------------------------------
    def window_probes(self) -> Iterable[np.ndarray]:
        """Probe arrays of recently executed batches, newest first."""
        raise NotImplementedError

    def refresh_plan(self) -> None:
        raise NotImplementedError

    @property
    def replans(self) -> int:
        raise NotImplementedError

    @property
    def nlist(self) -> int:
        raise NotImplementedError

    @property
    def default_max_batch(self) -> int:
        raise NotImplementedError

    @property
    def default_k(self) -> int:
        raise NotImplementedError

    @property
    def parallelism(self) -> int:
        """Batches the target can genuinely overlap on a real clock (the
        live front-end's default in-flight bound). 1 for a single
        server; the fleet reports its live replica count."""
        return 1


class SingleServerTarget(DispatchTarget):
    """One ``HarmonyServer`` behind the queue — the pre-fleet behaviour.

    Hedging here is *intra*-server: one worker slot per cluster node, the
    primary rotates over live nodes, and a hedge re-runs the batch on the
    next live node (every node executes the same search primitive, so the
    hedge target's answer is the primary's answer — HARMONY's replica
    layout recomputes visits). The hedge latency model is simulated, so
    it is charged to the virtual clock only; on the real clock
    (``execute_wall``) batches simply run back-to-back and cross-replica
    hedging belongs to the fleet.
    """

    def __init__(
        self,
        server,
        service_time_fn: Optional[Callable[[int], float]] = None,
        latency_fn: Optional[Callable[[int, object], float]] = None,
    ):
        self.server = server
        self.service_time_fn = service_time_fn
        self.latency_fn = latency_fn
        self.stats = server.stats
        self.busy_until = 0.0
        self._backend = ""
        self._hedge: Optional[HedgingExecutor] = None
        self._wall_mu = threading.Lock()    # serializes wall execution

    def configure(self, cfg: SchedulerConfig, k: int) -> None:
        self._backend = cfg.backend
        if (cfg.backend or getattr(self.server, "backend", "host")) == "spmd":
            # pre-compile the executors' bucket ladders (one per sealed
            # segment) so no in-trace dispatch charges a jit compile to
            # the virtual clock (which would distort queue-wait/shed
            # statistics by seconds)
            self.server.warmup_executors(k=k)
        if cfg.hedge_deadline_s > 0:
            self._hedge = HedgingExecutor(
                workers=[self._exec_task] * self.server.cluster.n_nodes,
                deadline_s=cfg.hedge_deadline_s,
                latency_fn=self.latency_fn or (lambda w, t: 0.0),
            )

    def next_free_s(self) -> float:
        return self.busy_until

    def prefetch(self, queries: np.ndarray) -> None:
        pf = getattr(self.server, "prefetch_batch", None)
        if pf is not None:
            pf(queries)

    def _exec_task(self, task):
        queries, k = task[:2]
        options = task[2] if len(task) > 2 else None
        return self.server.search_batch(
            queries, k, backend=self._backend or None,
            **options_kwargs(options),
        )

    def execute(self, queries, k, dispatch_s, batch_id, options=None):
        stats = self.server.stats
        t0 = time.perf_counter()
        sim_lat = 0.0
        if self._hedge is not None:
            # elastic scale-up (join_node) grows the cluster after init;
            # keep one worker slot per node so live indices stay valid
            while len(self._hedge.workers) < self.server.cluster.n_nodes:
                self._hedge.workers.append(self._exec_task)
            live = self.server.cluster.live_ids()
            primary = int(live[batch_id % len(live)])
            replica = (
                int(live[(batch_id + 1) % len(live)]) if len(live) > 1 else None
            )
            hedged_before = self._hedge.stats.hedged
            task = (queries, k) if options is None else (queries, k, options)
            res, _, sim_lat = self._hedge.run_timed(task, primary, replica)
            if self._hedge.stats.hedged > hedged_before:
                stats.hedged_batches += 1
        else:
            res = self.server.search_batch(
                queries, k, backend=self._backend or None,
                **options_kwargs(options),
            )
        wall = time.perf_counter() - t0
        service_s = (
            self.service_time_fn(queries.shape[0])
            if self.service_time_fn
            else wall
        ) + sim_lat
        self.busy_until = dispatch_s + service_s
        return res, self.busy_until

    def execute_wall(self, queries, k, batch_id, clock: Clock, options=None):
        """Wall-clock execution: one batch at a time on the server (the
        lock keeps ``ServeStats`` counters exact when the front-end is
        configured with in-flight > 1). With an injected
        ``service_time_fn`` the wall is padded by sleeping the shortfall —
        the real-clock analogue of the virtual service model (models a
        remote replica whose service time exceeds local compute)."""
        with self._wall_mu:
            t0 = clock.now()
            res = self.server.search_batch(
                queries, k, backend=self._backend or None,
                **options_kwargs(options),
            )
            if self.service_time_fn is not None:
                clock.sleep(
                    self.service_time_fn(queries.shape[0])
                    - (clock.now() - t0)
                )
            done_s = clock.now()
            self.busy_until = done_s
        return res, done_s

    # --- mutable-data-plane surface (DataPlane mixin): writes forward to
    # the server, whose own _note_write does the counting
    def _data_plane(self):
        return self.server

    # --- skew-adaptation surface -----------------------------------------
    def window_probes(self):
        # snapshot (newest first): with in-flight > 1 on the wall clock a
        # concurrent search_batch may append while the skew check iterates
        return list(self.server._recent_probes)[::-1]

    def refresh_plan(self):
        self.server.refresh_plan()

    @property
    def replans(self) -> int:
        return self.server.stats.replans

    @property
    def nlist(self) -> int:
        return self.server.index.nlist

    @property
    def default_max_batch(self) -> int:
        return self.server.cfg.query_block

    @property
    def default_k(self) -> int:
        return self.server.cfg.topk


class SkewMonitor:
    """Hot-mass drift detector behind the scheduler's skew adaptation.

    Tracks the workload concentration the current plan was built for and
    asks the target to re-plan when the live window drifts past
    ``cfg.replan_drift``. Factored out of ``ServingScheduler`` so the
    real-clock front-end reuses the identical trigger logic (pure code
    motion — the virtual-clock goldens pin its behaviour).
    """

    def __init__(self, cfg: SchedulerConfig, target: DispatchTarget):
        self.cfg = cfg
        self.target = target
        self.batches_since_replan = 0
        # skew baseline: hot-mass of the workload the current plan was
        # built for (set lazily; re-synced after ANY re-plan, including
        # fail_node / replan_every ones done behind the scheduler's back)
        self._plan_hot: Optional[float] = None
        self._seen_replans = target.replans

    def _window_hot_mass(self) -> Optional[float]:
        # walk the probe history from the newest batch back, taking only
        # enough arrays to cover the window (not the whole history)
        take, rows = [], 0
        for p in self.target.window_probes():
            take.append(p)
            rows += p.shape[0]
            if rows >= self.cfg.skew_window:
                break
        if not take:
            return None
        window = np.concatenate(take[::-1], axis=0)[-self.cfg.skew_window:]
        hits = estimate_cluster_hits(window, self.target.nlist)
        return workload_concentration(hits, self.cfg.hot_fraction)

    def after_batch(self) -> bool:
        """Account one dispatched batch; re-plan (and return True) if the
        live window's hot-mass drifted past the threshold."""
        self.batches_since_replan += 1
        if self.cfg.replan_drift <= 0:
            return False
        if self.target.replans != self._seen_replans:
            # the plan was rebuilt elsewhere (fail_node, replan_every):
            # re-baseline on the window that plan saw
            self._seen_replans = self.target.replans
            self._plan_hot = self._window_hot_mass()
            self.batches_since_replan = 0
            return False
        if self._plan_hot is None:
            # the initial plan was built from a uniform workload prior
            self._plan_hot = workload_concentration(
                np.ones(self.target.nlist), self.cfg.hot_fraction
            )
        if self.batches_since_replan < self.cfg.min_batches_between_replans:
            return False
        hot = self._window_hot_mass()
        if hot is None:
            return False
        if abs(hot - self._plan_hot) > self.cfg.replan_drift:
            self.target.refresh_plan()
            self.target.stats.skew_replans += 1
            self._plan_hot = hot
            self._seen_replans = self.target.replans
            self.batches_since_replan = 0
            return True
        return False


def next_fire(
    queue: "Deque[Request]",
    cfg: SchedulerConfig,
    max_batch: int,
    target_free_s: float,
) -> Tuple[float, str]:
    """Batch-forming policy: the earliest time the queued requests can
    dispatch, and why (``"full"`` size trigger, ``"deadline"`` oldest-wait
    trigger, or ``"capacity"`` bounded-queue early fire). Shared verbatim
    by the virtual-clock scheduler and the real-clock front-end."""
    if len(queue) >= max_batch:
        ready = queue[max_batch - 1].arrival_s
        trigger = "full"
    else:
        ready = queue[0].arrival_s + cfg.max_wait_s
        trigger = "deadline"
        if (cfg.queue_capacity
                and len(queue) >= cfg.queue_capacity
                and queue[-1].arrival_s < ready):
            # queue at its bound with the size trigger unreachable:
            # fire as soon as the target frees up instead of shedding
            # behind an idle server until the deadline
            ready = queue[-1].arrival_s
            trigger = "capacity"
    return max(ready, target_free_s), trigger


class ServingScheduler:
    """Admission-controlled adaptive batcher over a dispatch target
    (virtual-clock replay — the deterministic harness; for live traffic
    use :class:`repro.serve.frontend.ServingFrontend`).

    The first argument is either a ``HarmonyServer`` (wrapped in a
    :class:`SingleServerTarget`) or any :class:`DispatchTarget` — in
    particular a :class:`repro.serve.fleet.ReplicaFleet`.

    Usage: either drive it incrementally (``submit`` per arrival, then
    ``flush``) or replay a whole trace with :meth:`run_trace`. Arrival
    timestamps must be non-decreasing. ``on_batch(batch_idx, scheduler)``
    is invoked after every dispatched batch — tests use it to kill nodes
    or replicas mid-stream (the elastic invariant extends to scheduled
    serving).

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> from repro.core import build_ivf
    >>> from repro.serve import HarmonyServer
    >>> rng = np.random.default_rng(0)
    >>> x = rng.standard_normal((256, 8)).astype(np.float32)
    >>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3,
    ...                     kmeans_iters=2)
    >>> srv = HarmonyServer(build_ivf(x, cfg), n_nodes=2)
    >>> sched = ServingScheduler(srv, SchedulerConfig(max_batch=8), k=3)
    >>> trace = [(i * 1e-4, x[i]) for i in range(16)]   # replayed arrivals
    >>> results = sched.run_trace(trace)
    >>> len(results), results[0].ids.shape
    (16, (3,))
    >>> srv.stats.full_batches        # 16 requests → two size-8 batches
    2
    """

    def __init__(
        self,
        server,
        cfg: Optional[SchedulerConfig] = None,
        k: Optional[int] = None,
        service_time_fn: Optional[Callable[[int], float]] = None,
        latency_fn: Optional[Callable[[int, object], float]] = None,
        on_batch: Optional[Callable[[int, "ServingScheduler"], None]] = None,
        clock: Optional[VirtualClock] = None,
    ):
        self.cfg = cfg or SchedulerConfig()
        if isinstance(server, DispatchTarget):
            if service_time_fn is not None or latency_fn is not None:
                raise ValueError(
                    "service_time_fn/latency_fn belong to the target when "
                    "a DispatchTarget is passed (construct it with them)"
                )
            self.target = server
        else:
            self.target = SingleServerTarget(
                server, service_time_fn=service_time_fn, latency_fn=latency_fn
            )
        # back-compat alias: the single server, or the target itself
        self.server = getattr(self.target, "server", self.target)
        self.stats = self.target.stats
        self.clock = clock or VirtualClock()
        self.k = k or self.target.default_k
        self.max_batch = self.cfg.max_batch or self.target.default_max_batch
        assert self.max_batch >= 1
        self.on_batch = on_batch
        self.queue: Deque[Request] = deque()
        self.done: List[RequestResult] = []
        self.busy_until = 0.0           # last completion seen (makespan end)
        self.first_arrival_s: Optional[float] = None
        self._next_id = 0
        self._batch_id = 0
        self.target.configure(self.cfg, self.k)
        self._skew = SkewMonitor(self.cfg, self.target)
        # semantic cache + in-batch coalescing (inert when cfg.cache is
        # None/disabled — the goldens pin byte-identity of that default)
        self.cache = build_query_cache(self.cfg, self.target, self.stats)
        self._coalesce = self.cache is not None and self.cfg.cache.coalesce

    @property
    def _hedge(self) -> Optional[HedgingExecutor]:
        # back-compat: tests/examples inspect sched._hedge.stats
        return getattr(self.target, "_hedge", None)

    # ---------------------------------------------------------------- admit
    def submit(self, query, arrival_s: Optional[float] = None,
               _warn: bool = True) -> int:
        """Offer one request at virtual time ``arrival_s`` (default: the
        clock's current time). Returns its req_id, or -1 if shed by
        backpressure. Fires any batches due before ``arrival_s`` first.

        ``query`` is a :class:`repro.core.SearchRequest` (the canonical
        shape — its filter/hybrid/precision/k ride with the request) or a
        bare [D] array, which is auto-wrapped with a
        ``DeprecationWarning`` (``_warn=False`` silences the shim for
        internal wrappers that already own the old surface).

        req_ids are consumed by shed requests too, so a served request's
        req_id is always its submission (trace) position — results map
        back to the trace even after shedding."""
        if isinstance(query, SearchRequest):
            req_k, req_flt = query.k, query.filter
            req_text, req_prec = query.hybrid_text, query.precision
            req_dl = query.deadline
            query = query.vector
        else:
            if _warn:
                warnings.warn(
                    "submitting a bare ndarray is deprecated; pass a "
                    "repro.core.SearchRequest",
                    DeprecationWarning, stacklevel=2,
                )
            req_k = req_flt = req_text = req_prec = req_dl = None
        if arrival_s is None:
            arrival_s = self.clock.now()
        self.advance(arrival_s)
        stats = self.stats
        stats.offered += 1
        rid = self._next_id
        self._next_id += 1
        if self.first_arrival_s is None:
            self.first_arrival_s = arrival_s
        query = np.asarray(query)
        # per-request deadline already blown at submission: answer with
        # the sentinel degradation path (PR 7), never queue dead work —
        # checked before the cache so even a cached answer is refused
        if req_dl is not None and arrival_s > req_dl:
            stats.expired_requests += 1
            self.busy_until = max(self.busy_until, arrival_s)
            self._sentinel(rid, req_k or self.k, arrival_s, arrival_s,
                           arrival_s, batch_id=-1)
            return rid
        if self.cache is not None:
            k_r = req_k or self.k
            hit = self.cache.lookup(
                query, k_r, (req_flt, req_text, req_prec), arrival_s
            )
            if hit is not None:
                # served at arrival: no queueing, no shedding, no batch
                self.busy_until = max(self.busy_until, arrival_s)
                stats.queue_wait_ms.append(0.0)
                stats.request_latency_ms.append(0.0)
                self.done.append(RequestResult(
                    req_id=rid, ids=hit.ids, scores=hit.scores,
                    arrival_s=arrival_s, dispatch_s=arrival_s,
                    done_s=arrival_s, batch_id=-1,
                ))
                return rid
        if self.cfg.queue_capacity and len(self.queue) >= self.cfg.queue_capacity:
            stats.shed += 1
            return -1
        self.queue.append(Request(
            rid, query, arrival_s,
            k=req_k, filter=req_flt, hybrid_text=req_text, precision=req_prec,
            deadline=req_dl,
        ))
        stats.admitted += 1
        return rid

    def _sentinel(self, rid: int, k: int, arrival_s: float, dispatch_s: float,
                  done_s: float, batch_id: int) -> None:
        """Append a degraded (ids -1, +inf scores) result for a request
        answered without execution — the PR 7 sentinel shape."""
        self.done.append(RequestResult(
            req_id=rid,
            ids=np.full(k, -1, np.int64),
            scores=np.full(k, np.inf, np.float32),
            arrival_s=arrival_s, dispatch_s=dispatch_s, done_s=done_s,
            batch_id=batch_id,
        ))

    # ------------------------------------------------------------ batch form
    def _next_fire(self) -> Tuple[float, str]:
        """(virtual time at which the next batch can dispatch, trigger)."""
        return next_fire(
            self.queue, self.cfg, self.max_batch, self.target.next_free_s()
        )

    def advance(self, now: float):
        """Move the virtual clock to ``now``, firing every batch whose
        dispatch time is ≤ ``now``."""
        self.clock.advance_to(now)
        while self.queue:
            dispatch_s, trigger = self._next_fire()
            if dispatch_s > now:
                break
            self._dispatch(dispatch_s, trigger)

    def flush(self) -> List[RequestResult]:
        """Drain the queue (deadlines fire naturally on the virtual clock)
        and return all results in request order."""
        self.advance(math.inf)
        return sorted(self.done, key=lambda r: r.req_id)

    # -------------------------------------------------------------- dispatch
    def _dispatch(self, dispatch_s: float, trigger: str):
        batch = [self.queue.popleft()
                 for _ in range(min(len(self.queue), self.max_batch))]
        stats = self.stats
        # per-request deadline enforcement at dispatch: a request whose
        # absolute deadline passed while it queued is answered with the
        # sentinel degradation path (PR 7 shape), never executed
        expired = [req for req in batch
                   if req.deadline is not None and dispatch_s > req.deadline]
        if expired:
            stats.expired_requests += len(expired)
            for req in expired:
                self._sentinel(req.req_id, req.k or self.k, req.arrival_s,
                               dispatch_s, dispatch_s, self._batch_id)
            gone = {req.req_id for req in expired}
            batch = [req for req in batch if req.req_id not in gone]
            if not batch:
                # nothing left to execute: mirror the failed-batch path —
                # the batch id is consumed, no trigger/skew accounting
                self._batch_id += 1
                if self.on_batch is not None:
                    self.on_batch(self._batch_id - 1, self)
                return
        # partition the formed batch by request options: each group shares
        # one (k, filter, hybrid_text, precision) execution context. A
        # knob-free batch is exactly one group with key None and one
        # positional target.execute call — byte-identical to the
        # pre-request-API scheduler (the virtual-clock goldens pin this).
        groups: Dict[Optional[tuple], List[int]] = {}
        for row, req in enumerate(batch):
            groups.setdefault(req.options_key(), []).append(row)
        # in-batch coalescing: duplicate vectors inside one options group
        # execute once; the answer fans out to every duplicate row. The
        # virtual-clock twin of the front-end's in-flight coalescing —
        # deterministic, so replay harnesses exercise it.
        plans: Dict[Optional[tuple], Tuple[List[int], List[int]]] = {}
        for key, rows in groups.items():
            if self._coalesce:
                seen: Dict[bytes, int] = {}
                exec_rows: List[int] = []
                assign: List[int] = []
                for r in rows:
                    b = vec_bytes(batch[r].query)
                    j = seen.get(b)
                    if j is None:
                        j = len(exec_rows)
                        seen[b] = j
                        exec_rows.append(r)
                    else:
                        stats.coalesced += 1
                    assign.append(j)
                plans[key] = (exec_rows, assign)
            else:
                plans[key] = (rows, list(range(len(rows))))

        # lookahead prefetch: the requests still queued behind this batch
        # are (up to deadline expiry) exactly the next formed batch — hand
        # their knob-free vectors to the target *before* executing, so a
        # host-tier candidate upload can overlap this batch's compute.
        # Coalescing is mirrored so the predicted query block matches the
        # one the next dispatch will actually stack. Purely advisory.
        if self.queue:
            pf_seen: set = set()
            pf_qs = []
            for req in list(self.queue)[: self.max_batch]:
                if req.options_key() is not None:
                    continue
                b = vec_bytes(req.query)
                if self._coalesce and b in pf_seen:
                    continue
                pf_seen.add(b)
                pf_qs.append(req.query)
            if pf_qs:
                pf = getattr(self.target, "prefetch", None)
                if pf is not None:
                    pf(np.stack(pf_qs))

        def _run(eff_dispatch_s):
            row_ids = [None] * len(batch)
            row_scores = [None] * len(batch)
            g_done_max = eff_dispatch_s
            for key, rows in groups.items():
                exec_rows, assign = plans[key]
                queries = np.stack([batch[r].query for r in exec_rows])
                if key is None:
                    res, g_done = self.target.execute(
                        queries, self.k, eff_dispatch_s, self._batch_id
                    )
                else:
                    res, g_done = self.target.execute(
                        queries, key[0] or self.k, eff_dispatch_s,
                        self._batch_id, key[1:],
                    )
                g_done_max = max(g_done_max, g_done)
                for i, r in zip(assign, rows):
                    row_ids[r] = res.ids[i]
                    row_scores[r] = res.scores[i]
            return row_ids, row_scores, g_done_max

        # epoch read before execution: entries inserted from this batch
        # are stamped pre-execute, so a write landing mid-batch makes
        # them count as already-stale (conservative)
        pre_epoch = self.cache.epoch() if self.cache is not None else None
        # bounded retry of the (idempotent) batch: each re-issue charges
        # its backoff to the virtual clock via a later dispatch stamp
        eff_dispatch_s = dispatch_s
        err: Optional[BaseException] = None
        row_ids = row_scores = done_s = None
        for attempt in range(self.cfg.max_retries + 1):
            try:
                row_ids, row_scores, done_s = _run(eff_dispatch_s)
                err = None
                break
            except Exception as e:  # noqa: BLE001 - bounded retry below
                err = e
                if attempt >= self.cfg.max_retries:
                    break
                backoff = self.cfg.retry_backoff_s * (attempt + 1)
                if (self.cfg.request_deadline_s > 0
                        and (eff_dispatch_s + backoff - batch[0].arrival_s)
                        > self.cfg.request_deadline_s):
                    break       # deadline budget spent: fail now, not later
                stats.retried_batches += 1
                eff_dispatch_s += backoff
        if err is not None:
            if self.cfg.max_retries == 0:
                raise err       # resilience off: pre-PR-7 behaviour
            # degrade: answer the batch with sentinel results so the
            # trace keeps replaying (availability over completeness)
            stats.failed_batches += 1
            stats.failed_requests += len(batch)
            for req in batch:
                k_r = req.k or self.k
                self.done.append(RequestResult(
                    req_id=req.req_id,
                    ids=np.full(k_r, -1, np.int64),
                    scores=np.full(k_r, np.inf, np.float32),
                    arrival_s=req.arrival_s,
                    dispatch_s=dispatch_s,
                    done_s=eff_dispatch_s,
                    batch_id=self._batch_id,
                ))
            self._batch_id += 1
            if self.on_batch is not None:
                self.on_batch(self._batch_id - 1, self)
            return
        self.busy_until = max(self.busy_until, done_s)
        if self.cache is not None:
            for key, rows in groups.items():
                k_g = (key[0] or self.k) if key is not None else self.k
                options = key[1:] if key is not None else (None, None, None)
                for r in plans[key][0]:     # unique executed rows only
                    self.cache.insert(
                        batch[r].query, k_g, options,
                        row_ids[r], row_scores[r], done_s, epoch=pre_epoch,
                    )

        if trigger == "full":
            stats.full_batches += 1
        elif trigger == "capacity":
            stats.capacity_batches += 1
        else:
            stats.deadline_batches += 1
        for row, req in enumerate(batch):
            stats.queue_wait_ms.append((dispatch_s - req.arrival_s) * 1e3)
            stats.request_latency_ms.append((done_s - req.arrival_s) * 1e3)
            self.done.append(
                RequestResult(
                    req_id=req.req_id,
                    ids=row_ids[row],
                    scores=row_scores[row],
                    arrival_s=req.arrival_s,
                    dispatch_s=dispatch_s,
                    done_s=done_s,
                    batch_id=self._batch_id,
                )
            )
        self._batch_id += 1
        self._skew.after_batch()
        if self.on_batch is not None:
            self.on_batch(self._batch_id - 1, self)

    # ---------------------------------------------------------------- replay
    def run_trace(
        self, trace: Sequence[Tuple[float, np.ndarray]]
    ) -> List[RequestResult]:
        """Replay a whole (arrival_s, query)-trace and drain. Trace
        queries are :class:`repro.core.SearchRequest` objects or bare [D]
        arrays (deprecated — auto-wrapped, see :meth:`submit`). Returns
        served results ordered by req_id; shed requests have no result
        (compare ``stats.shed``)."""
        for arrival_s, q in trace:
            self.submit(q, arrival_s)
        return self.flush()

    # ------------------------------------------------------------- reporting
    @property
    def makespan_s(self) -> float:
        """First arrival → last completion on the virtual clock."""
        if self.first_arrival_s is None:
            return 0.0
        return max(self.busy_until - self.first_arrival_s, 0.0)

    @property
    def served_qps(self) -> float:
        """Served requests per second of makespan (virtual wall)."""
        return len(self.done) / self.makespan_s if self.makespan_s > 0 else 0.0
