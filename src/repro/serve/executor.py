"""Device-resident batched search executor — serving through the
Pallas/SPMD pipeline with static-shape bucketing.

This closes the gap between the scheduler's batch former (which used to
dispatch every batch into the host-side numpy engine) and the TPU-target
SPMD ring pipeline of :mod:`repro.core.pipeline`: served batches now run
the jit'd shard_map step — Pallas partial-distance with tile-granular
early-stop, ppermute dimension ring, fused running-top-K, τ tightening
between chunks — end to end on the device mesh.

Design:

* **Corpus residency** — the sharded corpus, per-block norms, cluster ids
  and row ids are packed once (:func:`repro.core.pipeline.build_corpus_arrays`)
  and ``device_put`` on the mesh at construction. Serving a batch moves
  only the query block, probe table, τ seeds, and a small int32 row-index
  table host→device; the corpus never re-crosses the PCIe/ICI boundary.
* **Candidate gather** — probed clusters are contiguous row ranges of the
  resident shards (the IVF pack is cluster-sorted), so the host computes a
  per-shard row-index union and the device gathers those rows into a
  padded static candidate buffer (:func:`gather_local_candidates`). The
  ring then scans ``cap_b`` gathered rows instead of the full shard.
* **Static-shape bucketing** — jit recompiles per shape, and the
  scheduler's adaptive batches vary in both query count and candidate
  volume. Both are padded up a small ladder of (qb, cap) buckets; the
  compiled step for each bucket is cached, so replaying mixed batch sizes
  compiles each bucket exactly once. Batches larger than the biggest qb
  bucket are split and merged host-side.

Exactness: identical guarantees to the host engine and the oracle —
padding adds rows whose cluster id is -1 (matches no probe) and queries
whose τ is -inf (everything prunes), neither of which can enter a top-K.
Pruning is auto-disabled for ``metric="ip"`` (partial -dot sums are not
monotone, so τ-pruning is only exact for L2).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map_compat
from repro.core.index import IVFIndex, assign_queries, preassign
from repro.core.pipeline import (
    SpmdConfig,
    build_corpus_arrays,
    build_query_arrays,
    corpus_shardings,
    gather_local_candidates,
    ring_chunk_search,
)
from repro.core.pruning import prewarm_tau
from repro.core.router import load_aware_assignment, ring_offsets
from repro.core.types import PartitionPlan, SearchResult


@dataclass(frozen=True)
class ExecutorConfig:
    """Knobs of the device-resident executor.

    ``qb_buckets`` is the query-count ladder (each entry is rounded up to a
    multiple of the mesh's dimension-block count); the candidate-capacity
    ladder is derived as chunk·2^i up to the full shard capacity.
    """

    d_blocks: int = 1               # model-axis size; data axis gets the rest
    chunk: int = 256                # candidate rows scored per ring pass
    qb_buckets: Tuple[int, ...] = (8, 32, 128)
    use_pallas: Optional[bool] = None   # None → Pallas on TPU, jnp elsewhere
    x_dtype: str = "float32"
    tile_m: int = 128
    tile_n: int = 128
    tile_k: int = 128
    prune: Optional[bool] = None    # None → index.cfg.enable_pruning (L2 only)


def _default_mesh(d_blocks: int) -> Mesh:
    devs = jax.devices()
    n = len(devs)
    assert n % d_blocks == 0, (n, d_blocks)
    return Mesh(
        np.asarray(devs).reshape(n // d_blocks, d_blocks), ("data", "model")
    )


class SpmdExecutor:
    """Batched search over the device-resident SPMD pipeline.

    Self-contained: builds its own cluster→shard packing for the mesh
    geometry (independent of the host engine's cost-model plan, which may
    be rebuilt under it by replans — results are plan-invariant, so the
    two paths stay interchangeable oracles for each other).
    """

    def __init__(
        self,
        index: IVFIndex,
        cfg: Optional[ExecutorConfig] = None,
        mesh: Optional[Mesh] = None,
    ):
        self.index = index
        self.cfg = cfg or ExecutorConfig()
        self.mesh = mesh if mesh is not None else _default_mesh(self.cfg.d_blocks)
        V, B = self.mesh.devices.shape
        self.k = index.cfg.topk
        self.metric = index.cfg.metric
        prune = self.cfg.prune
        if prune is None:
            prune = index.cfg.enable_pruning
        self.prune = bool(prune and self.metric == "l2")
        use_pallas = self.cfg.use_pallas
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = use_pallas

        plan = PartitionPlan(
            v_shards=V,
            d_blocks=B,
            cluster_to_shard=load_aware_assignment(index.sizes, None, V),
            ring_offsets=ring_offsets(V, B),
        )
        # pad_to=chunk keeps the full capacity (the top of the cap ladder)
        # chunk-aligned
        self.corpus = preassign(index, plan, pad_to=self.cfg.chunk)
        self.cap_full = self.corpus.cap
        dim_pad = -(-index.dim // B) * B
        self._base_scfg = SpmdConfig(
            v_shards=V,
            d_blocks=B,
            qb=8 * B,                   # placeholder; buckets override
            cap=self.cap_full,
            dim=dim_pad,
            nprobe=index.cfg.nprobe,
            k=self.k,
            chunk=self.cfg.chunk,
            metric=self.metric,
            prune=self.prune,
            x_dtype=self.cfg.x_dtype,
            use_pallas=self.use_pallas,
            tile_m=self.cfg.tile_m,
            tile_n=self.cfg.tile_n,
            tile_k=self.cfg.tile_k,
        )

        # bucket ladders (static shapes the step may compile for)
        self.qb_buckets = tuple(sorted({-(-b // B) * B for b in self.cfg.qb_buckets}))
        caps, c = [], self.cfg.chunk
        while c < self.cap_full:
            caps.append(c)
            c *= 2
        caps.append(self.cap_full)
        self.cap_buckets = tuple(caps)

        # corpus upload: once, at construction
        arrays = build_corpus_arrays(self.corpus, self._base_scfg)
        sh = corpus_shardings(self._base_scfg, self.mesh)
        self._resident = tuple(
            jax.device_put(arrays[name], sh[name])
            for name in ("x_blocks", "xn2_blocks", "cluster_ids", "row_ids")
        )

        # compile cache: (qb, cap, k, nprobe) → jit'd step
        self._steps: Dict[Tuple[int, int, int, int], object] = {}
        self.trace_counts: Dict[Tuple[int, int, int, int], int] = {}
        self.dispatches = 0
        self.queries = 0
        self.wall_s = 0.0
        self.tile_skipped = 0
        self.tile_total = 0

    def warmup(self, k: Optional[int] = None, nprobe: Optional[int] = None):
        """Pre-compile the whole (qb, cap) bucket ladder.

        Serving paths that charge measured walls to a clock (the
        scheduler's virtual-clock replay) call this once up front so no
        in-trace dispatch ever pays a jit compile."""
        k = k or self.k
        nprobe = nprobe if nprobe is not None else self.index.cfg.nprobe
        for qb in self.qb_buckets:
            for cap in self.cap_buckets:
                bscfg = dataclasses.replace(
                    self._base_scfg, qb=qb, cap=cap, k=k, nprobe=nprobe
                )
                step = self._get_step(bscfg)
                rows = np.full((bscfg.v_shards, cap), -1, np.int32)
                rows[:, 0] = 0
                qarr = build_query_arrays(
                    np.zeros((1, self.index.dim), np.float32), bscfg,
                    np.zeros((1, nprobe), np.int32),
                    np.full((1,), np.inf, np.float32),
                )
                step(*self._resident, rows,
                     qarr["queries"], qarr["probes"], qarr["tau0"])

    # ----------------------------------------------------------- bucketing
    def _pick_bucket(self, ladder: Tuple[int, ...], need: int) -> int:
        for b in ladder:
            if b >= need:
                return b
        return ladder[-1]

    def _gather_rows(self, probes: np.ndarray,
                     dead_rows: Optional[np.ndarray] = None):
        """Per-shard union of probed clusters' resident row ranges, padded
        to the smallest cap bucket. Returns (rows [V, cap_b] i32, cap_b);
        (None, 0) when the batch probes no resident rows.

        ``dead_rows`` (bool [NB] over *packed* index rows — the mutable
        data plane's tombstones) drops dead rows from the gather table, so
        deletes cost zero device work and never inflate K: masking happens
        in the host-side row union, the compiled step is untouched."""
        V = self._base_scfg.v_shards
        uniq = np.unique(probes) if probes.size else np.zeros(0, np.int64)
        uniq = uniq[uniq >= 0]
        per_shard = [[] for _ in range(V)]
        counts = np.zeros(V, np.int64)
        for c in uniq:
            v, lo, hi = self.corpus.cluster_slices[int(c)]
            if hi > lo:
                r = np.arange(lo, hi, dtype=np.int32)
                if dead_rows is not None:
                    # shard row lo+j of cluster c is packed row plo+j
                    plo, phi = self.index.cluster_rows(int(c))
                    r = r[~dead_rows[plo:phi]]
                if r.size:
                    per_shard[v].append(r)
                    counts[v] += r.size
        need = int(counts.max()) if len(uniq) else 0
        if need == 0:
            return None, 0
        cap_b = self._pick_bucket(self.cap_buckets, need)
        rows = np.full((V, cap_b), -1, np.int32)
        for v in range(V):
            if per_shard[v]:
                r = np.concatenate(per_shard[v])
                rows[v, : len(r)] = r
        return rows, cap_b

    # --------------------------------------------------------- compilation
    def _get_step(self, bscfg: SpmdConfig):
        key = (bscfg.qb, bscfg.cap, bscfg.k, bscfg.nprobe)
        step = self._steps.get(key)
        if step is None:
            step = self._make_step(bscfg, key)
            self._steps[key] = step
        return step

    def _make_step(self, bscfg: SpmdConfig, key):
        cap_full, db, counts = self.cap_full, bscfg.db, self.trace_counts

        def device_fn(x_res, xn2_res, cl_res, id_res, rows, q_blk, probes, tau0):
            # this Python body runs only while jit traces → counts compiles
            counts[key] = counts.get(key, 0) + 1
            x_res = x_res.reshape(cap_full, db)
            xn2_res = xn2_res.reshape(cap_full)
            cl_res = cl_res.reshape(cap_full)
            id_res = id_res.reshape(cap_full)
            rows = rows.reshape(bscfg.cap)
            q_blk = q_blk.reshape(bscfg.qb, db)
            x_c, xn2_c, cl_c, id_c = gather_local_candidates(
                rows, x_res, xn2_res, cl_res, id_res
            )
            return ring_chunk_search(
                bscfg, x_c, xn2_c, cl_c, id_c, q_blk, probes, tau0
            )

        ad, am = bscfg.axis_data, bscfg.axis_model
        in_specs = (
            P(ad, None, am),        # x_blocks  (resident)
            P(am, ad, None),        # xn2_blocks (resident)
            P(ad, None),            # cluster_ids (resident)
            P(ad, None),            # row_ids (resident)
            P(ad, None),            # rows (per-batch gather table)
            P(None, am),            # queries
            P(None, None),          # probes
            P(None),                # tau0
        )
        fn = shard_map_compat(
            device_fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=(P(), P(), P()),
        )
        return jax.jit(fn)

    # ------------------------------------------------------------- serving
    def search_batch(
        self,
        queries: np.ndarray,
        k: Optional[int] = None,
        nprobe: Optional[int] = None,
        probes: Optional[np.ndarray] = None,
        dead_rows: Optional[np.ndarray] = None,
    ) -> SearchResult:
        """Top-K for one batch through the device-resident pipeline.

        ``dead_rows`` applies the segmented data plane's tombstones (see
        :meth:`_gather_rows`); the τ prewarm excludes the same rows so
        pruning stays exact over the live set."""
        k = k or self.k
        queries = np.asarray(queries, np.float32)
        if queries.ndim == 1:
            queries = queries[None]
        nq = queries.shape[0]
        max_qb = self.qb_buckets[-1]
        if nq > max_qb:
            # batch exceeds the biggest bucket: split, serve, merge
            parts = [
                self.search_batch(
                    queries[lo : lo + max_qb], k=k, nprobe=nprobe,
                    probes=None if probes is None else probes[lo : lo + max_qb],
                    dead_rows=dead_rows,
                )
                for lo in range(0, nq, max_qb)
            ]
            return SearchResult(
                ids=np.concatenate([p.ids for p in parts]),
                scores=np.concatenate([p.scores for p in parts]),
                stats={
                    "backend": "spmd",
                    "wall_s": sum(p.stats["wall_s"] for p in parts),
                    "buckets": [b for p in parts for b in p.stats["buckets"]],
                    "tile_skipped": sum(p.stats["tile_skipped"] for p in parts),
                    "tile_total": sum(p.stats["tile_total"] for p in parts),
                    "pad_queries": sum(p.stats["pad_queries"] for p in parts),
                    "compiled": any(p.stats["compiled"] for p in parts),
                    "splits": len(parts),
                },
            )

        t0 = time.perf_counter()
        if probes is None:
            if nprobe is not None and nprobe <= 0:
                # assign_queries treats 0 as "use the config default"; an
                # explicit empty probe set means "no candidates"
                probes = np.zeros((nq, 0), np.int32)
            else:
                probes = assign_queries(self.index, queries, nprobe)
        rows, cap_b = self._gather_rows(probes, dead_rows)
        if cap_b == 0:
            dt = time.perf_counter() - t0
            self.dispatches += 1
            self.queries += nq
            self.wall_s += dt
            return SearchResult(
                ids=np.full((nq, k), -1, np.int64),
                scores=np.full((nq, k), np.inf, np.float32),
                stats={
                    "backend": "spmd", "wall_s": dt, "buckets": [],
                    "tile_skipped": 0, "tile_total": 0, "pad_queries": 0,
                    "compiled": False, "splits": 1,
                },
            )
        tau0 = (
            prewarm_tau(self.index, queries, probes, k,
                        self.index.cfg.prewarm_samples, self.metric,
                        dead_rows=dead_rows)
            if self.prune
            else np.full((nq,), np.inf, np.float32)
        )
        qb_b = self._pick_bucket(self.qb_buckets, nq)
        bscfg = dataclasses.replace(
            self._base_scfg, qb=qb_b, cap=cap_b, k=k, nprobe=probes.shape[1]
        )
        qarr = build_query_arrays(queries, bscfg, probes, tau0)
        compiles_before = self.compiles
        step = self._get_step(bscfg)
        gs, gi, st = step(
            *self._resident, rows,
            qarr["queries"], qarr["probes"], qarr["tau0"],
        )
        scores = np.asarray(gs)[:nq]
        ids = np.asarray(gi)[:nq].astype(np.int64)
        ids[~np.isfinite(scores)] = -1
        st = np.asarray(st)
        dt = time.perf_counter() - t0
        self.dispatches += 1
        self.queries += nq
        self.wall_s += dt
        self.tile_skipped += int(st[0])
        self.tile_total += int(st[1])
        return SearchResult(
            ids=ids,
            scores=scores,
            stats={
                "backend": "spmd",
                "wall_s": dt,
                "buckets": [(qb_b, cap_b)],
                "tile_skipped": int(st[0]),
                "tile_total": int(st[1]),
                "pad_queries": qb_b - nq,
                "compiled": self.compiles > compiles_before,
                "splits": 1,
            },
        )

    # ----------------------------------------------------------- reporting
    @property
    def compiles(self) -> int:
        return sum(self.trace_counts.values())

    def stats_summary(self) -> dict:
        """JSON-friendly digest (the benchmark harness folds this into the
        serving results blob)."""
        return {
            "dispatches": self.dispatches,
            "queries": self.queries,
            "wall_s": self.wall_s,
            "compiles": self.compiles,
            "buckets_compiled": {
                f"qb{qb}_cap{cap}_k{k}_p{p}": n
                for (qb, cap, k, p), n in sorted(self.trace_counts.items())
            },
            "qb_buckets": list(self.qb_buckets),
            "cap_buckets": list(self.cap_buckets),
            "tile_skipped": self.tile_skipped,
            "tile_total": self.tile_total,
            "tile_skip_frac": self.tile_skipped / max(self.tile_total, 1),
        }
