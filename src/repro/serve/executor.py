"""Device-resident batched search executor — serving through the
Pallas/SPMD pipeline with static-shape bucketing.

This closes the gap between the scheduler's batch former (which used to
dispatch every batch into the host-side numpy engine) and the TPU-target
SPMD ring pipeline of :mod:`repro.core.pipeline`: served batches now run
the jit'd shard_map step — Pallas partial-distance with tile-granular
early-stop, ppermute dimension ring, fused running-top-K, τ tightening
between chunks — end to end on the device mesh.

Design:

* **Corpus residency** — the sharded corpus, per-block norms, cluster ids
  and row ids are packed once (:func:`repro.core.pipeline.build_corpus_arrays`)
  and ``device_put`` on the mesh at construction. Serving a batch moves
  only the query block, probe table, τ seeds, and a small int32 row-index
  table host→device; the corpus never re-crosses the PCIe/ICI boundary.
* **Cold tier** (``tier="host"``) — for host-resident (demoted) segments
  nothing stays on the mesh: per batch, only the probed clusters' rows
  are gathered host-side (:func:`repro.core.pipeline.gather_host_candidates`)
  into the same static (qb, cap) bucket shapes and streamed up through a
  double-buffered async upload — :meth:`SpmdExecutor.prefetch` stages
  batch i+1's transfer while batch i's ring kernels run. int8 codes
  stream 4× less PCIe traffic than fp32 rows, and the fp32 re-rank reads
  host memory anyway, so the cold tier prefers the PR 6 quantized path.
  Results are bit-identical to ``tier="device"``: same gathered
  candidate set, same kernels, same bucket ladder.
* **Candidate gather** — probed clusters are contiguous row ranges of the
  resident shards (the IVF pack is cluster-sorted), so the host computes a
  per-shard row-index union and the device gathers those rows into a
  padded static candidate buffer (:func:`gather_local_candidates`). The
  ring then scans ``cap_b`` gathered rows instead of the full shard.
* **Static-shape bucketing** — jit recompiles per shape, and the
  scheduler's adaptive batches vary in both query count and candidate
  volume. Both are padded up a small ladder of (qb, cap) buckets; the
  compiled step for each bucket is cached, so replaying mixed batch sizes
  compiles each bucket exactly once. Batches larger than the biggest qb
  bucket are split and merged host-side.

Exactness: identical guarantees to the host engine and the oracle —
padding adds rows whose cluster id is -1 (matches no probe) and queries
whose τ is -inf (everything prunes), neither of which can enter a top-K.
Pruning is auto-disabled for ``metric="ip"`` (partial -dot sums are not
monotone, so τ-pruning is only exact for L2).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map_compat
from repro.core.index import IVFIndex, assign_queries, preassign
from repro.core.pipeline import (
    SpmdConfig,
    build_corpus_arrays,
    build_query_arrays,
    corpus_shardings,
    gather_host_candidates,
    gather_local_candidates,
    ring_chunk_search,
)
from repro.core.pruning import prewarm_tau
from repro.core.router import load_aware_assignment, ring_offsets
from repro.core.types import PartitionPlan, SearchResult


@dataclass(frozen=True)
class ExecutorConfig:
    """Knobs of the device-resident executor.

    ``qb_buckets`` is the query-count ladder (each entry is rounded up to a
    multiple of the mesh's dimension-block count); the candidate-capacity
    ladder is derived as chunk·2^i up to the full shard capacity.
    """

    d_blocks: int = 1               # model-axis size; data axis gets the rest
    chunk: int = 256                # candidate rows scored per ring pass
    qb_buckets: Tuple[int, ...] = (8, 32, 128)
    use_pallas: Optional[bool] = None   # None → Pallas on TPU, jnp elsewhere
    x_dtype: str = "float32"
    precision: str = "fp32"         # "int8" → quantized stage-1 + fp32 re-rank
    rerank_factor: int = 4          # int8: stage-1 keeps k·rerank_factor rows
    tile_m: int = 128
    tile_n: int = 128
    tile_k: int = 128
    prune: Optional[bool] = None    # None → index.cfg.enable_pruning (L2 only)


def _default_mesh(d_blocks: int) -> Mesh:
    devs = jax.devices()
    n = len(devs)
    assert n % d_blocks == 0, (n, d_blocks)
    return Mesh(
        np.asarray(devs).reshape(n // d_blocks, d_blocks), ("data", "model")
    )


class SpmdExecutor:
    """Batched search over the device-resident SPMD pipeline.

    Self-contained: builds its own cluster→shard packing for the mesh
    geometry (independent of the host engine's cost-model plan, which may
    be rebuilt under it by replans — results are plan-invariant, so the
    two paths stay interchangeable oracles for each other).
    """

    def __init__(
        self,
        index: IVFIndex,
        cfg: Optional[ExecutorConfig] = None,
        mesh: Optional[Mesh] = None,
        tier: str = "device",
    ):
        assert tier in ("device", "host"), tier
        self.tier = tier
        self.index = index
        self.cfg = cfg or ExecutorConfig()
        self.mesh = mesh if mesh is not None else _default_mesh(self.cfg.d_blocks)
        V, B = self.mesh.devices.shape
        self.k = index.cfg.topk
        self.metric = index.cfg.metric
        self.precision = self.cfg.precision
        assert self.precision in ("fp32", "int8"), self.precision
        if self.precision == "int8":
            assert self.metric == "l2", "int8 tier is L2-only"
            assert self.cfg.rerank_factor >= 1, self.cfg.rerank_factor
        prune = self.cfg.prune
        if prune is None:
            prune = index.cfg.enable_pruning
        self.prune = bool(prune and self.metric == "l2")
        use_pallas = self.cfg.use_pallas
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = use_pallas

        plan = PartitionPlan(
            v_shards=V,
            d_blocks=B,
            cluster_to_shard=load_aware_assignment(index.sizes, None, V),
            ring_offsets=ring_offsets(V, B),
        )
        # pad_to=chunk keeps the full capacity (the top of the cap ladder)
        # chunk-aligned
        self.corpus = preassign(index, plan, pad_to=self.cfg.chunk)
        self.cap_full = self.corpus.cap
        dim_pad = -(-index.dim // B) * B
        self._base_scfg = SpmdConfig(
            v_shards=V,
            d_blocks=B,
            qb=8 * B,                   # placeholder; buckets override
            cap=self.cap_full,
            dim=dim_pad,
            nprobe=index.cfg.nprobe,
            k=self.k,
            chunk=self.cfg.chunk,
            metric=self.metric,
            prune=self.prune,
            x_dtype=self.cfg.x_dtype,
            precision=self.precision,
            use_pallas=self.use_pallas,
            tile_m=self.cfg.tile_m,
            tile_n=self.cfg.tile_n,
            tile_k=self.cfg.tile_k,
        )

        # bucket ladders (static shapes the step may compile for)
        self.qb_buckets = tuple(sorted({-(-b // B) * B for b in self.cfg.qb_buckets}))
        caps, c = [], self.cfg.chunk
        while c < self.cap_full:
            caps.append(c)
            c *= 2
        caps.append(self.cap_full)
        self.cap_buckets = tuple(caps)

        # corpus residency is tier-dependent: "device" uploads the packed
        # arrays to the mesh once at construction (the hot tier);
        # "host" keeps them in host RAM and streams only the probed
        # clusters' rows per batch through a double-buffered upload
        # (the cold tier — int8 codes preferred, 4× less PCIe traffic)
        quant = index.int8_quant() if self.precision == "int8" else None
        arrays = build_corpus_arrays(self.corpus, self._base_scfg, quant=quant)
        self._quant_grid = arrays.pop("quant_grid", None)
        sh = corpus_shardings(self._base_scfg, self.mesh)
        names = ("x_blocks", "xn2_blocks", "cluster_ids", "row_ids")
        if self.precision == "int8":
            names = names + ("scale2",)
        if tier == "device":
            self._resident = tuple(
                jax.device_put(arrays[name], sh[name]) for name in names
            )
            self._host_arrays = None
        else:
            self._resident = None
            self._host_arrays = {name: arrays[name] for name in names}
            # scale2 is B floats — park it on the mesh even for the cold
            # tier rather than re-streaming it per batch
            self._scale2_dev = (
                jax.device_put(arrays["scale2"], sh["scale2"])
                if self.precision == "int8" else None
            )
            ad, am = self._base_scfg.axis_data, self._base_scfg.axis_model
            from jax.sharding import NamedSharding
            self._stream_sh = (
                NamedSharding(self.mesh, P(ad, None, am)),   # x_c
                NamedSharding(self.mesh, P(am, ad, None)),   # xn2_c
                NamedSharding(self.mesh, P(ad, None)),       # cl_c
                NamedSharding(self.mesh, P(ad, None)),       # id_c
            )
            # double-buffered prefetch queue: candidate uploads staged by
            # the scheduler's formed-batch lookahead, keyed on the gather
            # table so the later dispatch recognizes its own rows. Two
            # slots = the upload of batch i+1 in flight while batch i
            # computes; device_put is async, so the transfer genuinely
            # overlaps the ring kernels.
            self._prefetched: Dict[tuple, tuple] = {}
        # stage-2 re-rank lookup (ext id → packed row), built lazily
        self._id_order: Optional[np.ndarray] = None
        self._sorted_ids: Optional[np.ndarray] = None

        # compile cache: (qb, cap, k, nprobe) → jit'd step
        self._steps: Dict[Tuple[int, int, int, int], object] = {}
        self.trace_counts: Dict[Tuple[int, int, int, int], int] = {}
        # probe-table widths a compiled step exists for (see search_batch)
        self._probe_widths: set = set()
        self.dispatches = 0
        self.queries = 0
        self.wall_s = 0.0
        self.tile_skipped = 0
        self.tile_total = 0
        # cold-tier counters (always 0 for a device-tier executor)
        self.cold_dispatches = 0
        self.bytes_streamed = 0
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.prefetch_staged = 0

    def warmup(self, k: Optional[int] = None, nprobe=None):
        """Pre-compile the whole (qb, cap) bucket ladder.

        Serving paths that charge measured walls to a clock (the
        scheduler's virtual-clock replay) call this once up front so no
        in-trace dispatch ever pays a jit compile.

        ``nprobe`` may be an int or an iterable of probe-table widths;
        each width gets its own compiled steps (the compile cache keys on
        ``probes.shape[1]``, not on the config's nprobe — warming only the
        config default used to leave every explicit-probe dispatch cold).
        :meth:`search_batch` pads narrower probe tables up to the nearest
        warmed width, so a single warmed width also covers anything below
        it."""
        k = k or self.k
        k_step = min(k * self.cfg.rerank_factor, self.index.nb) \
            if self.precision == "int8" else k
        if nprobe is None:
            widths = (self.index.cfg.nprobe,)
        elif np.ndim(nprobe) == 0:
            widths = (int(nprobe),)
        else:
            widths = tuple(int(w) for w in nprobe)
        for w in widths:
            for qb in self.qb_buckets:
                for cap in self.cap_buckets:
                    bscfg = dataclasses.replace(
                        self._base_scfg, qb=qb, cap=cap, k=k_step, nprobe=w
                    )
                    step = self._get_step(bscfg)
                    rows = np.full((bscfg.v_shards, cap), -1, np.int32)
                    rows[:, 0] = 0
                    qarr = build_query_arrays(
                        np.zeros((1, self.index.dim), np.float32), bscfg,
                        np.zeros((1, w), np.int32),
                        np.full((1,), np.inf, np.float32),
                        quant_grid=self._quant_grid,
                    )
                    if self.tier == "host":
                        cand, _ = self._upload_candidates(rows, cap)
                        step(*cand,
                             qarr["queries"], qarr["probes"], qarr["tau0"])
                    else:
                        step(*self._resident, rows,
                             qarr["queries"], qarr["probes"], qarr["tau0"])

    # ----------------------------------------------------------- bucketing
    def _pick_bucket(self, ladder: Tuple[int, ...], need: int) -> int:
        for b in ladder:
            if b >= need:
                return b
        return ladder[-1]

    def _gather_rows(self, probes: np.ndarray,
                     dead_rows: Optional[np.ndarray] = None):
        """Per-shard union of probed clusters' resident row ranges, padded
        to the smallest cap bucket. Returns (rows [V, cap_b] i32, cap_b);
        (None, 0) when the batch probes no resident rows.

        ``dead_rows`` (bool [NB] over *packed* index rows — the mutable
        data plane's tombstones) drops dead rows from the gather table, so
        deletes cost zero device work and never inflate K: masking happens
        in the host-side row union, the compiled step is untouched."""
        V = self._base_scfg.v_shards
        uniq = np.unique(probes) if probes.size else np.zeros(0, np.int64)
        uniq = uniq[uniq >= 0]
        per_shard = [[] for _ in range(V)]
        counts = np.zeros(V, np.int64)
        for c in uniq:
            v, lo, hi = self.corpus.cluster_slices[int(c)]
            if hi > lo:
                r = np.arange(lo, hi, dtype=np.int32)
                if dead_rows is not None:
                    # shard row lo+j of cluster c is packed row plo+j
                    plo, phi = self.index.cluster_rows(int(c))
                    r = r[~dead_rows[plo:phi]]
                if r.size:
                    per_shard[v].append(r)
                    counts[v] += r.size
        need = int(counts.max()) if len(uniq) else 0
        if need == 0:
            return None, 0
        cap_b = self._pick_bucket(self.cap_buckets, need)
        rows = np.full((V, cap_b), -1, np.int32)
        for v in range(V):
            if per_shard[v]:
                r = np.concatenate(per_shard[v])
                rows[v, : len(r)] = r
        return rows, cap_b

    # --------------------------------------------------------- compilation
    def _get_step(self, bscfg: SpmdConfig):
        key = (bscfg.qb, bscfg.cap, bscfg.k, bscfg.nprobe)
        step = self._steps.get(key)
        if step is None:
            step = (self._make_stream_step(bscfg, key)
                    if self.tier == "host" else self._make_step(bscfg, key))
            self._steps[key] = step
        self._probe_widths.add(bscfg.nprobe)
        return step

    def _make_step(self, bscfg: SpmdConfig, key):
        cap_full, db, counts = self.cap_full, bscfg.db, self.trace_counts
        int8 = self.precision == "int8"

        def device_fn(x_res, xn2_res, cl_res, id_res, *rest):
            # this Python body runs only while jit traces → counts compiles
            counts[key] = counts.get(key, 0) + 1
            if int8:
                scale2, rows, q_blk, probes, tau0 = rest
            else:
                scale2, (rows, q_blk, probes, tau0) = None, rest
            x_res = x_res.reshape(cap_full, db)
            xn2_res = xn2_res.reshape(cap_full)
            cl_res = cl_res.reshape(cap_full)
            id_res = id_res.reshape(cap_full)
            rows = rows.reshape(bscfg.cap)
            q_blk = q_blk.reshape(bscfg.qb, db)
            x_c, xn2_c, cl_c, id_c = gather_local_candidates(
                rows, x_res, xn2_res, cl_res, id_res
            )
            return ring_chunk_search(
                bscfg, x_c, xn2_c, cl_c, id_c, q_blk, probes, tau0,
                scale2=scale2,
            )

        ad, am = bscfg.axis_data, bscfg.axis_model
        resident_specs = (
            P(ad, None, am),        # x_blocks  (resident)
            P(am, ad, None),        # xn2_blocks (resident)
            P(ad, None),            # cluster_ids (resident)
            P(ad, None),            # row_ids (resident)
        )
        if int8:
            resident_specs = resident_specs + (P(am),)   # scale2 (resident)
        in_specs = resident_specs + (
            P(ad, None),            # rows (per-batch gather table)
            P(None, am),            # queries
            P(None, None),          # probes
            P(None),                # tau0
        )
        fn = shard_map_compat(
            device_fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=(P(), P(), P()),
        )
        return jax.jit(fn)

    def _make_stream_step(self, bscfg: SpmdConfig, key):
        """Cold-tier step: the candidate arrays arrive *already gathered*
        (host-side, :func:`gather_host_candidates`) and streamed to the
        mesh, so the device body skips the resident gather and runs the
        identical ring kernels over the same (qb, cap) bucket shapes —
        one compile cache, bit-identical results to the resident path."""
        db, counts = bscfg.db, self.trace_counts
        int8 = self.precision == "int8"

        def device_fn(x_c, xn2_c, cl_c, id_c, *rest):
            counts[key] = counts.get(key, 0) + 1
            if int8:
                scale2, q_blk, probes, tau0 = rest
            else:
                scale2, (q_blk, probes, tau0) = None, rest
            x_c = x_c.reshape(bscfg.cap, db)
            xn2_c = xn2_c.reshape(bscfg.cap)
            cl_c = cl_c.reshape(bscfg.cap)
            id_c = id_c.reshape(bscfg.cap)
            q_blk = q_blk.reshape(bscfg.qb, db)
            return ring_chunk_search(
                bscfg, x_c, xn2_c, cl_c, id_c, q_blk, probes, tau0,
                scale2=scale2,
            )

        ad, am = bscfg.axis_data, bscfg.axis_model
        cand_specs = (
            P(ad, None, am),        # x_c  (streamed per batch)
            P(am, ad, None),        # xn2_c
            P(ad, None),            # cl_c
            P(ad, None),            # id_c
        )
        if int8:
            cand_specs = cand_specs + (P(am),)   # scale2 (resident)
        in_specs = cand_specs + (
            P(None, am),            # queries
            P(None, None),          # probes
            P(None),                # tau0
        )
        fn = shard_map_compat(
            device_fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=(P(), P(), P()),
        )
        return jax.jit(fn)

    # ---------------------------------------------------- cold-tier stream
    def _upload_candidates(self, rows: np.ndarray, cap_b: int):
        """Gather the probed rows host-side and start their (async)
        upload. Returns ``(device_arrays, nbytes)`` — the arrays are
        valid step inputs immediately; the actual transfer overlaps
        whatever the device is computing when this is called."""
        cand = gather_host_candidates(self._host_arrays, rows)
        nbytes = sum(a.nbytes for a in cand.values())
        xs, ns, cs, is_ = self._stream_sh
        dev = (
            jax.device_put(cand["x_c"], xs),
            jax.device_put(cand["xn2_c"], ns),
            jax.device_put(cand["cl_c"], cs),
            jax.device_put(cand["id_c"], is_),
        )
        if self.precision == "int8":
            dev = dev + (self._scale2_dev,)
        return dev, nbytes

    def prefetch(
        self,
        queries: Optional[np.ndarray] = None,
        probes: Optional[np.ndarray] = None,
        dead_rows: Optional[np.ndarray] = None,
        nprobe: Optional[int] = None,
    ) -> None:
        """Stage the *next* batch's cold-candidate upload while the
        current batch computes (the scheduler calls this with its
        formed-batch lookahead). No-op on a device-tier executor.

        The staged upload is keyed on the gather table itself, so the
        later :meth:`search_batch` recognizes its own candidate set no
        matter how the batch was predicted; a wrong prediction is just a
        miss (the dispatch uploads synchronously), never a wrong answer.
        The queue is bounded to two slots — classic double buffering."""
        if self.tier != "host":
            return
        if probes is None:
            if queries is None:
                return
            queries = np.asarray(queries, np.float32)
            if queries.ndim == 1:
                queries = queries[None]
            probes = assign_queries(self.index, queries, nprobe)
        max_qb = self.qb_buckets[-1]
        for lo in range(0, probes.shape[0], max_qb):
            rows, cap_b = self._gather_rows(probes[lo:lo + max_qb], dead_rows)
            if cap_b == 0:
                continue
            key = (rows.tobytes(), cap_b)
            if key in self._prefetched:
                continue
            self._prefetched[key] = self._upload_candidates(rows, cap_b)
            self.prefetch_staged += 1
            while len(self._prefetched) > 2:    # double buffer: 2 slots
                self._prefetched.pop(next(iter(self._prefetched)))

    # ------------------------------------------------------------- serving
    def search_batch(
        self,
        queries: np.ndarray,
        k: Optional[int] = None,
        nprobe: Optional[int] = None,
        probes: Optional[np.ndarray] = None,
        dead_rows: Optional[np.ndarray] = None,
    ) -> SearchResult:
        """Top-K for one batch through the device-resident pipeline.

        ``dead_rows`` applies the segmented data plane's tombstones (see
        :meth:`_gather_rows`); the τ prewarm excludes the same rows so
        pruning stays exact over the live set."""
        k = k or self.k
        queries = np.asarray(queries, np.float32)
        if queries.ndim == 1:
            queries = queries[None]
        nq = queries.shape[0]
        max_qb = self.qb_buckets[-1]
        if nq > max_qb:
            # batch exceeds the biggest bucket: split, serve, merge
            parts = [
                self.search_batch(
                    queries[lo : lo + max_qb], k=k, nprobe=nprobe,
                    probes=None if probes is None else probes[lo : lo + max_qb],
                    dead_rows=dead_rows,
                )
                for lo in range(0, nq, max_qb)
            ]
            return SearchResult(
                ids=np.concatenate([p.ids for p in parts]),
                scores=np.concatenate([p.scores for p in parts]),
                stats={
                    "backend": "spmd",
                    "wall_s": sum(p.stats["wall_s"] for p in parts),
                    "buckets": [b for p in parts for b in p.stats["buckets"]],
                    "tile_skipped": sum(p.stats["tile_skipped"] for p in parts),
                    "tile_total": sum(p.stats["tile_total"] for p in parts),
                    "pad_queries": sum(p.stats["pad_queries"] for p in parts),
                    "compiled": any(p.stats["compiled"] for p in parts),
                    "splits": len(parts),
                    "precision": self.precision,
                    "rerank_k": max(p.stats.get("rerank_k", 0) for p in parts),
                    "cold": max(p.stats.get("cold", 0) for p in parts),
                    "bytes_streamed": sum(p.stats.get("bytes_streamed", 0)
                                          for p in parts),
                    "prefetch_hits": sum(p.stats.get("prefetch_hits", 0)
                                         for p in parts),
                },
            )

        t0 = time.perf_counter()
        if probes is None:
            if nprobe is not None and nprobe <= 0:
                # assign_queries treats 0 as "use the config default"; an
                # explicit empty probe set means "no candidates"
                probes = np.zeros((nq, 0), np.int32)
            else:
                probes = assign_queries(self.index, queries, nprobe)
        rows, cap_b = self._gather_rows(probes, dead_rows)
        if cap_b == 0:
            dt = time.perf_counter() - t0
            self.dispatches += 1
            self.queries += nq
            self.wall_s += dt
            return SearchResult(
                ids=np.full((nq, k), -1, np.int64),
                scores=np.full((nq, k), np.inf, np.float32),
                stats={
                    "backend": "spmd", "wall_s": dt, "buckets": [],
                    "tile_skipped": 0, "tile_total": 0, "pad_queries": 0,
                    "compiled": False, "splits": 1,
                    "precision": self.precision, "rerank_k": 0,
                    "cold": int(self.tier == "host"),
                    "bytes_streamed": 0, "prefetch_hits": 0,
                },
            )
        int8 = self.precision == "int8"
        # τ prewarm runs over the *original* probe table: prewarm_tau
        # indexes per-cluster sample rows, so pad columns (-2) must never
        # reach it. int8 stage 1 scores in the quantized metric, where an
        # fp32-space τ seed is not a valid upper bound — start at +inf and
        # let the travelling τ tighten within the quantized metric instead.
        tau0 = (
            prewarm_tau(self.index, queries, probes, k,
                        self.index.cfg.prewarm_samples, self.metric,
                        dead_rows=dead_rows)
            if self.prune and not int8
            else np.full((nq,), np.inf, np.float32)
        )
        # compile-cache alignment: the step keys on probes.shape[1]; pad a
        # narrower probe table (-2 columns match no cluster) up to the
        # smallest already-compiled width so explicit-probe dispatches hit
        # warmed steps instead of recompiling per width.
        w = probes.shape[1]
        if w not in self._probe_widths:
            wider = sorted(pw for pw in self._probe_widths if pw > w)
            if wider:
                pad = np.full((nq, wider[0] - w), -2, np.int32)
                probes = np.concatenate([probes.astype(np.int32), pad], axis=1)
        k_step = min(k * self.cfg.rerank_factor, self.index.nb) if int8 else k
        qb_b = self._pick_bucket(self.qb_buckets, nq)
        bscfg = dataclasses.replace(
            self._base_scfg, qb=qb_b, cap=cap_b, k=k_step, nprobe=probes.shape[1]
        )
        qarr = build_query_arrays(queries, bscfg, probes, tau0,
                                  quant_grid=self._quant_grid)
        compiles_before = self.compiles
        step = self._get_step(bscfg)
        cold_bytes, pf_hit = 0, 0
        if self.tier == "host":
            pkey = (rows.tobytes(), cap_b)
            staged = self._prefetched.pop(pkey, None)
            if staged is not None:
                cand, cold_bytes = staged
                pf_hit = 1
                self.prefetch_hits += 1
            else:
                cand, cold_bytes = self._upload_candidates(rows, cap_b)
                self.prefetch_misses += 1
            self.cold_dispatches += 1
            self.bytes_streamed += cold_bytes
            gs, gi, st = step(
                *cand, qarr["queries"], qarr["probes"], qarr["tau0"],
            )
        else:
            gs, gi, st = step(
                *self._resident, rows,
                qarr["queries"], qarr["probes"], qarr["tau0"],
            )
        scores = np.asarray(gs)[:nq]
        ids = np.asarray(gi)[:nq].astype(np.int64)
        ids[~np.isfinite(scores)] = -1
        if int8:
            scores, ids = self._rerank(queries, scores, ids, k)
        st = np.asarray(st)
        dt = time.perf_counter() - t0
        self.dispatches += 1
        self.queries += nq
        self.wall_s += dt
        self.tile_skipped += int(st[0])
        self.tile_total += int(st[1])
        return SearchResult(
            ids=ids,
            scores=scores,
            stats={
                "backend": "spmd",
                "wall_s": dt,
                "buckets": [(qb_b, cap_b)],
                "tile_skipped": int(st[0]),
                "tile_total": int(st[1]),
                "pad_queries": qb_b - nq,
                "compiled": self.compiles > compiles_before,
                "splits": 1,
                "precision": self.precision,
                "rerank_k": k_step if int8 else 0,
                "cold": int(self.tier == "host"),
                "bytes_streamed": cold_bytes,
                "prefetch_hits": pf_hit,
            },
        )

    # -------------------------------------------------------------- rerank
    def _rerank(self, queries: np.ndarray, s1_scores: np.ndarray,
                s1_ids: np.ndarray, k: int):
        """Exact fp32 re-rank of int8 stage-1 survivors.

        Stage 1 returns the quantized-metric top ``K' = k·rerank_factor``
        external ids; this gathers their original fp32 vectors and returns
        the *exact* L2 top-k of that survivor set — identical scores to
        the fp32 path whenever the true top-k survive stage 1."""
        nq, kp = s1_ids.shape
        if self._id_order is None:
            self._id_order = np.argsort(self.index.ids, kind="stable")
            self._sorted_ids = self.index.ids[self._id_order]
        valid = np.isfinite(s1_scores) & (s1_ids >= 0)
        safe = np.where(valid, s1_ids, self._sorted_ids[0])
        pos = np.searchsorted(self._sorted_ids, safe)
        rows = self._id_order[np.clip(pos, 0, self.index.nb - 1)]
        xg = self.index.x[rows]                      # [nq, kp, D] fp32 gather
        d = (
            np.sum(queries * queries, axis=1)[:, None]
            - 2.0 * np.einsum("md,mkd->mk", queries, xg)
            + self.index.xnorm2[rows]
        ).astype(np.float32)
        d = np.where(valid, d, np.inf)
        if kp > k:
            sel = np.argpartition(d, kth=k - 1, axis=1)[:, :k]
        else:
            sel = np.broadcast_to(np.arange(kp)[None, :], (nq, kp))
        sc = np.take_along_axis(d, sel, axis=1)
        order = np.argsort(sc, axis=1, kind="stable")
        sel = np.take_along_axis(sel, order, axis=1)
        sc = np.take_along_axis(sc, order, axis=1)
        out_ids = np.take_along_axis(s1_ids, sel, axis=1)
        out_ids[~np.isfinite(sc)] = -1
        if sc.shape[1] < k:                          # tiny corpus: pad to k
            pad = k - sc.shape[1]
            sc = np.pad(sc, ((0, 0), (0, pad)), constant_values=np.inf)
            out_ids = np.pad(out_ids, ((0, 0), (0, pad)), constant_values=-1)
        return sc, out_ids

    # ----------------------------------------------------------- reporting
    @property
    def compiles(self) -> int:
        return sum(self.trace_counts.values())

    def stats_summary(self) -> dict:
        """JSON-friendly digest (the benchmark harness folds this into the
        serving results blob)."""
        return {
            "precision": self.precision,
            "tier": self.tier,
            "cold_dispatches": self.cold_dispatches,
            "bytes_streamed": self.bytes_streamed,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_misses": self.prefetch_misses,
            "prefetch_staged": self.prefetch_staged,
            "dispatches": self.dispatches,
            "queries": self.queries,
            "wall_s": self.wall_s,
            "compiles": self.compiles,
            "buckets_compiled": {
                f"qb{qb}_cap{cap}_k{k}_p{p}": n
                for (qb, cap, k, p), n in sorted(self.trace_counts.items())
            },
            "qb_buckets": list(self.qb_buckets),
            "cap_buckets": list(self.cap_buckets),
            "tile_skipped": self.tile_skipped,
            "tile_total": self.tile_total,
            "tile_skip_frac": self.tile_skipped / max(self.tile_total, 1),
        }
