"""Batched ANNS serving engine — the paper-kind production serving loop.

Requests (query vectors) arrive on a queue; the engine forms batches
(window = ``query_block``, the vector-level pipeline granularity of
Alg. 1), routes them through the planner's current plan, executes the
HARMONY staged engine, and returns per-request top-K. Integration points:

* **load-aware re-planning**: a sliding workload sample (recent probes)
  periodically refreshes the plan via the §4.2 cost model;
* **elastic**: node failures trigger ``replan_on_failure`` — results are
  unchanged, capacity shrinks;
* **straggler hedging**: per-visit deadlines re-issue work to peers
  (``HedgingExecutor``);
* results cache the paper's stats (pruning ratios, per-shard load) for
  the benchmark harnesses.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.config import HarmonyConfig
from repro.core import (
    IVFIndex,
    ShardedCorpus,
    assign_queries,
    harmony_search,
    plan_search,
    preassign,
)
from repro.runtime import ClusterState, replan_on_failure


@dataclass
class ServeStats:
    batches: int = 0
    queries: int = 0
    wall_s: float = 0.0
    replans: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    @property
    def qps(self) -> float:
        return self.queries / self.wall_s if self.wall_s else 0.0

    def latency_pct(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) if self.latencies_ms else 0.0


class HarmonyServer:
    """Single-process serving engine over the HARMONY core."""

    def __init__(
        self,
        index: IVFIndex,
        n_nodes: int,
        cfg: Optional[HarmonyConfig] = None,
        replan_every: int = 0,          # batches between plan refreshes (0=off)
        workload_window: int = 2048,
    ):
        self.index = index
        self.cfg = cfg or index.cfg
        self.cluster = ClusterState.fresh(n_nodes)
        self.replan_every = replan_every
        self._recent_probes: Deque[np.ndarray] = deque(maxlen=workload_window)
        self.stats = ServeStats()
        self._plan_decision, self.corpus = self._plan(None)

    # ------------------------------------------------------------- planning
    def _plan(self, probes_sample):
        decision = plan_search(
            self.index, self.cluster.n_live, self.cfg, probes_sample=probes_sample
        )
        return decision, preassign(self.index, decision.plan)

    def refresh_plan(self):
        sample = (
            np.concatenate(list(self._recent_probes), axis=0)
            if self._recent_probes
            else None
        )
        self._plan_decision, self.corpus = self._plan(sample)
        self.stats.replans += 1

    @property
    def plan(self):
        return self._plan_decision.plan

    # -------------------------------------------------------------- elastic
    def fail_node(self, node: int):
        self.cluster.fail(node)
        sample = (
            np.concatenate(list(self._recent_probes), axis=0)
            if self._recent_probes
            else None
        )
        self._plan_decision, self.corpus = replan_on_failure(
            self.index, self.cluster, self.cfg, sample
        )
        self.stats.replans += 1

    def join_node(self):
        self.cluster.join()
        self.refresh_plan()

    # -------------------------------------------------------------- serving
    def search_batch(self, queries: np.ndarray, k: Optional[int] = None):
        """One batch through the engine; records workload + stats."""
        t0 = time.perf_counter()
        probes = assign_queries(self.index, queries)
        self._recent_probes.append(probes)
        res = harmony_search(self.index, self.corpus, queries, k=k)
        dt = time.perf_counter() - t0
        self.stats.batches += 1
        self.stats.queries += queries.shape[0]
        self.stats.wall_s += dt
        self.stats.latencies_ms.append(dt * 1e3)
        if self.replan_every and self.stats.batches % self.replan_every == 0:
            self.refresh_plan()
        return res

    def serve(self, request_stream, k: Optional[int] = None):
        """Drain an iterable of query batches; returns list of results."""
        return [self.search_batch(q, k) for q in request_stream]
