"""Batched ANNS serving engine — the paper-kind production serving loop.

Requests (query vectors) arrive on a queue; the engine forms batches
(window = ``query_block``, the vector-level pipeline granularity of
Alg. 1), routes them through the planner's current plan, executes the
HARMONY staged engine, and returns per-request top-K. Integration points:

* **load-aware re-planning**: a sliding workload sample (recent probes)
  periodically refreshes the plan via the §4.2 cost model;
* **elastic**: node failures trigger ``replan_on_failure`` — results are
  unchanged, capacity shrinks;
* **straggler hedging**: per-visit deadlines re-issue work to peers
  (``HedgingExecutor``);
* results cache the paper's stats (pruning ratios, per-shard load) for
  the benchmark harnesses.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.config import HarmonyConfig
from repro.core import (
    IVFIndex,
    ShardedCorpus,
    assign_queries,
    harmony_search,
    plan_search,
    preassign,
)
from repro.runtime import ClusterState, replan_on_failure


@dataclass
class ServeStats:
    """Serving counters and timing samples.

    Unit convention (suffixes are authoritative): ``*_s`` fields are
    **seconds**, ``*_ms`` fields are **milliseconds**; unsuffixed fields
    are counts. Execution-side timings (``wall_s``, ``latencies_ms``)
    are *measured* process wall time of ``search_batch``; the
    admission-side timings (``queue_wait_ms``, ``request_latency_ms``)
    are on whichever clock drives serving — the virtual trace clock
    under ``ServingScheduler`` replays, the wall clock under the live
    ``ServingFrontend``."""

    batches: int = 0                 # search_batch calls
    queries: int = 0                 # rows across those batches
    wall_s: float = 0.0              # summed measured batch wall (seconds)
    replans: int = 0
    latencies_ms: List[float] = field(default_factory=list)  # per batch (ms)

    spmd_batches: int = 0            # batches served by the device executor

    # --- admission-controlled scheduler accounting (repro.serve.scheduler)
    offered: int = 0                 # requests submitted to admission control
    admitted: int = 0                # requests accepted into the queue
    shed: int = 0                    # requests rejected by backpressure
    full_batches: int = 0            # batches fired by the size trigger
    deadline_batches: int = 0        # batches fired by the max-wait deadline
    capacity_batches: int = 0        # fired early because the queue hit its bound
    skew_replans: int = 0            # re-plans triggered by hot-mass drift
    hedged_batches: int = 0          # batch dispatches whose hedge fired
    queue_wait_ms: List[float] = field(default_factory=list)     # per request
    request_latency_ms: List[float] = field(default_factory=list)  # arrival→done

    @property
    def qps(self) -> float:
        """Queries per second of *summed batch execution wall*
        (``queries / wall_s``) — engine throughput while serving, not
        end-to-end trace throughput (idle gaps between batches don't
        count; for trace-level QPS see ``ServingScheduler.served_qps`` /
        ``ServingFrontend.served_qps``)."""
        return self.queries / self.wall_s if self.wall_s else 0.0

    def latency_pct(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) if self.latencies_ms else 0.0

    def queue_wait_pct(self, p: float) -> float:
        return float(np.percentile(self.queue_wait_ms, p)) if self.queue_wait_ms else 0.0

    def request_latency_pct(self, p: float) -> float:
        return (
            float(np.percentile(self.request_latency_ms, p))
            if self.request_latency_ms
            else 0.0
        )

    def _pct_or_none(self, arr: List[float], p: float) -> Optional[float]:
        # empty-array quantiles raise in numpy; a trace where nothing
        # completed (everything shed, or summarised pre-flush) reports
        # None instead of a misleading 0.0 — and never raises
        return float(np.percentile(arr, p)) if arr else None

    def summary(self) -> dict:
        """JSON-friendly digest for the serving benchmarks. Percentile
        fields are ``None`` when no request completed.

        Units: every ``p50_*``/``p99_*`` key is **milliseconds** (the
        ``_ms`` suffix is part of the key); all other keys are plain
        counts. ``p50/p99_queue_wait_ms`` measure arrival → batch
        dispatch; ``p50/p99_request_latency_ms`` measure arrival → batch
        completion (so latency ≥ queue wait for the same request). The
        full schema is documented in ``benchmarks/README.md``."""
        return {
            "batches": self.batches,
            "spmd_batches": self.spmd_batches,
            "queries": self.queries,
            "replans": self.replans,
            "offered": self.offered,
            "admitted": self.admitted,
            "shed": self.shed,
            "full_batches": self.full_batches,
            "deadline_batches": self.deadline_batches,
            "capacity_batches": self.capacity_batches,
            "skew_replans": self.skew_replans,
            "hedged_batches": self.hedged_batches,
            "p50_queue_wait_ms": self._pct_or_none(self.queue_wait_ms, 50),
            "p99_queue_wait_ms": self._pct_or_none(self.queue_wait_ms, 99),
            "p50_request_latency_ms": self._pct_or_none(self.request_latency_ms, 50),
            "p99_request_latency_ms": self._pct_or_none(self.request_latency_ms, 99),
        }


class HarmonyServer:
    """Single-process serving engine over the HARMONY core.

    Owns one partition plan (cost-model chosen, refreshed on workload
    drift or node failure), a simulated cluster of ``n_nodes``, and the
    backend switch between the host numpy engine and the device-resident
    SPMD executor. One server = one replica; stack several behind a
    :class:`repro.serve.fleet.ReplicaFleet` to scale out.

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> from repro.core import build_ivf
    >>> rng = np.random.default_rng(0)
    >>> x = rng.standard_normal((256, 8)).astype(np.float32)
    >>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3,
    ...                     kmeans_iters=2)
    >>> srv = HarmonyServer(build_ivf(x, cfg), n_nodes=2)
    >>> res = srv.search_batch(x[:4], k=3)      # one batch, top-3 each
    >>> res.ids.shape, res.scores.shape
    ((4, 3), (4, 3))
    >>> bool((res.ids[:, 0] == np.arange(4)).all())   # self-NN is exact
    True
    >>> srv.stats.batches, srv.stats.queries
    (1, 4)
    """

    def __init__(
        self,
        index: IVFIndex,
        n_nodes: int,
        cfg: Optional[HarmonyConfig] = None,
        replan_every: int = 0,          # batches between plan refreshes (0=off)
        workload_window: int = 2048,
        backend: str = "host",          # "host" | "spmd" default for batches
        executor_cfg=None,              # ExecutorConfig for the spmd backend
    ):
        assert backend in ("host", "spmd"), backend
        self.index = index
        self.cfg = cfg or index.cfg
        self.cluster = ClusterState.fresh(n_nodes)
        self.replan_every = replan_every
        self.backend = backend
        self._executor_cfg = executor_cfg
        self._executor = None           # built lazily on first spmd batch
        self._recent_probes: Deque[np.ndarray] = deque(maxlen=workload_window)
        self.stats = ServeStats()
        self._plan_decision, self.corpus = self._plan(None)

    @property
    def executor(self):
        """Lazily-built device-resident executor (the "spmd" backend).

        Self-contained w.r.t. re-planning: the executor keeps its own
        mesh-shaped corpus packing, so host-plan refreshes (skew drift,
        fail_node) never force a corpus re-upload — results are
        plan-invariant by the exactness guarantee."""
        if self._executor is None:
            from repro.serve.executor import SpmdExecutor

            self._executor = SpmdExecutor(self.index, self._executor_cfg)
        return self._executor

    # ------------------------------------------------------------- planning
    def _plan(self, probes_sample):
        decision = plan_search(
            self.index, self.cluster.n_live, self.cfg, probes_sample=probes_sample
        )
        return decision, preassign(self.index, decision.plan)

    def refresh_plan(self):
        sample = (
            np.concatenate(list(self._recent_probes), axis=0)
            if self._recent_probes
            else None
        )
        self._plan_decision, self.corpus = self._plan(sample)
        self.stats.replans += 1

    @property
    def plan(self):
        return self._plan_decision.plan

    # -------------------------------------------------------------- elastic
    def fail_node(self, node: int):
        self.cluster.fail(node)
        sample = (
            np.concatenate(list(self._recent_probes), axis=0)
            if self._recent_probes
            else None
        )
        self._plan_decision, self.corpus = replan_on_failure(
            self.index, self.cluster, self.cfg, sample
        )
        self.stats.replans += 1

    def join_node(self):
        self.cluster.join()
        self.refresh_plan()

    # -------------------------------------------------------------- serving
    def search_batch(
        self,
        queries: np.ndarray,
        k: Optional[int] = None,
        backend: Optional[str] = None,
    ):
        """One batch through the engine; records workload + stats.

        ``backend="host"`` runs the staged numpy engine (the exactness
        oracle); ``backend="spmd"`` dispatches into the device-resident
        executor. Results are identical up to floating-point tie order."""
        backend = backend or self.backend
        t0 = time.perf_counter()
        probes = assign_queries(self.index, queries)
        self._recent_probes.append(probes)
        if backend == "spmd":
            res = self.executor.search_batch(queries, k=k, probes=probes)
            self.stats.spmd_batches += 1
        else:
            res = harmony_search(self.index, self.corpus, queries, k=k)
        dt = time.perf_counter() - t0
        self.stats.batches += 1
        self.stats.queries += queries.shape[0]
        self.stats.wall_s += dt
        self.stats.latencies_ms.append(dt * 1e3)
        if self.replan_every and self.stats.batches % self.replan_every == 0:
            self.refresh_plan()
        return res

    def serve(self, request_stream, k: Optional[int] = None, sched=None,
              arrivals=None):
        """Admission-controlled scheduled serving of an iterable of query
        batches. Incoming batches are flattened into per-query requests and
        pushed through :class:`repro.serve.scheduler.ServingScheduler`,
        which re-forms batches adaptively (size/deadline triggers) and
        keeps :meth:`search_batch` as the inner execution primitive (the
        host engine or, with ``sched.backend="spmd"``, the device-resident
        executor). Returns one ``SearchResult`` per input batch, aligned
        with the stream (the synchronous drain-loop contract).

        ``arrivals`` optionally supplies per-batch arrival timestamps for
        replayed traces (aligned with ``request_stream``; each entry is a
        scalar for the whole batch or a per-row sequence, non-decreasing
        across the stream). Without it every request arrives at t=0 and
        queue-wait/deadline statistics degenerate."""
        from repro.core.types import SearchResult
        from repro.serve.scheduler import SchedulerConfig, ServingScheduler

        sched_cfg = sched or SchedulerConfig()   # unbounded queue by default
        k = k or self.cfg.topk
        scheduler = ServingScheduler(self, sched_cfg, k=k)
        owners: Dict[int, tuple] = {}            # req_id → (batch_idx, row)
        shapes: List[int] = []
        arr_iter = iter(arrivals) if arrivals is not None else None
        for bi, qb in enumerate(request_stream):
            qb = np.asarray(qb)
            shapes.append(qb.shape[0])
            if arr_iter is None:
                t_b = 0.0
            else:
                try:
                    t_b = next(arr_iter)
                except StopIteration:
                    raise ValueError(
                        f"arrivals exhausted at batch {bi}: it must yield "
                        "one timestamp (or per-row sequence) per "
                        "request_stream batch"
                    ) from None
            for r in range(qb.shape[0]):
                t_r = float(t_b) if np.ndim(t_b) == 0 else float(t_b[r])
                rid = scheduler.submit(qb[r], arrival_s=t_r)
                if rid >= 0:
                    owners[rid] = (bi, r)
                # shed requests (bounded sched config) keep their -1/inf
                # placeholder rows in the output
        done = scheduler.flush()

        out = [
            SearchResult(
                ids=np.full((n, k), -1, np.int64),
                scores=np.full((n, k), np.inf, np.float32),
                stats={"scheduled": True, "wall_s": 0.0, "queue_wait_ms": []},
            )
            for n in shapes
        ]
        for rr in done:
            bi, r = owners.get(rr.req_id, (None, None))
            if bi is None:
                continue
            out[bi].ids[r] = rr.ids
            out[bi].scores[r] = rr.scores
            st = out[bi].stats
            # per-input-batch wall = first arrival → last completion of its
            # requests on the scheduler's virtual clock
            st["wall_s"] = max(st["wall_s"], rr.done_s - rr.arrival_s)
            st["queue_wait_ms"].append(rr.queue_wait_s * 1e3)
        return out
