"""Batched ANNS serving engine — the paper-kind production serving loop.

Requests (query vectors) arrive on a queue; the engine forms batches
(window = ``query_block``, the vector-level pipeline granularity of
Alg. 1), routes them through the planner's current plan, executes the
HARMONY staged engine, and returns per-request top-K. Integration points:

* **load-aware re-planning**: a sliding workload sample (recent probes)
  periodically refreshes the plan via the §4.2 cost model;
* **elastic**: node failures trigger a survivor re-plan — results are
  unchanged, capacity shrinks;
* **straggler hedging**: per-visit deadlines re-issue work to peers
  (``HedgingExecutor``);
* results cache the paper's stats (pruning ratios, per-shard load) for
  the benchmark harnesses.

Mutable data plane (PR 5): the server no longer owns one frozen
``IVFIndex`` — it serves a :class:`repro.core.SegmentedIndex` (sealed
segments + delta buffer + tombstones; a plain ``IVFIndex`` is wrapped as
the one-sealed-segment special case). Per segment the server derives a
cost-model plan, a host ``ShardedCorpus``, and (lazily, for the spmd
backend) a device-resident :class:`~repro.serve.executor.SpmdExecutor`;
a batch searches every sealed segment (tombstone-masked) plus a
brute-force delta scan and merges the per-segment top-Ks — through the
fused ``running_topk_update`` kernel on the spmd path. Derived state is
keyed by segment id and adopted per data-plane *generation*: a
compaction commit bumps the generation and the server hot-swaps to the
new segment set on its next batch (or eagerly via
:class:`repro.serve.compactor.Compactor`, which pre-builds the derived
state off the serving path so the swap is O(1)).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core import (
    DataSnapshot,
    Segment,
    SegmentedIndex,
    assign_queries,
    delta_topk,
    filter_excluded_rows,
    filtered_assign_queries,
    harmony_search,
    merge_topk,
    plan_search,
    preassign,
    two_stage_search,
)
from repro.core.fusion import BM25Index, reciprocal_rank_fusion, segment_bm25
from repro.core.index import meta_rows_to_store
from repro.core.types import DataPlane, Filter, SearchRequest, SearchResult
from repro.runtime import ClusterState


@dataclass
class ServeStats:
    """Serving counters and timing samples.

    Unit convention (suffixes are authoritative): ``*_s`` fields are
    **seconds**, ``*_ms`` fields are **milliseconds**; unsuffixed fields
    are counts. Execution-side timings (``wall_s``, ``latencies_ms``)
    are *measured* process wall time of ``search_batch``; the
    admission-side timings (``queue_wait_ms``, ``request_latency_ms``)
    are on whichever clock drives serving — the virtual trace clock
    under ``ServingScheduler`` replays, the wall clock under the live
    ``ServingFrontend``."""

    batches: int = 0                 # search_batch calls
    queries: int = 0                 # rows across those batches
    wall_s: float = 0.0              # summed measured batch wall (seconds)
    replans: int = 0
    latencies_ms: List[float] = field(default_factory=list)  # per batch (ms)

    spmd_batches: int = 0            # batches served by the device executor

    # --- mutable-data-plane accounting
    upserts: int = 0                 # vector rows upserted
    deletes: int = 0                 # delete calls' id rows
    generation_swaps: int = 0        # data-plane generations adopted

    # --- admission-controlled scheduler accounting (repro.serve.scheduler)
    offered: int = 0                 # requests submitted to admission control
    admitted: int = 0                # requests accepted into the queue
    shed: int = 0                    # requests rejected by backpressure
    full_batches: int = 0            # batches fired by the size trigger
    deadline_batches: int = 0        # batches fired by the max-wait deadline
    capacity_batches: int = 0        # fired early because the queue hit its bound
    skew_replans: int = 0            # re-plans triggered by hot-mass drift
    hedged_batches: int = 0          # batch dispatches whose hedge fired
    queue_wait_ms: List[float] = field(default_factory=list)     # per request
    request_latency_ms: List[float] = field(default_factory=list)  # arrival→done

    # --- resilience accounting (fleet circuit breaker + dispatch retries)
    replica_failures: int = 0        # replica executions that raised
    breaker_opens: int = 0           # circuit-breaker ejections
    breaker_closes: int = 0          # half-open probes that re-admitted
    health_probes: int = 0           # explicit half-open health checks run
    retried_batches: int = 0         # batch dispatch attempts after a failure
    failed_batches: int = 0          # batches that exhausted every retry
    failed_requests: int = 0         # requests inside those failed batches
    shutdown_leaks: int = 0          # frontend shutdowns leaving live threads

    # --- semantic cache + coalescing front door (repro.serve.cache):
    # cache-off (the default) leaves all six at 0. Hits and expirations
    # complete at admission, so on the scheduler
    # offered == admitted + shed + expired_requests + cache hits, and the
    # front-end additionally subtracts coalesced (followers never queue;
    # the virtual scheduler coalesces at dispatch, inside admitted).
    cache_hits_exact: int = 0        # answered verbatim from the exact tier
    cache_hits_semantic: int = 0     # answered from a cached neighbor
    cache_misses: int = 0            # lookups that fell through to execution
    cache_invalidations: int = 0     # entries dropped (epoch/TTL/explicit)
    coalesced: int = 0               # duplicates that shared an execution
    expired_requests: int = 0        # per-request deadlines enforced

    # --- tiered-corpus accounting (memory hierarchy): all 0 while every
    # segment is device-resident (the default placement)
    cold_batches: int = 0            # batches touching ≥1 host-tier segment
    bytes_streamed: int = 0          # cold candidate bytes uploaded
    prefetch_hits: int = 0           # cold uploads pre-staged by lookahead
    placement_swaps: int = 0         # tier placements adopted

    @property
    def qps(self) -> float:
        """Queries per second of *summed batch execution wall*
        (``queries / wall_s``) — engine throughput while serving, not
        end-to-end trace throughput (idle gaps between batches don't
        count; for trace-level QPS see ``ServingScheduler.served_qps`` /
        ``ServingFrontend.served_qps``)."""
        return self.queries / self.wall_s if self.wall_s else 0.0

    def latency_pct(self, p: float) -> float:
        return float(np.percentile(self.latencies_ms, p)) if self.latencies_ms else 0.0

    def queue_wait_pct(self, p: float) -> float:
        return float(np.percentile(self.queue_wait_ms, p)) if self.queue_wait_ms else 0.0

    def request_latency_pct(self, p: float) -> float:
        return (
            float(np.percentile(self.request_latency_ms, p))
            if self.request_latency_ms
            else 0.0
        )

    def _pct_or_none(self, arr: List[float], p: float) -> Optional[float]:
        # empty-array quantiles raise in numpy; a trace where nothing
        # completed (everything shed, or summarised pre-flush) reports
        # None instead of a misleading 0.0 — and never raises
        return float(np.percentile(arr, p)) if arr else None

    def summary(self) -> dict:
        """JSON-friendly digest for the serving benchmarks. Percentile
        fields are ``None`` when no request completed.

        Units: every ``p50_*``/``p99_*`` key is **milliseconds** (the
        ``_ms`` suffix is part of the key); all other keys are plain
        counts. ``p50/p99_queue_wait_ms`` measure arrival → batch
        dispatch; ``p50/p99_request_latency_ms`` measure arrival → batch
        completion (so latency ≥ queue wait for the same request). The
        full schema is documented in ``benchmarks/README.md``."""
        return {
            "batches": self.batches,
            "spmd_batches": self.spmd_batches,
            "queries": self.queries,
            "replans": self.replans,
            "upserts": self.upserts,
            "deletes": self.deletes,
            "generation_swaps": self.generation_swaps,
            "offered": self.offered,
            "admitted": self.admitted,
            "shed": self.shed,
            "full_batches": self.full_batches,
            "deadline_batches": self.deadline_batches,
            "capacity_batches": self.capacity_batches,
            "skew_replans": self.skew_replans,
            "hedged_batches": self.hedged_batches,
            "replica_failures": self.replica_failures,
            "breaker_opens": self.breaker_opens,
            "breaker_closes": self.breaker_closes,
            "health_probes": self.health_probes,
            "retried_batches": self.retried_batches,
            "failed_batches": self.failed_batches,
            "failed_requests": self.failed_requests,
            "shutdown_leaks": self.shutdown_leaks,
            "cache_hits_exact": self.cache_hits_exact,
            "cache_hits_semantic": self.cache_hits_semantic,
            "cache_misses": self.cache_misses,
            "cache_invalidations": self.cache_invalidations,
            "coalesced": self.coalesced,
            "expired_requests": self.expired_requests,
            "cold_batches": self.cold_batches,
            "bytes_streamed": self.bytes_streamed,
            "prefetch_hits": self.prefetch_hits,
            "placement_swaps": self.placement_swaps,
            "p50_queue_wait_ms": self._pct_or_none(self.queue_wait_ms, 50),
            "p99_queue_wait_ms": self._pct_or_none(self.queue_wait_ms, 99),
            "p50_request_latency_ms": self._pct_or_none(self.request_latency_ms, 50),
            "p99_request_latency_ms": self._pct_or_none(self.request_latency_ms, 99),
        }


@dataclass
class _SegmentState:
    """Per-(server, sealed segment) derived serving state."""

    segment: Segment
    decision: object                 # PlanDecision for this segment
    corpus: object                   # ShardedCorpus (host engine layout)
    executor: object = None          # SpmdExecutor, built lazily (spmd)
    tier: str = "device"             # executor residency: "device" | "host"

    @property
    def int32_ids(self) -> bool:
        """Do this segment's external ids fit the device pipeline's int32
        id carrier? Cached — segments are immutable. A segment with
        larger ids is served by the host engine even under the spmd
        backend (silent id wraparound is never acceptable)."""
        cached = self.__dict__.get("_int32_ids")
        if cached is None:
            ids = self.segment.index.ids
            cached = bool(
                np.abs(ids).max(initial=0) <= np.iinfo(np.int32).max
            )
            self.__dict__["_int32_ids"] = cached
        return cached


class HarmonyServer(DataPlane):
    """Single-process serving engine over the HARMONY core.

    Owns the shared :class:`repro.core.SegmentedIndex` data plane (a
    plain ``IVFIndex`` is wrapped as one sealed segment), per-segment
    plans/corpora/executors derived for its simulated cluster of
    ``n_nodes``, and the backend switch between the host numpy engine
    and the device-resident SPMD executor. One server = one replica;
    stack several behind a :class:`repro.serve.fleet.ReplicaFleet` to
    scale out — replicas then share the *same* data plane object, so an
    ``upsert``/``delete`` on any surface is visible fleet-wide.

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> from repro.core import build_ivf
    >>> rng = np.random.default_rng(0)
    >>> x = rng.standard_normal((256, 8)).astype(np.float32)
    >>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3,
    ...                     kmeans_iters=2)
    >>> srv = HarmonyServer(build_ivf(x, cfg), n_nodes=2)
    >>> res = srv.search_batch(x[:4], k=3)      # one batch, top-3 each
    >>> res.ids.shape, res.scores.shape
    ((4, 3), (4, 3))
    >>> bool((res.ids[:, 0] == np.arange(4)).all())   # self-NN is exact
    True
    >>> srv.stats.batches, srv.stats.queries
    (1, 4)
    >>> srv.upsert([999], x[:1] + 10.0)         # streaming write...
    >>> n = srv.delete([0])                     # ...and a tombstone
    >>> srv.data.delta_len, n
    (1, 1)
    >>> int(srv.search_batch(x[:1] + 10.0, k=1).ids[0, 0])  # reachable now
    999
    >>> from repro.core import SearchRequest, TagIn
    >>> srv.upsert([1000, 1001], x[:2] + 20.0, meta={"color": [1, 2]})
    >>> req = SearchRequest(vector=x[0] + 20.0, k=1,
    ...                     filter=TagIn("color", (2,)))
    >>> int(srv.search_batch(req).ids[0, 0])    # only color=2 is allowed
    1001
    """

    def __init__(
        self,
        index,
        n_nodes: int,
        cfg: Optional[HarmonyConfig] = None,
        replan_every: int = 0,          # batches between plan refreshes (0=off)
        workload_window: int = 2048,
        backend: str = "host",          # "host" | "spmd" default for batches
        executor_cfg=None,              # ExecutorConfig for the spmd backend
        precision: str = "fp32",        # "int8" → quantized tier + fp32 re-rank
    ):
        assert backend in ("host", "spmd"), backend
        assert precision in ("fp32", "int8"), precision
        self.data: SegmentedIndex = (
            index if isinstance(index, SegmentedIndex)
            else SegmentedIndex.from_static(index)
        )
        self.cfg = cfg or self.data.cfg
        self.precision = precision
        if precision == "int8":
            assert self.cfg.metric == "l2", "int8 tier is L2-only"
        self.cluster = ClusterState.fresh(n_nodes)
        self.replan_every = replan_every
        self.backend = backend
        self._executor_cfg = executor_cfg
        self._recent_probes: Deque[np.ndarray] = deque(maxlen=workload_window)
        self.stats = ServeStats()
        # per-segment derived state, adopted per data-plane generation
        self._dp_mu = threading.Lock()
        self._seg_states: Dict[int, _SegmentState] = {}
        self._staged: Dict[int, _SegmentState] = {}
        self._generation = -1
        self._placement_version = -1
        self._plan_decision = None
        self._sync(self.data.snapshot())

    # ------------------------------------------------------------- data plane
    @property
    def index(self) -> SegmentedIndex:
        """The (shared) data plane — kept under the historical name so
        ``server.index.nlist``-style call sites keep working."""
        return self.data

    @property
    def generation(self) -> int:
        """Data-plane generation this server has adopted."""
        return self._generation

    # upsert()/delete() come from the DataPlane mixin; the server's whole
    # contribution is where writes go and which counters they bump
    def _data_plane(self) -> SegmentedIndex:
        return self.data

    def _note_write(self, kind: str, n: int) -> None:
        if kind == "upsert":
            self.stats.upserts += n
        else:
            self.stats.deletes += n

    @staticmethod
    def _primary(segments) -> Optional[Segment]:
        return max(segments, key=lambda s: (s.nb, -s.seg_id), default=None)

    def _build_state(self, seg: Segment,
                     probes_sample: Optional[np.ndarray] = None,
                     tier: str = "device") -> _SegmentState:
        decision = plan_search(
            seg.index, self.cluster.n_live, self.cfg.replace(
                nlist=seg.index.nlist,
                nprobe=min(self.cfg.nprobe, seg.index.nlist),
            ),
            probes_sample=probes_sample,
        )
        if self.precision == "int8":
            # eager: quantize off the serving path (idempotent — seal()
            # already populated the cache for segments born in this plane)
            seg.index.int8_quant(self.cfg.quant_blocks)
        return _SegmentState(
            segment=seg, decision=decision,
            corpus=preassign(seg.index, decision.plan),
            tier=tier,
        )

    def _executor_for(self, st: _SegmentState):
        if st.executor is None:
            import dataclasses as _dc

            from repro.serve.executor import ExecutorConfig, SpmdExecutor

            ecfg = self._executor_cfg or ExecutorConfig()
            if self.precision == "int8" and ecfg.precision != "int8":
                ecfg = _dc.replace(ecfg, precision="int8",
                                   rerank_factor=self.cfg.rerank_factor)
            st.executor = SpmdExecutor(st.segment.index, ecfg, tier=st.tier)
        return st.executor

    def _sync(self, snap: DataSnapshot) -> bool:
        """Adopt a data-plane snapshot: build (or promote pre-staged)
        derived state for new segments, drop state of retired ones. The
        compile caches of retired segments' executors die with them —
        the cache is effectively keyed by (segment id, generation).

        Generations only move forward: a thread carrying a snapshot older
        than the adopted generation must NOT roll the server back (it
        would destroy the compactor's freshly prepared state mid-swap) —
        it returns False and the caller re-snapshots. The same applies to
        tier placement: a stale ``placement_version`` never demotes or
        promotes a segment (results are tier-invariant, so serving a few
        batches on the old residency is correct, just differently
        paced)."""
        with self._dp_mu:
            if snap.generation < self._generation:
                return False
            tiers = snap.tiers or {}
            fresh_placement = snap.placement_version >= self._placement_version
            for seg in snap.segments:
                want = (tiers.get(seg.seg_id, "device")
                        if fresh_placement else None)
                st = self._seg_states.get(seg.seg_id)
                if st is None:
                    st = self._staged.pop(seg.seg_id, None)
                    if st is None or (want is not None and st.tier != want):
                        st = self._build_state(seg, tier=want or "device")
                    self._seg_states[seg.seg_id] = st
                elif want is not None and st.tier != want:
                    # tier move: promote the placement-prepared state if
                    # one is staged, else rebuild residency inline (the
                    # lazy-resync path after a crashed swap)
                    staged = self._staged.pop(seg.seg_id, None)
                    if staged is not None and staged.tier == want:
                        self._seg_states[seg.seg_id] = staged
                    else:
                        self._seg_states[seg.seg_id] = self._build_state(
                            seg, tier=want
                        )
            keep = {s.seg_id for s in snap.segments}
            for sid in list(self._seg_states):
                if sid not in keep:
                    del self._seg_states[sid]
            self._staged = {s: st for s, st in self._staged.items() if s in keep}
            if snap.generation != self._generation:
                if self._generation >= 0:
                    self.stats.generation_swaps += 1
                self._generation = snap.generation
            if fresh_placement and snap.placement_version != self._placement_version:
                if self._placement_version >= 0:
                    self.stats.placement_swaps += 1
                self._placement_version = snap.placement_version
            primary = self._primary(snap.segments)
            if primary is not None:
                self._plan_decision = self._seg_states[primary.seg_id].decision
            return True

    def prepare_segments(self, segments) -> None:
        """Pre-build derived state for segments about to be committed (the
        compactor calls this *before* the swap, off the serving path, so
        adoption is O(1) and read p99 stays flat through a compaction)."""
        for seg in segments:
            with self._dp_mu:
                known = seg.seg_id in self._seg_states or seg.seg_id in self._staged
            if known:
                continue
            st = self._build_state(seg)
            if self.backend == "spmd" and st.int32_ids:
                self._executor_for(st).warmup(k=self.cfg.topk)
            with self._dp_mu:
                self._staged[seg.seg_id] = st

    def prepare_placement(self, tiers: Dict[int, str]) -> None:
        """Pre-build executor state for segments whose tier is about to
        change — the *prepare* leg of a placement swap
        (:func:`repro.serve.placement.apply_placement`). Runs off the
        serving path so the adopt is O(1), like a compaction swap."""
        snap = self.data.snapshot()
        seg_by_id = {s.seg_id: s for s in snap.segments}
        for sid, want in tiers.items():
            seg = seg_by_id.get(sid)
            if seg is None:
                continue
            with self._dp_mu:
                st = self._seg_states.get(sid)
                staged = self._staged.get(sid)
                ready = ((st is not None and st.tier == want)
                         or (staged is not None and staged.tier == want))
            if ready:
                continue
            new = self._build_state(seg, tier=want)
            if self.backend == "spmd" and new.int32_ids:
                self._executor_for(new).warmup(k=self.cfg.topk)
            with self._dp_mu:
                self._staged[sid] = new

    def prefetch_batch(self, queries) -> None:
        """Lookahead hook (called by the scheduler with the *next* formed
        batch while the current one computes): stage every host-tier
        segment's candidate upload so the async ``device_put`` overlaps
        the in-flight batch's kernels. Purely advisory — a wrong or
        missing prefetch is a ``prefetch_misses`` bump, never a wrong
        answer. No-op on the host backend or an all-device placement."""
        if self.backend != "spmd":
            return
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        snap = self.data.snapshot()
        if (snap.generation != self._generation
                or snap.placement_version != self._placement_version):
            self._sync(snap)
        with self._dp_mu:
            states = [self._seg_states.get(s.seg_id) for s in snap.segments]
        for st in states:
            if st is None or st.tier != "host" or not st.int32_ids:
                continue
            probes = assign_queries(st.segment.index, queries)
            self._executor_for(st).prefetch(
                probes=probes, dead_rows=snap.dead_rows[st.segment.seg_id]
            )

    def adopt(self) -> None:
        """Hot-swap to the data plane's current generation and tier
        placement now (otherwise the next batch adopts lazily)."""
        self._sync(self.data.snapshot())

    def warmup_executors(self, k: Optional[int] = None) -> None:
        """Pre-compile every sealed segment's device executor bucket
        ladder (so no in-trace dispatch pays a jit compile)."""
        snap = self.data.snapshot()
        if snap.generation != self._generation:
            self._sync(snap)
        with self._dp_mu:
            states = [self._seg_states[s.seg_id] for s in snap.segments]
        for st in states:
            if st.int32_ids:
                self._executor_for(st).warmup(k=k)

    @property
    def executor(self):
        """Device executor of the primary (largest) sealed segment —
        back-compat accessor for the single-segment case."""
        with self._dp_mu:
            primary = self._primary([st.segment for st in self._seg_states.values()])
            if primary is None:
                raise RuntimeError("no sealed segments (all data in delta "
                                   "or the corpus is empty)")
            st = self._seg_states[primary.seg_id]
        return self._executor_for(st)

    # ------------------------------------------------------------- planning
    def _window_sample(self) -> Optional[np.ndarray]:
        return (
            np.concatenate(list(self._recent_probes), axis=0)
            if self._recent_probes
            else None
        )

    def refresh_plan(self):
        """Re-plan every sealed segment for the current live node set (the
        workload sample steers the primary segment's assignment; device
        executors keep their own packing and stay resident)."""
        sample = self._window_sample()
        with self._dp_mu:
            states = list(self._seg_states.values())
            primary = self._primary([st.segment for st in states])
            for st in states:
                st.decision = plan_search(
                    st.segment.index, self.cluster.n_live, self.cfg.replace(
                        nlist=st.segment.index.nlist,
                        nprobe=min(self.cfg.nprobe, st.segment.index.nlist),
                    ),
                    probes_sample=sample if st.segment is primary else None,
                )
                st.corpus = preassign(st.segment.index, st.decision.plan)
                if st.segment is primary:
                    self._plan_decision = st.decision
        self.stats.replans += 1

    @property
    def plan(self):
        return self._plan_decision.plan

    @property
    def corpus(self):
        """Host-engine corpus of the primary sealed segment."""
        with self._dp_mu:
            primary = self._primary([st.segment for st in self._seg_states.values()])
            if primary is None:
                raise RuntimeError("no sealed segments (all data in delta "
                                   "or the corpus is empty)")
            return self._seg_states[primary.seg_id].corpus

    # -------------------------------------------------------------- elastic
    def fail_node(self, node: int):
        self.cluster.fail(node)
        if self.cluster.n_live == 0:
            raise RuntimeError("no live nodes")
        self.refresh_plan()

    def join_node(self):
        self.cluster.join()
        self.refresh_plan()

    # -------------------------------------------------------------- serving
    def _delta_allowed(self, snap: DataSnapshot, flt: Filter) -> np.ndarray:
        """Allowed-mask [delta rows] of the snapshot's delta buffer under
        ``flt`` (the delta's per-row metadata dicts, columnarized on the
        fly — the buffer is small by construction)."""
        n = snap.delta_ids.size
        store = meta_rows_to_store(list(snap.delta_meta))
        if store is None:
            return np.zeros(n, bool)
        return flt.evaluate(store.tags, store.nums, n)

    def _lexical_topk(self, snap, states, text, k, flt, delta_live):
        """Global BM25 top-k external ids for ``text`` — the lexical tier
        of a hybrid search (query-independent within a batch: the batch
        shares one ``hybrid_text``). Per sealed segment the cached
        posting index scores under the *same* excluded-row mask the
        vector tier used; the delta buffer is brute-scored; candidates
        merge by score (ties toward the lower id, deterministic)."""
        cands = []                          # (score, ext_id)
        for st in states:
            seg = st.segment
            bm = segment_bm25(seg.index)
            if bm is None:
                continue
            excluded = filter_excluded_rows(
                seg.index, flt, snap.dead_rows[seg.seg_id]
            )
            sc, rows = bm.topk(text, k, excluded=excluded)
            ext = seg.index.ids[rows]
            cands += [(float(s), int(e)) for s, e in zip(sc, ext)]
        if snap.delta_ids.size:
            texts = [(m or {}).get("text") for m in snap.delta_meta]
            if any(texts):
                sc, rows = BM25Index(texts).topk(text, k, excluded=~delta_live)
                cands += [(float(s), int(snap.delta_ids[r]))
                          for s, r in zip(sc, rows)]
        cands.sort(key=lambda c: (-c[0], c[1]))
        return np.array([e for _, e in cands[:k]], np.int64)

    def search_batch(
        self,
        queries,
        k: Optional[int] = None,
        backend: Optional[str] = None,
        flt: Optional[Filter] = None,
        hybrid_text: Optional[str] = None,
        precision: Optional[str] = None,
    ):
        """One batch through the engine; records workload + stats.

        ``queries`` is a [NQ, D] array or a :class:`SearchRequest` (whose
        vector/k/filter/hybrid_text/precision fields fill the matching
        parameters). Searches every sealed segment of the current
        data-plane snapshot (tombstone-masked, ``backend="host"`` via the
        staged numpy engine or ``backend="spmd"`` via the device-resident
        executor), scans the delta buffer brute-force, and merges the
        per-part top-Ks — via the fused ``running_topk_update`` kernel on
        the spmd path. Results are identical across backends up to
        floating-point tie order. The snapshot is taken once per batch: a
        concurrent upsert/delete/compaction never tears an in-flight
        batch.

        A ``flt`` predicate is compiled to per-segment bitmaps and merged
        into the tombstone masking path end-to-end (a filter is just a
        per-query tombstone set): probe selection drops fully-excluded
        clusters (:func:`repro.core.search.filtered_assign_queries`), the
        engines mask filtered rows exactly like dead ones — on the spmd
        backend inside the host-side gather, so the device work and the
        (qb, cap) compile-cache keys are unchanged — and K never
        inflates. ``hybrid_text`` adds the BM25 lexical tier, fused with
        the vector top-k by reciprocal-rank fusion (scores then are
        negated RRF, ``stats["fused"]=True``). ``precision`` overrides
        the server's tier per batch; an override that differs from the
        executor's compiled precision is served by the host engine."""
        if isinstance(queries, SearchRequest):
            req = queries
            queries = np.atleast_2d(np.asarray(req.vector, np.float32))
            k = k if k is not None else req.k
            flt = flt if flt is not None else req.filter
            hybrid_text = (hybrid_text if hybrid_text is not None
                           else req.hybrid_text)
            precision = precision if precision is not None else req.precision
        backend = backend or self.backend
        k = k or self.cfg.topk
        prec = precision or self.precision
        assert prec in ("fp32", "int8"), prec
        if prec == "int8":
            assert self.cfg.metric == "l2", "int8 tier is L2-only"
        t0 = time.perf_counter()
        queries = np.asarray(queries, np.float32)
        while True:
            snap = self.data.snapshot()
            if (snap.generation != self._generation
                    or snap.placement_version != self._placement_version):
                self._sync(snap)
            with self._dp_mu:
                if all(s.seg_id in self._seg_states for s in snap.segments):
                    states = [self._seg_states[s.seg_id] for s in snap.segments]
                    break
            # lost a race with a concurrent adopt(): our snapshot's
            # segments were retired while we read it — generations only
            # move forward, so a fresh snapshot converges immediately
        primary = self._primary(snap.segments)
        seg_results = []
        for st in states:
            seg = st.segment
            dead = snap.dead_rows[seg.seg_id]
            dead_arg = filter_excluded_rows(seg.index, flt, dead)
            if flt is None:
                probes = assign_queries(seg.index, queries)
            else:
                # predicate pushdown: clusters with no allowed live row
                # drop out of probe selection entirely
                probes = filtered_assign_queries(seg.index, queries, dead_arg)
            # feed the placement policy's cluster-hotness EWMA with the
            # actual probe selection (every segment, every batch)
            self.data.note_probes(seg.seg_id, probes)
            if seg is primary:
                self._recent_probes.append(probes)
            if backend == "spmd" and st.int32_ids and prec == self.precision:
                res = self._executor_for(st).search_batch(
                    queries, k=k, probes=probes, dead_rows=dead_arg
                )
            elif prec == "int8":
                res = two_stage_search(
                    seg.index, queries, k=k, probes=probes,
                    rerank_factor=self.cfg.rerank_factor,
                    dead_rows=dead_arg,
                    quant_blocks=self.cfg.quant_blocks,
                )
            else:
                res = harmony_search(
                    seg.index, st.corpus, queries, k=k, probes=probes,
                    dead_rows=dead_arg,
                    # the dead-mask device cache is keyed by (generation,
                    # dead_version) only — a filter changes the mask under
                    # the same key, so it must bypass the cache
                    dead_key=None if flt is not None
                    else (snap.generation, snap.dead_version),
                )
            seg_results.append(res)
        parts = [(r.scores, r.ids) for r in seg_results]
        delta_live = snap.delta_live
        if flt is not None and snap.delta_ids.size:
            delta_live = delta_live & self._delta_allowed(snap, flt)
        if snap.delta_ids.size:
            parts.append(delta_topk(
                snap.delta_x, snap.delta_ids, delta_live,
                queries, k, self.cfg.metric,
            ))
        if len(parts) == 1 and seg_results:
            # one sealed segment, empty delta — the static special case:
            # return the engine's result (rich stats) unmerged
            res = seg_results[0]
            res.ids[~np.isfinite(res.scores)] = -1
        else:
            nq = queries.shape[0]
            if not parts:
                scores = np.full((nq, k), np.inf, np.float32)
                ids = np.full((nq, k), -1, np.int64)
            else:
                scores, ids = merge_topk(parts, k, fused=(backend == "spmd"))
            res = SearchResult(ids=ids, scores=scores, stats={
                "backend": backend,
                "segments": len(seg_results),
                "delta_candidates": int(delta_live.sum()),
                "generation": snap.generation,
            })
        if hybrid_text is not None:
            lex = self._lexical_topk(
                snap, states, hybrid_text, k, flt, delta_live
            )
            ranked = [res.ids]
            if lex.size:
                ranked.append(
                    np.broadcast_to(lex, (queries.shape[0], lex.size))
                )
            f_scores, f_ids = reciprocal_rank_fusion(ranked, k)
            res = SearchResult(ids=f_ids, scores=f_scores,
                               stats={**res.stats, "fused": True})
        cold_n = sum(int(r.stats.get("cold", 0)) for r in seg_results)
        res.stats["cold_segments"] = cold_n
        res.stats["bytes_streamed"] = sum(
            int(r.stats.get("bytes_streamed", 0)) for r in seg_results)
        res.stats["prefetch_hits"] = sum(
            int(r.stats.get("prefetch_hits", 0)) for r in seg_results)
        if cold_n:
            self.stats.cold_batches += 1
            self.stats.bytes_streamed += res.stats["bytes_streamed"]
            self.stats.prefetch_hits += res.stats["prefetch_hits"]
        dt = time.perf_counter() - t0
        res.stats["wall_s"] = dt
        if backend == "spmd":
            self.stats.spmd_batches += 1
        self.stats.batches += 1
        self.stats.queries += queries.shape[0]
        self.stats.wall_s += dt
        self.stats.latencies_ms.append(dt * 1e3)
        if self.replan_every and self.stats.batches % self.replan_every == 0:
            self.refresh_plan()
        return res

    def serve(self, request_stream, k: Optional[int] = None, sched=None,
              arrivals=None):
        """Admission-controlled scheduled serving of an iterable of query
        batches. Incoming batches are flattened into per-query requests and
        pushed through :class:`repro.serve.scheduler.ServingScheduler`,
        which re-forms batches adaptively (size/deadline triggers) and
        keeps :meth:`search_batch` as the inner execution primitive (the
        host engine or, with ``sched.backend="spmd"``, the device-resident
        executor). Returns one ``SearchResult`` per input batch, aligned
        with the stream (the synchronous drain-loop contract).

        ``arrivals`` optionally supplies per-batch arrival timestamps for
        replayed traces (aligned with ``request_stream``; each entry is a
        scalar for the whole batch or a per-row sequence, non-decreasing
        across the stream). Without it every request arrives at t=0 and
        queue-wait/deadline statistics degenerate.

        Stream entries may also be :class:`SearchRequest` objects (vector
        [D] or [NQ, D]); their filter/hybrid/precision/k ride along with
        every row of that entry."""
        from repro.serve.scheduler import SchedulerConfig, ServingScheduler

        sched_cfg = sched or SchedulerConfig()   # unbounded queue by default
        k = k or self.cfg.topk
        scheduler = ServingScheduler(self, sched_cfg, k=k)
        owners: Dict[int, tuple] = {}            # req_id → (batch_idx, row)
        shapes: List[Tuple[int, int]] = []       # (rows, k) per input batch
        arr_iter = iter(arrivals) if arrivals is not None else None
        for bi, qb in enumerate(request_stream):
            breq = qb if isinstance(qb, SearchRequest) else None
            qb = np.atleast_2d(
                np.asarray(breq.vector if breq is not None else qb)
            )
            k_b = (breq.k or k) if breq is not None else k
            shapes.append((qb.shape[0], k_b))
            if arr_iter is None:
                t_b = 0.0
            else:
                try:
                    t_b = next(arr_iter)
                except StopIteration:
                    raise ValueError(
                        f"arrivals exhausted at batch {bi}: it must yield "
                        "one timestamp (or per-row sequence) per "
                        "request_stream batch"
                    ) from None
            for r in range(qb.shape[0]):
                t_r = float(t_b) if np.ndim(t_b) == 0 else float(t_b[r])
                row_req = (
                    SearchRequest(vector=qb[r], k=breq.k, filter=breq.filter,
                                  hybrid_text=breq.hybrid_text,
                                  precision=breq.precision,
                                  deadline=breq.deadline)
                    if breq is not None else qb[r]
                )
                rid = scheduler.submit(row_req, arrival_s=t_r, _warn=False)
                if rid >= 0:
                    owners[rid] = (bi, r)
                # shed requests (bounded sched config) keep their -1/inf
                # placeholder rows in the output
        done = scheduler.flush()

        out = [
            SearchResult(
                ids=np.full((n, k_b), -1, np.int64),
                scores=np.full((n, k_b), np.inf, np.float32),
                stats={"scheduled": True, "wall_s": 0.0, "queue_wait_ms": []},
            )
            for n, k_b in shapes
        ]
        for rr in done:
            bi, r = owners.get(rr.req_id, (None, None))
            if bi is None:
                continue
            out[bi].ids[r] = rr.ids
            out[bi].scores[r] = rr.scores
            st = out[bi].stats
            # per-input-batch wall = first arrival → last completion of its
            # requests on the scheduler's virtual clock
            st["wall_s"] = max(st["wall_s"], rr.done_s - rr.arrival_s)
            st["queue_wait_ms"].append(rr.queue_wait_s * 1e3)
        return out
