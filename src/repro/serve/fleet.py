"""Multi-replica serving fleet: load-aware routing over several
``HarmonyServer`` replicas behind one admission queue.

This is the scale-*out* rung of the serving stack (ROADMAP's
"multi-replica routing"): PR 1's admission queue forms batches, PR 2's
executor serves them fast on one mesh — the fleet now stands N full
server replicas (host or spmd backend, heterogeneous capacities allowed)
behind that same queue and *routes* each formed batch, BatANN-style,
instead of pinning everything to one server.

Routing is load-estimate driven. Each replica carries

* **backlog** — outstanding work in queue-seconds (``busy_until`` minus
  the dispatch time on the virtual clock);
* **service estimate** — an EWMA of observed per-query service time,
  seeded from the §4.2.1 cost model of the replica's own plan (so a
  replica is routable before its first batch, and a slow/spmd/low-capacity
  replica is predicted slow from its plan cost, not discovered slow);
* **capacity weight** — relative speed of heterogeneous replicas.

Policies: ``"p2c"`` (power-of-two-choices: sample two live replicas,
dispatch to the less loaded — the classic lowest-variance scalable
policy), ``"least_loaded"`` (global argmin), ``"round_robin"`` (the
baseline the load-balance Gini is benchmarked against).

Cross-replica hedging: with a hedge deadline set, dispatch goes through
:meth:`repro.runtime.straggler.HedgingExecutor.run_ranked` over the
fleet's load ranking — a hedge re-runs the batch on the
*second-least-loaded replica*, not just another node of the same server.
Every replica serves the full corpus, so the hedge answer equals the
primary answer (result parity is tested).

Elasticity rides the existing :class:`repro.runtime.elastic.ClusterState`
machinery at replica granularity: ``fail_replica`` removes a replica from
routing (in-flight virtual work still completes — no admitted request is
lost), ``join_replica`` stands up a new server mid-trace.

Per-replica plans stay independent: each server keeps its own workload
window and re-plans from *its* observed probes (skew re-planning can
diverge per replica, the SPFresh-style accuracy-preserving property —
results are plan-invariant by the exactness guarantee).

Clocks: behind :class:`repro.serve.scheduler.ServingScheduler` the fleet
runs the deterministic virtual-clock replay (``execute``); behind
:class:`repro.serve.frontend.ServingFrontend` it executes for real
(``execute_wall``) — replicas genuinely overlap on a thread pool, with
per-replica locks serializing same-replica batches and all load/EWMA
accounting made atomic (``_record_service``).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional, Sequence, Union

import numpy as np

from repro.runtime.elastic import ClusterState
from repro.runtime.faults import fault_point
from repro.runtime.straggler import HedgingExecutor
from repro.serve.clock import Clock
from repro.serve.engine import HarmonyServer, ServeStats
from repro.serve.scheduler import DispatchTarget, SchedulerConfig, options_kwargs


def gini(x: Sequence[float]) -> float:
    """Gini coefficient of a non-negative load vector (0 = perfectly
    balanced, →1 = all load on one replica)."""
    x = np.sort(np.asarray(x, np.float64))
    n = x.size
    if n == 0 or x.sum() <= 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


@dataclass(frozen=True)
class ReplicaSpec:
    """How to stand up one replica."""

    backend: str = "host"           # "host" | "spmd"
    capacity: float = 1.0           # relative speed weight (2.0 = 2× faster)
    n_nodes: int = 4                # nodes inside the replica's own cluster
    replan_every: int = 0
    executor_cfg: Optional[object] = None   # ExecutorConfig for spmd


@dataclass
class Replica:
    """One server plus its fleet-side routing state.

    Times are **seconds** on whichever clock drives the fleet (virtual
    replay or the live front-end's wall clock); ``service_ms`` is
    **milliseconds** per served batch. ``lock`` serializes wall-clock
    execution on this replica — two batches routed to the same replica
    queue behind it while other replicas run concurrently."""

    server: HarmonyServer
    spec: ReplicaSpec
    busy_until: float = 0.0         # time (s) its dispatch queue drains
    busy_s: float = 0.0             # total service seconds
    batches: int = 0
    queries: int = 0
    failures: int = 0               # batches this replica raised on
    consec_failures: int = 0        # current run of failures (resets on success)
    # circuit breaker: None = closed (routable); a time = open until then
    # (ejected from routing), after which the replica is *half-open* — the
    # next health probe or trial batch decides close vs re-open
    open_until: Optional[float] = None
    ewma_per_q_s: Optional[float] = None
    service_ms: List[float] = field(default_factory=list)
    lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    # wall-clock mode only: predicted service-seconds of batches dispatched
    # to this replica but not yet completed. On the virtual clock execution
    # is inline, so busy_until always carries the backlog and this stays 0;
    # on the real clock busy_until is stale while a batch runs, and without
    # this term the router would pile every batch onto the same "idle"
    # replica (they'd serialize on its lock).
    inflight_s: float = 0.0

    def predict_service_s(
        self, n_queries: int, fleet_per_q_s: Optional[float] = None
    ) -> float:
        """Expected service seconds for a batch of ``n_queries``.

        Uses the replica's own EWMA blended 50/50 with the fleet-wide
        capacity-normalized EWMA (``fleet_per_q_s``, already divided by
        this replica's capacity by the caller). The blend matters: a
        replica's own EWMA only updates when it serves, so one noisy-slow
        observation would otherwise self-reinforce into starvation —
        anchoring on the fleet mean (heterogeneity carried by the known
        capacity weight) keeps every replica routable. Before any
        observation, falls back to the cost model of this replica's own
        plan (comp+comm per query, scaled by capacity)."""
        if self.ewma_per_q_s is not None:
            own = self.ewma_per_q_s
            if fleet_per_q_s is not None:
                return 0.5 * (own + fleet_per_q_s) * n_queries
            return own * n_queries
        if fleet_per_q_s is not None:
            return fleet_per_q_s * n_queries
        # cost-model seed: the plan's comp+comm is costed for a uniform
        # one-query-per-cluster prior; a real query touches nprobe of
        # nlist clusters, so scale by the probe fraction
        cost = self.server._plan_decision.cost
        frac = self.server.cfg.nprobe / max(self.server.index.nlist, 1)
        per_q = (cost["comp_s"] + cost["comm_s"]) * frac
        return per_q * n_queries / max(self.spec.capacity, 1e-9)


class ReplicaFleet(DispatchTarget):
    """N ``HarmonyServer`` replicas behind one admission queue.

    Drop-in :class:`DispatchTarget`: hand it to ``ServingScheduler`` in
    place of a server and every formed batch is routed by load estimate.

    ``service_time_fn(replica_idx, n_queries) -> seconds`` replaces the
    measured wall on the virtual clock (tests inject deterministic and
    heterogeneous service models); the default charges the measured
    ``search_batch`` wall divided by the replica's capacity weight.
    ``latency_fn(replica_idx, task)`` overrides the hedge's effective-
    latency model (default: the fleet's own load estimate).

    >>> import numpy as np
    >>> from repro.config import HarmonyConfig
    >>> from repro.core import build_ivf
    >>> from repro.serve import SchedulerConfig, ServingScheduler
    >>> rng = np.random.default_rng(0)
    >>> x = rng.standard_normal((256, 8)).astype(np.float32)
    >>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3,
    ...                     kmeans_iters=2)
    >>> fleet = ReplicaFleet(build_ivf(x, cfg), replicas=2, cfg=cfg,
    ...                      service_time_fn=lambda r, n: n * 1e-3, seed=0)
    >>> sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=3)
    >>> results = sched.run_trace([(i * 1e-5, x[i]) for i in range(32)])
    >>> len(results), sum(r.batches for r in fleet.replicas)
    (32, 4)
    >>> sum(1 for r in fleet.replicas if r.batches > 0) > 1  # spread out
    True
    """

    def __init__(
        self,
        index,
        replicas: Union[int, Sequence[ReplicaSpec]] = 2,
        cfg=None,
        routing: str = "p2c",
        ewma_alpha: float = 0.25,
        service_time_fn: Optional[Callable[[int, int], float]] = None,
        latency_fn: Optional[Callable[[int, object], float]] = None,
        workload_window: int = 2048,
        seed: int = 0,
        breaker_threshold: int = 3,
        breaker_cooldown_s: float = 1.0,
    ):
        assert routing in ("p2c", "least_loaded", "round_robin"), routing
        if isinstance(replicas, int):
            replicas = [ReplicaSpec() for _ in range(replicas)]
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        # one shared mutable data plane for the whole fleet: every replica
        # (including ones that join mid-trace) serves the same
        # SegmentedIndex object, so upserts/deletes/compaction commits are
        # visible fleet-wide and a joiner adopts the *current* segment
        # generation, never the boot-time index
        from repro.core import SegmentedIndex

        self.index = (
            index if isinstance(index, SegmentedIndex)
            else SegmentedIndex.from_static(index)
        )
        self.cfg = cfg or self.index.cfg
        self.routing = routing
        self.ewma_alpha = ewma_alpha
        self.service_time_fn = service_time_fn
        self.latency_fn = latency_fn
        # consecutive failures that trip a replica's circuit breaker
        # (0 disables breakers entirely) and how long it then sits out
        # of routing before a half-open health probe may readmit it
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._breaker_active = 0        # replicas with open_until set
        self.replicas: List[Replica] = [
            Replica(self._make_server(spec), spec) for spec in replicas
        ]
        self.cluster = ClusterState.fresh(len(self.replicas))
        self.stats = ServeStats()       # fleet-level admission accounting
        self._recent_probes: Deque[np.ndarray] = deque(maxlen=workload_window)
        self._rng = np.random.default_rng(seed)
        self._rr = 0
        self._backend = ""
        self._k = self.cfg.topk
        self._hedge: Optional[HedgingExecutor] = None
        self._last_done_s = 0.0
        self._last_start_s = 0.0
        # fleet-wide EWMA of capacity-normalized per-query service time
        # (the anchor every replica's load estimate blends against)
        self._fleet_ewma_norm_per_q: Optional[float] = None
        # guards routing state (busy_until, EWMAs, rng, probes window) so
        # the real-clock front-end can dispatch to replicas from a thread
        # pool; uncontended (hence free) on the single-threaded virtual path
        self._mu = threading.Lock()

    def _make_server(self, spec: ReplicaSpec) -> HarmonyServer:
        return HarmonyServer(
            self.index,
            n_nodes=spec.n_nodes,
            cfg=self.cfg,
            replan_every=spec.replan_every,
            backend=spec.backend,
            executor_cfg=spec.executor_cfg,
        )

    # ------------------------------------------------------ DispatchTarget
    def configure(self, cfg: SchedulerConfig, k: int) -> None:
        self._backend = cfg.backend
        self._k = k
        for rep in self.replicas:
            self._warmup_replica(rep)
        if cfg.hedge_deadline_s > 0:
            self._hedge = HedgingExecutor(
                workers=[self._make_worker(i) for i in range(len(self.replicas))],
                deadline_s=cfg.hedge_deadline_s,
                latency_fn=self.latency_fn or self._estimate_latency,
            )

    def _warmup_replica(self, rep: Replica) -> None:
        if (self._backend or rep.server.backend) == "spmd":
            rep.server.warmup_executors(k=self._k)

    # ------------------------------------------------- mutable data plane
    @property
    def data(self):
        """The fleet-shared :class:`repro.core.SegmentedIndex`."""
        return self.index

    # upsert()/delete() come from the DataPlane mixin: one write to the
    # shared data plane — every replica's next batch sees it
    def _data_plane(self):
        return self.index

    def _note_write(self, kind: str, n: int) -> None:
        with self._mu:
            if kind == "upsert":
                self.stats.upserts += n
            else:
                self.stats.deletes += n

    def live_servers(self):
        """Servers of the live replicas (the compactor's swap targets)."""
        return [self.replicas[int(i)].server for i in self.cluster.live_ids()]

    def next_free_s(self) -> float:
        live = self.cluster.live_ids()
        if live.size == 0:
            raise RuntimeError("no live replicas")
        frees = [self.replicas[int(i)].busy_until for i in live
                 if self.replicas[int(i)].open_until is None]
        if not frees:       # every breaker open: fail open, don't stall
            frees = [self.replicas[int(i)].busy_until for i in live]
        return min(frees)

    def execute(self, queries, k, dispatch_s, batch_id, options=None):
        if self._breaker_active:
            self.health_check(dispatch_s)
        ranked = self._rank_replicas(queries.shape[0], dispatch_s, batch_id)
        last_err = None
        for attempt, r_idx in enumerate(ranked):
            try:
                if attempt == 0 and self._hedge is not None:
                    hedged_before = self._hedge.stats.hedged
                    res, served_by, _ = self._hedge.run_ranked(
                        (queries, k, dispatch_s, options), ranked
                    )
                    if self._hedge.stats.hedged > hedged_before:
                        self.stats.hedged_batches += 1
                        if served_by != ranked[0]:
                            # the hedge target only received the batch when
                            # the deadline expired — its execution cannot
                            # have started before dispatch+deadline; charge
                            # the hedge wait to the virtual clock (the
                            # fleet's latency_fn is the hedge *decision*
                            # model, so unlike the single-server target it
                            # is never added to service time — real time
                            # lives in busy_until/service accounting)
                            shift = (dispatch_s + self._hedge.deadline_s
                                     - self._last_start_s)
                            if shift > 0:
                                self.replicas[served_by].busy_until += shift
                                self._last_done_s += shift
                else:
                    res = self._run_on(r_idx, queries, k, dispatch_s, options)
                return res, self._last_done_s
            except Exception as e:  # noqa: BLE001 - retried on next replica
                last_err = e
                if attempt + 1 < len(ranked):
                    self.stats.retried_batches += 1
        raise last_err

    def execute_wall(self, queries, k, batch_id, clock: Clock, options=None):
        """Real-clock dispatch for the live front-end: route by the same
        load estimates (``clock.now()`` as "now"), then actually run the
        batch on the chosen replica — concurrently with batches other
        worker threads are running on *other* replicas. With a hedge
        deadline configured, dispatch goes through
        :meth:`repro.runtime.straggler.HedgingExecutor.run_ranked_wall`:
        the primary really runs, and if it misses the deadline the batch
        is re-issued to the least-loaded other replica, first result
        wins. A replica that *raises* (crash-injected or real) records a
        failure against its breaker and the batch is retried down the
        ranked order — replicas serve the full corpus, so a retried
        answer is the primary answer."""
        if self._breaker_active:
            self.health_check(clock.now())
        n = queries.shape[0]
        with self._mu:
            ranked = self._rank_replicas(n, clock.now(), batch_id)
        last_err = None
        for attempt, r_idx in enumerate(ranked):
            rep = self.replicas[r_idx]
            with self._mu:
                # reserve the predicted service so concurrent dispatches
                # see this replica as loaded while the batch is in flight
                reserve_s = self._predict_service_s(rep, n)
                rep.inflight_s += reserve_s
            try:
                if attempt == 0 and self._hedge is not None and len(ranked) > 1:
                    (res, done_s), served_by, hedge_fired = (
                        self._hedge.run_ranked_wall(
                            (queries, k, clock, options), ranked
                        )
                    )
                    if hedge_fired:
                        with self._mu:
                            self.stats.hedged_batches += 1
                else:
                    res, done_s = self._run_on_wall(
                        r_idx, queries, k, clock, options
                    )
                return res, done_s
            except Exception as e:  # noqa: BLE001 - retried on next replica
                last_err = e
                if attempt + 1 < len(ranked):
                    with self._mu:
                        self.stats.retried_batches += 1
            finally:
                with self._mu:
                    rep.inflight_s = max(rep.inflight_s - reserve_s, 0.0)
        raise last_err

    # ------------------------------------------------------------- routing
    def _predict_service_s(self, rep: Replica, n_queries: int) -> float:
        """Predicted service seconds for a batch on ``rep``: the replica's
        own EWMA blended with the capacity-normalized fleet EWMA (cost-
        model seeded before any observation). Single source for both the
        routing estimate and the wall-mode in-flight reservation."""
        fleet_per_q = (
            self._fleet_ewma_norm_per_q / max(rep.spec.capacity, 1e-9)
            if self._fleet_ewma_norm_per_q is not None
            else None
        )
        return rep.predict_service_s(n_queries, fleet_per_q)

    def load_estimate(self, r_idx: int, now: float, n_queries: int) -> float:
        """Queue-seconds this batch would wait-plus-run on replica
        ``r_idx``: outstanding backlog (completed-work horizon plus
        in-flight reservations) + predicted service time."""
        rep = self.replicas[r_idx]
        return (
            max(rep.busy_until - now, 0.0)
            + rep.inflight_s
            + self._predict_service_s(rep, n_queries)
        )

    def _estimate_latency(self, r_idx: int, task) -> float:
        queries, _, dispatch_s = task[:3]
        return self.load_estimate(r_idx, dispatch_s, queries.shape[0])

    def _rank_replicas(self, n: int, now: float, batch_id: int) -> List[int]:
        """Dispatch order: [primary, hedge target, ...rest]. The primary
        follows the routing policy; the hedge target is always the least-
        loaded *other* live replica (so a hedge lands on the second-least-
        loaded replica when the primary is the least-loaded)."""
        live = [int(i) for i in self.cluster.live_ids()]
        if not live:
            raise RuntimeError("no live replicas")
        if len(live) == 1:
            return live
        # circuit breakers: open replicas sit out routing until their
        # cooldown elapses. Fail open — when every live breaker is open,
        # availability beats breaker purity and the full live set routes
        # again. With no breaker active (the fault-free path) this block
        # is skipped entirely, so routing and its rng stream are
        # bit-identical to the breaker-less fleet.
        if self._breaker_active:
            avail = [r for r in live if self._routable(self.replicas[r], now)]
            if not avail:
                avail = live
        else:
            avail = live
        loads = {r: self.load_estimate(r, now, n) for r in live}
        if len(avail) == 1:
            primary = avail[0]
        elif self.routing == "round_robin":
            primary = avail[self._rr % len(avail)]
            self._rr += 1
        elif self.routing == "p2c":
            # capacity-weighted power-of-two-choices: heterogeneous fleets
            # sample fast replicas proportionally more often (plain p2c
            # wastes every slow-slow sample), then the load estimate picks
            # between the two
            caps = np.array([self.replicas[r].spec.capacity for r in avail])
            a, b = self._rng.choice(
                len(avail), size=2, replace=False, p=caps / caps.sum()
            )
            primary = min(avail[int(a)], avail[int(b)], key=lambda r: loads[r])
        else:                                   # least_loaded
            primary = min(avail, key=lambda r: loads[r])
        # retry/hedge order: remaining routable replicas by load, then —
        # last resort only — open-breaker replicas by load
        routable = set(avail)
        rest = sorted((r for r in live if r != primary),
                      key=lambda r: (r not in routable, loads[r]))
        return [primary] + rest

    @staticmethod
    def _routable(rep: Replica, now: float) -> bool:
        """Closed breaker, or half-open (cooldown elapsed — the replica
        may take a trial batch)."""
        return rep.open_until is None or now >= rep.open_until

    # ----------------------------------------------------------- execution
    def _make_worker(self, r_idx: int):
        def run(task):
            # task is (queries, k, dispatch_s[, options]) on the virtual
            # clock, or (queries, k, clock[, options]) from the real-clock
            # front-end
            queries, k, when = task[:3]
            options = task[3] if len(task) > 3 else None
            if isinstance(when, Clock):
                return self._run_on_wall(r_idx, queries, k, when, options)
            return self._run_on(r_idx, queries, k, when, options)
        return run

    def _run_on(self, r_idx: int, queries, k, dispatch_s: float,
                options=None):
        rep = self.replicas[r_idx]
        start_s = max(dispatch_s, rep.busy_until)
        self._last_start_s = start_s
        t0 = time.perf_counter()
        try:
            # named fault site: an installed FaultPlan can crash this
            # replica mid-batch (raise) or stretch its service time
            # (delay, returned in seconds and charged below)
            extra_s = fault_point("replica.execute", replica=r_idx)
            res = rep.server.search_batch(
                queries, k, backend=self._backend or None,
                **options_kwargs(options),
            )
        except Exception:
            self._record_failure(r_idx, dispatch_s)
            raise
        wall = time.perf_counter() - t0
        n = queries.shape[0]
        service_s = (
            self.service_time_fn(r_idx, n)
            if self.service_time_fn
            else wall / max(rep.spec.capacity, 1e-9)
        ) + extra_s
        self._note_success(r_idx)
        self._record_service(rep, n, service_s, done_s=start_s + service_s)
        return res

    def _run_on_wall(self, r_idx: int, queries, k, clock: Clock,
                     options=None):
        """Wall-clock execution on one replica: ``rep.lock`` serializes
        batches routed to the *same* replica (they queue, as a real
        replica's dispatch queue would) while other replicas run
        concurrently on the front-end's thread pool. With an injected
        ``service_time_fn`` the wall is padded by sleeping the shortfall —
        the real-clock analogue of the virtual service model (models a
        remote replica whose service time exceeds local compute).

        Hedge losers run to completion here and are *deliberately*
        recorded: a discarded hedge execution still consumed the
        replica's time for real, so counting it keeps busy-seconds,
        EWMAs, and load estimates honest (it is the ``wasted`` in
        ``HedgeStats.wasted``). Per-replica ``queries`` sums can
        therefore exceed served requests in wall mode — by exactly the
        hedged-and-lost batches."""
        rep = self.replicas[r_idx]
        with rep.lock:
            t0 = clock.now()
            try:
                extra_s = fault_point("replica.execute", replica=r_idx)
                res = rep.server.search_batch(
                    queries, k, backend=self._backend or None,
                    **options_kwargs(options),
                )
            except Exception:
                self._record_failure(r_idx, clock.now())
                raise
            n = queries.shape[0]
            if self.service_time_fn is not None:
                clock.sleep(
                    self.service_time_fn(r_idx, n) + extra_s
                    - (clock.now() - t0)
                )
            elif extra_s > 0.0:
                clock.sleep(extra_s)        # injected straggler latency
            done_s = clock.now()
        self._note_success(r_idx)
        self._record_service(rep, n, done_s - t0, done_s)
        return res, done_s

    # --------------------------------------------------- circuit breakers
    def _record_failure(self, r_idx: int, now: float) -> None:
        rep = self.replicas[r_idx]
        with self._mu:
            rep.failures += 1
            rep.consec_failures += 1
            self.stats.replica_failures += 1
            if rep.open_until is not None:
                # half-open trial failed: restart the cooldown
                rep.open_until = now + self.breaker_cooldown_s
            elif (self.breaker_threshold > 0
                  and rep.consec_failures >= self.breaker_threshold):
                rep.open_until = now + self.breaker_cooldown_s
                self._breaker_active += 1
                self.stats.breaker_opens += 1

    def _note_success(self, r_idx: int) -> None:
        rep = self.replicas[r_idx]
        if rep.consec_failures == 0 and rep.open_until is None:
            return          # hot path: nothing to reset, no lock taken
        closed = False
        with self._mu:
            rep.consec_failures = 0
            if rep.open_until is not None:
                rep.open_until = None
                self._breaker_active -= 1
                self.stats.breaker_closes += 1
                closed = True
        if closed:
            # the replica sat out routing while its breaker cooled; adopt()
            # (outside _mu — it takes the server's own locks) catches it up
            # on any data-plane generation it missed. No-op when current.
            rep.server.adopt()

    def health_check(self, now: Optional[float] = None):
        """Probe every live *half-open* replica (cooldown elapsed) with a
        one-query search. A clean probe closes the breaker and
        ``adopt()``\\ s the replica back onto the current data-plane
        generation; a failing probe restarts the cooldown. Runs
        automatically at dispatch whenever any breaker is active (cheap
        guard: skipped entirely when none is), or call it from an
        operator loop. Returns ``[(replica_idx, ok), ...]`` for the
        replicas probed."""
        checked = []
        for r_idx in range(len(self.replicas)):
            rep = self.replicas[r_idx]
            with self._mu:
                half_open = (
                    bool(self.cluster.live[r_idx])
                    and rep.open_until is not None
                    and (now is None or now >= rep.open_until)
                )
            if not half_open:
                continue
            ok = True
            try:
                fault_point("replica.execute", replica=r_idx, probe=True)
                rep.server.search_batch(
                    np.zeros((1, self.cfg.dim), np.float32), 1,
                    backend=self._backend or None,
                )
            except Exception:   # noqa: BLE001 - probe outcome is the point
                ok = False
            with self._mu:
                self.stats.health_probes += 1
                if ok:
                    rep.consec_failures = 0
                    if rep.open_until is not None:
                        rep.open_until = None
                        self._breaker_active -= 1
                        self.stats.breaker_closes += 1
                else:
                    rep.failures += 1
                    rep.consec_failures += 1
                    self.stats.replica_failures += 1
                    if now is not None:
                        rep.open_until = now + self.breaker_cooldown_s
            if ok:
                rep.server.adopt()
            checked.append((r_idx, ok))
        return checked

    def _record_service(self, rep: Replica, n: int, service_s: float,
                        done_s: float):
        """Atomically account one served batch: busy bookkeeping, the
        per-replica and fleet-wide EWMAs, and the probe-window mirror.
        Shared by the virtual and wall paths; ``_mu`` keeps concurrent
        wall-mode dispatches exact (EWMA read-modify-writes and counter
        increments would otherwise race)."""
        with self._mu:
            rep.busy_until = done_s
            rep.busy_s += service_s
            rep.batches += 1
            rep.queries += n
            rep.service_ms.append(service_s * 1e3)
            obs_per_q = service_s / max(n, 1)
            rep.ewma_per_q_s = (
                obs_per_q
                if rep.ewma_per_q_s is None
                else self.ewma_alpha * obs_per_q
                + (1.0 - self.ewma_alpha) * rep.ewma_per_q_s
            )
            norm_per_q = obs_per_q * rep.spec.capacity
            self._fleet_ewma_norm_per_q = (
                norm_per_q
                if self._fleet_ewma_norm_per_q is None
                else self.ewma_alpha * norm_per_q
                + (1.0 - self.ewma_alpha) * self._fleet_ewma_norm_per_q
            )
            # the replica's server just recorded this batch's probes;
            # mirror them into the fleet-level window (newest last) for
            # the scheduler's hot-mass drift trigger
            if rep.server._recent_probes:
                self._recent_probes.append(rep.server._recent_probes[-1])
            self._last_done_s = done_s

    # ------------------------------------------------------------ elastic
    def fail_replica(self, r_idx: int) -> None:
        """Remove a replica from routing. Virtual work already dispatched
        to it completes (the batch result was computed at dispatch); no
        admitted request is lost — the shared queue re-routes everything
        else to the survivors."""
        with self._mu:
            self.cluster.fail(r_idx)
            if self.cluster.n_live == 0:
                raise RuntimeError("no live replicas")

    def join_replica(self, spec: Optional[ReplicaSpec] = None) -> int:
        """Stand up one more replica mid-trace; returns its index.

        The server is built and warmed *before* the replica becomes
        routable, and the routing state (replica list, hedge worker slot,
        live set) is updated atomically under the fleet lock — a
        concurrent wall-clock dispatch never sees a live replica without
        its hedge worker. The new server is constructed over the fleet's
        *shared* data plane, so a joiner adopts the current segment
        generation (upserts/deletes/compactions that happened mid-trace
        included), never the boot-time index."""
        spec = spec or ReplicaSpec()
        rep = Replica(self._make_server(spec), spec)
        self._warmup_replica(rep)
        with self._mu:
            self.replicas.append(rep)
            if self._hedge is not None:
                self._hedge.workers.append(
                    self._make_worker(len(self.replicas) - 1)
                )
            self.cluster.join()
            return len(self.replicas) - 1

    # ------------------------------------------- skew-adaptation surface
    def window_probes(self):
        # snapshot under the lock: wall-mode workers append to the deque
        # concurrently, and iterating a mutating deque raises
        with self._mu:
            return list(self._recent_probes)[::-1]       # newest first

    def refresh_plan(self) -> None:
        """Re-plan every live replica from its *own* workload window —
        per-replica plans diverge under skew, results stay exact."""
        for i in self.cluster.live_ids():
            self.replicas[int(i)].server.refresh_plan()

    @property
    def replans(self) -> int:
        return sum(r.server.stats.replans for r in self.replicas)

    @property
    def nlist(self) -> int:
        return self.index.nlist

    @property
    def default_max_batch(self) -> int:
        return self.cfg.query_block

    @property
    def default_k(self) -> int:
        return self.cfg.topk

    @property
    def parallelism(self) -> int:
        """Live replica count — the front-end's default in-flight bound
        (one wall-clock batch per live replica can genuinely overlap)."""
        return max(int(self.cluster.n_live), 1)

    # ---------------------------------------------------------- reporting
    @property
    def load_balance_gini(self) -> float:
        """Gini of per-replica virtual busy-seconds (work, not counts —
        a capacity-blind router looks balanced in counts while its slow
        replicas drown in seconds)."""
        return gini([r.busy_s for r in self.replicas])

    def summary(self) -> dict:
        """Fleet-level digest: per-replica QPS/latency/shed (each
        replica's own ServeStats threaded up), the load-balance Gini, and
        the cross-replica hedge win rate, alongside the fleet's admission
        accounting (see :meth:`repro.serve.engine.ServeStats.summary` for
        those keys).

        Units — seconds vs milliseconds are explicit in key names:

        * ``replicas[i].busy_s`` — total service time in **seconds** (on
          the driving clock: virtual in replay, wall under the live
          front-end);
        * ``replicas[i].virtual_qps`` — ``queries / busy_s``: the
          replica's throughput while busy (queries per second), not
          wall-clock QPS — idle gaps between batches don't count;
        * ``replicas[i].p50_service_ms`` / ``p99_service_ms`` —
          per-*batch* service-time percentiles in **milliseconds**
          (``None`` until the replica has served a batch);
        * ``load_balance_gini`` — dimensionless in [0, 1) over
          per-replica busy-seconds (0 = perfectly balanced);
        * ``hedge.win_rate`` — fraction of fired hedges the hedge target
          won, in [0, 1].
        """
        per_replica = []
        for i, rep in enumerate(self.replicas):
            sm = np.asarray(rep.service_ms, np.float64)
            per_replica.append({
                "replica": i,
                "backend": rep.server.backend,
                "capacity": rep.spec.capacity,
                "live": bool(self.cluster.live[i]),
                "failures": rep.failures,
                "breaker_open": rep.open_until is not None,
                "batches": rep.batches,
                "queries": rep.queries,
                "busy_s": rep.busy_s,
                "virtual_qps": rep.queries / rep.busy_s if rep.busy_s else 0.0,
                "p50_service_ms": float(np.percentile(sm, 50)) if sm.size else None,
                "p99_service_ms": float(np.percentile(sm, 99)) if sm.size else None,
                "server": rep.server.stats.summary(),
            })
        hs = self._hedge.stats if self._hedge is not None else None
        return {
            "routing": self.routing,
            "n_replicas": len(self.replicas),
            "n_live": self.cluster.n_live,
            "load_balance_gini": self.load_balance_gini,
            "hedge": {
                "dispatched": hs.dispatched if hs else 0,
                "hedged": hs.hedged if hs else 0,
                "wasted": hs.wasted if hs else 0,
                "hedge_wins": hs.hedge_wins if hs else 0,
                "win_rate": hs.win_rate if hs else 0.0,
            },
            "replicas": per_replica,
            **self.stats.summary(),
            # fleet aggregates (the admission-level ServeStats never sees
            # execution, which happens inside each replica's server)
            "batches": sum(r.batches for r in self.replicas),
            "queries": sum(r.queries for r in self.replicas),
            "replans": self.replans,
            "spmd_batches": sum(
                r.server.stats.spmd_batches for r in self.replicas
            ),
        }
