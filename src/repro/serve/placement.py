"""Segment placement policy for the two-tier memory hierarchy.

PR 6 made sealed segments cheap to score (int8 codes, fp32 re-rank);
this module decides *where each sealed segment's scoring arrays live*:

* ``device`` — the SPMD executor keeps the segment's packed rows (int8
  codes when serving int8, fp32 otherwise), block norms and id columns
  resident on the mesh, uploaded once per generation;
* ``host`` — nothing is resident; per batch, only the probed clusters'
  rows are gathered host-side and streamed through the executor's
  double-buffered upload path (:class:`repro.serve.executor.SpmdExecutor`,
  ``tier="host"``).

The policy is a greedy knapsack over *probe heat*: the data plane keeps
a per-segment cluster-hotness EWMA fed by every served batch's probe
selection (:meth:`repro.core.SegmentedIndex.note_probes`); segments are
ranked by heat per device byte and packed into the budget hottest-first.
A small hysteresis bonus keeps the incumbent device set sticky so a
near-tie can't flap a segment across the PCIe bus every cycle.

Placement changes ride the same prepare→swap→adopt shape as a
compaction generation swap (:func:`apply_placement`), so a tier move is
zero-downtime: in-flight batches finish on the old residency, the next
batch picks up the new one. Results are tier-invariant by construction
— the host tier streams the exact same packed rows through the exact
same kernels — so query caches survive a move untouched.

>>> import numpy as np
>>> from repro.config import HarmonyConfig
>>> from repro.core import SegmentedIndex
>>> rng = np.random.default_rng(0)
>>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3, kmeans_iters=2)
>>> data = SegmentedIndex.build(rng.standard_normal((64, 8)), cfg)
>>> data.upsert(np.arange(64, 96), rng.standard_normal((32, 8)))
>>> data.compact_inline()                    # seals the delta: 2 segments
>>> data.note_probes(0, np.array([[0, 1], [2, 3]]))   # heat on segment 0
>>> budget = 3 * sum(device_bytes_by_segment(data).values()) // 4
>>> tiers = plan_placement(data, PlacementConfig(device_budget_bytes=budget))
>>> tiers[0], tiers[1]
('device', 'host')
>>> plan_placement(data, PlacementConfig())           # no budget: all hot
{0: 'device', 1: 'device'}
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

from repro.core.index import SegmentedIndex, segment_device_bytes
from repro.runtime.faults import fault_point


@dataclass(frozen=True)
class PlacementConfig:
    """Knobs of the hotness-driven placement policy.

    ``device_budget_bytes`` is the HBM the corpus may occupy (None =
    unbounded, everything device-resident). ``precision`` is the budget
    currency — ``"int8"`` counts code bytes (4× more corpus per budget
    byte, PR 6's tier), ``"fp32"`` full rows. ``hysteresis`` is the
    relative heat bonus granted to currently-device segments so ties
    don't flap placement."""

    device_budget_bytes: Optional[int] = None
    precision: str = "fp32"
    d_blocks: int = 1
    hysteresis: float = 0.10


def device_bytes_by_segment(data: SegmentedIndex,
                            precision: str = "fp32",
                            d_blocks: int = 1) -> Dict[int, int]:
    """seg_id -> HBM cost of keeping that segment device-resident."""
    return {s.seg_id: segment_device_bytes(s, precision, d_blocks)
            for s in data.segments}


def plan_placement(data: SegmentedIndex,
                   cfg: PlacementConfig) -> Dict[int, str]:
    """Greedy heat-per-byte knapsack: every sealed segment gets a tier,
    hottest-per-device-byte first until the budget is spent. Fully
    deterministic: ties break by segment id, and the incumbent device
    set gets a ``hysteresis`` heat bonus so a stable workload yields a
    stable placement."""
    costs = device_bytes_by_segment(data, cfg.precision, cfg.d_blocks)
    if cfg.device_budget_bytes is None:
        return {sid: "device" for sid in costs}
    heat = data.segment_hotness()
    current = data.tiers()
    scored = []
    for sid, cost in costs.items():
        h = heat.get(sid, 0.0)
        if current.get(sid, "device") == "device":
            h *= 1.0 + cfg.hysteresis
        # heat density: probe mass bought per device byte. The +1 floor
        # keeps never-probed segments ordered (small first) and nonzero.
        scored.append(((h + 1.0) / max(cost, 1), sid, cost))
    scored.sort(key=lambda t: (-t[0], t[1]))
    out: Dict[int, str] = {}
    budget = int(cfg.device_budget_bytes)
    for _, sid, cost in scored:
        if cost <= budget:
            out[sid] = "device"
            budget -= cost
        else:
            out[sid] = "host"
    return out


def apply_placement(data: SegmentedIndex, servers: Sequence,
                    tiers: Dict[int, str]) -> bool:
    """Install ``tiers`` across the data plane and every serving replica
    with the compaction swap's zero-downtime shape:

    1. *prepare* — each server pre-builds executor state for the
       segments whose tier changes, off the serving path;
    2. *swap* — the data plane's tier map flips atomically
       (``placement_version`` bump);
    3. *adopt* — each server promotes its staged states.

    A crash between (2) and (3) (fault site ``"placement.swap"``) is
    harmless: servers that missed the adopt re-sync lazily on their next
    batch because the snapshot carries ``placement_version`` — a segment
    is never unreachable, at worst one batch rebuilds residency inline.
    Returns False when ``tiers`` is already the current placement."""
    if tiers == data.tiers():
        return False
    fault_point("placement.prepare")
    for srv in servers:
        prep = getattr(srv, "prepare_placement", None)
        if prep is not None:
            prep(tiers)
    data.set_tiers(tiers)
    fault_point("placement.swap")
    for srv in servers:
        adopt = getattr(srv, "adopt", None)
        if adopt is not None:
            adopt()
    return True
