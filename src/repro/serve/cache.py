"""Two-tier semantic query cache + coalescing support for the serving
front door (the ROADMAP's "survive million-user traffic" item).

Real query streams are heavily repetitive, but the admission queue
executes every duplicate as if it were fresh work. :class:`QueryCache`
sits *in front of admission* (``ServingScheduler.submit`` /
``ServingFrontend.submit``) and answers repeats from already-paid-for
work:

* **exact tier** — a TTL'd map keyed by the canonical request identity
  (query-vector bytes + k + filter + hybrid text + precision). A hit
  replays a previously served answer verbatim, so it is bit-identical to
  re-executing the request against the same data-plane state.
* **semantic tier** — answers a query from a previously served
  *neighbor* within ``semantic_threshold``. Finding that neighbor is
  itself a tiny exact ANN search, so it reuses the repo's own scan
  machinery: the cached query vectors of one (k, options) group form a
  small brute-force index scanned with
  :func:`repro.core.search.delta_topk` (the delta-buffer primitive).
  Thresholds are in **score space** — squared L2 for the ``"l2"``
  metric — and the boundary is inclusive (a query at exactly the
  threshold hits). The semantic tier is L2-only.

Staleness is bounded by a cheap *epoch*, read from the root data plane
on every lookup/insert: ``(generation, op_count)`` of the underlying
:class:`repro.core.SegmentedIndex`. The rules (enforced in
:meth:`QueryCache.lookup`):

* a **generation swap** (compaction commit — the PR 5 adoption path,
  ``HarmonyServer.adopt`` / the fleet's shared plane) invalidates
  unconditionally: no hit is ever served across it;
* an **upsert/delete** (``op_count`` moved) invalidates once the entry
  is older than ``staleness_s`` — the configured staleness budget; with
  the default budget of 0 every write invalidates immediately;
* entries expire after ``exact_ttl_s`` regardless of writes.

So a cache entry can never outlive the snapshot it was computed from by
more than the staleness budget. Entries are stamped with the epoch read
*before* their batch executed (conservative: a write that lands
mid-execution makes the entry count as already-stale).

In-flight request **coalescing** (``CacheConfig.coalesce``) is the third
leg: concurrent duplicate submissions share one execution instead of
enqueueing N times — in :class:`~repro.serve.frontend.ServingFrontend`
duplicates attach to the in-flight leader's future; in
:class:`~repro.serve.scheduler.ServingScheduler` duplicate rows of a
formed batch execute once and fan out (deterministic on the virtual
clock, so replay harnesses exercise it).

Default-off: ``SchedulerConfig(cache=None)`` (or
``CacheConfig(enabled=False)``) leaves every admission code path
byte-identical to the cache-less scheduler — the virtual-clock goldens
pin this.

>>> import numpy as np
>>> epoch = [0, 0]                       # (generation, op_count) stand-in
>>> c = QueryCache(CacheConfig(enabled=True, exact_ttl_s=10.0,
...                            semantic_threshold=4.0),
...                epoch_fn=lambda: tuple(epoch))
>>> q = np.zeros(4, np.float32)
>>> c.insert(q, 3, (None, None, None),
...          np.array([5, 7, -1]), np.array([0.1, 0.2, np.inf]), now_s=0.0)
>>> c.lookup(q, 3, (None, None, None), now_s=1.0).tier
'exact'
>>> near = q.copy(); near[0] = 2.0       # sq-L2 distance exactly 4.0
>>> c.lookup(near, 3, (None, None, None), now_s=1.0).tier   # inclusive
'semantic'
>>> epoch[0] += 1                        # generation swap
>>> c.lookup(q, 3, (None, None, None), now_s=1.0) is None
True
>>> (c.stats.cache_hits_exact, c.stats.cache_hits_semantic,
...  c.stats.cache_misses, c.stats.cache_invalidations)
(1, 1, 1, 1)
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.search import delta_topk


@dataclass(frozen=True)
class CacheConfig:
    """Knobs of the admission-side query cache (frozen so it can ride
    inside the frozen ``SchedulerConfig``). All durations are seconds.

    ``enabled=False`` (the default) keeps the whole front door inert —
    scheduler and front-end behave byte-identically to a cache-less
    build. ``semantic_threshold`` is in score space (squared L2),
    inclusive at the boundary; 0 disables the semantic tier (exact tier
    only). ``staleness_s`` is the budget an entry may be served across
    upserts/deletes (generation swaps always invalidate). ``max_entries``
    bounds the cache with deterministic LRU eviction. ``coalesce``
    additionally merges concurrent duplicate submissions into one
    execution."""

    enabled: bool = False
    exact_ttl_s: float = 60.0
    semantic_threshold: float = 0.0
    staleness_s: float = 0.0
    max_entries: int = 4096
    coalesce: bool = True


@dataclass
class CacheHit:
    """A served-from-cache answer: the stored top-K plus which tier
    produced it (``"exact"`` | ``"semantic"``)."""

    ids: np.ndarray                 # [K] int64, -1 padded
    scores: np.ndarray              # [K] float32, +inf padded
    tier: str


@dataclass
class _Entry:
    key: tuple                      # exact-tier key (vec bytes, k, options)
    group_key: tuple                # semantic-tier group (k, options)
    row: int                        # row in the group's vector buffer
    ids: np.ndarray
    scores: np.ndarray
    generation: int                 # epoch at (pre-execution of) insert
    op_count: int
    time_s: float


class _Group:
    """Vector buffer of one (k, options) semantic group — a tiny
    append-only brute-force index with a live mask (dead rows are
    evicted/invalidated entries), scanned by ``delta_topk``."""

    __slots__ = ("x", "live", "keys", "n")

    def __init__(self, dim: int):
        self.x = np.zeros((8, dim), np.float32)
        self.live = np.zeros(8, bool)
        self.keys: List[Optional[tuple]] = [None] * 8
        self.n = 0

    def append(self, vec: np.ndarray, key: tuple) -> int:
        if self.n == self.x.shape[0]:
            grow = self.x.shape[0]
            self.x = np.concatenate(
                [self.x, np.zeros((grow, self.x.shape[1]), np.float32)]
            )
            self.live = np.concatenate([self.live, np.zeros(grow, bool)])
            self.keys.extend([None] * grow)
        row = self.n
        self.x[row] = vec
        self.live[row] = True
        self.keys[row] = key
        self.n += 1
        return row

    def kill(self, row: int) -> None:
        self.live[row] = False
        self.keys[row] = None


def vec_bytes(vector: np.ndarray) -> bytes:
    """Canonical byte identity of a query vector (float32, contiguous) —
    the exact tier's vector component and the coalescing dedup key."""
    return np.ascontiguousarray(np.asarray(vector, np.float32)).tobytes()


class QueryCache:
    """The two-tier cache. Thread-safe (one lock around both tiers) —
    the wall-clock front-end looks up from submitter threads and inserts
    from pool workers; the virtual-clock scheduler is single-threaded and
    fully deterministic.

    ``epoch_fn`` returns the live ``(generation, op_count)`` of the data
    plane being served (see :func:`build_query_cache`); ``stats`` is the
    shared :class:`repro.serve.engine.ServeStats` whose
    ``cache_hits_exact`` / ``cache_hits_semantic`` / ``cache_misses`` /
    ``cache_invalidations`` counters this cache bumps.
    """

    def __init__(
        self,
        cfg: CacheConfig,
        epoch_fn: Optional[Callable[[], Tuple[int, int]]] = None,
        stats=None,
        metric: str = "l2",
    ):
        if cfg.semantic_threshold > 0:
            assert metric == "l2", (
                "the semantic tier's distance threshold is squared-L2 "
                "score space; metric %r is not supported" % metric
            )
        self.cfg = cfg
        self.metric = metric
        self.epoch_fn = epoch_fn or (lambda: (0, 0))
        if stats is None:
            from repro.serve.engine import ServeStats

            stats = ServeStats()
        self.stats = stats
        self._mu = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._groups: Dict[tuple, _Group] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def request_key(vector, k: int, options: tuple) -> tuple:
        """Exact-tier identity of a request: vector bytes + k +
        (filter, hybrid_text, precision). Filters are frozen/hashable by
        construction, so the tuple is a dict key."""
        return (vec_bytes(vector), int(k), options)

    def epoch(self) -> Tuple[int, int]:
        return tuple(self.epoch_fn())

    # ------------------------------------------------------------ validity
    def _valid(self, e: _Entry, epoch: Tuple[int, int], now_s: float) -> bool:
        gen, ops = epoch
        if e.generation != gen:
            return False                    # never across a generation swap
        if now_s - e.time_s > self.cfg.exact_ttl_s:
            return False                    # TTL bound
        if e.op_count != ops and now_s - e.time_s > self.cfg.staleness_s:
            return False                    # writes landed, budget spent
        return True

    def _drop(self, e: _Entry) -> None:
        self._entries.pop(e.key, None)
        g = self._groups.get(e.group_key)
        if g is not None and e.row < g.n and g.keys[e.row] == e.key:
            g.kill(e.row)
        self.stats.cache_invalidations += 1

    # -------------------------------------------------------------- lookup
    def lookup(self, vector, k: int, options: tuple,
               now_s: float) -> Optional[CacheHit]:
        """Try both tiers for (vector, k, options) at time ``now_s``.
        Invalid entries encountered along the way are dropped (counted in
        ``cache_invalidations``); a miss is counted in ``cache_misses``."""
        v = np.ascontiguousarray(np.asarray(vector, np.float32))
        key = (v.tobytes(), int(k), options)
        with self._mu:
            epoch = self.epoch()
            e = self._entries.get(key)
            if e is not None:
                if self._valid(e, epoch, now_s):
                    self._entries.move_to_end(key)      # LRU refresh
                    self.stats.cache_hits_exact += 1
                    return CacheHit(e.ids.copy(), e.scores.copy(), "exact")
                self._drop(e)
            thr = self.cfg.semantic_threshold
            if thr > 0:
                g = self._groups.get((int(k), options))
                # nearest cached query via the delta-buffer scan primitive;
                # re-scan after dropping a stale best candidate
                while g is not None and g.n and g.live[:g.n].any():
                    sc, rows = delta_topk(
                        g.x[:g.n], np.arange(g.n), g.live[:g.n],
                        v[None, :], 1, self.metric,
                    )
                    row = int(rows[0, 0])
                    if row < 0 or float(sc[0, 0]) > thr:
                        break
                    e = self._entries.get(g.keys[row])
                    if e is None:           # defensive: orphaned row
                        g.kill(row)
                        continue
                    if self._valid(e, epoch, now_s):
                        self.stats.cache_hits_semantic += 1
                        return CacheHit(
                            e.ids.copy(), e.scores.copy(), "semantic"
                        )
                    self._drop(e)
            self.stats.cache_misses += 1
            return None

    # -------------------------------------------------------------- insert
    def insert(self, vector, k: int, options: tuple, ids, scores,
               now_s: float, epoch: Optional[Tuple[int, int]] = None) -> None:
        """Store one served answer. ``epoch`` should be the epoch read
        *before* the answer's batch executed (conservative staleness
        stamping); None reads the live epoch."""
        v = np.ascontiguousarray(np.asarray(vector, np.float32))
        key = (v.tobytes(), int(k), options)
        with self._mu:
            gen, ops = self.epoch() if epoch is None else epoch
            old = self._entries.pop(key, None)
            if old is not None:             # refresh, not an invalidation
                g = self._groups.get(old.group_key)
                if (g is not None and old.row < g.n
                        and g.keys[old.row] == old.key):
                    g.kill(old.row)
            while len(self._entries) >= max(1, self.cfg.max_entries):
                _, victim = self._entries.popitem(last=False)   # LRU evict
                g = self._groups.get(victim.group_key)
                if (g is not None and victim.row < g.n
                        and g.keys[victim.row] == victim.key):
                    g.kill(victim.row)
            gkey = (int(k), options)
            g = self._groups.get(gkey)
            if g is None:
                g = self._groups[gkey] = _Group(v.shape[0])
            self._maybe_compact(g)
            row = g.append(v, key)
            self._entries[key] = _Entry(
                key=key, group_key=gkey, row=row,
                ids=np.array(ids, np.int64, copy=True).reshape(-1),
                scores=np.array(scores, np.float32, copy=True).reshape(-1),
                generation=int(gen), op_count=int(ops),
                time_s=float(now_s),
            )

    def _maybe_compact(self, g: _Group) -> None:
        """Rebuild a group's buffer when dead rows dominate (evictions /
        invalidations leave holes; the scan cost tracks ``n``, so shrink
        it back to the live set). Entry rows are remapped in place."""
        if g.n < 64 or int(g.live[:g.n].sum()) * 2 > g.n:
            return
        live_rows = np.nonzero(g.live[:g.n])[0]
        for new_row, old_row in enumerate(live_rows):
            e = self._entries.get(g.keys[old_row])
            if e is not None:
                e.row = new_row
        g.x[:live_rows.size] = g.x[live_rows]
        g.keys[:live_rows.size] = [g.keys[r] for r in live_rows]
        g.live[:live_rows.size] = True
        g.live[live_rows.size:] = False
        g.keys[live_rows.size:] = [None] * (len(g.keys) - live_rows.size)
        g.n = int(live_rows.size)

    # ---------------------------------------------------------- bulk hooks
    def invalidate_all(self) -> int:
        """Drop every entry (counted in ``cache_invalidations``); returns
        how many were dropped. The epoch rules make this unnecessary for
        correctness — it is an explicit hook for tests and operators."""
        with self._mu:
            n = len(self._entries)
            self._entries.clear()
            self._groups.clear()
            self.stats.cache_invalidations += n
            return n


def build_query_cache(sched_cfg, target, stats) -> Optional[QueryCache]:
    """Construct the cache a scheduler/front-end config asks for (or None
    when ``cfg.cache`` is absent/disabled — the inert default). The epoch
    source is the *root* data plane under ``target``
    (:meth:`repro.core.types.DataPlane._root_data_plane` — ultimately the
    shared :class:`repro.core.SegmentedIndex`, so fleet-wide writes and
    compaction commits are seen no matter which surface made them); stub
    targets without a data plane get a constant epoch."""
    ccfg: Optional[CacheConfig] = getattr(sched_cfg, "cache", None)
    if ccfg is None or not ccfg.enabled:
        return None
    try:
        root = target._root_data_plane()
    except NotImplementedError:
        root = None
    metric = getattr(getattr(root, "cfg", None), "metric", "l2")
    if root is None or not hasattr(root, "generation"):
        epoch_fn = lambda: (0, 0)               # noqa: E731 - constant epoch
    else:
        epoch_fn = lambda: (root.generation, root.op_count)  # noqa: E731
    return QueryCache(ccfg, epoch_fn=epoch_fn, stats=stats, metric=metric)
