"""Background compaction for the mutable segmented data plane.

The :class:`repro.core.SegmentedIndex` absorbs writes into a small
append-only delta buffer and tombstone bitmaps; left alone, the delta's
brute-force scan and the dead rows' wasted residency would slowly tax
every query. The :class:`Compactor` keeps both bounded, off the serving
path:

* **seal** — when the delta reaches ``delta_threshold`` live rows, seal
  it into a new sealed segment (k-means + pack, the expensive step, runs
  without holding the data-plane lock; writes that land meanwhile are
  journaled and replayed at commit);
* **merge** — when the sealed segment count exceeds ``max_segments`` or
  the tombstoned fraction exceeds ``max_dead_fraction``, re-seal *all*
  live rows into one fresh segment (dropping dead rows and resetting the
  tombstone bitmaps). A full merge is bit-identical to ``build_ivf``
  over the live set — recall is exactly a from-scratch rebuild's.

Swap protocol (zero dropped queries):

1. ``begin_compaction`` snapshots the rows to re-seal and starts the
   write journal — serving continues on the old segments;
2. ``seal`` builds the new segment(s) — long, lock-free;
3. every live replica ``prepare_segments`` — plans/corpora (and warmed
   device executors for spmd replicas) are built into a staging area, so
   the swap itself is O(1);
4. ``commit_compaction`` atomically installs the new segment set, replays
   the journal, and bumps the generation;
5. every live replica ``adopt``\\ s the new generation (a replica that
   missed this call self-heals on its next batch).

In-flight batches keep searching their snapshot throughout — a query
admitted at any point during 1–5 is answered, exactly, by whichever
generation its batch snapshotted.

>>> import numpy as np
>>> from repro.config import HarmonyConfig
>>> from repro.core import SegmentedIndex
>>> from repro.serve import HarmonyServer
>>> from repro.serve.compactor import CompactionConfig, Compactor
>>> rng = np.random.default_rng(0)
>>> cfg = HarmonyConfig(dim=8, nlist=4, nprobe=4, topk=3, kmeans_iters=2)
>>> data = SegmentedIndex.build(
...     rng.standard_normal((128, 8)).astype(np.float32), cfg)
>>> srv = HarmonyServer(data, n_nodes=2)
>>> comp = Compactor(data, srv, CompactionConfig(delta_threshold=4))
>>> srv.upsert(np.arange(128, 134), rng.standard_normal((6, 8)))
>>> event = comp.maybe_compact()
>>> event["reason"], event["generation"], data.delta_len, data.n_segments
('delta_full', 1, 0, 2)
>>> int(srv.search_batch(data.segments[-1].index.x[:1], k=1).ids[0, 0]) >= 128
True
"""

from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core import SegmentedIndex
from repro.runtime.faults import fault_point
from repro.serve.placement import (
    PlacementConfig,
    apply_placement,
    plan_placement,
)


@dataclass(frozen=True)
class CompactionConfig:
    """Compaction policy knobs.

    ``delta_threshold`` — live delta rows that trigger a seal;
    ``max_segments`` — sealed segment count that triggers a full merge;
    ``max_dead_fraction`` — tombstoned fraction of sealed rows that
    triggers a full merge; ``poll_s`` — background thread poll interval
    (seconds); ``placement`` — optional
    :class:`repro.serve.placement.PlacementConfig`: when set, the
    compactor also owns tier placement — it re-plans the hot/cold split
    after every commit (new segments are born unplaced) and whenever
    :meth:`Compactor.maybe_place` sees the hotness-driven plan drift
    from the installed one."""

    delta_threshold: int = 1024
    max_segments: int = 4
    max_dead_fraction: float = 0.25
    poll_s: float = 0.05
    placement: Optional[PlacementConfig] = None


class Compactor:
    """Seals/merges a :class:`~repro.core.SegmentedIndex` and hot-swaps
    the result into live replicas.

    ``servers`` is the set of replicas to prepare/adopt around each
    commit: a single ``HarmonyServer``, a
    :class:`repro.serve.fleet.ReplicaFleet` (its *live* servers are
    re-resolved on every cycle, so replicas that fail or join mid-trace
    are handled), an explicit sequence of servers, or ``None`` (replicas
    then adopt lazily on their next batch). Use :meth:`maybe_compact`
    from a scheduler hook (deterministic / virtual-clock harnesses) or
    :meth:`start` for a real background thread (the live front-end).
    ``events`` records one dict per completed compaction."""

    def __init__(
        self,
        data: SegmentedIndex,
        servers=None,
        cfg: Optional[CompactionConfig] = None,
    ):
        self.data = data
        self.cfg = cfg or CompactionConfig()
        self._servers_arg = servers
        self.events: List[Dict] = []
        self.errors: List[str] = []         # failed background cycles
        self._op_mu = threading.Lock()      # one compaction cycle at a time
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- targets
    def _servers(self) -> Sequence:
        s = self._servers_arg
        if s is None:
            return ()
        if hasattr(s, "live_servers"):          # ReplicaFleet
            return s.live_servers()
        if hasattr(s, "prepare_segments"):      # single HarmonyServer
            return (s,)
        return tuple(s)

    # -------------------------------------------------------------- policy
    def should_compact(self) -> Optional[str]:
        """Why a compaction is due now, or None. ``"delta_full"`` seals
        the delta; ``"too_many_segments"``/``"dead_heavy"`` full-merge."""
        cfg = self.cfg
        if self.data.n_segments > cfg.max_segments:
            return "too_many_segments"
        sealed = sum(s.nb for s in self.data.segments)
        dead = sum(self.data.dead_count_by_segment().values())
        if sealed and dead / sealed > cfg.max_dead_fraction:
            return "dead_heavy"
        if self.data.delta_len >= cfg.delta_threshold:
            # sealing the delta would push the segment count over the
            # bound anyway: merge instead of seal-then-merge
            if self.data.n_segments >= cfg.max_segments:
                return "too_many_segments"
            return "delta_full"
        return None

    # ------------------------------------------------------------- cycles
    def run_once(self, merge_all: bool = False, reason: str = "manual") -> Dict:
        """One full begin → seal → prepare → commit → adopt cycle.
        Serving is never paused; a concurrent cycle is waited out (the
        data plane itself raises only if ``begin_compaction`` races a
        non-Compactor caller)."""
        with self._op_mu:
            return self._run_once_locked(merge_all, reason)

    def _run_once_locked(self, merge_all: bool, reason: str) -> Dict:
        t0 = time.perf_counter()
        plan = self.data.begin_compaction(merge_all=merge_all)
        # the named fault sites sit BETWEEN the phases, outside the abort
        # handler on purpose: an InjectedFault there simulates the process
        # dying at that boundary, so the aftermath (open journal, staged-
        # but-uncommitted segments, committed-but-unadopted generation) is
        # exactly a kill's — :meth:`recover` is what cleans it up. A real
        # failure *inside* seal/prepare still aborts as before.
        fault_point("compactor.begin", reason=reason)
        try:
            segments = self.data.seal(plan)
        except BaseException:
            self.data.abort_compaction()
            raise
        fault_point("compactor.seal", reason=reason)
        try:
            for srv in self._servers():
                srv.prepare_segments(segments)
        except BaseException:
            self.data.abort_compaction()
            raise
        fault_point("compactor.prepare", reason=reason)
        generation = self.data.commit_compaction(plan, segments)
        fault_point("compactor.commit", reason=reason)
        for srv in self._servers():
            srv.adopt()
        placed = self._place_locked()
        event = {
            "reason": reason,
            "generation": generation,
            "merge_all": merge_all,
            "sealed_rows": int(plan.ids.size),
            "merged_segments": len(plan.merge_seg_ids),
            "carried_segments": len(plan.carry_seg_ids),
            "new_segments": len(segments),
            "segments_after": self.data.n_segments,
            "placed": placed,
            "wall_s": time.perf_counter() - t0,
        }
        self.events.append(event)
        return event

    # ----------------------------------------------------------- placement
    def _place_locked(self) -> bool:
        pcfg = self.cfg.placement
        if pcfg is None:
            return False
        tiers = plan_placement(self.data, pcfg)
        return apply_placement(self.data, self._servers(), tiers)

    def maybe_place(self) -> Optional[Dict]:
        """Re-run the hotness-driven placement policy and install the
        plan if it drifted from the current tiers (no-op otherwise; also
        a no-op without ``cfg.placement``). Like :meth:`maybe_compact`,
        safe to call from scheduler hooks at any frequency — the swap is
        zero-downtime and results are tier-invariant."""
        if self.cfg.placement is None:
            return None
        with self._op_mu:
            if not self._place_locked():
                return None
            event = {
                "reason": "placement",
                "tiers": dict(self.data.tiers()),
                "placement_version": self.data.placement_version,
            }
            self.events.append(event)
            return event

    def maybe_compact(self) -> Optional[Dict]:
        """Run one cycle if the policy says so (no-op otherwise). Safe to
        call from scheduler hooks at any frequency. The policy is
        re-evaluated *after* acquiring the cycle lock — a call that
        queued behind another cycle must not execute that cycle's stale
        decision (e.g. a second full merge of an already-merged plane)."""
        if self.should_compact() is None:       # cheap pre-check, no lock
            return None
        with self._op_mu:
            reason = self.should_compact()
            if reason is None:
                return None
            return self._run_once_locked(
                merge_all=(reason != "delta_full"), reason=reason
            )

    # ------------------------------------------------------ crash recovery
    def recover(self) -> Dict:
        """Bring the plane back to a clean compactable state after a
        crash mid-cycle (or on any restart — a no-op when clean).

        The crash matrix, by the phase boundary the cycle died at:

        * **begin/seal/prepare** (journal open, nothing committed) —
          roll back: ``abort_compaction`` closes the journal. Nothing is
          lost — begin only *snapshots* rows, so every write is still
          live in the delta/tombstone state, and the sealed-but-never-
          committed segments are garbage by construction;
        * **commit** (generation bumped, replicas not yet told) —
          roll forward: every live server ``adopt``\\ s the committed
          generation (they would also self-heal lazily on their next
          batch). Adopt also prunes any staged-but-never-committed
          segment state a prepare-phase crash parked on a server.

        Returns ``{"rolled_back": bool, "adopted": [...], "generation"}``.
        """
        rolled_back = False
        with self._op_mu:
            if self.data.compaction_in_flight:
                self.data.abort_compaction()
                rolled_back = True
            adopted = []
            for srv in self._servers():
                if srv.generation != self.data.generation:
                    adopted.append(srv.generation)
                srv.adopt()
        report = {
            "rolled_back": rolled_back,
            "adopted": adopted,
            "generation": self.data.generation,
        }
        self.events.append({"reason": "recover", **report})
        return report

    # ---------------------------------------------------------- background
    def start(self) -> "Compactor":
        """Start the background thread (idempotent); pair with
        :meth:`stop` or use as a context manager."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="harmony-compactor", daemon=True
            )
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.maybe_compact()
                self.maybe_place()
            except Exception as e:      # noqa: BLE001 - must not die silently
                # a failed cycle (seal/prepare/commit error) is recorded
                # and surfaced, never swallowed — the loop keeps serving
                # the compaction policy, but an operator can see why the
                # delta is growing
                self.errors.append(repr(e))
                warnings.warn(f"background compaction failed: {e!r}")
            self._stop.wait(self.cfg.poll_s)

    def stop(self, timeout: float = 30.0) -> bool:
        """Signal the loop and join. Returns True once the thread is down.

        On a join timeout the handle is *kept* (dropping it would leak a
        live thread that :meth:`start` could then duplicate, and the
        stop event it still polls could be cleared under it) and the
        failure is recorded in ``self.errors`` — call again to re-join."""
        self._stop.set()
        t = self._thread
        if t is None:
            return True
        t.join(timeout=timeout)
        if t.is_alive():
            self.errors.append(
                f"stop(): compactor thread still alive after {timeout}s join"
            )
            return False
        self._thread = None
        return True

    def __enter__(self) -> "Compactor":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
