from repro.serve.engine import HarmonyServer, ServeStats

__all__ = ["HarmonyServer", "ServeStats"]
