from repro.serve.engine import HarmonyServer, ServeStats
from repro.serve.scheduler import (
    Request,
    RequestResult,
    SchedulerConfig,
    ServingScheduler,
)

__all__ = [
    "HarmonyServer",
    "ServeStats",
    "Request",
    "RequestResult",
    "SchedulerConfig",
    "ServingScheduler",
]
