"""Serving layer: scheduler-backed batched ANNS over the HARMONY core.

See ``docs/ARCHITECTURE.md`` for the end-to-end picture; the short map:

Backend selection
-----------------
Every scheduled batch executes through ``HarmonyServer.search_batch``,
which dispatches to one of two interchangeable engines:

* ``backend="host"`` (default) — the staged numpy engine
  (:func:`repro.core.search.harmony_search`), the CPU-measured
  reproduction path and the exactness oracle;
* ``backend="spmd"`` — the device-resident executor
  (:class:`repro.serve.executor.SpmdExecutor`), which holds the sharded
  corpus, per-block norms and ids on the device mesh once and runs the
  jit'd Pallas/SPMD ring pipeline per batch.

Select per server (``HarmonyServer(..., backend="spmd")``), per call
(``search_batch(q, backend=...)``), or per scheduler
(``SchedulerConfig(backend="spmd")`` — what ``HarmonyServer.serve`` uses).
Both backends return identical top-K up to floating-point tie order.

Dispatch targets
----------------
The scheduler's batch former is decoupled from execution: formed batches
go to a pluggable :class:`repro.serve.scheduler.DispatchTarget` —
:class:`~repro.serve.scheduler.SingleServerTarget` (one server, the
default when a ``HarmonyServer`` is passed) or
:class:`repro.serve.fleet.ReplicaFleet` (N replicas behind the same
admission queue with load-estimate routing, power-of-two-choices
sampling, cross-replica straggler hedging, and replica fail/join
elasticity).

Clocks
------
The queue/deadline/shed logic is clock-agnostic
(:class:`repro.serve.clock.Clock`):

* :class:`~repro.serve.scheduler.ServingScheduler` +
  :class:`~repro.serve.clock.VirtualClock` — deterministic trace replay,
  the test oracle (``tests/test_virtual_clock_goldens.py`` pins it);
* :class:`~repro.serve.frontend.ServingFrontend` +
  :class:`~repro.serve.clock.MonotonicClock` — live wall-clock serving:
  ``submit()``/``asubmit()`` return futures, a dispatcher thread fires
  the same batch-forming triggers, and a thread pool overlaps replica
  execution for real (per-replica locks, atomic EWMA accounting,
  wall-clock hedging).

Mutable data plane
------------------
Servers serve a shared :class:`repro.core.SegmentedIndex` (sealed
segments + delta buffer + tombstones; a plain ``IVFIndex`` is wrapped as
the one-sealed-segment special case). ``upsert()``/``delete()`` are
exposed at every level — ``HarmonyServer``, ``ReplicaFleet``,
``ServingFrontend`` — and are visible to the next dispatched batch;
:class:`repro.serve.compactor.Compactor` seals the delta / merges
segments in the background and hot-swaps the result into all live
replicas with zero dropped queries (see ``docs/ARCHITECTURE.md``,
"Data-plane lifecycle").

The bucket ladder
-----------------
jit recompiles per static shape, while the scheduler's adaptive batches
vary in query count and candidate volume. The executor therefore pads
each batch up a small ladder of (qb, cap) buckets — qb from
``ExecutorConfig.qb_buckets``, cap = chunk·2^i up to the shard capacity —
and caches one compiled step per bucket, so a mixed-size replay compiles
each bucket at most once. Batches beyond the biggest qb bucket are split
and merged host-side.
"""

from repro.core.types import (
    And,
    DataPlane,
    Filter,
    NumRange,
    Or,
    SearchRequest,
    SearchResult,
    TagIn,
)
from repro.serve.cache import CacheConfig, CacheHit, QueryCache
from repro.serve.clock import Clock, MonotonicClock, VirtualClock
from repro.serve.compactor import CompactionConfig, Compactor
from repro.serve.engine import HarmonyServer, ServeStats
from repro.serve.executor import ExecutorConfig, SpmdExecutor
from repro.serve.fleet import Replica, ReplicaFleet, ReplicaSpec, gini
from repro.serve.frontend import ServingFrontend, ShedError
from repro.serve.placement import (
    PlacementConfig,
    apply_placement,
    device_bytes_by_segment,
    plan_placement,
)
from repro.serve.scheduler import (
    DispatchTarget,
    Request,
    RequestResult,
    SchedulerConfig,
    ServingScheduler,
    SingleServerTarget,
    SkewMonitor,
)

__all__ = [
    "HarmonyServer",
    "ServeStats",
    "SearchRequest",
    "SearchResult",
    "Filter",
    "TagIn",
    "NumRange",
    "And",
    "Or",
    "DataPlane",
    "CacheConfig",
    "CacheHit",
    "QueryCache",
    "Compactor",
    "CompactionConfig",
    "PlacementConfig",
    "plan_placement",
    "apply_placement",
    "device_bytes_by_segment",
    "ExecutorConfig",
    "SpmdExecutor",
    "Clock",
    "VirtualClock",
    "MonotonicClock",
    "DispatchTarget",
    "SingleServerTarget",
    "SkewMonitor",
    "Replica",
    "ReplicaFleet",
    "ReplicaSpec",
    "gini",
    "Request",
    "RequestResult",
    "SchedulerConfig",
    "ServingScheduler",
    "ServingFrontend",
    "ShedError",
]
