"""Persist/restore the segmented ANNS data plane through
:class:`repro.checkpoint.Checkpointer`.

Servers no longer rebuild (train + add + pre-assign) the corpus on every
start: ``save_segmented_index`` writes the sealed segments (centers,
packed rows, external ids, cluster tables, and — when present — the
int8 quantized tier's codes/scales), the dead-row bitmaps, the
live delta rows, and the config as one generation-numbered checkpoint
step; ``load_segmented_index`` reconstructs a byte-equivalent
:class:`repro.core.SegmentedIndex` that any ``HarmonyServer`` /
``ReplicaFleet`` can serve immediately (plans/corpora/executors are
derived state and rebuilt on adopt, as after any generation swap).

Layout: the standard Checkpointer step directory (manifest + npz), with
the tree structure encoded in the flat keys (``segments/<i>/<leaf>``) and
the non-array metadata (config, segment ids, generation) JSON-encoded in
a ``meta`` uint8 leaf. The step number is the data plane's generation, so
``latest_step()`` is always the newest committed data.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.config import HarmonyConfig
from repro.core import Int8Quant, IVFIndex, MetadataStore, Segment, SegmentedIndex


def _meta_array(meta: dict) -> np.ndarray:
    return np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8).copy()


def _meta_parse(arr: np.ndarray) -> dict:
    return json.loads(bytes(arr.astype(np.uint8)).decode("utf-8"))


def save_segmented_index(
    ckpt: Checkpointer, data: SegmentedIndex, step: Optional[int] = None
) -> Path:
    """Write ``data`` as checkpoint step ``step`` (default: its current
    generation). Point-in-time consistent: the snapshot is taken under
    the data-plane lock, so a concurrent writer can't tear it."""
    with data._mu:
        step = data.generation if step is None else step
        meta = {
            "generation": data.generation,
            "op_count": data.op_count,
            # WAL watermark: the last durable log record this checkpoint
            # already contains — recovery replays only records past it
            "wal_seq": data.wal_seq,
            "next_seg_id": data._next_seg_id,
            "seg_ids": [s.seg_id for s in data.segments],
            "seg_cfgs": [dataclasses.asdict(s.index.cfg) for s in data.segments],
            "cfg": dataclasses.asdict(data.cfg),
            # which segments carry a persisted int8 tier (the canonical
            # cfg.quant_blocks grid; mesh-granularity grids are derived
            # state and rebuilt by the executor on adopt)
            "quantized": [
                s.index.cfg.quant_blocks
                in s.index.__dict__.get("_int8_quants", {})
                for s in data.segments
            ],
            # per-segment metadata column manifest (None = segment has no
            # metadata — old checkpoints load unchanged via .get)
            "meta_cols": [
                None if s.index.meta is None else {
                    "tags": sorted(s.index.meta.tags),
                    "nums": sorted(s.index.meta.nums),
                    "texts": s.index.meta.texts is not None,
                }
                for s in data.segments
            ],
            # memory-hierarchy placement: per-segment tier + version so a
            # restored plane resumes the exact hot/cold split (old
            # checkpoints load all-device via .get)
            "tiers": [data._tier.get(s.seg_id, "device")
                      for s in data.segments],
            "placement_version": data.placement_version,
        }
        tree = {"meta": _meta_array(meta)}
        for i, seg in enumerate(data.segments):
            leaf = {
                "centers": seg.index.centers,
                "x": seg.index.x,
                "ids": seg.index.ids,
                "cluster_of": seg.index.cluster_of,
                "offsets": seg.index.offsets,
                "dead_rows": data._dead_rows[seg.seg_id].copy(),
            }
            q = seg.index.__dict__.get("_int8_quants", {}).get(
                seg.index.cfg.quant_blocks
            )
            if q is not None:
                leaf["quant_codes"] = q.codes
                leaf["quant_scale"] = q.scale
                leaf["quant_zero"] = q.zero
            h = data._hotness.get(seg.seg_id)
            if h is not None:
                leaf["hotness"] = h.copy()
            ms = seg.index.meta
            if ms is not None:
                for name, col in ms.tags.items():
                    leaf[f"meta_tag_{name}"] = col
                for name, col in ms.nums.items():
                    leaf[f"meta_num_{name}"] = col
                if ms.texts is not None:
                    leaf["meta_texts"] = _meta_array({"texts": list(ms.texts)})
            tree[f"segments/{i}"] = leaf
        n = data._delta_len
        live = data._delta_live[:n]
        tree["delta"] = {
            "ids": data._delta_ids[:n][live].copy(),
            "x": data._delta_x[:n][live].copy(),
        }
        delta_meta = [data._delta_meta[r] for r in np.nonzero(live)[0]]
        if any(r for r in delta_meta):
            tree["delta"]["meta_rows"] = _meta_array({"rows": delta_meta})
    return ckpt.save(step, tree)


def load_segmented_index(
    ckpt: Checkpointer, step: Optional[int] = None
) -> SegmentedIndex:
    """Rebuild the :class:`SegmentedIndex` from checkpoint ``step``
    (default: the latest). Searches over the restored index are
    bit-identical to the saved one's."""
    _, arrays = ckpt.load_arrays(step)
    meta = _meta_parse(arrays["meta"])
    cfg = HarmonyConfig(**meta["cfg"])
    quantized = meta.get("quantized", [False] * len(meta["seg_ids"]))
    meta_cols = meta.get("meta_cols", [None] * len(meta["seg_ids"]))
    segments = []
    for i, seg_id in enumerate(meta["seg_ids"]):
        pre = f"segments/{i}/"
        seg_cfg = HarmonyConfig(**meta["seg_cfgs"][i])
        store = None
        if meta_cols[i] is not None:
            mc = meta_cols[i]
            store = MetadataStore(
                tags={n: arrays[pre + f"meta_tag_{n}"].astype(np.int64)
                      for n in mc["tags"]},
                nums={n: arrays[pre + f"meta_num_{n}"].astype(np.float32)
                      for n in mc["nums"]},
                texts=tuple(_meta_parse(arrays[pre + "meta_texts"])["texts"])
                if mc["texts"] else None,
            )
        index = IVFIndex(
            cfg=seg_cfg,
            centers=arrays[pre + "centers"],
            x=arrays[pre + "x"],
            ids=arrays[pre + "ids"].astype(np.int64),
            cluster_of=arrays[pre + "cluster_of"].astype(np.int32),
            offsets=arrays[pre + "offsets"].astype(np.int64),
            build_times={},
            meta=store,
        )
        if quantized[i]:
            index.attach_int8_quant(Int8Quant(
                codes=arrays[pre + "quant_codes"].astype(np.int8),
                scale=arrays[pre + "quant_scale"].astype(np.float32),
                zero=arrays[pre + "quant_zero"].astype(np.float32),
            ))
        segments.append(Segment(seg_id=int(seg_id), index=index))
    data = SegmentedIndex(cfg, segments)
    data.generation = int(meta["generation"])
    data.op_count = int(meta["op_count"])
    data.wal_seq = int(meta.get("wal_seq", 0))
    data._next_seg_id = int(meta["next_seg_id"])
    # placement + hotness: restored verbatim so the restart resumes the
    # saved hot/cold split instead of an all-device cold start
    tiers = meta.get("tiers")
    if tiers is not None:
        data._tier = {int(s): t for s, t in zip(meta["seg_ids"], tiers)}
    data.placement_version = int(meta.get("placement_version", 0))
    for i, seg in enumerate(segments):
        if f"segments/{i}/hotness" in arrays:
            data._hotness[seg.seg_id] = (
                arrays[f"segments/{i}/hotness"].astype(np.float64)
            )
    # rebuild the location map from the dead bitmaps: an external id is
    # live in exactly one (segment, row) — the one whose bit is clear.
    # (The constructor's map ignores tombstones, and a stale sealed copy
    # of an overwritten id must not shadow the live one.)
    data._loc = {}
    for i, seg in enumerate(segments):
        dead = arrays[f"segments/{i}/dead_rows"].astype(bool)
        data._dead_rows[seg.seg_id] = dead
        for r in np.nonzero(~dead)[0]:
            data._loc[int(seg.index.ids[r])] = (seg.seg_id, int(r))
    d_ids = arrays["delta/ids"].astype(np.int64)
    d_x = arrays["delta/x"].astype(np.float32)
    d_meta = [None] * len(d_ids)
    if "delta/meta_rows" in arrays:
        d_meta = _meta_parse(arrays["delta/meta_rows"])["rows"]
    with data._mu:
        for i, v, m in zip(d_ids, d_x, d_meta):
            # saved delta rows are the live set: any sealed copy of the
            # same id was tombstoned at save time (dead_rows), so a plain
            # append reconstructs the exact live state
            data._append_delta_locked(int(i), v, m)
    return data
