from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.index_io import load_segmented_index, save_segmented_index

__all__ = ["Checkpointer", "save_segmented_index", "load_segmented_index"]
