from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.index_io import load_segmented_index, save_segmented_index
from repro.checkpoint.wal import (
    WriteAheadLog,
    checkpoint_segmented_index,
    read_wal,
    recover_segmented_index,
    replay_wal_into,
)

__all__ = [
    "Checkpointer",
    "save_segmented_index",
    "load_segmented_index",
    "WriteAheadLog",
    "read_wal",
    "replay_wal_into",
    "checkpoint_segmented_index",
    "recover_segmented_index",
]
