"""Write-ahead log for the mutable segmented data plane: crash
durability between checkpoints.

``save_segmented_index`` makes the sealed state durable, but a crash
between checkpoints would silently lose every acknowledged upsert/delete
since the last save. The :class:`WriteAheadLog` closes that window:

* **journal** — :meth:`repro.core.SegmentedIndex.attach_wal` makes every
  accepted write append one CRC-framed record (still inside the data-
  plane critical section, so WAL order is exactly apply order) and
  fsync it before the write call returns — *acknowledged implies
  durable*;
* **rotate** — :func:`checkpoint_segmented_index` persists the plane
  (the checkpoint meta carries the ``wal_seq`` watermark of the last
  record it contains), starts a fresh log file named after the step,
  and prunes log files the checkpoint fully covers;
* **recover** — :func:`recover_segmented_index` is
  ``load_segmented_index`` + replay of every WAL record past the
  checkpoint's watermark, tolerant of a *torn final record* (a crash
  mid-``write``): the intact prefix is replayed, the torn tail is
  truncated away, and appending resumes. Records carry global sequence
  numbers, so replay is exact regardless of where rotation crashed —
  a record is applied at most once, in original order.

Framing (little-endian): ``magic "HWAL" | payload_len u32 | seq u64 |
crc32(payload) u32`` then the payload — ``kind u8 (0=upsert 1=delete) |
n u32 | dim u32 | ids int64[n] | vecs float32[n*dim]`` (vecs absent for
deletes). A reader stops at the first frame that fails any check; only
a tail can tear because frames are appended and fsynced in order.

>>> import numpy as np, tempfile
>>> from repro.config import HarmonyConfig
>>> from repro.core import SegmentedIndex
>>> d = tempfile.mkdtemp()
>>> wal = WriteAheadLog(d, sync=False)
>>> wal.append_upsert(np.array([7]), np.ones((1, 4), np.float32))
1
>>> wal.append_delete(np.array([3, 4]))
2
>>> r = read_wal(wal.path)
>>> [(rec.seq, rec.kind) for rec in r.records], r.torn_tail
([(1, 'upsert'), (2, 'delete')], False)
"""

from __future__ import annotations

import os
import re
import struct
import threading
import warnings
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import List, Optional, Tuple

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.index_io import (
    load_segmented_index,
    save_segmented_index,
)
from repro.runtime.faults import InjectedFault, fault_point

_MAGIC = b"HWAL"
_HEADER = struct.Struct("<4sIQI")   # magic, payload_len, seq, crc32(payload)
_KIND_UPSERT = 0
_KIND_DELETE = 1
# upsert carrying per-row metadata: the plain-upsert payload followed by a
# JSON-encoded list of per-row dicts. Meta-free upserts keep kind 0, so
# logs written before the metadata store exist byte-identically.
_KIND_UPSERT_META = 2


def _fsync_dir(path: Path) -> None:
    # make a create/rename durable, not just the file contents; best
    # effort on platforms without directory fds
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclass(frozen=True)
class WalRecord:
    """One decoded log record. ``end_offset`` is the byte offset just
    past this record's frame — the crash points the recovery property
    truncates at."""

    seq: int
    kind: str                       # "upsert" | "delete"
    ids: np.ndarray                 # [n] int64
    vecs: Optional[np.ndarray]      # [n, D] float32 (None for deletes)
    end_offset: int
    meta: Optional[list] = None     # [n] per-row metadata dicts, or None


@dataclass
class WalReadResult:
    """Decoded file: the intact record prefix plus what the tail looked
    like. ``torn_tail`` is True when trailing bytes failed framing/CRC —
    ``valid_bytes`` is where the intact prefix ends (truncate there to
    repair)."""

    records: List[WalRecord] = field(default_factory=list)
    torn_tail: bool = False
    valid_bytes: int = 0

    @property
    def last_seq(self) -> int:
        return self.records[-1].seq if self.records else 0


def _encode(kind: int, ids: np.ndarray, vecs: Optional[np.ndarray],
            meta: Optional[list] = None) -> bytes:
    ids = np.ascontiguousarray(ids, np.int64)
    dim = 0 if vecs is None else int(vecs.shape[1])
    out = [struct.pack("<BII", kind, len(ids), dim), ids.tobytes()]
    if vecs is not None:
        out.append(np.ascontiguousarray(vecs, np.float32).tobytes())
    if kind == _KIND_UPSERT_META:
        import json
        out.append(json.dumps(meta).encode("utf-8"))
    return b"".join(out)


def read_wal(path: Path) -> WalReadResult:
    """Decode one log file, stopping (without raising) at the first
    torn/corrupt frame — the intact prefix is exactly the acknowledged
    writes a crashed process had made durable."""
    res = WalReadResult()
    path = Path(path)
    if not path.exists():
        return res
    buf = path.read_bytes()
    off = 0
    while off + _HEADER.size <= len(buf):
        magic, plen, seq, crc = _HEADER.unpack_from(buf, off)
        start = off + _HEADER.size
        if magic != _MAGIC or start + plen > len(buf):
            break
        payload = buf[start:start + plen]
        if zlib.crc32(payload) != crc:
            break
        kind, n, dim = struct.unpack_from("<BII", payload, 0)
        p = struct.calcsize("<BII")
        ids = np.frombuffer(payload, np.int64, count=n, offset=p).copy()
        vecs = meta = None
        if kind in (_KIND_UPSERT, _KIND_UPSERT_META):
            vecs = np.frombuffer(
                payload, np.float32, count=n * dim, offset=p + ids.nbytes
            ).reshape(n, dim).copy()
            if kind == _KIND_UPSERT_META:
                import json
                meta = json.loads(
                    payload[p + ids.nbytes + vecs.nbytes:].decode("utf-8")
                )
        off = start + plen
        res.records.append(WalRecord(
            seq=int(seq),
            kind="delete" if kind == _KIND_DELETE else "upsert",
            ids=ids, vecs=vecs, end_offset=off, meta=meta,
        ))
    res.valid_bytes = off
    res.torn_tail = off < len(buf)
    return res


class WriteAheadLog:
    """Append-only, CRC-framed, fsync'd log of data-plane writes.

    Opening an existing directory continues it: the newest
    ``wal_<step>.log`` is repaired (a torn tail from a previous crash is
    truncated away) and appending resumes with the next global sequence
    number. ``sync=False`` skips the per-record fsync (still flushed) —
    for benchmarks that model group commit; durability tests keep the
    default. Appends are internally locked, but the intended caller is
    :meth:`repro.core.SegmentedIndex.attach_wal`, whose data-plane lock
    already serializes writers (keeping WAL order = apply order)."""

    def __init__(self, directory, sync: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.sync = sync
        self._mu = threading.Lock()
        self._f = None
        files = self.files()
        last_seq = 0
        for p in files:
            r = read_wal(p)
            if r.torn_tail and p == files[-1]:
                # repair: drop the torn final record so appends can't
                # bury it mid-file (it was never acknowledged)
                with open(p, "r+b") as f:
                    f.truncate(r.valid_bytes)
            last_seq = max(last_seq, r.last_seq)
        self._next_seq = last_seq + 1
        step = self._step_of(files[-1]) if files else 0
        self._open(step)

    # ------------------------------------------------------------- files
    @staticmethod
    def _step_of(path: Path) -> int:
        m = re.fullmatch(r"wal_(\d+)\.log", path.name)
        if not m:
            raise ValueError(f"not a wal file: {path}")
        return int(m.group(1))

    def files(self) -> List[Path]:
        """Log files, oldest step first."""
        out = [p for p in self.dir.glob("wal_*.log")
               if re.fullmatch(r"wal_(\d+)\.log", p.name)]
        return sorted(out, key=self._step_of)

    @property
    def path(self) -> Path:
        """The file currently being appended to."""
        return self._path

    @property
    def last_seq(self) -> int:
        """Sequence number of the last acknowledged record (0 if none)."""
        with self._mu:
            return self._next_seq - 1

    def _open(self, step: int) -> None:
        self._path = self.dir / f"wal_{step:09d}.log"
        existed = self._path.exists()
        self._f = open(self._path, "ab")
        if not existed:
            _fsync_dir(self.dir)

    # ------------------------------------------------------------ append
    def _append(self, kind: int, ids, vecs, meta=None) -> int:
        payload = _encode(
            kind, np.asarray(ids, np.int64).reshape(-1), vecs, meta
        )
        with self._mu:
            seq = self._next_seq
            frame = _HEADER.pack(
                _MAGIC, len(payload), seq, zlib.crc32(payload)
            ) + payload
            try:
                fault_point("wal.append", seq=seq)
            except InjectedFault as e:
                if e.kind == "torn":
                    # a power cut mid-write(2): persist a partial frame,
                    # then die — recovery must treat it as never written
                    cut = _HEADER.size + len(payload) // 2
                    self._f.write(frame[:cut])
                    self._f.flush()
                    os.fsync(self._f.fileno())
                raise
            self._f.write(frame)
            self._f.flush()
            if self.sync:
                os.fsync(self._f.fileno())
            self._next_seq = seq + 1
            return seq

    def append_upsert(self, ids, vecs, meta=None) -> int:
        """Journal one acknowledged upsert batch (``meta``: per-row
        metadata dicts, or None); returns its seq."""
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None]
        if meta is not None and any(r for r in meta):
            return self._append(
                _KIND_UPSERT_META, ids, vecs,
                [r or None for r in meta],
            )
        return self._append(_KIND_UPSERT, ids, vecs)

    def append_delete(self, ids) -> int:
        """Journal one acknowledged delete batch; returns its seq."""
        return self._append(_KIND_DELETE, ids, None)

    # ----------------------------------------------------------- rotation
    def rotate(self, step: int, prune_up_to_seq: Optional[int] = None) -> Path:
        """Start a fresh ``wal_<step>.log`` (after a checkpoint commit)
        and delete older files whose every record is ≤
        ``prune_up_to_seq`` (i.e. fully contained in that checkpoint).
        Records are never rewritten — a crash anywhere around rotation
        leaves replay exact because recovery filters by sequence
        number, not by file."""
        with self._mu:
            self._f.close()
            self._open(step)
            if prune_up_to_seq is not None:
                for p in self.files():
                    if p == self._path:
                        continue
                    if read_wal(p).last_seq <= prune_up_to_seq:
                        p.unlink()
                _fsync_dir(self.dir)
            return self._path

    def close(self) -> None:
        with self._mu:
            if self._f is not None:
                self._f.close()
                self._f = None

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


# --------------------------------------------------------------- recovery
def checkpoint_segmented_index(
    ckpt: Checkpointer, data, wal: WriteAheadLog
) -> Path:
    """Durable checkpoint commit: persist the plane (the saved meta
    carries its ``wal_seq`` watermark), then rotate the WAL onto a fresh
    file and prune files the checkpoint fully covers. The watermark is
    read *before* the save, so a write landing concurrently is never
    pruned — worst case it survives in both the checkpoint and a kept
    log file, and replay's sequence filter drops the duplicate."""
    watermark = data.wal_seq
    path = save_segmented_index(ckpt, data)
    step = int(re.fullmatch(r"step_(\d+)", path.name).group(1))
    wal.rotate(step, prune_up_to_seq=watermark)
    return path


def replay_wal_into(data, directory, min_seq: int = 0) -> dict:
    """Apply every WAL record with ``seq > min_seq`` (oldest file first)
    to ``data``. The plane must not have a WAL attached yet — replay
    must not re-journal its own records. Returns a report dict."""
    if data._wal is not None:
        raise RuntimeError("detach the WAL before replaying into the plane")
    replayed = skipped = 0
    torn = False
    wal_dir = Path(directory)
    files = sorted(
        (p for p in wal_dir.glob("wal_*.log")
         if re.fullmatch(r"wal_(\d+)\.log", p.name)),
        key=WriteAheadLog._step_of,
    )
    for p in files:
        r = read_wal(p)
        torn = torn or r.torn_tail
        for rec in r.records:
            if rec.seq <= min_seq:
                skipped += 1
                continue
            if rec.kind == "upsert":
                data.upsert(rec.ids, rec.vecs, meta=rec.meta)
            else:
                data.delete(rec.ids)
            data.wal_seq = rec.seq
            replayed += 1
    return {"replayed": replayed, "skipped": skipped, "torn_tail": torn,
            "files": len(files)}


def recover_segmented_index(
    ckpt: Checkpointer,
    wal_dir,
    cfg=None,
    step: Optional[int] = None,
    sync: bool = True,
) -> Tuple[object, WriteAheadLog, dict]:
    """Crash recovery: latest readable checkpoint + WAL tail replay.

    Returns ``(data, wal, report)`` — the recovered plane (every
    acknowledged write present, the torn tail of an interrupted final
    record dropped), a repaired :class:`WriteAheadLog` re-attached to
    the plane (journaling continues with the next sequence number), and
    a report of what replay did. With no checkpoint on disk the plane
    is rebuilt from ``cfg`` alone (all rows live in the delta until the
    first compaction) — pass the serving config for that cold-start
    path, or get ``FileNotFoundError``."""
    from repro.core import SegmentedIndex

    try:
        data = load_segmented_index(ckpt, step)
    except FileNotFoundError:
        if cfg is None:
            raise
        warnings.warn(
            f"no checkpoint under {ckpt.dir}; recovering from WAL alone"
        )
        data = SegmentedIndex(cfg, ())
    report = replay_wal_into(data, wal_dir, min_seq=data.wal_seq)
    wal = WriteAheadLog(wal_dir, sync=sync)     # repairs any torn tail
    data.attach_wal(wal)
    return data, wal, report
