"""Sharded checkpointing with manifest, retention, async writes, and
**resharding restore** (load into a different mesh — the elastic-scaling
path).

Layout per step::

    <dir>/step_000042/
        manifest.json        # tree structure, shapes, dtypes, step
        arrays.npz           # one entry per leaf (path-keyed)

Each process writes its addressable shards; in this single-process
container that is the full array (the npz key scheme ``<leaf>@shard0``
leaves room for per-process shard files on real multi-host). Restore
optionally takes ``shardings`` (a pytree of NamedSharding) and places
leaves directly onto the (possibly different) target mesh.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.runtime.faults import fault_point


def _fsync_file(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None
        self.errors: list = []          # failed async writes (repr strings)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> Path:
        """Write one step crash-atomically: arrays + manifest land in a
        hidden temp dir (invisible to ``all_steps``), are fsynced, and
        are published by a single directory rename — an interrupted
        write (sync or async, at any instant) can never leave a corrupt
        ``step_*`` dir, at worst dead ``.tmp_*``/``.old_*`` litter that
        the next save of the same step sweeps. Async-mode failures are
        recorded in ``self.errors`` and warned, never swallowed."""
        flat = _flatten(tree)
        # np.load returns ml_dtypes (bf16) arrays as raw void — store them
        # as uint16 views and reconstruct from the manifest dtype on load.
        host = {}
        for k, v in flat.items():
            a = np.asarray(v)
            host[k] = a.view(np.uint16) if a.dtype.name == "bfloat16" else a
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
            "time": time.time(),
        }
        final = self.dir / f"step_{step:09d}"

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **{f"{k}@shard0": v for k, v in host.items()})
            fault_point("checkpoint.write", step=step)
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            _fsync_file(tmp / "arrays.npz")
            _fsync_file(tmp / "manifest.json")
            # publish: directory renames are atomic, so readers see either
            # the complete old step or the complete new one. Overwriting
            # an existing step moves it aside first (rename, not rmtree —
            # a crash mid-delete would tear the only copy); a crash in
            # the window between the two renames leaves no step_ dir for
            # this step and load_arrays falls back to the previous one.
            old = None
            if final.exists():
                old = self.dir / f".old_step_{step:09d}"
                if old.exists():
                    shutil.rmtree(old)
                final.rename(old)
            fault_point("checkpoint.publish", step=step)
            tmp.rename(final)
            try:
                _fsync_file(self.dir)
            except OSError:
                pass
            if old is not None:
                shutil.rmtree(old)
            self._gc()

        if self.async_write:
            self.wait()

            def write_guarded():
                try:
                    write()
                except BaseException as e:     # noqa: BLE001 - surfaced below
                    self.errors.append(repr(e))
                    warnings.warn(f"async checkpoint write failed: {e!r}")

            self._pending = threading.Thread(target=write_guarded, daemon=True)
            self._pending.start()
        else:
            write()
        return final

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        # an orphaned .old_step_* is a step's only surviving copy — put
        # it back before sweeping, or the sweep would destroy data
        self._recover_interrupted_publish()
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
        # crash litter: published steps never live under these names, and
        # only one write is in flight at a time (async waits its
        # predecessor), so anything left here is a dead interrupted write
        for p in list(self.dir.glob(".tmp_step_*")) + list(
            self.dir.glob(".old_step_*")
        ):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)

    def _recover_interrupted_publish(self):
        """Undo a crash in :meth:`save`'s publish window. Overwriting an
        existing step moves the old copy aside (``.old_step_N``) before
        renaming the new one in; dying between the two renames leaves no
        ``step_N`` at all — and if N was the only step, every write the
        WAL already pruned as checkpoint-covered would be gone with it.
        The moved-aside copy is the previously *published* step, complete
        and fsynced, so restoring it is always safe: rename it back
        whenever its ``step_N`` is missing. An ``.old_step_N`` whose
        ``step_N`` exists means the publish completed — that one really
        is dead litter and is left for the sweep."""
        restored = []
        for p in self.dir.glob(".old_step_*"):
            m = re.fullmatch(r"\.old_step_(\d+)", p.name)
            if not m or not p.is_dir():
                continue
            final = self.dir / f"step_{m.group(1)}"
            if final.exists():
                continue
            p.rename(final)
            restored.append(int(m.group(1)))
            warnings.warn(
                f"restored checkpoint step {int(m.group(1))} from an "
                f"interrupted overwrite under {self.dir}"
            )
        return restored

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_step(self, step: int):
        """Fully read one step (manifest parse + every array materialized)
        — raises on any corruption, so callers can fall back."""
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        arrays = {}
        for k in data.files:
            key = k[: -len("@shard0")]
            arr = data[k]
            want = manifest["leaves"].get(key, {}).get("dtype")
            if want == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            arrays[key] = arr
        return manifest, arrays

    def load_arrays(self, step: Optional[int] = None):
        """Load one step's raw (manifest, flat arrays) without needing a
        ``target_like`` pytree — for consumers whose structure is encoded
        in the arrays themselves (e.g. the segmented-index manifest,
        whose segment count is data). Keys are the flattened tree paths
        (``a/b/c``). Leaves saved as bfloat16 (stored on disk as uint16
        views) are reconstructed from the manifest dtype, as
        :meth:`restore` does.

        With no explicit ``step``, unreadable steps (a manifest or npz
        torn by a crash that predates the atomic-publish protocol, or
        external corruption) are *skipped with a warning* and the newest
        readable step is returned — a damaged latest checkpoint must
        degrade recovery to the previous one, not block it. An explicit
        ``step`` still raises: the caller asked for that step, silently
        substituting another would be wrong."""
        self.wait()
        self._recover_interrupted_publish()
        if step is not None:
            return self._read_step(step)
        steps = self.all_steps()
        for s in reversed(steps):
            try:
                return self._read_step(s)
            except Exception as e:      # noqa: BLE001 - fall back + warn
                warnings.warn(
                    f"skipping unreadable checkpoint step {s} "
                    f"under {self.dir}: {e!r}"
                )
        raise FileNotFoundError(f"no readable checkpoints under {self.dir}")

    def restore(self, target_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``target_like``. ``shardings``
        (same pytree structure, of NamedSharding) reshards onto a possibly
        different mesh — the elastic restart path."""
        self.wait()
        self._recover_interrupted_publish()
        if step is None:
            # same unreadable-step fallback as load_arrays: restore from
            # the newest step whose npz actually opens
            for s in reversed(self.all_steps()):
                try:
                    data = np.load(self.dir / f"step_{s:09d}" / "arrays.npz")
                    step = s
                    break
                except Exception as e:  # noqa: BLE001 - fall back + warn
                    warnings.warn(
                        f"skipping unreadable checkpoint step {s} "
                        f"under {self.dir}: {e!r}"
                    )
            if step is None:
                raise FileNotFoundError(
                    f"no readable checkpoints under {self.dir}"
                )
        else:
            data = np.load(self.dir / f"step_{step:09d}" / "arrays.npz")
        flat_t = _flatten(target_like)
        flat_s = _flatten(shardings) if shardings is not None else {}
        import ml_dtypes

        out = {}
        for key, like in flat_t.items():
            arr = data[f"{key}@shard0"]
            want = getattr(like, "dtype", None)
            if want is not None and str(want) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16) if arr.dtype == np.uint16 else arr
            elif want is not None and arr.dtype.kind != "V":
                arr = arr.astype(want)
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # unflatten back into the target structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(target_like)
        treedef = jax.tree_util.tree_structure(target_like)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_paths[0]
        ]
        return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
