"""Sharded checkpointing with manifest, retention, async writes, and
**resharding restore** (load into a different mesh — the elastic-scaling
path).

Layout per step::

    <dir>/step_000042/
        manifest.json        # tree structure, shapes, dtypes, step
        arrays.npz           # one entry per leaf (path-keyed)

Each process writes its addressable shards; in this single-process
container that is the full array (the npz key scheme ``<leaf>@shard0``
leaves room for per-process shard files on real multi-host). Restore
optionally takes ``shardings`` (a pytree of NamedSharding) and places
leaves directly onto the (possibly different) target mesh.
"""

from __future__ import annotations

import json
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> Path:
        flat = _flatten(tree)
        # np.load returns ml_dtypes (bf16) arrays as raw void — store them
        # as uint16 views and reconstruct from the manifest dtype on load.
        host = {}
        for k, v in flat.items():
            a = np.asarray(v)
            host[k] = a.view(np.uint16) if a.dtype.name == "bfloat16" else a
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host.items()
            },
            "time": time.time(),
        }
        final = self.dir / f"step_{step:09d}"

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **{f"{k}@shard0": v for k, v in host.items()})
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)          # atomic publish
            self._gc()

        if self.async_write:
            self.wait()
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()
        return final

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_arrays(self, step: Optional[int] = None):
        """Load one step's raw (manifest, flat arrays) without needing a
        ``target_like`` pytree — for consumers whose structure is encoded
        in the arrays themselves (e.g. the segmented-index manifest,
        whose segment count is data). Keys are the flattened tree paths
        (``a/b/c``). Leaves saved as bfloat16 (stored on disk as uint16
        views) are reconstructed from the manifest dtype, as
        :meth:`restore` does."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        arrays = {}
        for k in data.files:
            key = k[: -len("@shard0")]
            arr = data[k]
            want = manifest["leaves"].get(key, {}).get("dtype")
            if want == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            arrays[key] = arr
        return manifest, arrays

    def restore(self, target_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``target_like``. ``shardings``
        (same pytree structure, of NamedSharding) reshards onto a possibly
        different mesh — the elastic restart path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        data = np.load(d / "arrays.npz")
        flat_t = _flatten(target_like)
        flat_s = _flatten(shardings) if shardings is not None else {}
        import ml_dtypes

        out = {}
        for key, like in flat_t.items():
            arr = data[f"{key}@shard0"]
            want = getattr(like, "dtype", None)
            if want is not None and str(want) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16) if arr.dtype == np.uint16 else arr
            elif want is not None and arr.dtype.kind != "V":
                arr = arr.astype(want)
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # unflatten back into the target structure
        leaves_paths = jax.tree_util.tree_flatten_with_path(target_like)
        treedef = jax.tree_util.tree_structure(target_like)
        keys = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_paths[0]
        ]
        return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
