"""Gradient compression for DP all-reduce: int8 quantization with error
feedback (1-bit-Adam-family trick, applied at the data-parallel boundary).

``compressed_psum`` is the manual-collective building block (used inside
shard_map at the DP boundary, e.g. for cross-pod DCN reduces where
bandwidth is ~10× scarcer than ICI). ``CompressionState`` carries the
per-leaf error-feedback residual; the quantization error is re-injected
into the next step's gradient, so the *accumulated* update is unbiased —
the property the convergence test asserts.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(
    g: jnp.ndarray, err: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale, new_err). new_err = (g+err) − deq(q)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum(
    g: jnp.ndarray, err: jnp.ndarray, axis_name: str
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-compressed gradient all-reduce over ``axis_name`` (call inside
    shard_map). 4× fewer bytes on the wire than f32; int32 accumulate.

    Returns (reduced f32 mean gradient, new error residual)."""
    q, scale, new_err = compress_with_feedback(g, err)
    # per-shard scales differ → agree on the max scale (one pmax of a
    # scalar), requantize locally to the common scale, then wire-sum the
    # 1-byte payload with int32 accumulation.
    smax = jax.lax.pmax(scale, axis_name)
    q2 = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * smax / n
    return mean, new_err


def init_error_state(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
