"""Training step/loop: microbatch gradient accumulation, grad clip,
optimizer update, metrics. The returned step is a single jit-able function
so the dry-run can ``.lower().compile()`` it at production scale.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import RunCtx, loss_fn
from repro.train.optimizer import OptConfig, init_opt_state, opt_update


def make_train_step(
    cfg: ModelConfig,
    ocfg: OptConfig,
    ctx: RunCtx = RunCtx(),
    microbatches: int = 1,
):
    """Returns train_step(params, opt_state, batch) → (params', opt', metrics).

    Microbatch accumulation: the global batch is split along axis 0 and
    grads are accumulated with a scan (accum dtype = f32 for AdamW models,
    param dtype for Adafactor giants — see kimi_k2 notes).
    """
    accum_f32 = ocfg.name == "adamw"

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, ctx
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)
            gdtype = jnp.float32 if accum_f32 else None

            def acc_body(carry, mb_i):
                gsum, lsum = carry
                loss, _, grads = grads_of(params, mb_i)
                gsum = jax.tree.map(
                    lambda a, g: a + (g.astype(a.dtype)), gsum, grads
                )
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdtype or p.dtype), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (gzero, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"loss": loss, "aux": jnp.float32(0),
                       "logits_mean_abs": jnp.float32(0)}

        new_params, new_opt = opt_update(params, grads, opt_state, ocfg)
        metrics = dict(metrics)
        metrics["grad_norm"] = new_opt["gnorm"]
        return new_params, new_opt, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    params,
    pipeline,
    steps: int,
    ocfg: Optional[OptConfig] = None,
    ctx: RunCtx = RunCtx(),
    checkpointer=None,
    ckpt_every: int = 0,
    start_step: int = 0,
    log_every: int = 10,
):
    """Host-side loop: deterministic data pipeline + jit'd step + optional
    checkpointing. Returns (params, opt_state, loss history)."""
    ocfg = ocfg or OptConfig(name=cfg.optimizer)
    opt_state = init_opt_state(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg, ctx))
    history = []
    for step in range(start_step, start_step + steps):
        batch = {k: jnp.asarray(v) for k, v in pipeline.batch_for_step(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  gnorm {float(metrics['grad_norm']):.3f}")
        if checkpointer is not None and ckpt_every and (step + 1) % ckpt_every == 0:
            checkpointer.save(step + 1, {"params": params, "opt": opt_state})
    return params, opt_state, history
