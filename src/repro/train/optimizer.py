"""Optimizers as pure pytree transforms: AdamW (f32 state) and Adafactor
(factored second moment — the only state that fits for the 1T-param arch;
see kimi_k2 config notes).

No optax dependency; state layouts are plain dicts so the checkpointer
and the dry-run's sharding rules treat them like params.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    min_dim_factored: int = 128    # factor 2nd moment only for big matrices
    eps_af: float = 1e-30


def _factored(p, ocfg: OptConfig) -> bool:
    return p.ndim >= 2 and min(p.shape[-2:]) >= ocfg.min_dim_factored


def init_opt_state(params, ocfg: OptConfig) -> Dict[str, Any]:
    if ocfg.name == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "gnorm": jnp.zeros((), jnp.float32),
        }
    if ocfg.name == "adafactor":
        def factored_state(p):
            if _factored(p, ocfg):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),                 # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(factored_state, params),
            "gnorm": jnp.zeros((), jnp.float32),
        }
    raise ValueError(ocfg.name)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def opt_update(params, grads, state, ocfg: OptConfig) -> Tuple[Any, Dict[str, Any]]:
    """One optimizer step. Returns (new_params, new_state)."""
    grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)
    step = state["step"] + 1
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)

    if ocfg.name == "adamw":
        t = step.astype(jnp.float32)
        bc1 = 1.0 - ocfg.b1 ** t
        bc2 = 1.0 - ocfg.b2 ** t
        leaves_mu = treedef.flatten_up_to(state["mu"])
        leaves_nu = treedef.flatten_up_to(state["nu"])
        new_p, new_mu, new_nu = [], [], []
        for p, g, mu, nu in zip(leaves_p, leaves_g, leaves_mu, leaves_nu):
            gf = g.astype(jnp.float32)
            mu2 = ocfg.b1 * mu + (1 - ocfg.b1) * gf
            nu2 = ocfg.b2 * nu + (1 - ocfg.b2) * gf * gf
            update = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + ocfg.eps)
            if p.ndim >= 2:
                update = update + ocfg.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - ocfg.lr * update).astype(p.dtype))
            new_mu.append(mu2)
            new_nu.append(nu2)
        return treedef.unflatten(new_p), {
            "step": step,
            "mu": treedef.unflatten(new_mu),
            "nu": treedef.unflatten(new_nu),
            "gnorm": gnorm,
        }

    # adafactor
    leaves_v = treedef.flatten_up_to(state["v"])
    new_p, new_v = [], []
    for p, g, v in zip(leaves_p, leaves_g, leaves_v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + ocfg.eps_af
        if _factored(p, ocfg):
            vr = 0.999 * v["vr"] + 0.001 * jnp.mean(g2, axis=-1)
            vc = 0.999 * v["vc"] + 0.001 * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), ocfg.eps_af)
            precond = jax.lax.rsqrt(jnp.maximum(r, ocfg.eps_af))[..., None] * \
                jax.lax.rsqrt(jnp.maximum(vc, ocfg.eps_af))[..., None, :]
            update = gf * precond
            v2 = {"vr": vr, "vc": vc}
        else:
            vv = 0.999 * v["v"] + 0.001 * g2
            update = gf * jax.lax.rsqrt(jnp.maximum(vv, ocfg.eps_af))
            v2 = {"v": vv}
        # RMS-clip the update (standard adafactor, d=1.0)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        p2 = p.astype(jnp.float32) - ocfg.lr * update
        if p.ndim >= 2:
            p2 = p2 - ocfg.lr * ocfg.weight_decay * p.astype(jnp.float32)
        new_p.append(p2.astype(p.dtype))
        new_v.append(v2)
    return treedef.unflatten(new_p), {
        "step": step,
        "v": treedef.unflatten(new_v),
        "gnorm": gnorm,
    }
