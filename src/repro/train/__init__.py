from repro.train.optimizer import OptConfig, init_opt_state, opt_update, global_norm
from repro.train.train_loop import make_train_step, train_loop

__all__ = [
    "OptConfig", "init_opt_state", "opt_update", "global_norm",
    "make_train_step", "train_loop",
]
