"""Zamba2-2.7B [arXiv:2411.15242; Mamba2 + shared attention blocks].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Unit = 6 Mamba2 blocks + the shared attention/FFN block (one weight copy
applied at every unit — Zamba2's parameter-sharing scheme; the
concat-with-embedding LoRA path is simplified away, see DESIGN.md).
Recurrent state + bounded attention cache → long_500k runs.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    hybrid_attn_every=6, ssm_state=64, ssm_expand=2, ssm_conv=4,
    supports_long_context=True,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=128, num_heads=2, num_kv_heads=2, head_dim=64,
    d_ff=256, vocab_size=512, hybrid_attn_every=2, ssm_state=16,
)
