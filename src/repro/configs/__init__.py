"""Config registry: one module per assigned architecture (exact published
configs) plus reduced smoke variants for CPU tests.

``get_config(name)`` → full ModelConfig; ``get_smoke_config(name)`` →
same family/structure at toy width/depth (constraints preserved: head
divisibility, unit patterns, MoE expert counts divisible by the EP axis).
"""

from __future__ import annotations

from typing import Dict, List

from repro.config import ModelConfig

from repro.configs.qwen15_4b import CONFIG as qwen15_4b, SMOKE as qwen15_4b_smoke
from repro.configs.internlm2_20b import CONFIG as internlm2_20b, SMOKE as internlm2_20b_smoke
from repro.configs.phi3_mini_3p8b import CONFIG as phi3_mini, SMOKE as phi3_mini_smoke
from repro.configs.gemma3_27b import CONFIG as gemma3_27b, SMOKE as gemma3_27b_smoke
from repro.configs.kimi_k2_1t import CONFIG as kimi_k2, SMOKE as kimi_k2_smoke
from repro.configs.olmoe_1b_7b import CONFIG as olmoe, SMOKE as olmoe_smoke
from repro.configs.hubert_xlarge import CONFIG as hubert, SMOKE as hubert_smoke
from repro.configs.xlstm_1p3b import CONFIG as xlstm, SMOKE as xlstm_smoke
from repro.configs.qwen2_vl_7b import CONFIG as qwen2_vl, SMOKE as qwen2_vl_smoke
from repro.configs.zamba2_2p7b import CONFIG as zamba2, SMOKE as zamba2_smoke

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen15_4b, internlm2_20b, phi3_mini, gemma3_27b, kimi_k2,
        olmoe, hubert, xlstm, qwen2_vl, zamba2,
    ]
}

_SMOKE: Dict[str, ModelConfig] = {
    c.name: s
    for c, s in [
        (qwen15_4b, qwen15_4b_smoke), (internlm2_20b, internlm2_20b_smoke),
        (phi3_mini, phi3_mini_smoke), (gemma3_27b, gemma3_27b_smoke),
        (kimi_k2, kimi_k2_smoke), (olmoe, olmoe_smoke),
        (hubert, hubert_smoke), (xlstm, xlstm_smoke),
        (qwen2_vl, qwen2_vl_smoke), (zamba2, zamba2_smoke),
    ]
}


def arch_names() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {arch_names()}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    return _SMOKE[get_config(name).name]
