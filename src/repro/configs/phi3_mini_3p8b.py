"""Phi-3-mini 3.8B [arXiv:2404.14219; dense, RoPE SwiGLU GQA].

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064, head_dim=96.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    rope_theta=10000.0, mlp="swiglu",
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
)
