"""OLMoE-1B-7B [arXiv:2409.02060; MoE 64 experts top-8].

16L d_model=2048 16H (GQA kv=16) d_ff=1024(per expert) vocab=50304.
"""

from repro.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    rope_theta=10000.0, mlp="swiglu",
    moe=MoEConfig(num_experts=64, experts_per_token=8),
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=512,
    moe=MoEConfig(num_experts=8, experts_per_token=2),
)
