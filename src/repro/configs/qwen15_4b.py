"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family; dense, QKV bias].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936, head_dim=128.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab_size=151936, head_dim=128,
    qkv_bias=True, rope_theta=1e6, mlp="swiglu",
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
)
