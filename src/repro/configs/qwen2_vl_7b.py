"""Qwen2-VL-7B [arXiv:2409.12191; VLM backbone, M-RoPE].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, head_dim=128.
Backbone only: vision patches arrive as precomputed embeddings via the
batch's optional ``positions`` [3, B, S] (M-RoPE t/h/w sections 16/24/24).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    rope_style="mrope", rope_theta=1e6, mlp="swiglu",
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=256, num_heads=2, num_kv_heads=2, head_dim=128,
    d_ff=256, vocab_size=512,
)
