"""HuBERT X-Large [arXiv:2106.07447; audio encoder-only].

48L d_model=1280 16H d_ff=5120 vocab=504 (cluster units). The conv
waveform frontend is a STUB: input_specs provides precomputed frame
embeddings [B, S, d_model]; training uses HuBERT-style masked unit
prediction. No decode shapes (encoder-only).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80,
    mlp="gelu", frontend="audio_frames", encoder_only=True,
    supports_decode=False, supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64,
)
