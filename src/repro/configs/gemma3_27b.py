"""Gemma3-27B [hf:google/gemma-3 family; dense, 5:1 local:global, 128k].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, head_dim=128.
62 = 10 units of (5 local + 1 global) + 2 tail local layers.
Sliding window 1024; tied embeddings with sqrt(d) scaling.
long_500k: runs (sliding-window layers dominate; the per-unit global
layer's KV is sequence-sharded over the model axis) — see DESIGN.md.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    rope_theta=1e6, mlp="gelu", sliding_window=1024, local_global_ratio=5,
    tie_embeddings=True, scale_embed=True, fsdp_params=True,
    supports_long_context=True,
)

SMOKE = CONFIG.replace(
    num_layers=8,            # 1 unit (5+1) + 2 tail locals
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, sliding_window=32, fsdp_params=False,
)
