"""InternLM2-20B [arXiv:2403.17297; dense GQA].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544, head_dim=128.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, head_dim=128,
    rope_theta=1e6, mlp="swiglu", fsdp_params=True,
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=512, fsdp_params=False,
)
