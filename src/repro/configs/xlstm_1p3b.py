"""xLSTM-1.3B [arXiv:2405.04517; sLSTM + mLSTM blocks].

48L d_model=2048 4H d_ff=0 (mixers carry their own up/down projections)
vocab=50304. Unit = 8 blocks (7 mLSTM + 1 sLSTM). Recurrent state decode
→ long_500k runs.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm_slstm_every=8, ssm_expand=2, tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
    vocab_size=512, xlstm_slstm_every=2,
)
