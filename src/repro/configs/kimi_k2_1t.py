"""Kimi K2 1T-A32B [arXiv:2501.kimi2; trillion-param MoE].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(per expert) vocab=163840,
MoE 384 experts top-8, head_dim=112. Adafactor optimizer + FSDP param
sharding (Adam fp32 state for 1T params does not fit 512 v5e chips —
see EXPERIMENTS.md dry-run notes).
"""

from repro.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=112,
    rope_theta=50000.0, mlp="swiglu",
    moe=MoEConfig(num_experts=384, experts_per_token=8),
    optimizer="adafactor", fsdp_params=True,
    supports_long_context=False,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=512,
    moe=MoEConfig(num_experts=8, experts_per_token=2),
    optimizer="adamw", fsdp_params=False,
)
