"""Shared model building blocks: norms, RoPE (incl. M-RoPE), GQA attention
(causal / bidirectional / sliding-window / softcap, prefill + decode),
SwiGLU/GeLU FFN, init helpers.

All blocks are pure functions over param pytrees (no framework dependency).
Compute dtype = cfg.dtype (bf16 by default), norms/softmax statistics in
f32. Chunked (flash-style) attention streams query chunks with an online
softmax; ``unroll_chunks=True`` replaces the chunk scan with a Python loop
so the dry-run's per-layer cost analysis is exact (see DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, hd]; pos broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                   # [half]
    ang = pos.astype(jnp.float32)[..., None] * freqs         # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, pos3: jnp.ndarray, theta: float, sections=(16, 24, 24)
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the half-dim frequency bands are split into
    (temporal, height, width) sections, each rotated by its own position.

    x [B, S, H, hd]; pos3 [3, B, S]. For text tokens pos3[i] are all equal,
    which reduces exactly to standard RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                   # [half]
    # section id per frequency index
    sec = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                        # [half]
    pos_per_freq = jnp.take(pos3, sec, axis=0)               # [half, B, S] -> gather over axis 0
    pos_per_freq = jnp.moveaxis(pos_per_freq, 0, -1)         # [B, S, half]
    ang = pos_per_freq.astype(jnp.float32) * freqs           # [B, S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> Dict[str, Any]:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), 0, dt),
        "wk": dense_init(ks[1], (d, KV * hd), 0, dt),
        "wv": dense_init(ks[2], (d, KV * hd), 0, dt),
        "wo": dense_init(ks[3], (H * hd, d), 0, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    return p


def _qkv(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _attend_dense(q, k, v, mask, softcap: float) -> jnp.ndarray:
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd] (KV repeated to H outside), mask
    [B?,Sq,Sk] bool (True = attend). f32 softmax."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = _softcap(logits * scale, softcap)
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # [B, S, D]
    pos: jnp.ndarray,                    # [B, S] or [3, B, S] for mrope
    *,
    causal: bool = True,
    sliding_window: int = 0,
    q_chunk: int = 1024,
    unroll_chunks: bool = False,
    kv_range_chunking: bool = False,
    head_sharding=None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill), flash-style over q chunks.

    ``kv_range_chunking`` (perf opt, EXPERIMENTS.md §Perf): each unrolled
    q chunk only reads the KV positions it can attend — ``[0, chunk_end)``
    for causal, ``[chunk_start − window + 1, chunk_end)`` for sliding
    windows — instead of masking the full sequence. Halves causal
    attention flops/bytes on average and cuts sliding-window layers to
    O(S·(C+W)); token order must be the natural arange (it is for all
    train/prefill paths here).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _qkv(p, cfg, x)
    if cfg.rope_style == "mrope":
        q = apply_mrope(q, pos, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.rope_theta)
        pos_row = pos[0]
    elif cfg.rope_style == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        pos_row = pos
    else:
        pos_row = pos if pos.ndim == 2 else pos[0]
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    if head_sharding is not None:
        # pin head-parallel attention (perf opt): without this, GSPMD may
        # shard head_dim instead when H doesn't divide the model axis, and
        # then every QKᵀ contraction all-reduces full [B,H,C,S] logits.
        q = jax.lax.with_sharding_constraint(q, head_sharding)
        k = jax.lax.with_sharding_constraint(k, head_sharding)
        v = jax.lax.with_sharding_constraint(v, head_sharding)

    kpos = pos_row                                            # [B, S]

    def chunk_out(qc, qpos, kc, vc, kposc):
        # qc [B, C, H, hd]; kc/vc [B, L, H, hd]
        m = jnp.ones((B, qc.shape[1], kc.shape[1]), bool)
        if causal:
            m &= qpos[:, :, None] >= kposc[:, None, :]
        if sliding_window and sliding_window > 0:
            m &= qpos[:, :, None] - kposc[:, None, :] < sliding_window
        return _attend_dense(qc, kc, vc, m, cfg.attn_logit_softcap)

    if S <= q_chunk:
        out = chunk_out(q, pos_row, k, v, kpos)
    else:
        nchunks = -(-S // q_chunk)
        Sp = nchunks * q_chunk
        qpad = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        ppad = jnp.pad(pos_row, ((0, 0), (0, Sp - S)))
        qs = qpad.reshape(B, nchunks, q_chunk, H, hd)
        ps = ppad.reshape(B, nchunks, q_chunk)
        if unroll_chunks:
            outs = []
            for i in range(nchunks):
                if kv_range_chunking:
                    end = min(S, (i + 1) * q_chunk)
                    start = 0
                    if causal and sliding_window and sliding_window > 0:
                        start = max(0, i * q_chunk - sliding_window + 1)
                    kc, vc, kpc = k[:, start:end], v[:, start:end], kpos[:, start:end]
                else:
                    kc, vc, kpc = k, v, kpos
                outs.append(chunk_out(qs[:, i], ps[:, i], kc, vc, kpc))
            out = jnp.concatenate(outs, axis=1)[:, :S]
        else:
            def body(_, xs):
                qc, pc = xs
                return (), chunk_out(qc, pc, k, v, kpos)
            _, outs = jax.lax.scan(
                body, (), (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ps, 1, 0))
            )
            out = jnp.moveaxis(outs, 0, 1).reshape(B, Sp, H, hd)[:, :S]

    return out.reshape(B, S, H * hd) @ p["wo"]


def attention_decode(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,                      # [B, 1, D]
    pos: jnp.ndarray,                    # [B] current position (or [3,B])
    k_cache: jnp.ndarray,                # [B, Smax, KV, hd]
    v_cache: jnp.ndarray,
    *,
    sliding_window: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a KV cache. Returns (out [B,1,D], k', v').

    The KV cache may be sequence-sharded on the mesh's model axis; the
    softmax reductions over Smax then lower to cross-shard collectives
    (GSPMD flash-decode).
    """
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    Smax = k_cache.shape[1]
    q, k, v = _qkv(p, cfg, x)                                # [B,1,·,hd]
    if cfg.rope_style == "mrope":
        pos3 = pos if pos.ndim == 2 else jnp.broadcast_to(pos[None], (3, B))
        q = apply_mrope(q, pos3[:, :, None], cfg.rope_theta)
        k = apply_mrope(k, pos3[:, :, None], cfg.rope_theta)
        pos_row = pos3[0]
    elif cfg.rope_style == "rope":
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        pos_row = pos
    else:
        pos_row = pos

    # functional cache update at position pos (per batch row)
    oh = jax.nn.one_hot(pos_row, Smax, dtype=k.dtype)        # [B, Smax]
    k_new = k_cache * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    v_new = v_cache * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * v

    kk = _repeat_kv(k_new, H // KV)
    vv = _repeat_kv(v_new, H // KV)
    idx = jnp.arange(Smax)[None, :]                          # [1, Smax]
    m = idx <= pos_row[:, None]
    if sliding_window and sliding_window > 0:
        m &= pos_row[:, None] - idx < sliding_window
    out = _attend_dense(q, kk, vv, m[:, None, :].transpose(0, 1, 2), cfg.attn_logit_softcap) \
        if False else _attend_dense(q, kk, vv, m[:, None, :], cfg.attn_logit_softcap)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, k_new, v_new


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w1": dense_init(ks[0], (d, f), 0, dt),     # gate
            "w3": dense_init(ks[1], (d, f), 0, dt),     # up
            "w2": dense_init(ks[2], (f, d), 0, dt),     # down
        }
    return {
        "w1": dense_init(ks[0], (d, f), 0, dt),
        "w2": dense_init(ks[2], (f, d), 0, dt),
    }


def ffn(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    return jax.nn.gelu(x @ p["w1"], approximate=True) @ p["w2"]
