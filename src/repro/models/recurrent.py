"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and Mamba2 (Zamba2).

Training/prefill uses a *chunkwise-parallel* formulation (linear-attention
algebra): within a chunk the quadratic masked form runs on the MXU; across
chunks a compact state is carried by a scan (or an unrolled Python loop
when ``unroll_chunks`` — used by the dry-run so per-layer cost analysis is
exact, see DESIGN.md). Decode carries the same state one token at a time.

Simplifications vs the source papers (recorded in DESIGN.md §Arch-
applicability): mLSTM uses sigmoid forget / exp input gating with a
per-chunk max stabilizer (same compute/memory pattern, not bit-identical
to xLSTM's m-state); Zamba2's shared attention block omits the
concat-with-embedding LoRA path.

State conventions (per layer):
  mLSTM:  C [B, H, hd, hd], n [B, H, hd]
  sLSTM:  c [B, H, hd], n [B, H, hd], h [B, H, hd]
  mamba2: ssm [B, Hm, dh, ds], conv [B, W-1, d_conv_in]
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import dense_init, dtype_of, rms_norm


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM) — linear-attention chunkwise form
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.num_heads
    di = cfg.ssm_expand * d if cfg.ssm_expand else 2 * d
    hd = di // H
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di), 0, dt),       # (x, gate)
        "wq": dense_init(ks[1], (di, di), 0, dt),
        "wk": dense_init(ks[2], (di, di), 0, dt),
        "wv": dense_init(ks[3], (di, di), 0, dt),
        "w_if": dense_init(ks[4], (di, 2 * H), 0, jnp.float32),  # input/forget gates
        "w_down": dense_init(ks[5], (di, d), 0, dt),
        "norm": jnp.zeros((di,), jnp.float32),
    }


def _mlstm_chunk(q, k, v, ig, fg, C, n):
    """One chunk of the mLSTM recurrence in parallel form.

    q/k/v [B, c, H, hd]; ig/fg [B, c, H] (input gate ≥0, forget ∈(0,1)).
    State (C [B,H,hd,hd], n [B,H,hd]). Returns (h [B,c,H,hd], C', n').
    """
    Bsz, c, H, hd = q.shape
    logf = jnp.log(fg + 1e-9)                                # [B,c,H]
    cum = jnp.cumsum(logf, axis=1)                           # Π f up to t (inclusive)
    tot = cum[:, -1:]                                        # [B,1,H]
    # decay from state entry to position t: Π_{s≤t} f_s
    dec_in = jnp.exp(cum)                                    # [B,c,H]
    # pairwise decay t←s (s<t): exp(cum_t − cum_s) · i_s ; causal mask
    a = cum[:, :, None, :] - cum[:, None, :, :]              # [B,t,s,H]
    causal = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(causal[None, :, :, None], jnp.exp(a), 0.0) * ig[:, None, :, :]
    # intra-chunk: h_intra[t] = Σ_s w[t,s] (q_t·k_s) v_s ; n_intra = Σ_s w k_s
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    qk = jnp.einsum("bthd,bshd->btsh", qf, kf)
    h_intra = jnp.einsum("btsh,btsh,bshd->bthd", qk, w, vf)
    n_intra = jnp.einsum("btsh,bshd->bthd", w, kf)
    # inter-chunk: state contribution
    h_inter = jnp.einsum("bthd,bhde->bthe", qf, C) * dec_in[..., None]
    n_inter = n[:, None] * dec_in[..., None]                 # [B,c,H,hd]
    num = h_intra + h_inter
    den = jnp.einsum("bthd,bthd->bth", qf, n_intra + n_inter)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    # state update: C' = (Π f) C + Σ_s (Π_{r>s} f_r) i_s k_s v_sᵀ
    decay_out = jnp.exp(tot[:, 0, :, None, None])            # [B,H,1,1]
    wk_s = jnp.exp(tot - cum) * ig                           # [B,c,H]
    C_new = C * decay_out + jnp.einsum("bsh,bshd,bshe->bhde", wk_s, kf, vf)
    n_new = n * decay_out[..., 0] + jnp.einsum("bsh,bshd->bhd", wk_s, kf)
    return h.astype(q.dtype), C_new, n_new


def mlstm_mix(
    p, cfg: ModelConfig, x: jnp.ndarray, *, chunk: int = 128,
    unroll_chunks: bool = False,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """x [B, S, D] → (y [B, S, D], state'). Works for train (state=None) and
    stateful prefill/decode-chunk."""
    B, S, D = x.shape
    H = cfg.num_heads
    up = x @ p["w_up"]
    di = up.shape[-1] // 2
    inner, gate = up[..., :di], up[..., di:]
    hd = di // H
    q = (inner @ p["wq"]).reshape(B, S, H, hd)
    k = (inner @ p["wk"]).reshape(B, S, H, hd) / jnp.sqrt(jnp.asarray(hd, x.dtype))
    v = (inner @ p["wv"]).reshape(B, S, H, hd)
    gif = inner.astype(jnp.float32) @ p["w_if"]
    ig = jnp.exp(jnp.minimum(gif[..., :H], 8.0))             # [B,S,H]
    fg = jax.nn.sigmoid(gif[..., H:])

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        C0, n0 = state

    c = min(chunk, S)
    nchunks = -(-S // c)
    Sp = nchunks * c
    pad = Sp - S

    def pad_t(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    qs, ks_, vs, igs, fgs = map(pad_t, (q, k, v, ig, fg))
    # padded steps: fg=1, ig=0 → no-op on state
    if pad:
        igs = igs.at[:, S:].set(0.0)
        fgs = fgs.at[:, S:].set(1.0)

    def chunk_step(carry, idx):
        C, n = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * c, c, 1)
        h, C, n = _mlstm_chunk(sl(qs), sl(ks_), sl(vs), sl(igs), sl(fgs), C, n)
        return (C, n), h

    if unroll_chunks:
        hs = []
        carry = (C0, n0)
        for i in range(nchunks):
            carry, h = chunk_step(carry, i)
            hs.append(h)
        h = jnp.concatenate(hs, axis=1)
        C0, n0 = carry
    else:
        (C0, n0), hs = jax.lax.scan(chunk_step, (C0, n0), jnp.arange(nchunks))
        h = jnp.moveaxis(hs, 0, 1).reshape(B, Sp, H, hd)
    h = h[:, :S].reshape(B, S, di)
    h = rms_norm(h, p["norm"], cfg.norm_eps)
    y = (h * jax.nn.silu(gate)) @ p["w_down"]
    return y, (C0, n0)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with recurrent weights) — sequential
# ---------------------------------------------------------------------------


def init_slstm(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), 0, dt),        # z,i,f,o pre-acts
        "r": dense_init(ks[1], (H, hd, 4 * hd), 1, jnp.float32),
        "w_down": dense_init(ks[2], (d, d), 0, dt),
        "norm": jnp.zeros((d,), jnp.float32),
    }


def slstm_mix(
    p, cfg: ModelConfig, x: jnp.ndarray,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
    **_,
) -> Tuple[jnp.ndarray, Tuple]:
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    pre = (x @ p["w_in"]).reshape(B, S, H, 4 * hd).astype(jnp.float32)
    if state is None:
        cc = jnp.zeros((B, H, hd), jnp.float32)
        nn_ = jnp.ones((B, H, hd), jnp.float32)
        hh = jnp.zeros((B, H, hd), jnp.float32)
    else:
        cc, nn_, hh = state

    def step(carry, pre_t):
        c, n, h = carry                                       # [B,H,hd]
        rec = jnp.einsum("bhd,hde->bhe", h, p["r"])           # [B,H,4hd]
        z, i, f, o = jnp.split(pre_t + rec, 4, axis=-1)
        z = jnp.tanh(z)
        i = jnp.exp(jnp.minimum(i, 8.0))
        f = jax.nn.sigmoid(f)
        o = jax.nn.sigmoid(o)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h), h

    (cc, nn_, hh), hs = jax.lax.scan(step, (cc, nn_, hh), jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["w_down"], (cc, nn_, hh)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — chunkwise linear-attention form
# ---------------------------------------------------------------------------


def init_mamba2(cfg: ModelConfig, key) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    dh = 64                                                   # head dim
    Hm = di // dh
    dt_ = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    conv_in = di + 2 * ds
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * ds + Hm), 0, dt_),  # z, xBC, dt
        "conv": dense_init(ks[1], (cfg.ssm_conv, conv_in), 0, jnp.float32) * 0.5,
        "A_log": jnp.zeros((Hm,), jnp.float32) + jnp.log(jnp.arange(1, Hm + 1, dtype=jnp.float32)),
        "D": jnp.ones((Hm,), jnp.float32),
        "dt_bias": jnp.zeros((Hm,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "w_down": dense_init(ks[2], (di, d), 0, dt_),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, conv_state: Optional[jnp.ndarray]):
    """Depthwise causal conv. xbc [B, S, C], w [W, C]. Returns (y, new_state
    [B, W-1, C])."""
    B, S, C = xbc.shape
    W = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), xbc.dtype)
    ext = jnp.concatenate([conv_state, xbc], axis=1)          # [B, S+W-1, C]
    y = sum(ext[:, i : i + S] * w[i] for i in range(W))
    return jax.nn.silu(y), ext[:, -(W - 1) :] if W > 1 else jnp.zeros((B, 0, C), xbc.dtype)


def _ssd_chunk(xh, dt, A, Bm, Cm, ssm):
    """One SSD chunk. xh [B,c,Hm,dh]; dt [B,c,Hm]; A [Hm] (<0); Bm/Cm
    [B,c,ds]; ssm [B,Hm,dh,ds]. Returns (y, ssm')."""
    Bsz, c, Hm, dh = xh.shape
    logf = dt * A[None, None, :]                              # [B,c,Hm] ≤ 0
    cum = jnp.cumsum(logf, axis=1)
    tot = cum[:, -1]
    dec_in = jnp.exp(cum)                                     # decay state→t
    a = cum[:, :, None, :] - cum[:, None, :, :]
    causal = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(causal[None, :, :, None], jnp.exp(a), 0.0)  # [B,t,s,Hm]
    cb = jnp.einsum("bts,btsh->btsh", jnp.einsum("btn,bsn->bts", Cm, Bm), w)
    y_intra = jnp.einsum("btsh,bsh,bshd->bthd", cb, dt, xh.astype(jnp.float32))
    y_inter = jnp.einsum("btn,bhdn->bthd", Cm, ssm) * dec_in[..., None]
    y = y_intra + y_inter
    decay_out = jnp.exp(tot)[:, :, None, None]                # [B,Hm,1,1]
    wk = jnp.exp(tot[:, None, :] - cum) * dt                  # [B,c,Hm]
    ssm_new = ssm * decay_out + jnp.einsum(
        "bsh,bshd,bsn->bhdn", wk, xh.astype(jnp.float32), Bm
    )
    return y, ssm_new


def mamba2_mix(
    p, cfg: ModelConfig, x: jnp.ndarray, *, chunk: int = 128,
    unroll_chunks: bool = False,
    state: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """x [B,S,D] → (y, (ssm_state, conv_state))."""
    B, S, D = x.shape
    di = cfg.ssm_expand * D
    ds = cfg.ssm_state
    dh = 64
    Hm = di // dh
    proj = x @ p["w_in"]
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * ds]
    dt_pre = proj[..., 2 * di + 2 * ds :].astype(jnp.float32)  # [B,S,Hm]
    ssm0, conv0 = (None, None) if state is None else state
    xbc_c, conv_new = _causal_conv(xbc, p["conv"], conv0)
    xh = xbc_c[..., :di].reshape(B, S, Hm, dh)
    Bm = xbc_c[..., di : di + ds].astype(jnp.float32)
    Cm = xbc_c[..., di + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre + p["dt_bias"])               # [B,S,Hm]
    A = -jnp.exp(p["A_log"])                                  # [Hm] < 0

    if ssm0 is None:
        ssm0 = jnp.zeros((B, Hm, dh, ds), jnp.float32)

    c = min(chunk, S)
    nchunks = -(-S // c)
    Sp = nchunks * c
    pad = Sp - S
    pad_t = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    xh_p, dt_p, Bm_p, Cm_p = map(pad_t, (xh, dt, Bm, Cm))
    if pad:
        dt_p = dt_p.at[:, S:].set(0.0)                        # no-op steps

    def chunk_step(carry, idx):
        ssm = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, idx * c, c, 1)
        y, ssm = _ssd_chunk(sl(xh_p), sl(dt_p), A, sl(Bm_p), sl(Cm_p), ssm)
        return ssm, y

    if unroll_chunks:
        ys = []
        ssm = ssm0
        for i in range(nchunks):
            ssm, y = chunk_step(ssm, i)
            ys.append(y)
        y = jnp.concatenate(ys, axis=1)
        ssm0 = ssm
    else:
        ssm0, ys = jax.lax.scan(chunk_step, ssm0, jnp.arange(nchunks))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, Hm, dh)
    y = y[:, :S]
    y = y + xh * p["D"][None, None, :, None]                  # skip
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return y @ p["w_down"], (ssm0, conv_new)
