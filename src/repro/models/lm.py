"""Model assembly for the assigned architecture pool.

One generic stack covers all ten architectures via ``ModelConfig``:

* families ``dense`` / ``moe`` / ``vlm`` / ``audio`` → transformer units
  (attention + FFN/MoE), with per-family attention flavors (GQA, RoPE /
  M-RoPE, sliding-window local:global patterns, QKV bias, softcap,
  bidirectional for encoders);
* family ``ssm`` → xLSTM units (mLSTM blocks with periodic sLSTM);
* family ``hybrid`` → Zamba2 units (Mamba2 blocks + a *shared* attention
  block applied every ``hybrid_attn_every`` layers).

Layers are grouped into **units** (one unit = the config's repeating layer
pattern) and scanned with ``lax.scan`` so the lowered HLO contains one unit
body regardless of depth — essential for 512-device dry-run compile times.
``n_units=0`` lowers the surrounding embed/head only (used by the roofline
harness's two-compile differencing; see DESIGN.md).

Public entry points:
  init_params(cfg, key)                        → param pytree
  loss_fn(params, cfg, batch, ...)             → (loss, metrics)
  prefill(params, cfg, batch, ...)             → (logits_last, cache)
  decode_step(params, cfg, token, pos, cache)  → (logits, cache)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import ModelConfig
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import recurrent as rec


# ---------------------------------------------------------------------------
# unit structure
# ---------------------------------------------------------------------------


def unit_layout(cfg: ModelConfig) -> Dict[str, Any]:
    """How many layers form one scanned unit, and of which kinds."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.local_global_ratio > 0:
            unit = cfg.local_global_ratio + 1
            return {"kind": "transformer", "unit_layers": unit,
                    "n_units": cfg.num_layers // unit,
                    "locals": cfg.local_global_ratio,
                    "tail_locals": cfg.num_layers % unit}
        return {"kind": "transformer", "unit_layers": 1,
                "n_units": cfg.num_layers, "locals": 0, "tail_locals": 0}
    if cfg.family == "ssm":
        every = cfg.xlstm_slstm_every or cfg.num_layers + 1
        if cfg.xlstm_slstm_every:
            assert cfg.num_layers % every == 0
            return {"kind": "xlstm", "unit_layers": every,
                    "n_units": cfg.num_layers // every,
                    "mlstm_per_unit": every - 1}
        return {"kind": "xlstm", "unit_layers": 1, "n_units": cfg.num_layers,
                "mlstm_per_unit": 1}
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        assert every > 0 and cfg.num_layers % every == 0
        return {"kind": "zamba", "unit_layers": every,
                "n_units": cfg.num_layers // every, "mamba_per_unit": every}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_transformer_unit(cfg: ModelConfig, key, layout) -> Dict[str, Any]:
    n_local = layout["locals"]
    ks = iter(jax.random.split(key, 4 * (n_local + 1) + 4))

    def one_block(k, use_moe: bool):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        blk = {
            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": cm.init_attention(cfg, k1),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if use_moe:
            blk["moe"] = moe_mod.init_moe(cfg, k2)
        else:
            blk["ffn"] = cm.init_ffn(cfg, k2)
        return blk

    use_moe = cfg.is_moe
    if n_local:
        local_keys = jax.random.split(next(ks), n_local)
        local = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one_block(k, use_moe) for k in local_keys]
        )
        return {"local": local, "global": one_block(next(ks), use_moe)}
    return {"block": one_block(next(ks), use_moe)}


def _init_xlstm_unit(cfg: ModelConfig, key, layout) -> Dict[str, Any]:
    m = layout["mlstm_per_unit"]
    ks = jax.random.split(key, m + 1)
    out: Dict[str, Any] = {}
    if m:
        stacked = [
            {"ln": jnp.zeros((cfg.d_model,), jnp.float32), "mix": rec.init_mlstm(cfg, k)}
            for k in ks[:m]
        ]
        out["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if layout["unit_layers"] > m:
        out["slstm"] = {
            "ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "mix": rec.init_slstm(cfg, ks[m]),
        }
    return out


def _init_zamba_unit(cfg: ModelConfig, key, layout) -> Dict[str, Any]:
    m = layout["mamba_per_unit"]
    ks = jax.random.split(key, m)
    stacked = [
        {"ln": jnp.zeros((cfg.d_model,), jnp.float32), "mix": rec.init_mamba2(cfg, k)}
        for k in ks
    ]
    return {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)}


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    layout = unit_layout(cfg)
    k_embed, k_units, k_head, k_shared = jax.random.split(key, 4)
    dt = cm.dtype_of(cfg)
    params: Dict[str, Any] = {
        "embed": cm.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    unit_keys = jax.random.split(k_units, max(layout["n_units"], 1))
    init_unit = {
        "transformer": _init_transformer_unit,
        "xlstm": _init_xlstm_unit,
        "zamba": _init_zamba_unit,
    }[layout["kind"]]
    units = [init_unit(cfg, k, layout) for k in unit_keys]
    params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if layout.get("tail_locals"):
        tail_keys = jax.random.split(jax.random.fold_in(k_units, 7), layout["tail_locals"])
        tail = [_init_transformer_unit(
            cfg.replace(local_global_ratio=0), k,
            {"locals": 0})["block"] for k in tail_keys]
        params["tail_local"] = jax.tree.map(lambda *xs: jnp.stack(xs), *tail)
    if cfg.family == "hybrid":
        # Zamba2 shared attention+FFN block (one copy, applied every unit)
        k1, k2 = jax.random.split(k_shared)
        params["shared"] = {
            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": cm.init_attention(cfg, k1),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "ffn": cm.init_ffn(cfg, k2),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_head, (cfg.d_model, cfg.vocab_size), 0, dt)
    return params


# ---------------------------------------------------------------------------
# forward pieces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunCtx:
    mesh: Optional[Mesh] = None
    unroll_chunks: bool = False
    q_chunk: int = 1024
    rec_chunk: int = 128
    n_units_override: Optional[int] = None     # 0 → skip stack (roofline)
    kv_range_chunking: bool = False            # perf opt (EXPERIMENTS §Perf)
    shard_heads: bool = False                  # perf opt (EXPERIMENTS §Perf)
    remat_policy: str = "full"                 # full | dots (save matmul outs)

    def head_sharding(self):
        if not (self.shard_heads and self.mesh is not None):
            return None
        from jax.sharding import NamedSharding, PartitionSpec as P

        ba = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
        return NamedSharding(self.mesh, P(ba, None, "model", None))


def _attn_block(blk, cfg: ModelConfig, x, pos, ctx: RunCtx, *, sliding: int,
                causal: bool, use_moe: bool):
    h = cm.rms_norm(x, blk["ln1"], cfg.norm_eps)
    h = cm.attention(
        blk["attn"], cfg, h, pos, causal=causal, sliding_window=sliding,
        q_chunk=ctx.q_chunk, unroll_chunks=ctx.unroll_chunks,
        kv_range_chunking=ctx.kv_range_chunking and causal,
        head_sharding=ctx.head_sharding(),
    )
    x = x + h
    h = cm.rms_norm(x, blk["ln2"], cfg.norm_eps)
    if use_moe:
        h, aux = moe_mod.moe_ffn(blk["moe"], cfg, h, ctx.mesh)
    else:
        h, aux = cm.ffn(blk["ffn"], cfg, h), jnp.float32(0)
    return x + h, aux


def _transformer_unit_fwd(cfg, unit, x, pos, ctx: RunCtx, layout):
    aux = jnp.float32(0)
    causal = not cfg.encoder_only
    if layout["locals"]:
        for i in range(layout["locals"]):
            blk = jax.tree.map(lambda a: a[i], unit["local"])
            x, a = _attn_block(blk, cfg, x, pos, ctx,
                               sliding=cfg.sliding_window, causal=causal,
                               use_moe=cfg.is_moe)
            aux += a
        x, a = _attn_block(unit["global"], cfg, x, pos, ctx, sliding=0,
                           causal=causal, use_moe=cfg.is_moe)
        aux += a
    else:
        x, a = _attn_block(unit["block"], cfg, x, pos, ctx,
                           sliding=cfg.sliding_window, causal=causal,
                           use_moe=cfg.is_moe)
        aux += a
    return x, aux


def _xlstm_unit_fwd(cfg, unit, x, ctx: RunCtx, state=None, collect_state=False):
    new_state: Dict[str, Any] = {}
    m_states = []
    if "mlstm" in unit:
        n_m = jax.tree.leaves(unit["mlstm"])[0].shape[0]
        for i in range(n_m):
            blk = jax.tree.map(lambda a: a[i], unit["mlstm"])
            st = None if state is None else jax.tree.map(lambda a: a[i], state["mlstm"])
            h, st2 = rec.mlstm_mix(
                blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps),
                chunk=ctx.rec_chunk, unroll_chunks=ctx.unroll_chunks, state=st,
            )
            x = x + h
            m_states.append(st2)
        if collect_state:
            new_state["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *m_states)
    if "slstm" in unit:
        blk = unit["slstm"]
        st = None if state is None else state["slstm"]
        h, st2 = rec.slstm_mix(
            blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps), state=st
        )
        x = x + h
        if collect_state:
            new_state["slstm"] = st2
    return x, new_state


def _zamba_unit_fwd(cfg, unit, shared, x, pos, ctx: RunCtx, state=None,
                    collect_state=False):
    new_state: Dict[str, Any] = {}
    m_states = []
    n_m = jax.tree.leaves(unit["mamba"])[0].shape[0]
    for i in range(n_m):
        blk = jax.tree.map(lambda a: a[i], unit["mamba"])
        st = None if state is None else jax.tree.map(lambda a: a[i], state["mamba"])
        h, st2 = rec.mamba2_mix(
            blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps),
            chunk=ctx.rec_chunk, unroll_chunks=ctx.unroll_chunks, state=st,
        )
        x = x + h
        m_states.append(st2)
    if collect_state:
        new_state["mamba"] = jax.tree.map(lambda *xs: jnp.stack(xs), *m_states)
    # shared attention block (weights shared across units)
    h = cm.rms_norm(x, shared["ln1"], cfg.norm_eps)
    h = cm.attention(shared["attn"], cfg, h, pos, causal=True,
                     q_chunk=ctx.q_chunk, unroll_chunks=ctx.unroll_chunks,
                     kv_range_chunking=ctx.kv_range_chunking,
                     head_sharding=ctx.head_sharding())
    x = x + h
    h = cm.rms_norm(x, shared["ln2"], cfg.norm_eps)
    x = x + cm.ffn(shared["ffn"], cfg, h)
    return x, new_state


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill-style)
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.frontend == "audio_frames":
        x = batch["frames"].astype(cm.dtype_of(cfg))
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return x, pos
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    if cfg.rope_style == "mrope":
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
        return x, pos
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, pos


def _head(params, cfg: ModelConfig, x) -> jnp.ndarray:
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ w).astype(jnp.float32)


def forward(params, cfg: ModelConfig, batch, ctx: RunCtx = RunCtx()) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits [B,S,V] f32, aux loss)."""
    layout = unit_layout(cfg)
    x, pos = _embed_in(params, cfg, batch)
    n_units = layout["n_units"] if ctx.n_units_override is None else ctx.n_units_override

    def unit_body(carry, unit):
        x, aux = carry
        if layout["kind"] == "transformer":
            x, a = _transformer_unit_fwd(cfg, unit, x, pos, ctx, layout)
        elif layout["kind"] == "xlstm":
            x, _ = _xlstm_unit_fwd(cfg, unit, x, ctx)
            a = jnp.float32(0)
        else:
            x, _ = _zamba_unit_fwd(cfg, unit, params["shared"], x, pos, ctx)
            a = jnp.float32(0)
        return (x, aux + a), None

    body = unit_body
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if ctx.remat_policy == "dots" else None)
        body = jax.checkpoint(unit_body, prevent_cse=False, policy=policy)

    if n_units > 0:
        units = jax.tree.map(lambda a: a[:n_units], params["units"])
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), units)
    else:
        aux = jnp.float32(0)
    if layout.get("tail_locals") and (ctx.n_units_override is None
                                      or ctx.n_units_override > 0):
        for i in range(layout["tail_locals"]):
            blk = jax.tree.map(lambda a: a[i], params["tail_local"])
            x, a = _attn_block(blk, cfg, x, pos, ctx,
                               sliding=cfg.sliding_window,
                               causal=not cfg.encoder_only, use_moe=cfg.is_moe)
            aux += a
    logits = _head(params, cfg, x)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, ctx: RunCtx = RunCtx()):
    """Causal-LM (or masked-prediction for encoders) cross-entropy."""
    logits, aux = forward(params, cfg, batch, ctx)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: partitions cleanly
    # when the vocab axis is TP-sharded (no logits all-gather).
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1)
        loss = (nll * mask).sum() / denom
    else:
        loss = nll.mean()
    total = loss + cfg.moe.load_balance_loss * aux
    return total, {"loss": loss, "aux": aux, "logits_mean_abs": jnp.mean(jnp.abs(logits))}


# ---------------------------------------------------------------------------
# KV-cache serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> Dict[str, Any]:
    """Decode state for all units (transformer KV / recurrent states)."""
    layout = unit_layout(cfg)
    n, dt = layout["n_units"], cm.dtype_of(cfg)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    B = batch_size

    def kv(S):
        return {
            "k": jnp.zeros((n, B, S, KV, hd), dt),
            "v": jnp.zeros((n, B, S, KV, hd), dt),
        }

    if layout["kind"] == "transformer":
        if layout["locals"]:
            W = max(cfg.sliding_window, 1)
            out = {
                "local": {
                    "k": jnp.zeros((n, layout["locals"], B, W, KV, hd), dt),
                    "v": jnp.zeros((n, layout["locals"], B, W, KV, hd), dt),
                    "pos": jnp.full((n, layout["locals"], B, W), -1, jnp.int32),
                },
                "global": kv(max_len),
            }
            if layout.get("tail_locals"):
                t = layout["tail_locals"]
                out["tail_local"] = {
                    "k": jnp.zeros((t, B, W, KV, hd), dt),
                    "v": jnp.zeros((t, B, W, KV, hd), dt),
                    "pos": jnp.full((t, B, W), -1, jnp.int32),
                }
            return out
        return {"block": kv(max_len)}
    if layout["kind"] == "xlstm":
        di = (cfg.ssm_expand or 2) * cfg.d_model
        H = cfg.num_heads
        hd_i = di // H
        out: Dict[str, Any] = {}
        m = layout["mlstm_per_unit"]
        if m:
            out["mlstm"] = (
                jnp.zeros((n, m, B, H, hd_i, hd_i), jnp.float32),
                jnp.zeros((n, m, B, H, hd_i), jnp.float32),
            )
        if layout["unit_layers"] > m:
            hd_s = cfg.d_model // H
            out["slstm"] = (
                jnp.zeros((n, B, H, hd_s), jnp.float32),
                jnp.ones((n, B, H, hd_s), jnp.float32),
                jnp.zeros((n, B, H, hd_s), jnp.float32),
            )
        return out
    # zamba hybrid: mamba states + shared-attn KV per unit
    di = cfg.ssm_expand * cfg.d_model
    Hm, dh, ds = di // 64, 64, cfg.ssm_state
    m = layout["mamba_per_unit"]
    W = cfg.ssm_conv - 1
    return {
        "mamba": (
            jnp.zeros((n, m, B, Hm, dh, ds), jnp.float32),
            jnp.zeros((n, m, B, W, di + 2 * ds), dt),
        ),
        "shared": kv(max_len),
    }


def decode_step(params, cfg: ModelConfig, token, pos, cache,
                ctx: RunCtx = RunCtx(), embeds: Optional[jnp.ndarray] = None):
    """One-token decode. token [B] int32 (or embeds [B, D]), pos [B] int32.
    Returns (logits [B, V] f32, cache')."""
    layout = unit_layout(cfg)
    B = token.shape[0] if token is not None else embeds.shape[0]
    if embeds is None:
        x = params["embed"][token][:, None, :]             # [B,1,D]
        if cfg.scale_embed:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    else:
        x = embeds[:, None, :].astype(cm.dtype_of(cfg))

    def unit_body(x, xs):
        unit, cache_u = xs
        if layout["kind"] == "transformer":
            x, cache_u = _transformer_unit_decode(cfg, unit, x, pos, cache_u, layout, ctx)
        elif layout["kind"] == "xlstm":
            x, cache_u = _xlstm_unit_decode(cfg, unit, x, cache_u, ctx)
        else:
            x, cache_u = _zamba_unit_decode(cfg, unit, params["shared"], x, pos,
                                            cache_u, ctx)
        return x, cache_u

    if ctx.n_units_override == 0:          # roofline zero-stack variant
        logits = _head(params, cfg, x)[:, 0]
        return logits, cache
    tail_cache = cache.get("tail_local")
    if tail_cache is not None:
        cache = {kk: vv for kk, vv in cache.items() if kk != "tail_local"}
    x, new_cache = jax.lax.scan(unit_body, x, (params["units"], cache))
    if tail_cache is not None:
        tk, tv, tp = [], [], []
        for i in range(layout["tail_locals"]):
            blk = jax.tree.map(lambda a: a[i], params["tail_local"])
            h = cm.rms_norm(x, blk["ln1"], cfg.norm_eps)
            out, k2, v2, p2 = _ring_attention_decode(
                blk["attn"], cfg, h, pos,
                tail_cache["k"][i], tail_cache["v"][i], tail_cache["pos"][i])
            x = x + out
            h = cm.rms_norm(x, blk["ln2"], cfg.norm_eps)
            x = x + cm.ffn(blk["ffn"], cfg, h)
            tk.append(k2); tv.append(v2); tp.append(p2)
        new_cache = dict(new_cache)
        new_cache["tail_local"] = {"k": jnp.stack(tk), "v": jnp.stack(tv),
                                   "pos": jnp.stack(tp)}
    logits = _head(params, cfg, x)[:, 0]
    return logits, new_cache


def _transformer_unit_decode(cfg, unit, x, pos, cache_u, layout, ctx=RunCtx()):
    new_cache: Dict[str, Any] = {}
    if layout["locals"]:
        lk, lv, lpos = [], [], []
        for i in range(layout["locals"]):
            blk = jax.tree.map(lambda a: a[i], unit["local"])
            h = cm.rms_norm(x, blk["ln1"], cfg.norm_eps)
            out, k2, v2, p2 = _ring_attention_decode(
                blk["attn"], cfg, h, pos,
                cache_u["local"]["k"][i], cache_u["local"]["v"][i],
                cache_u["local"]["pos"][i],
            )
            x = x + out
            h = cm.rms_norm(x, blk["ln2"], cfg.norm_eps)
            x = x + (cm.ffn(blk["ffn"], cfg, h) if "ffn" in blk
                     else moe_mod.moe_ffn(blk["moe"], cfg, h, ctx.mesh)[0])
            lk.append(k2)
            lv.append(v2)
            lpos.append(p2)
        new_cache["local"] = {
            "k": jnp.stack(lk), "v": jnp.stack(lv), "pos": jnp.stack(lpos)
        }
        blk = unit["global"]
        h = cm.rms_norm(x, blk["ln1"], cfg.norm_eps)
        out, k2, v2 = cm.attention_decode(
            blk["attn"], cfg, h, pos, cache_u["global"]["k"], cache_u["global"]["v"]
        )
        x = x + out
        h = cm.rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + (cm.ffn(blk["ffn"], cfg, h) if "ffn" in blk
                 else moe_mod.moe_ffn(blk["moe"], cfg, h, ctx.mesh)[0])
        new_cache["global"] = {"k": k2, "v": v2}
        return x, new_cache
    blk = unit["block"]
    h = cm.rms_norm(x, blk["ln1"], cfg.norm_eps)
    out, k2, v2 = cm.attention_decode(
        blk["attn"], cfg, h, pos, cache_u["block"]["k"], cache_u["block"]["v"],
        sliding_window=cfg.sliding_window,
    )
    x = x + out
    h = cm.rms_norm(x, blk["ln2"], cfg.norm_eps)
    x = x + (cm.ffn(blk["ffn"], cfg, h) if "ffn" in blk
             else moe_mod.moe_ffn(blk["moe"], cfg, h, ctx.mesh)[0])
    return x, {"block": {"k": k2, "v": v2}}


def _ring_attention_decode(p, cfg, x, pos, k_cache, v_cache, pos_cache):
    """Sliding-window decode with a ring-buffer cache [B, W, KV, hd]."""
    B = x.shape[0]
    W = k_cache.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = cm._qkv(p, cfg, x)
    if cfg.rope_style == "rope":
        q = cm.apply_rope(q, pos[:, None], cfg.rope_theta)
        k = cm.apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % W
    oh = jax.nn.one_hot(slot, W, dtype=k.dtype)               # [B, W]
    k2 = k_cache * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * k
    v2 = v_cache * (1 - oh[:, :, None, None]) + oh[:, :, None, None] * v
    p2 = pos_cache * (1 - oh.astype(jnp.int32)) + oh.astype(jnp.int32) * pos[:, None]
    kk = cm._repeat_kv(k2, H // KV)
    vv = cm._repeat_kv(v2, H // KV)
    m = (p2 >= 0) & (p2 <= pos[:, None]) & (pos[:, None] - p2 < cfg.sliding_window)
    out = cm._attend_dense(q, kk, vv, m[:, None, :], cfg.attn_logit_softcap)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, k2, v2, p2


def _xlstm_unit_decode(cfg, unit, x, cache_u, ctx):
    st = dict(cache_u)
    # reuse the sequence mixers with S=1
    new_state = {}
    if "mlstm" in unit:
        n_m = jax.tree.leaves(unit["mlstm"])[0].shape[0]
        Cs, ns = [], []
        for i in range(n_m):
            blk = jax.tree.map(lambda a: a[i], unit["mlstm"])
            sti = jax.tree.map(lambda a: a[i], st["mlstm"])
            h, (C2, n2) = rec.mlstm_mix(
                blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps),
                chunk=1, state=sti,
            )
            x = x + h
            Cs.append(C2)
            ns.append(n2)
        new_state["mlstm"] = (jnp.stack(Cs), jnp.stack(ns))
    if "slstm" in unit:
        blk = unit["slstm"]
        h, st2 = rec.slstm_mix(
            blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps),
            state=st["slstm"],
        )
        x = x + h
        new_state["slstm"] = st2
    return x, new_state


def _zamba_unit_decode(cfg, unit, shared, x, pos, cache_u, ctx):
    new_state: Dict[str, Any] = {}
    n_m = jax.tree.leaves(unit["mamba"])[0].shape[0]
    ssms, convs = [], []
    for i in range(n_m):
        blk = jax.tree.map(lambda a: a[i], unit["mamba"])
        sti = (cache_u["mamba"][0][i], cache_u["mamba"][1][i])
        h, (ssm2, conv2) = rec.mamba2_mix(
            blk["mix"], cfg, cm.rms_norm(x, blk["ln"], cfg.norm_eps),
            chunk=1, state=sti,
        )
        x = x + h
        ssms.append(ssm2)
        convs.append(conv2)
    new_state["mamba"] = (jnp.stack(ssms), jnp.stack(convs))
    h = cm.rms_norm(x, shared["ln1"], cfg.norm_eps)
    out, k2, v2 = cm.attention_decode(
        shared["attn"], cfg, h, pos, cache_u["shared"]["k"], cache_u["shared"]["v"]
    )
    x = x + out
    h = cm.rms_norm(x, shared["ln2"], cfg.norm_eps)
    x = x + cm.ffn(shared["ffn"], cfg, h)
    new_state["shared"] = {"k": k2, "v": v2}
    return x, new_state


def prefill(params, cfg: ModelConfig, batch, ctx: RunCtx = RunCtx()):
    """Prefill = full forward; for serving-shape dry-runs the logits of the
    last position are returned (cache construction is exercised by decode
    smoke tests at small scale — see DESIGN.md)."""
    logits, _ = forward(params, cfg, batch, ctx)
    return logits[:, -1]
