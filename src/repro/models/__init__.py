"""LM substrate: layers, per-family blocks, assembly for the arch pool."""

from repro.models.lm import (
    RunCtx,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    unit_layout,
)

__all__ = [
    "RunCtx", "decode_step", "forward", "init_cache", "init_params",
    "loss_fn", "prefill", "unit_layout",
]
