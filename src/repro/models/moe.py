"""Mixture-of-Experts FFN with expert parallelism.

Production path (mesh given): a ``shard_map`` layer —

* experts are sharded over the mesh's ``data`` axis (EP), expert FFN
  hidden dims over ``model`` (TP inside experts);
* token→expert routing uses fixed-capacity send buffers and a single
  ``all_to_all`` over the EP axis each way (switch-transformer style);
  over-capacity slots are dropped (their gate mass is lost, standard);
* the down-projection's partial sums are ``psum`` over ``model``.

Fallback path (mesh=None, smoke tests / single device): dense
compute-all-experts einsum — numerically the same routing, no dropping,
only viable at toy sizes.

Top-k gates are softmax-renormalized; a load-balance aux loss
(Switch/GShard style: E · Σ_e f_e · p_e) is returned for training.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map_compat
from repro.config import ModelConfig
from repro.models.common import dense_init, dtype_of


def init_moe(cfg: ModelConfig, key) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "w1": dense_init(ks[1], (e, d, f), 1, dt),
        "w3": dense_init(ks[2], (e, d, f), 1, dt),
        "w2": dense_init(ks[3], (e, f, d), 1, dt),
    }


def _route(cfg: ModelConfig, xt: jnp.ndarray, router: jnp.ndarray):
    """Returns (gates [T,k] f32, experts [T,k] i32, aux_loss scalar)."""
    k = cfg.moe.experts_per_token
    logits = xt.astype(jnp.float32) @ router                 # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * Σ_e (token fraction to e) * (mean prob of e)
    e_count = cfg.moe.num_experts
    frac = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], e_count, dtype=jnp.float32), axis=0
    )
    aux = e_count * jnp.sum(frac * jnp.mean(probs, axis=0))
    return gates, top_e.astype(jnp.int32), aux


def _expert_ffn(cfg: ModelConfig, buf: jnp.ndarray, w1, w3, w2) -> jnp.ndarray:
    """buf [E_l, C, D] → [E_l, C, D] through each local expert's SwiGLU."""
    h1 = jnp.einsum("ecd,edf->ecf", buf, w1)
    h3 = jnp.einsum("ecd,edf->ecf", buf, w3)
    h = jax.nn.silu(h1) * h3
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_ffn_dense(p, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense fallback: computes every expert for every token (toy sizes)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    gates, top_e, aux = _route(cfg, xt, p["router"])
    h1 = jnp.einsum("td,edf->tef", xt, p["w1"])
    h3 = jnp.einsum("td,edf->tef", xt, p["w3"])
    h = jax.nn.silu(h1) * h3
    out_all = jnp.einsum("tef,efd->ted", h, p["w2"])         # [T, E, D]
    comb = jnp.zeros(out_all.shape[:2], out_all.dtype)       # [T, E]
    t_idx = jnp.arange(xt.shape[0])[:, None]
    comb = comb.at[t_idx, top_e].add(gates.astype(out_all.dtype))
    out = jnp.einsum("te,ted->td", comb, out_all)
    return out.reshape(B, S, D), aux


def moe_ffn_ep(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,
    mesh: Mesh,
    *,
    capacity_factor: float = 1.5,
    data_axis: str = "data",
    model_axis: str = "model",
    pod_axis: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map + all_to_all (production path)."""
    E = cfg.moe.num_experts
    k = cfg.moe.experts_per_token
    ep = mesh.shape[data_axis]
    assert E % ep == 0, (E, ep)
    e_local = E // ep
    batch_axes = (pod_axis, data_axis) if pod_axis else (data_axis,)

    def device_fn(xl, router, w1, w3, w2):
        # xl [Bl, S, D]; w1/w3 [E_l, D, F_l]; w2 [E_l, F_l, D]
        Bl, S, D = xl.shape
        T = Bl * S
        xt = xl.reshape(T, D)
        gates, top_e, aux = _route(cfg, xt, router)

        fe = top_e.reshape(-1)                               # [T*k]
        fg = gates.reshape(-1)
        tok = jnp.arange(T * k) // k
        dest = fe // e_local                                 # EP rank
        cap_send = max(8, int(capacity_factor * T * k / ep))
        onehot = (dest[:, None] == jnp.arange(ep)[None, :]).astype(jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, dest[:, None], axis=1
        )[:, 0]
        keep = pos < cap_send
        safe_pos = jnp.where(keep, pos, cap_send - 1)

        send_x = jnp.zeros((ep, cap_send, D), xl.dtype)
        send_e = jnp.full((ep, cap_send), -1, jnp.int32)
        send_x = send_x.at[dest, safe_pos].set(
            jnp.where(keep[:, None], xt[tok], 0.0).astype(xl.dtype)
        )
        send_e = send_e.at[dest, safe_pos].set(
            jnp.where(keep, fe % e_local, -1).astype(jnp.int32)
        )

        recv_x = jax.lax.all_to_all(send_x, data_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, data_axis, 0, 0, tiled=True)
        rx = recv_x.reshape(ep * cap_send, D)
        re = recv_e.reshape(ep * cap_send)

        # bucket received tokens by local expert (fixed capacity)
        cap_e = max(8, int(capacity_factor * ep * cap_send / e_local))
        onehot_e = (re[:, None] == jnp.arange(e_local)[None, :]).astype(jnp.int32)
        pos_e = jnp.take_along_axis(
            jnp.cumsum(onehot_e, axis=0) - 1,
            jnp.clip(re, 0, e_local - 1)[:, None],
            axis=1,
        )[:, 0]
        valid = (re >= 0) & (pos_e < cap_e)
        safe_e = jnp.where(valid, re, 0)
        safe_pe = jnp.where(valid, pos_e, cap_e - 1)
        buf = jnp.zeros((e_local, cap_e, D), xl.dtype)
        buf = buf.at[safe_e, safe_pe].set(
            jnp.where(valid[:, None], rx, 0.0).astype(xl.dtype)
        )

        out_buf = _expert_ffn(cfg, buf, w1, w3, w2)          # partial over F_l
        out_buf = jax.lax.psum(out_buf, model_axis)

        back = jnp.where(valid[:, None], out_buf[safe_e, safe_pe], 0.0)
        back = back.reshape(ep, cap_send, D)
        ret = jax.lax.all_to_all(back, data_axis, 0, 0, tiled=True)
        # ret[dest, pos] is the processed slot this device sent to `dest`
        slot_out = ret[dest, safe_pos] * jnp.where(keep, fg, 0.0)[:, None].astype(xl.dtype)
        y = jnp.zeros((T, D), xl.dtype).at[tok].add(slot_out)
        aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(Bl, S, D), aux

    fn = shard_map_compat(
        device_fn,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(None, None),
            P(data_axis, None, model_axis),
            P(data_axis, None, model_axis),
            P(data_axis, model_axis, None),
        ),
        out_specs=(P(batch_axes, None, None), P()),
    )
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"])


def moe_ffn(
    p, cfg: ModelConfig, x: jnp.ndarray, mesh: Optional[Mesh] = None, **kw
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if mesh is None or mesh.shape.get("data", 1) == 1:
        return moe_ffn_dense(p, cfg, x)
    return moe_ffn_ep(p, cfg, x, mesh, **kw)
