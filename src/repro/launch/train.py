"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
        --steps 20 [--ckpt-dir /tmp/ckpt]

``--smoke`` selects the reduced config (CPU-runnable); without it the full
assigned config is used (TPU pods — pair with the dry-run-validated mesh).
Resumes automatically from the latest checkpoint in --ckpt-dir.
"""

from __future__ import annotations

import argparse

import jax

from repro import configs as cfgs
from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.models import RunCtx, init_params
from repro.train import OptConfig, init_opt_state, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=cfgs.arch_names())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = cfgs.get_smoke_config(args.arch) if args.smoke else cfgs.get_config(args.arch)
    if cfg.frontend != "none":
        raise SystemExit(f"{args.arch} is encoder-only/frontend-stubbed; use "
                         "its masked-prediction path via tests/models instead")
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ck = Checkpointer(args.ckpt_dir, keep=3, async_write=True) if args.ckpt_dir else None
    start = 0
    if ck is not None and ck.latest_step() is not None:
        ocfg = OptConfig(name=cfg.optimizer, lr=args.lr)
        target = {"params": params, "opt": init_opt_state(params, ocfg)}
        restored = ck.restore(target)
        params = restored["params"]
        start = ck.latest_step()
        print(f"resumed from step {start}")
    params, _, hist = train_loop(
        cfg, params, pipe, steps=args.steps,
        ocfg=OptConfig(name=cfg.optimizer, lr=args.lr),
        ctx=RunCtx(rec_chunk=16, q_chunk=64),
        checkpointer=ck, ckpt_every=args.ckpt_every, start_step=start,
    )
    if ck:
        ck.wait()
    print(f"final loss {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
