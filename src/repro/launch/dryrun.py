import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes (16×16 single-pod, 2×16×16 multi-pod), plus
the paper's own ANNS pipeline at billion-vector scale.

Per cell and mesh this records into a JSON cache (benchmarks/ and the
roofline report read it — nothing is recompiled downstream):

* ``memory_analysis`` (argument/output/temp bytes per device),
* ``cost_analysis`` flops / bytes accessed,
* collective result-bytes by op kind parsed from the compiled HLO,
* compile wall time.

Each cell is lowered TWICE — full stack and ``n_units_override=0`` —
because XLA's cost analysis counts a ``lax.scan`` body once regardless of
trip count: total = zero_variant + n_units × (full − zero). Inner
recurrent/attention chunk loops are unrolled (``unroll_chunks``) when the
chunk count is ≤ MAX_UNROLL so the per-unit body cost is exact; cells
where that would blow up HLO size keep the inner scan and record its trip
count for the analytic correction (see EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun [--arch A]... [--shape S]... \
      [--mesh single|multi|both] [--anns] [--out benchmarks/dryrun_results.json]
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import numpy as np

from repro import configs as cfgs
from repro.config import ModelConfig, ShapeSpec, applicable_shapes, shape_by_name
from repro.launch.hlo import collective_bytes, count_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import RunCtx, decode_step, init_cache, init_params, prefill
from repro.sharding.rules import (
    batch_axes,
    batch_shardings,
    cache_shardings,
    opt_shardings,
    param_shardings,
)
from repro.train import OptConfig, init_opt_state, make_train_step

MAX_UNROLL = 64          # inner-loop unroll budget (HLO-size vs exactness)
# beyond-paper optimizations, toggled by --opt (EXPERIMENTS.md §Perf)
OPT_FLAGS = {"kv_range_chunking": False, "shard_heads": False,
             "remat_policy": "full"}
DEFAULT_OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results.json"


def _ctx_for(cfg: ModelConfig, shape: ShapeSpec, mesh, n_override):
    """Unroll inner chunk loops whenever the cell actually has them, so the
    per-unit HLO cost is exact (cost_analysis counts scan bodies once).

    decode steps have no full-sequence chunk loops → nothing to unroll;
    transformer families only chunk attention (q); ssm/hybrid also chunk
    the recurrence (rec). sLSTM's per-timestep scan can never be unrolled —
    the roofline corrects those layers analytically (EXPERIMENTS.md).
    """
    q_chunk = 2048 if shape.seq_len > 8192 else 1024
    rec_chunk = 512 if shape.seq_len > 8192 else 256
    if shape.kind == "decode":
        trips = 1
    elif cfg.family in ("ssm", "hybrid"):
        trips = max(-(-shape.seq_len // q_chunk), -(-shape.seq_len // rec_chunk))
    else:
        trips = -(-shape.seq_len // q_chunk)
    unroll = trips <= MAX_UNROLL
    return RunCtx(
        mesh=mesh, unroll_chunks=unroll, q_chunk=q_chunk, rec_chunk=rec_chunk,
        n_units_override=n_override,
        kv_range_chunking=OPT_FLAGS["kv_range_chunking"],
        shard_heads=OPT_FLAGS["shard_heads"],
        remat_policy=OPT_FLAGS["remat_policy"],
    ), {"q_chunk": q_chunk, "rec_chunk": rec_chunk, "inner_unrolled": unroll,
        "opt": dict(OPT_FLAGS),
        "inner_trips": {"q": -(-shape.seq_len // q_chunk),
                        "rec": -(-shape.seq_len // rec_chunk),
                        "effective": trips}}


def _abstract(fn, *a, **k):
    return jax.eval_shape(fn, *a, **k)


def _batch_sds(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jax.numpy.int32, jax.numpy.float32
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
            "loss_mask": jax.ShapeDtypeStruct((B, S), f32),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "targets": jax.ShapeDtypeStruct((B, S), i32),
    }
    if cfg.rope_style == "mrope":
        out["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    return out


def _analyze(lowered, compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collective_result_bytes": collective_bytes(txt),
        "collective_counts": count_collectives(txt),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        },
    }


def run_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, mesh_name: str) -> dict:
    """Lower+compile one (arch, shape, mesh): full and zero-stack variants."""
    from repro.models.lm import unit_layout

    layout = unit_layout(cfg)
    results = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
               "kind": shape.kind, "n_units": layout["n_units"],
               "unit_layers": layout["unit_layers"],
               "tail_locals": layout.get("tail_locals", 0),
               "variants": {}, "ok": False}

    p_shape = _abstract(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = param_shardings(p_shape, cfg, mesh)

    for variant, n_override in (("full", None), ("zero", 0)):
        ctx, ctx_meta = _ctx_for(cfg, shape, mesh, n_override)
        t0 = time.time()
        if shape.kind == "train":
            ocfg = OptConfig(name=cfg.optimizer)
            o_shape = _abstract(lambda: init_opt_state(p_shape, ocfg))
            o_sh = opt_shardings(o_shape, p_shape, cfg, mesh)
            b_sds = _batch_sds(cfg, shape)
            b_sh = batch_shardings(cfg, shape, mesh)
            step = make_train_step(cfg, ocfg, ctx)
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh)
            ).lower(p_shape, o_shape, b_sds)
        elif shape.kind == "prefill":
            b_sds = _batch_sds(cfg, shape)
            b_sh = batch_shardings(cfg, shape, mesh)
            step = partial(prefill, cfg=cfg, ctx=ctx)
            lowered = jax.jit(
                lambda p, b: prefill(p, cfg, b, ctx),
                in_shardings=(p_sh, b_sh),
            ).lower(p_shape, b_sds)
        else:  # decode
            B, S = shape.global_batch, shape.seq_len
            c_shape = _abstract(lambda: init_cache(cfg, B, S))
            c_sh = cache_shardings(cfg, c_shape, shape, mesh)
            ba = batch_axes(mesh)
            bsz = int(np.prod([mesh.shape[a] for a in ba]))
            from jax.sharding import NamedSharding, PartitionSpec as P

            tok_sh = NamedSharding(mesh, P(ba if B % bsz == 0 and B >= bsz else None))
            tok = jax.ShapeDtypeStruct((B,), jax.numpy.int32)
            pos = jax.ShapeDtypeStruct((B,), jax.numpy.int32)
            lowered = jax.jit(
                lambda p, t, po, c: decode_step(p, cfg, t, po, c, ctx),
                in_shardings=(p_sh, tok_sh, tok_sh, c_sh),
            ).lower(p_shape, tok, pos, c_shape)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        entry = _analyze(lowered, compiled)
        entry.update({"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
                      "ctx": ctx_meta})
        results["variants"][variant] = entry
        print(f"    {variant}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops {entry['flops']:.3g} temp {entry['memory']['temp_bytes']/2**30:.2f} GiB",
              flush=True)
    results["ok"] = True
    return results


def run_anns_cell(mesh, mesh_name: str, multi_pod: bool) -> dict:
    """The paper's own workload: HARMONY SPMD pipeline at SpaceV1B scale.

    --opt: the §4.2 cost model evaluated with TPU v5e constants picks a
    vector-heavy factorization (dimension rings are ICI/HBM-hostile at
    197 TFLOP/s — see EXPERIMENTS.md §Perf): V=128 × B=2 instead of 16×16,
    and the corpus is stored bf16 (accumulators stay f32)."""
    import jax as _jax

    from repro.core.pipeline import SpmdConfig, input_specs, make_spmd_search

    n_pods = 2 if multi_pod else 1
    if OPT_FLAGS["kv_range_chunking"]:          # --opt
        ax = ("pod", "data", "model") if multi_pod else ("data", "model")
        shp = (2, 128, 2) if multi_pod else (128, 2)
        mesh = _jax.make_mesh(shp, ax,
                              axis_types=(_jax.sharding.AxisType.Auto,) * len(ax))
        scfg = SpmdConfig(
            v_shards=128, d_blocks=2, n_pods=n_pods,
            qb=1024, cap=2**19, dim=128, nprobe=64, k=10, chunk=2**15,
            x_dtype="bfloat16", use_pallas=False,
        )
    else:
        scfg = SpmdConfig(
            v_shards=16, d_blocks=16, n_pods=n_pods,
            qb=1024, cap=2**22, dim=128, nprobe=64, k=10, chunk=2**16,
            use_pallas=False,     # jnp scoring path lowers on the CPU backend
        )
    res = {"arch": "harmony-anns", "shape": "spacev1b_like", "mesh": mesh_name,
           "kind": "serve", "variants": {}, "ok": False,
           "scfg": {"cap": scfg.cap, "chunk": scfg.chunk, "qb": scfg.qb,
                    "dim": scfg.dim, "n_chunks": scfg.n_chunks,
                    "v_shards": scfg.v_shards, "d_blocks": scfg.d_blocks,
                    "x_dtype": scfg.x_dtype, "opt": dict(OPT_FLAGS)}}
    step = make_spmd_search(scfg, mesh)
    sds = input_specs(scfg)
    t0 = time.time()
    lowered = step.lower(
        sds["x_blocks"], sds["xn2_blocks"], sds["cluster_ids"],
        sds["row_ids"], sds["queries"], sds["probes"], sds["tau0"],
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    entry = _analyze(lowered, compiled)
    entry.update({"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
                  "inner_trips": {"chunks": scfg.n_chunks, "ring": scfg.d_blocks}})
    res["variants"]["full"] = entry
    res["ok"] = True
    print(f"    anns: lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"flops {entry['flops']:.3g}", flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--anns", action="store_true", help="only the ANNS cells")
    ap.add_argument("--no-anns", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", action="store_true",
                    help="enable perf optimizations; writes *_opt.json")
    ap.add_argument("--remat-policy", dest="remat_policy", default=None,
                    choices=["full", "dots"])
    args = ap.parse_args()
    if args.opt:
        OPT_FLAGS["kv_range_chunking"] = True
        OPT_FLAGS["shard_heads"] = True
        # NOTE: remat_policy="dots" was evaluated and REFUTED (see
        # EXPERIMENTS.md §Perf iteration log): −18% collective but 3.5×
        # resident memory — stays off.
    if args.remat_policy:
        OPT_FLAGS["remat_policy"] = args.remat_policy
    if args.out is None:
        args.out = str(DEFAULT_OUT.with_name(
            "dryrun_results_opt.json" if args.opt else "dryrun_results.json"))

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False), False))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True), True))

    out_path = Path(args.out)
    existing = {}
    if out_path.exists():
        for r in json.loads(out_path.read_text()):
            existing[(r["arch"], r["shape"], r["mesh"])] = r

    def save():
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(list(existing.values()), indent=1))

    if not args.anns:
        arch_list = args.arch or cfgs.arch_names()
        for arch in arch_list:
            cfg = cfgs.get_config(arch)
            shapes = applicable_shapes(cfg)
            if args.shape:
                shapes = [s for s in shapes if s.name in args.shape]
            for shape in shapes:
                for mesh_name, mesh, _ in meshes:
                    key = (arch, shape.name, mesh_name)
                    if key in existing and existing[key].get("ok"):
                        print(f"[skip cached] {key}")
                        continue
                    print(f"[cell] {arch} × {shape.name} × {mesh_name}", flush=True)
                    try:
                        existing[key] = run_cell(cfg, shape, mesh, mesh_name)
                    except Exception as e:
                        traceback.print_exc()
                        existing[key] = {
                            "arch": arch, "shape": shape.name, "mesh": mesh_name,
                            "ok": False, "error": f"{type(e).__name__}: {e}",
                        }
                    save()

    if not args.no_anns:
        for mesh_name, mesh, multi in meshes:
            key = ("harmony-anns", "spacev1b_like", mesh_name)
            if key in existing and existing[key].get("ok"):
                print(f"[skip cached] {key}")
                continue
            print(f"[cell] harmony-anns × spacev1b_like × {mesh_name}", flush=True)
            try:
                existing[key] = run_anns_cell(mesh, mesh_name, multi)
            except Exception as e:
                traceback.print_exc()
                existing[key] = {"arch": "harmony-anns", "shape": "spacev1b_like",
                                 "mesh": mesh_name, "ok": False,
                                 "error": f"{type(e).__name__}: {e}"}
            save()

    n_ok = sum(1 for r in existing.values() if r.get("ok"))
    print(f"\ndone: {n_ok}/{len(existing)} cells ok → {out_path}")
    return 0 if n_ok == len(existing) else 1


if __name__ == "__main__":
    raise SystemExit(main())
