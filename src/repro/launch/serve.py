"""ANNS serving launcher — the production entry point for the paper's
system. Builds (or loads) an index, starts the HarmonyServer, and drains a
synthetic request stream while reporting QPS/latency/replans.

    PYTHONPATH=src python -m repro.launch.serve --nb 20000 --nodes 8 \
        --batches 16 [--fail-node 3]
"""

from __future__ import annotations

import argparse

from repro.config import HarmonyConfig
from repro.core import build_ivf
from repro.data import make_dataset, make_queries
from repro.serve import HarmonyServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nb", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--nlist", type=int, default=128)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--skew", type=float, default=0.5)
    ap.add_argument("--fail-node", type=int, default=None)
    ap.add_argument("--replan-every", type=int, default=4)
    args = ap.parse_args()

    ds = make_dataset(nb=args.nb, dim=args.dim, n_components=max(args.nlist // 4, 8),
                      spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=args.dim, nlist=args.nlist, nprobe=args.nprobe,
                        topk=args.topk)
    index = build_ivf(ds.x, cfg)
    srv = HarmonyServer(index, n_nodes=args.nodes, replan_every=args.replan_every)
    print(f"plan V×B = {srv.plan.v_shards}×{srv.plan.d_blocks} on {args.nodes} nodes")
    for i in range(args.batches):
        q = make_queries(ds, nq=args.batch_size, skew=args.skew, noise=0.2, seed=i)
        srv.search_batch(q)
        if args.fail_node is not None and i == args.batches // 2:
            print(f"killing node {args.fail_node}")
            srv.fail_node(args.fail_node)
            print(f"re-planned: V×B = {srv.plan.v_shards}×{srv.plan.d_blocks}")
    s = srv.stats
    print(f"{s.queries} queries | QPS(serial)={s.qps:.0f} | "
          f"p50={s.latency_pct(50):.1f}ms p95={s.latency_pct(95):.1f}ms | "
          f"replans={s.replans}")


if __name__ == "__main__":
    main()
