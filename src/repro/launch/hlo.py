"""HLO-text analysis: collective-bytes accounting for the roofline.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
collective op (all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute), bucketed by op kind. Notes:

* while-loop bodies appear once in the text (same convention as
  ``cost_analysis``'s flops) — the roofline harness recovers per-layer
  totals by the two-compile differencing described in DESIGN.md;
* result-shape bytes are the wire proxy: exact for ppermute/all-to-all,
  the gathered size for all-gather (ring transfer ≈ (n−1)/n of it), and
  the reduced size for all-reduce (ring ≈ 2(n−1)/n ·bytes); the roofline
  applies those ring factors.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind (…-done ops are skipped so
    async pairs are not double-counted)."""
    out: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        # skip the -done halves of async pairs
        window = hlo_text[m.start(): m.end()]
        if "-done(" in window:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return dict(out)


def wire_bytes(coll: Dict[str, int], n_shards: int) -> float:
    """Ring-algorithm wire-byte estimate per device from result bytes."""
    f = (n_shards - 1) / max(n_shards, 1)
    total = 0.0
    for kind, b in coll.items():
        if kind == "all-reduce":
            total += 2 * f * b
        elif kind in ("all-gather", "reduce-scatter"):
            total += f * b
        elif kind == "all-to-all":
            total += f * b
        elif kind == "collective-permute":
            total += b
    return total


def count_collectives(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        out[m.group(2)] += 1
    return dict(out)
