"""Roofline analysis from the dry-run cache (no recompilation).

Per (arch × shape × mesh) this derives the three roofline terms on TPU v5e
constants and identifies the dominant bottleneck:

    compute    = HLO_FLOPs_dev / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes_dev / HBM_bw              (819 GB/s)
    collective = wire_bytes_dev / ICI_bw             (50 GB/s/link)

HLO numbers are reconstructed from the two-compile differencing
(total = zero + n_units × unit; gemma's tail layers are apportioned by
layer count). Collective wire bytes apply ring factors to HLO result
bytes (see launch/hlo.py). Cells whose per-unit body still contains an
inner scan that cannot be unrolled (xLSTM's per-timestep sLSTM) get an
analytic flop correction, recorded in the row.

MODEL_FLOPS follows the brief: 6·N·D (train) / 2·N·D (prefill/decode
tokens), N = params excluding the embedding table (MoE: active experts
only). The ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled
compute is "useful" (remat, attention, and routing overheads push it
below 1).

Usage: python -m repro.launch.roofline [--json benchmarks/dryrun_results.json]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link
HBM_BYTES = 16 * 2**30     # v5e
_RING_F = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _n_shards(mesh_name: str) -> int:
    return 512 if mesh_name.startswith("2pod") else 256


def _wire(coll: dict, groups: int = 16) -> float:
    f = (groups - 1) / groups
    total = 0.0
    for kind, b in coll.items():
        scale = _RING_F.get(kind, 1.0)
        total += (scale * f if kind != "collective-permute" else 1.0) * b
    return total


def _combine(cell: dict, key_path) -> float:
    """total = zero + n_units·unit (+ tail share)."""
    full = cell["variants"]["full"]
    zero = cell["variants"].get("zero")
    get = lambda v: key_path(v) if v else 0.0
    if zero is None:
        return get(full)
    n = cell.get("n_units", 1)
    ul = cell.get("unit_layers", 1)
    tl = cell.get("tail_locals", 0)
    delta = get(full) - get(zero)
    if tl:
        unit = delta * ul / (ul + tl)
        tail = delta - unit
        return get(zero) + n * unit + tail
    return get(zero) + n * delta


def _combine_coll(cell: dict) -> dict:
    full = cell["variants"]["full"].get("collective_result_bytes", {})
    zero = (cell["variants"].get("zero") or {}).get("collective_result_bytes", {})
    n = cell.get("n_units", 1)
    ul, tl = cell.get("unit_layers", 1), cell.get("tail_locals", 0)
    out = {}
    for k in set(full) | set(zero):
        delta = full.get(k, 0) - zero.get(k, 0)
        if tl:
            unit = delta * ul / (ul + tl)
            out[k] = zero.get(k, 0) + n * unit + (delta - unit)
        else:
            out[k] = zero.get(k, 0) + n * delta
    return out


def _model_flops(arch: str, shape_name: str, kind: str, n_devices: int):
    """Analytic 6·N·D / 2·N·D per the brief (global, then per device)."""
    from repro import configs as cfgs
    from repro.config import shape_by_name

    import jax

    cfg = cfgs.get_config(arch)
    shape = shape_by_name(shape_name)
    from repro.models import init_params

    p = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(p)[0]:
        names = [str(getattr(x, "key", getattr(x, "idx", x))) for x in path]
        n = int(np.prod(leaf.shape))
        if names[-1] == "embed":
            continue                      # lookup is not a matmul
        total += n
        if "moe" in names and names[-1] in ("w1", "w2", "w3"):
            active += n * cfg.moe.experts_per_token / cfg.moe.num_experts
        else:
            active += n
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        g = 6.0 * active * tokens
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        g = 2.0 * active * tokens
    else:  # decode: one token per sequence
        g = 2.0 * active * shape.global_batch
    return g, g / n_devices


def _slstm_correction(arch: str, shape_name: str, kind: str, n_devices: int) -> float:
    """Per-device analytic flops for sLSTM per-timestep recurrences that
    stay inside an un-unrollable scan (HLO counts the body once)."""
    from repro import configs as cfgs
    from repro.config import shape_by_name

    cfg = cfgs.get_config(arch)
    if cfg.family != "ssm" or not cfg.xlstm_slstm_every or kind == "decode":
        return 0.0
    shape = shape_by_name(shape_name)
    n_slstm = cfg.num_layers // cfg.xlstm_slstm_every
    H = cfg.num_heads
    hd = cfg.d_model // H
    per_step = 2.0 * H * hd * 4 * hd          # recurrent einsum per token
    g = n_slstm * shape.global_batch * shape.seq_len * per_step
    if kind == "train":
        g *= 3
    return g / n_devices


def analyze(cells, mesh_filter=None):
    rows = []
    for cell in cells:
        if not cell.get("ok") or "full" not in cell.get("variants", {}):
            continue
        if mesh_filter and cell["mesh"] != mesh_filter:
            continue
        ndev = _n_shards(cell["mesh"])
        arch, shape, kind = cell["arch"], cell["shape"], cell.get("kind", "serve")

        if arch == "harmony-anns":
            # inner (chunk × ring) scans are counted once → multiply back
            v = cell["variants"]["full"]
            trips = v["inner_trips"]["chunks"] * v["inner_trips"]["ring"]
            flops = v["flops"] * trips
            bytes_ = v["bytes_accessed"] * trips
            coll = {k: b * trips for k, b in v["collective_result_bytes"].items()}
            # model flops: every (query-group pair × dim) scored once per
            # device across the ring: 2 · QG · cap · D
            sc = cell.get("scfg", {})
            qg = sc.get("qb", 1024) // sc.get("d_blocks", 16)
            model_dev = 2.0 * qg * sc.get("cap", 0) * sc.get("dim", 128)
            model_g = model_dev * ndev
            correction = 0.0
        else:
            flops = _combine(cell, lambda v: v["flops"])
            bytes_ = _combine(cell, lambda v: v["bytes_accessed"])
            coll = _combine_coll(cell)
            correction = _slstm_correction(arch, shape, kind, ndev)
            flops += correction
            model_g, model_dev = _model_flops(arch, shape, kind, ndev)

        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_ / HBM_BW
        wire = _wire(coll)
        collective_s = wire / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        dominant = max(terms, key=terms.get)
        mem = cell["variants"]["full"].get("memory", {})
        resident = mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
        # lower bound on HBM traffic: compulsory argument+output bytes
        # (the HLO 'bytes accessed' above is the CPU backend's UNFUSED
        # upper bound — TPU fusion lands in between; see EXPERIMENTS.md)
        memory_lower_s = (mem.get("argument_bytes", 0)
                          + mem.get("output_bytes", 0)) / HBM_BW
        rows.append({
            "arch": arch, "shape": shape, "mesh": cell["mesh"], "kind": kind,
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "hlo_flops_dev": flops, "hlo_bytes_dev": bytes_,
            "wire_bytes_dev": wire,
            "memory_lower_s": memory_lower_s,
            "model_flops_global": model_g,
            "model_flops_ratio": (model_dev / flops) if flops and model_dev == model_dev else 0.0,
            "slstm_correction_dev": correction,
            "resident_bytes_dev": resident,
            "fits_hbm": bool(resident <= HBM_BYTES),
            "roofline_fraction": (model_dev / PEAK_FLOPS) / max(terms[dominant], 1e-30),
        })
    return rows


RECOMMEND = {
    "compute": "compute-bound: raise MXU utilization (larger per-chip tiles, "
               "fewer remat recomputes) or accept — this is the good roof",
    "memory": "HBM-bound: cut bytes/step — fuse elementwise chains, shrink "
              "activation dtypes, avoid materialized logits/one-hots",
    "collective": "ICI-bound: reshard to cut cross-chip traffic (fewer "
                  "dimension blocks / more vector shards, overlap ppermute "
                  "with compute, or move the axis onto faster links)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=str(Path(__file__).resolve().parents[3]
                                          / "benchmarks" / "dryrun_results.json"))
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[3]
                                         / "benchmarks" / "roofline.json"))
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    cells = json.loads(Path(args.json).read_text())
    rows = analyze(cells, args.mesh)
    Path(args.out).write_text(json.dumps(rows, indent=1))

    hdr = (f"{'arch':<18} {'shape':<12} {'mesh':<12} {'comp_s':>9} {'mem_s':>9} "
           f"{'coll_s':>9} {'bound':<10} {'MF/HLO':>6} {'fit':>4}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        print(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<12} "
              f"{r['compute_s']:>9.3g} {r['memory_s']:>9.3g} "
              f"{r['collective_s']:>9.3g} {r['dominant']:<10} "
              f"{r['model_flops_ratio']:>6.2f} {'ok' if r['fits_hbm'] else 'OOM':>4}")
    print(f"\n{len(rows)} rows → {args.out}")


if __name__ == "__main__":
    main()
