"""Production mesh builders.

Importing this module never touches jax device state — meshes are built
only inside the functions (the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod (TPU v5e); 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — for tests and
    CPU examples."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
