"""Jit'd public wrappers around the Pallas kernels.

On CPU hosts (this container) the kernels execute under
``interpret=True`` — the kernel body runs as regular JAX ops so the
BlockSpec/when logic is validated end-to-end; on TPU they compile to
Mosaic. Call sites never need to care.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distance import partial_distance_update as _pallas_update
from repro.kernels.distance_int8 import (
    int8_partial_distance_update as _pallas_update_int8,
)
from repro.kernels.topk_update import running_topk_update as _pallas_topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def partial_distance_update(
    x: jnp.ndarray,
    xn2: jnp.ndarray,
    q: jnp.ndarray,
    qn2: jnp.ndarray,
    acc: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    prune: bool = True,
    metric: str = "l2",
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """acc' = acc + partial_distance_block, pruned against τ.

    Returns (acc' [M,N] f32, tile_skip_map [m_tiles, n_tiles] int32).
    ``use_pallas=False`` routes to the pure-jnp oracle (fast XLA path used
    by CPU-measured benchmarks; the skip map is then computed post-hoc).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if use_pallas:
        return _pallas_update(
            x, xn2, q, qn2, acc, tau,
            prune=prune, metric=metric,
            tile_m=tile_m, tile_n=tile_n, tile_k=tile_k,
            interpret=interpret,
        )
    out = ref.partial_distance_update_ref(
        x, xn2, q, qn2, acc, tau, prune=prune, metric=metric
    )
    skip = _tile_skip_map(acc, tile_m, tile_n)
    return out, skip


def int8_partial_distance_update(
    x: jnp.ndarray,
    xn2: jnp.ndarray,
    q: jnp.ndarray,
    qn2: jnp.ndarray,
    scale2: jnp.ndarray,
    acc: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    prune: bool = True,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized stage-1 scoring: acc' = acc + s²·‖Q−P‖²_b, pruned vs τ.

    ``x``/``q`` are int8 codes on a shared per-dimension-block grid;
    ``xn2``/``qn2`` carry the pre-scaled s²·Σcode² norms (f32). The MXU
    contraction accumulates in int32. L2 only. Returns
    (acc' [M,N] f32, tile_skip_map [m_tiles, n_tiles] int32).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if use_pallas:
        return _pallas_update_int8(
            x, xn2, q, qn2, scale2, acc, tau,
            prune=prune,
            tile_m=tile_m, tile_n=tile_n, tile_k=tile_k,
            interpret=interpret,
        )
    out = ref.int8_partial_distance_update_ref(
        x, xn2, q, qn2, scale2, acc, tau, prune=prune
    )
    skip = _tile_skip_map(acc, tile_m, tile_n)
    return out, skip


def _tile_skip_map(acc: jnp.ndarray, tile_m: int, tile_n: int) -> jnp.ndarray:
    """Which [tile_m, tile_n] tiles were fully pruned on entry (post-hoc)."""
    m, n = acc.shape
    mp, np_ = -(-m // tile_m) * tile_m, -(-n // tile_n) * tile_n
    a = jnp.pad(acc, ((0, mp - m), (0, np_ - n)), constant_values=jnp.inf)
    a = a.reshape(mp // tile_m, tile_m, np_ // tile_n, tile_n)
    alive = jnp.isfinite(a).any(axis=(1, 3))
    return (~alive).astype(jnp.int32)


def running_topk_update(
    scores: jnp.ndarray,      # [M, C] f32, +inf = invalid
    ids: jnp.ndarray,         # [M, C] i32
    run_s: jnp.ndarray,       # [M, K] f32 ascending
    run_i: jnp.ndarray,       # [M, K] i32
    *,
    k: int,
    tile_m: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge a candidate chunk into the per-query running top-K.

    Routes to the fused VMEM-resident Pallas kernel (interpret-mode off
    TPU) or the concat+sort jnp oracle with ``use_pallas=False``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if use_pallas:
        return _pallas_topk(
            scores, ids, run_s, run_i, k=k, tile_m=tile_m, interpret=interpret
        )
    return ref.running_topk_ref(scores, ids, run_s, run_i, k=k)


def masked_topk(scores: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Ascending top-k of finite entries (oracle-backed; see ref)."""
    return ref.masked_topk_ref(scores, ids, k)
