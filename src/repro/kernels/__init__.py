"""Pallas TPU kernels for HARMONY's compute hot-spot (distance scoring).

* ``distance.py`` — pl.pallas_call kernel: partial-distance accumulate with
  tile-granular early-stop pruning (BlockSpec VMEM tiling, MXU matmul).
* ``ops.py`` — jit'd wrappers (auto interpret=True off-TPU).
* ``ref.py`` — pure-jnp oracles defining the exact semantics.
"""

from repro.kernels.ops import partial_distance_update, masked_topk
from repro.kernels.topk_update import running_topk_update

__all__ = ["partial_distance_update", "masked_topk", "running_topk_update"]
