"""Pallas TPU kernel: partial-distance accumulate + monotone prune.

This is HARMONY's compute hot-spot (the paper: "over 90 % of ANNS time is
distance computation"), adapted to the TPU memory hierarchy:

* the [M, N] accumulator tile, the [bm, bk]/[bn, bk] operand tiles, and the
  per-row norm/threshold vectors live in VMEM via ``BlockSpec``;
* the partial distance is computed on the MXU as
  ``acc + ‖p‖²_b − 2·Q@Xᵀ + ‖q‖²_b`` with f32 accumulation;
* **tile-granular early-stop**: if every pair in the [bm, bn] accumulator
  tile is already pruned (+inf), the MXU matmul for this tile is skipped
  via ``pl.when`` — the TPU-native replacement for the paper's per-element
  CPU branch. A per-tile skip map is emitted so benchmarks can report the
  realized compute saving.

Grid: (m_tiles, n_tiles, k_chunks); the k axis is minor-most so the output
tile is revisited across the contraction and stays resident in VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    x_ref,      # [bn, bk]
    xn2_ref,    # [1, bn]
    q_ref,      # [bm, bk]
    qn2_ref,    # [bm, 1]
    acc_ref,    # [bm, bn]
    tau_ref,    # [bm, 1]
    out_ref,    # [bm, bn]
    skip_ref,   # [1, 1] int32 per-tile skip marker
    *,
    nk: int,
    prune: bool,
    metric: str,
):
    k = pl.program_id(2)
    acc_in = acc_ref[...]
    alive = jnp.isfinite(acc_in)
    any_alive = jnp.any(alive)

    @pl.when(k == 0)
    def _init():
        # base = acc + per-block norms (L2) — constant over k chunks
        if metric == "l2":
            base = acc_in + qn2_ref[...] + xn2_ref[...]
        else:
            base = acc_in
        out_ref[...] = jnp.where(alive, base, jnp.inf)
        skip_ref[0, 0] = jnp.where(any_alive, 0, 1).astype(jnp.int32)

    @pl.when(any_alive)
    def _matmul():
        xf = x_ref[...].astype(jnp.float32)
        qf = q_ref[...].astype(jnp.float32)
        dot = jax.lax.dot_general(
            qf,
            xf,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        scale = 2.0 if metric == "l2" else 1.0
        out_ref[...] = out_ref[...] - scale * dot

    @pl.when(k == nk - 1)
    def _finalize():
        out = jnp.where(alive, out_ref[...], jnp.inf)
        if prune:
            out = jnp.where(out > tau_ref[...], jnp.inf, out)
        out_ref[...] = out


def _pad_to(a: jnp.ndarray, mult: Tuple[int, ...], value) -> jnp.ndarray:
    pads = []
    for dim, m in zip(a.shape, mult):
        rem = (-dim) % m
        pads.append((0, rem))
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads, constant_values=value)
    return a


@functools.partial(
    jax.jit,
    static_argnames=("prune", "metric", "tile_m", "tile_n", "tile_k", "interpret"),
)
def partial_distance_update(
    x: jnp.ndarray,       # [N, Db]
    xn2: jnp.ndarray,     # [N]
    q: jnp.ndarray,       # [M, Db]
    qn2: jnp.ndarray,     # [M]
    acc: jnp.ndarray,     # [M, N] f32, +inf = pruned
    tau: jnp.ndarray,     # [M]
    *,
    prune: bool = True,
    metric: str = "l2",
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (acc' [M, N] f32, tile_skipped [m_tiles, n_tiles] int32)."""
    m, n, d = q.shape[0], x.shape[0], x.shape[1]
    # Pad to tile multiples. Padded candidate rows get acc=+inf (excluded);
    # padded query rows get tau=-inf so everything in them prunes away.
    xp = _pad_to(x, (tile_n, tile_k), 0)
    qp = _pad_to(q, (tile_m, tile_k), 0)
    xn2p = _pad_to(xn2.reshape(1, -1), (1, tile_n), 0)
    qn2p = _pad_to(qn2.reshape(-1, 1), (tile_m, 1), 0)
    taup = _pad_to(tau.reshape(-1, 1), (tile_m, 1), jnp.float32(-jnp.inf))
    accp = jnp.pad(
        acc,
        ((0, (-m) % tile_m), (0, (-n) % tile_n)),
        constant_values=jnp.float32(jnp.inf),
    )
    mp, np_ = accp.shape
    dp = xp.shape[1]
    nm, nn, nk = mp // tile_m, np_ // tile_n, dp // tile_k

    out, skip = pl.pallas_call(
        functools.partial(_kernel, nk=nk, prune=prune, metric=metric),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((tile_n, tile_k), lambda i, j, k: (j, k)),   # x
            pl.BlockSpec((1, tile_n), lambda i, j, k: (0, j)),        # xn2
            pl.BlockSpec((tile_m, tile_k), lambda i, j, k: (i, k)),   # q
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, 0)),        # qn2
            pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),   # acc
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, 0)),        # tau
        ],
        out_specs=[
            pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),   # out
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),             # skip map
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((nm, nn), jnp.int32),
        ],
        interpret=interpret,
    )(xp, xn2p, qp, qn2p, accp, taup)
    return out[:m, :n], skip
