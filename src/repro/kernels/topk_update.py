"""Pallas TPU kernel: fused running-top-K update.

The second hot op of the ANNS inner loop (after distance scoring): merge a
chunk of candidate scores into the per-query running top-K. The jnp path
concatenates [K + chunk] and re-sorts per chunk — O((K+C)·log) with an HBM
round-trip of the running state. This kernel keeps the running (scores,
ids) tile in VMEM and performs K passes of masked min-extraction over the
chunk — O(K·C) vector work, no HBM churn, exact.

Grid: one program per query tile; the chunk axis stays resident. For the
K ≤ 16, C ≤ 64k regime of the serving engine, K·C vector ops beat the
sort-based merge and, more importantly, remove the [QG, K+C] concatenate
buffer entirely. Oracle: ``ref.running_topk_ref``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, ids_ref, run_s_ref, run_i_ref, out_s_ref, out_i_ref,
            *, k: int):
    """scores [bm, C] f32 (+inf = invalid), ids [bm, C] i32,
    run_s/run_i [bm, K] (ascending). Outputs the merged top-K."""
    cand_s = scores_ref[...]
    cand_i = ids_ref[...]
    run_s = run_s_ref[...]
    run_i = run_i_ref[...]

    # K passes: extract the global min among (remaining run slot, remaining
    # candidates). run is sorted ascending, so its "cursor" is an index.
    bm = cand_s.shape[0]
    rows = jnp.arange(bm)

    def body(state, _):
        out_s, out_i, slot, cursor, cand_s, run_taken = state
        # current head of the running list per row
        head_s = jnp.take_along_axis(run_s, cursor[:, None], axis=1)[:, 0]
        head_i = jnp.take_along_axis(run_i, cursor[:, None], axis=1)[:, 0]
        # best remaining candidate per row
        cmin = jnp.min(cand_s, axis=1)
        carg = jnp.argmin(cand_s, axis=1).astype(jnp.int32)
        cid = jnp.take_along_axis(cand_i, carg[:, None], axis=1)[:, 0]
        take_run = head_s <= cmin
        sel_s = jnp.where(take_run, head_s, cmin)
        sel_i = jnp.where(take_run, head_i, cid)
        out_s = out_s.at[:, slot].set(sel_s)
        out_i = out_i.at[:, slot].set(sel_i)
        cursor = jnp.where(take_run, cursor + 1, cursor)
        # knock out the taken candidate
        knock = (~take_run)[:, None] & (
            jnp.arange(cand_s.shape[1])[None, :] == carg[:, None]
        )
        cand_s = jnp.where(knock, jnp.inf, cand_s)
        return (out_s, out_i, slot + 1, cursor, cand_s, run_taken), None

    out_s0 = jnp.full(run_s.shape, jnp.inf, jnp.float32)
    out_i0 = jnp.full(run_i.shape, -1, jnp.int32)
    cursor0 = jnp.zeros((bm,), jnp.int32)
    state = (out_s0, out_i0, 0, cursor0, cand_s, None)
    for _ in range(k):                      # static K unroll
        state, _ = body(state, None)
    out_s_ref[...] = state[0]
    out_i_ref[...] = state[1]


@functools.partial(
    jax.jit, static_argnames=("k", "tile_m", "interpret")
)
def running_topk_update(
    scores: jnp.ndarray,      # [M, C] f32, +inf = invalid
    ids: jnp.ndarray,         # [M, C] i32
    run_s: jnp.ndarray,       # [M, K] f32 ascending
    run_i: jnp.ndarray,       # [M, K] i32
    *,
    k: int,
    tile_m: int = 8,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m, c = scores.shape
    mp = -(-m // tile_m) * tile_m
    pad = ((0, mp - m), (0, 0))
    scores_p = jnp.pad(scores, pad, constant_values=jnp.inf)
    ids_p = jnp.pad(ids, pad, constant_values=-1)
    run_s_p = jnp.pad(run_s, pad, constant_values=jnp.inf)
    run_i_p = jnp.pad(run_i, pad, constant_values=-1)

    out_s, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(mp // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, c), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, c), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.float32),
            jax.ShapeDtypeStruct((mp, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores_p, ids_p, run_s_p, run_i_p)
    return out_s[:m], out_i[:m]
