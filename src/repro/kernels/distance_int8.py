"""Pallas TPU kernel: int8 partial-distance accumulate with int32 MXU
accumulation — the quantized stage-1 of the two-stage search path.

Corpus and query share one affine quantization grid per dimension block
(scale s_b, zero-point z_b), so the zero-points cancel in the difference:

    d̂²_b(p, q) = s_b² · Σ_j (P_j − Q_j)²
               = s_b²·ΣQ² − 2·s_b²·(Q·P) + s_b²·ΣP²

The norm inputs (``xn2``/``qn2``) carry the already-dequantized s²·Σcode²
terms in f32; only the Q·P term runs on the MXU, as a pure int8×int8
matmul with ``preferred_element_type=jnp.int32`` (no f32 casts of the
operands — the 4× narrower codes are what the MXU reads from VMEM).

Everything else mirrors ``distance.py``: same grid (m_tiles, n_tiles,
k_chunks) with k minor-most, same +inf/−inf padding conventions, same
tile-granular ``pl.when`` early-stop with a per-tile skip map. L2 only —
the quantized difference form has no inner-product analogue here.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    x_ref,      # [bn, bk] int8 corpus codes
    xn2_ref,    # [1, bn]  f32, s²·ΣP² for this dimension block
    q_ref,      # [bm, bk] int8 query codes
    qn2_ref,    # [bm, 1]  f32, s²·ΣQ² for this dimension block
    s2_ref,     # [1, 1]   f32, s² shared by corpus and query
    acc_ref,    # [bm, bn]
    tau_ref,    # [bm, 1]
    out_ref,    # [bm, bn]
    skip_ref,   # [1, 1] int32 per-tile skip marker
    *,
    nk: int,
    prune: bool,
):
    k = pl.program_id(2)
    acc_in = acc_ref[...]
    alive = jnp.isfinite(acc_in)
    any_alive = jnp.any(alive)

    @pl.when(k == 0)
    def _init():
        base = acc_in + qn2_ref[...] + xn2_ref[...]
        out_ref[...] = jnp.where(alive, base, jnp.inf)
        skip_ref[0, 0] = jnp.where(any_alive, 0, 1).astype(jnp.int32)

    @pl.when(any_alive)
    def _matmul():
        dot = jax.lax.dot_general(
            q_ref[...],
            x_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        out_ref[...] = out_ref[...] - (2.0 * s2_ref[0, 0]) * dot.astype(
            jnp.float32
        )

    @pl.when(k == nk - 1)
    def _finalize():
        out = jnp.where(alive, out_ref[...], jnp.inf)
        if prune:
            out = jnp.where(out > tau_ref[...], jnp.inf, out)
        out_ref[...] = out


def _pad_to(a: jnp.ndarray, mult: Tuple[int, ...], value) -> jnp.ndarray:
    pads = []
    for dim, m in zip(a.shape, mult):
        rem = (-dim) % m
        pads.append((0, rem))
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads, constant_values=value)
    return a


@functools.partial(
    jax.jit,
    static_argnames=("prune", "tile_m", "tile_n", "tile_k", "interpret"),
)
def int8_partial_distance_update(
    x: jnp.ndarray,       # [N, Db] int8 codes
    xn2: jnp.ndarray,     # [N] f32, s²·ΣP²
    q: jnp.ndarray,       # [M, Db] int8 codes
    qn2: jnp.ndarray,     # [M] f32, s²·ΣQ²
    scale2: jnp.ndarray,  # scalar f32, shared s² of this dimension block
    acc: jnp.ndarray,     # [M, N] f32, +inf = pruned
    tau: jnp.ndarray,     # [M]
    *,
    prune: bool = True,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (acc' [M, N] f32, tile_skipped [m_tiles, n_tiles] int32).

    Code pad value is 0 on both operands, so padded contraction dims add
    (0−0)² = 0 exactly; padded rows/queries follow the fp32 kernel's
    +inf/−inf conventions.

    >>> import jax.numpy as jnp
    >>> x8 = jnp.array([[3, -2]], jnp.int8)    # one candidate row's codes
    >>> q8 = jnp.array([[1, 2]], jnp.int8)     # one query's codes
    >>> s2 = jnp.float32(0.25)                 # shared grid, s = 0.5
    >>> xn2 = s2 * jnp.array([13.0]); qn2 = s2 * jnp.array([5.0])
    >>> acc = jnp.zeros((1, 1), jnp.float32); tau = jnp.array([jnp.inf])
    >>> out, _ = int8_partial_distance_update(
    ...     x8, xn2, q8, qn2, s2, acc, tau,
    ...     tile_m=8, tile_n=8, tile_k=8, interpret=True)
    >>> float(out[0, 0])   # 0.25 * ((3-1)² + (-2-2)²)
    5.0
    """
    m, n = q.shape[0], x.shape[0]
    xp = _pad_to(x, (tile_n, tile_k), 0)
    qp = _pad_to(q, (tile_m, tile_k), 0)
    xn2p = _pad_to(xn2.reshape(1, -1), (1, tile_n), 0)
    qn2p = _pad_to(qn2.reshape(-1, 1), (tile_m, 1), 0)
    taup = _pad_to(tau.reshape(-1, 1), (tile_m, 1), jnp.float32(-jnp.inf))
    accp = jnp.pad(
        acc,
        ((0, (-m) % tile_m), (0, (-n) % tile_n)),
        constant_values=jnp.float32(jnp.inf),
    )
    s2p = jnp.asarray(scale2, jnp.float32).reshape(1, 1)
    mp, np_ = accp.shape
    dp = xp.shape[1]
    nm, nn, nk = mp // tile_m, np_ // tile_n, dp // tile_k

    out, skip = pl.pallas_call(
        functools.partial(_kernel, nk=nk, prune=prune),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((tile_n, tile_k), lambda i, j, k: (j, k)),   # x codes
            pl.BlockSpec((1, tile_n), lambda i, j, k: (0, j)),        # xn2
            pl.BlockSpec((tile_m, tile_k), lambda i, j, k: (i, k)),   # q codes
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, 0)),        # qn2
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),             # s²
            pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),   # acc
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, 0)),        # tau
        ],
        out_specs=[
            pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),   # out
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),             # skip map
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((nm, nn), jnp.int32),
        ],
        interpret=interpret,
    )(xp, xn2p, qp, qn2p, s2p, accp, taup)
    return out[:m, :n], skip
