"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match (asserted by the
shape/dtype sweep in tests/kernels/). All math in f32 accumulation.
"""

from __future__ import annotations

import jax.numpy as jnp


def partial_distance_update_ref(
    x: jnp.ndarray,       # [N, Db]  candidate rows, this dimension block
    xn2: jnp.ndarray,     # [N]      per-row squared norm of this block
    q: jnp.ndarray,       # [M, Db]  query rows, this dimension block
    qn2: jnp.ndarray,     # [M]      per-query squared norm of this block
    acc: jnp.ndarray,     # [M, N]   running partial distances; +inf = pruned
    tau: jnp.ndarray,     # [M]      per-query pruning threshold
    *,
    prune: bool = True,
    metric: str = "l2",
) -> jnp.ndarray:
    """acc' = acc + d_b²  (or −partial dot), then prune acc' > τ → +inf.

    +inf entries stay +inf (pruned pairs never resurrect); pruning keeps
    exactly the entries ≤ τ (monotone partial sums make this exact).
    """
    xf = x.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    if metric == "l2":
        part = (
            qn2.astype(jnp.float32)[:, None]
            - 2.0 * (qf @ xf.T)
            + xn2.astype(jnp.float32)[None, :]
        )
    elif metric == "ip":
        part = -(qf @ xf.T)
    else:
        raise ValueError(metric)
    out = acc.astype(jnp.float32) + part
    out = jnp.where(jnp.isfinite(acc), out, jnp.inf)
    if prune:
        out = jnp.where(out > tau.astype(jnp.float32)[:, None], jnp.inf, out)
    return out


def int8_partial_distance_update_ref(
    x: jnp.ndarray,       # [N, Db]  int8 corpus codes, this dimension block
    xn2: jnp.ndarray,     # [N]      f32, s²·Σcode² of this block
    q: jnp.ndarray,       # [M, Db]  int8 query codes (same grid as corpus)
    qn2: jnp.ndarray,     # [M]      f32, s²·Σcode² of this block
    scale2: jnp.ndarray,  # scalar f32, shared s² of this block
    acc: jnp.ndarray,     # [M, N]   running partial distances; +inf = pruned
    tau: jnp.ndarray,     # [M]      per-query pruning threshold
    *,
    prune: bool = True,
) -> jnp.ndarray:
    """Quantized-L2 analogue of ``partial_distance_update_ref``.

    The Q·P contraction accumulates in int32 (codes are ≤127 in magnitude,
    so int32 is exact for any realistic block width); everything else is
    f32. Zero-points cancel because corpus and query share the grid.
    """
    dot = jnp.matmul(
        q.astype(jnp.int32), x.astype(jnp.int32).T
    )
    part = (
        qn2.astype(jnp.float32)[:, None]
        - 2.0 * jnp.asarray(scale2, jnp.float32) * dot.astype(jnp.float32)
        + xn2.astype(jnp.float32)[None, :]
    )
    out = acc.astype(jnp.float32) + part
    out = jnp.where(jnp.isfinite(acc), out, jnp.inf)
    if prune:
        out = jnp.where(out > tau.astype(jnp.float32)[:, None], jnp.inf, out)
    return out


def masked_topk_ref(scores: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Ascending top-k of finite scores per row; +inf/invalid → (-1, +inf).

    scores [M, N] float32 (smaller = better), ids [M, N] int32/int64.
    Returns (top_scores [M, k], top_ids [M, k]).
    """
    import jax

    neg, idx = jax.lax.top_k(-scores, k)          # max-k of negated = min-k
    top_scores = -neg
    top_ids = jnp.take_along_axis(ids, idx, axis=1)
    top_ids = jnp.where(jnp.isfinite(top_scores), top_ids, -1)
    return top_scores, top_ids


def running_topk_ref(scores, ids, run_s, run_i, k: int):
    """Merge candidate (scores, ids) into the running ascending top-K.
    scores [M,C] (+inf invalid), run_s/run_i [M,K]. Returns (s', i')."""
    import jax

    import jax.numpy as jnp

    cat_s = jnp.concatenate([run_s, scores], axis=1)
    cat_i = jnp.concatenate([run_i, ids], axis=1)
    neg, pos = jax.lax.top_k(-cat_s, k)
    out_s = -neg
    out_i = jnp.take_along_axis(cat_i, pos, axis=1)
    out_i = jnp.where(jnp.isfinite(out_s), out_i, -1)
    return out_s, out_i
