"""Quickstart: build a HARMONY index, let the cost model pick a partition
plan, run a distributed search, and check recall + pruning stats.

    PYTHONPATH=src python examples/quickstart.py

Set HARMONY_BENCH_TINY=1 to run at CI-smoke sizes (seconds, same code
paths — the examples job uses it so examples can't rot).
"""

import os

import numpy as np

from repro.config import HarmonyConfig
from repro.core import build_ivf, harmony_search, plan_search, preassign
from repro.data import brute_force_topk, make_dataset, make_queries, recall_at_k

TINY = os.environ.get("HARMONY_BENCH_TINY", "") not in ("", "0")


def main():
    # 1. corpus + config
    nb, nlist, nq = (4000, 32, 32) if TINY else (20_000, 128, 128)
    ds = make_dataset(nb=nb, dim=128, n_components=48, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=128, nlist=nlist, nprobe=16, topk=10)
    print(f"corpus: {ds.nb} × {ds.dim}")

    # 2. index build (Train + Add)
    index = build_ivf(ds.x, cfg)
    print(f"built IVF: nlist={index.nlist}  "
          f"train={index.build_times['train']:.2f}s add={index.build_times['add']:.3f}s")

    # 3. the cost model picks the partition plan for an 8-node cluster
    decision = plan_search(index, n_nodes=8, cfg=cfg)
    plan = decision.plan
    print(f"plan: V×B = {plan.v_shards}×{plan.d_blocks}  "
          f"(cost ranking: {decision.candidates})")

    # 4. pre-assign (distribute clusters onto the grid)
    corpus = preassign(index, plan)

    # 5. search
    q = make_queries(ds, nq=nq, skew=0.3, noise=0.2, seed=1)
    res = harmony_search(index, corpus, q)

    # 6. verify
    true_idx, _ = brute_force_topk(ds.x, q, cfg.topk)
    rec = recall_at_k(res.ids, true_idx)
    st = res.stats
    print(f"recall@10 = {rec:.3f}")
    print(f"pruning per slice: {np.round(st['slice_pruned_ratio'], 3)}")
    print(f"flops saved by pruning: {1 - st['pair_flops'] / st['dense_flops']:.1%}")
    print(f"per-shard load (pair-flops): {st['shard_pair_flops']}")
    assert rec > 0.9
    print("OK")


if __name__ == "__main__":
    main()
