"""Train a small LM end-to-end with the framework's training substrate
(deterministic sharded data pipeline, AdamW, checkpoint/restore). The
paper's kind is a serving system — serve_anns.py is the primary e2e
driver — but the training path is exercised here too.

    PYTHONPATH=src python examples/train_lm.py [--steps 40] [--d-model 192]

With --d-model 640 --layers 10 --vocab 50304 this is a ~100M-param model
(too slow for this 1-core container; the default is a quick CPU demo).
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.config import ModelConfig
from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.models import init_params
from repro.train import OptConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=192)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="demo-lm", family="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 2), num_kv_heads=max(args.d_model // 64, 2),
        d_ff=args.d_model * 4, vocab_size=args.vocab, remat=False,
    )
    n_params = sum(np.prod(p.shape) for p in
                   jax.tree.leaves(init_params(cfg, jax.random.PRNGKey(0))))
    print(f"model: {n_params/1e6:.1f}M params")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_write=True)
        params, opt, hist = train_loop(
            cfg, params, pipe, steps=args.steps,
            ocfg=OptConfig(lr=3e-3), checkpointer=ck, ckpt_every=20,
        )
        ck.wait()
        print(f"checkpoints on disk: {ck.all_steps()}")
    first, last = np.mean(hist[:5]), np.mean(hist[-5:])
    print(f"loss: {first:.3f} → {last:.3f}")
    assert last < first - 0.1, "loss did not drop"
    print("OK")


if __name__ == "__main__":
    main()
