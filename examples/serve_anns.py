"""End-to-end serving driver (the paper's kind): a skew-drifting Poisson
request trace through the admission-controlled serving scheduler, with
skew-triggered re-planning, a node failure mid-stream (elastic re-plan),
straggler-hedged batch dispatch, and full queue/latency accounting.

    PYTHONPATH=src python examples/serve_anns.py

Set HARMONY_BENCH_TINY=1 to run at CI-smoke sizes (seconds, same code
paths — the examples job uses it so examples can't rot).
"""

import os

import numpy as np

from repro.config import HarmonyConfig
from repro.core import NumRange, SearchRequest, TagIn, build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import HarmonyServer, SchedulerConfig, ServingScheduler

TINY = os.environ.get("HARMONY_BENCH_TINY", "") not in ("", "0")


def request_trace(ds, n_req=1024, rate_qps=4000.0, seed=0):
    """Poisson arrivals whose workload drifts from uniform to skewed
    mid-stream (forces the scheduler's hot-mass drift trigger)."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_req))
    half = n_req // 2
    qu = make_queries(ds, nq=half, skew=0.0, noise=0.2, seed=seed + 1)
    qh = make_queries(ds, nq=n_req - half, skew=0.85, hot_fraction=0.04,
                      noise=0.2, seed=seed + 2)
    q = np.concatenate([qu, qh])
    trace = [(float(t[i]), SearchRequest(vector=q[i])) for i in range(n_req)]
    return trace, q


def main():
    nb, nlist, n_req = (4000, 32, 192) if TINY else (20_000, 128, 1024)
    ds = make_dataset(nb=nb, dim=128, n_components=48, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=128, nlist=nlist, nprobe=16, topk=10)
    # every row carries metadata: an int tag and a float attribute, the
    # columns the filtered demo below predicates on
    mrng = np.random.default_rng(42)
    index = build_ivf(ds.x, cfg, meta={
        "color": mrng.integers(0, 8, size=nb),
        "price": mrng.uniform(0.0, 1.0, size=nb),
    })
    srv = HarmonyServer(index, n_nodes=8)
    print(f"serving with plan V×B = {srv.plan.v_shards}×{srv.plan.d_blocks}")

    trace, q = request_trace(ds, n_req=n_req)
    kill_at = 2 if TINY else 12

    def mid_stream(batch_idx, sched):
        if batch_idx == kill_at:
            print("!! killing node 3 mid-serve")
            sched.server.fail_node(3)
            print(f"   re-planned: V×B = {srv.plan.v_shards}×{srv.plan.d_blocks} "
                  f"on {srv.cluster.n_live} live nodes")

    # node 2 straggles; the 10ms hedge deadline re-issues its batches
    straggle = lambda w, t: 1.0 if w == 2 else 1e-4
    sched = ServingScheduler(
        srv,
        SchedulerConfig(
            max_batch=cfg.query_block,
            max_wait_s=2e-3,
            queue_capacity=16 * cfg.query_block,
            replan_drift=0.2,
            min_batches_between_replans=2,
            hedge_deadline_s=0.01,
        ),
        latency_fn=straggle,
        on_batch=mid_stream,
    )
    results = sched.run_trace(trace)

    # spot-check exactness on the served requests
    served = [r.req_id for r in results]
    oracle = search_oracle(index, q[served])
    scores = np.stack([r.scores for r in results])
    assert np.allclose(scores, oracle.scores, rtol=1e-3, atol=1e-3)
    print(f"   {len(results)} results verified against oracle")

    s = srv.stats
    print(f"served {s.queries} queries in {s.batches} batches "
          f"(full={s.full_batches} deadline={s.deadline_batches}) | "
          f"QPS(replay)={sched.served_qps:.0f} | "
          f"queue-wait p50={s.queue_wait_pct(50):.1f}ms "
          f"p99={s.queue_wait_pct(99):.1f}ms | shed={s.shed} | "
          f"replans={s.replans} (skew-triggered={s.skew_replans})")
    print(f"hedging: dispatched={sched._hedge.stats.dispatched} "
          f"hedged={sched._hedge.stats.hedged} "
          f"wasted={sched._hedge.stats.wasted}")

    # --- filtered serving: the same engine, with a per-request metadata
    # predicate pushed down into the scan (fully-excluded clusters are
    # never probed; surviving rows are masked like tombstones)
    from repro.core import filter_bitmap

    flt = TagIn("color", (1, 2, 3)) & NumRange("price", 0.25, 0.75)
    fq = q[:32]
    fres = srv.search_batch(SearchRequest(vector=fq, k=cfg.topk, filter=flt))
    # exact invariant: every returned id satisfies the predicate
    allowed = filter_bitmap(index, flt)
    allowed_ext = set(index.ids[allowed].tolist())
    got = fres.ids[fres.ids >= 0]
    assert all(int(i) in allowed_ext for i in got.ravel())
    # quality: recall vs the full-coverage filtered ground truth
    truth = search_oracle(index, fq, k=cfg.topk, nprobe=cfg.nlist, flt=flt)
    hits = sum(
        len(set(fres.ids[i][fres.ids[i] >= 0])
            & set(truth.ids[i][truth.ids[i] >= 0]))
        for i in range(len(fq))
    )
    denom = max(int((truth.ids >= 0).sum()), 1)
    print(f"filtered: selectivity={allowed.mean():.2f} | "
          f"{len(got)} hits all satisfy the predicate | "
          f"recall@{cfg.topk}={hits / denom:.3f} at nprobe={cfg.nprobe}")

    # --- scale OUT: the same trace through a 4-replica fleet (one
    # half-speed replica) with load-estimate p2c routing and
    # cross-replica hedging behind the same admission queue
    from repro.serve import ReplicaFleet, ReplicaSpec

    fleet = ReplicaFleet(
        index,
        replicas=[ReplicaSpec(n_nodes=8, capacity=c)
                  for c in (1.0, 1.0, 1.0, 0.5)],
        cfg=cfg,
        seed=0,
    )
    fsched = ServingScheduler(
        fleet,
        SchedulerConfig(max_batch=cfg.query_block, max_wait_s=2e-3,
                        hedge_deadline_s=0.05),
    )
    fresults = fsched.run_trace(trace)
    fs = fleet.summary()
    assert len(fresults) == len(trace)
    print(f"fleet: {fs['n_replicas']} replicas | "
          f"QPS(replay)={fsched.served_qps:.0f} | "
          f"per-replica batches="
          f"{'/'.join(str(r['batches']) for r in fs['replicas'])} | "
          f"load-balance gini={fs['load_balance_gini']:.3f} | "
          f"hedge win rate={fs['hedge']['win_rate']:.2f}")
    print("OK")


if __name__ == "__main__":
    main()
