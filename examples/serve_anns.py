"""End-to-end serving driver (the paper's kind): batched ANNS requests
through the HARMONY serving engine, with load-aware re-planning, a node
failure mid-run (elastic re-plan), and straggler-hedged dispatch stats.

    PYTHONPATH=src python examples/serve_anns.py
"""

import numpy as np

from repro.config import HarmonyConfig
from repro.core import build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.runtime import HedgingExecutor
from repro.serve import HarmonyServer


def request_stream(ds, n_batches=24, batch=64, seed=0):
    """Workload that drifts from uniform to skewed mid-stream (forces the
    load-aware planner to adapt)."""
    for i in range(n_batches):
        skew = 0.0 if i < n_batches // 2 else 0.85
        yield make_queries(ds, nq=batch, skew=skew, noise=0.2, seed=seed + i)


def main():
    ds = make_dataset(nb=20_000, dim=128, n_components=48, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=128, nlist=128, nprobe=16, topk=10)
    index = build_ivf(ds.x, cfg)
    srv = HarmonyServer(index, n_nodes=8, replan_every=6)

    print(f"serving with plan V×B = {srv.plan.v_shards}×{srv.plan.d_blocks}")
    for i, q in enumerate(request_stream(ds)):
        res = srv.search_batch(q)
        if i == 15:
            print("!! killing node 3 mid-serve")
            srv.fail_node(3)
            print(f"   re-planned: V×B = {srv.plan.v_shards}×{srv.plan.d_blocks} "
                  f"on {srv.cluster.n_live} live nodes")
        # spot-check exactness on a sample batch
        if i in (0, 20):
            oracle = search_oracle(index, q)
            assert np.allclose(res.scores, oracle.scores, rtol=1e-3, atol=1e-3)
            print(f"   batch {i}: results verified against oracle")

    s = srv.stats
    print(f"served {s.queries} queries in {s.batches} batches | "
          f"QPS(serial-measured)={s.qps:.0f} | p50={s.latency_pct(50):.1f}ms "
          f"p95={s.latency_pct(95):.1f}ms | replans={s.replans}")

    # straggler hedging demo: node 2 becomes slow; deadline re-issues work
    lat = lambda w, t: 1.0 if w == 2 else 1e-4
    ex = HedgingExecutor([lambda t: t] * srv.cluster.n_live, deadline_s=0.01,
                         latency_fn=lat)
    for t in range(20):
        ex.run(t, primary=t % srv.cluster.n_live,
               replica=(t + 1) % srv.cluster.n_live)
    print(f"hedging: dispatched={ex.stats.dispatched} hedged={ex.stats.hedged} "
          f"wasted={ex.stats.wasted}")
    print("OK")


if __name__ == "__main__":
    main()
