"""Live serving demo: real-clock traffic through the async front-end,
with a writer thread streaming upserts/deletes into the shared data plane.

Where ``serve_anns.py`` *replays* a trace on the virtual clock, this demo
serves for real: a 4-replica fleet (one half-speed replica, one wall-
clock straggler) behind :class:`repro.serve.frontend.ServingFrontend` —
requests submitted at Poisson arrival times on the wall clock, batches
formed by the size/deadline triggers, replicas overlapping on a thread
pool, stragglers hedged for real (first finisher wins), and an asyncio
client awaiting individual results. Meanwhile a **writer thread** streams
upserts (and deletes of its own keys) through ``frontend.upsert/delete``
into the fleet-shared :class:`repro.core.SegmentedIndex`, and a
background :class:`repro.serve.compactor.Compactor` seals the growing
delta buffer into new segments mid-traffic — the demo prints the
delta-buffer size and every compaction event. (The writer inserts far
from the query distribution and the compactor only *seals* — never
re-trains the original segment — so the oracle check on read results
stays exact.)

    PYTHONPATH=src python examples/serve_live.py

Set HARMONY_BENCH_TINY=1 to run at CI-smoke sizes (seconds, same code
paths — the examples job uses it so examples can't rot).
"""

import asyncio
import os
import threading
import time

import numpy as np

from repro.config import HarmonyConfig
from repro.core import SearchRequest, TagIn, build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import (
    CompactionConfig,
    Compactor,
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingFrontend,
)

TINY = os.environ.get("HARMONY_BENCH_TINY", "") not in ("", "0")


def main():
    nb, nlist, n_req = (2000, 16, 128) if TINY else (8000, 64, 512)
    dim = 32
    ds = make_dataset(nb=nb, dim=dim, n_components=12, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=dim, nlist=nlist, nprobe=8, topk=10)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=n_req, skew=0.4, noise=0.2, seed=1)

    # calibrate the wall service model from one measured batch so the
    # sleeps (not host compute contention) dominate at any corpus size:
    # full-speed replicas serve ~5x the measured per-query wall, the
    # half-speed one 2x that, and replica 3 stalls 250ms per batch — the
    # hedge's prey
    from repro.serve import HarmonyServer

    probe_srv = HarmonyServer(index, n_nodes=4)
    qb = q[:16]
    probe_srv.search_batch(qb, cfg.topk)            # warm caches
    t0 = time.perf_counter()
    probe_srv.search_batch(qb, cfg.topk)
    per_q = max(5.0 * (time.perf_counter() - t0) / len(qb), 1e-3)

    def service(r, n):
        if r == 3:
            return 0.25
        return n * per_q / caps[r]

    caps = [1.0, 1.0, 0.5, 1.0]
    fleet = ReplicaFleet(
        index,
        replicas=[ReplicaSpec(capacity=c) for c in caps],
        cfg=cfg,
        service_time_fn=service,
        seed=0,
    )
    sched_cfg = SchedulerConfig(
        max_batch=16,
        max_wait_s=2e-3,
        queue_capacity=8 * 16,
        hedge_deadline_s=0.05,
    )

    # open-loop Poisson arrivals saturating one full-speed replica
    # (rate = its entire capacity): alone it would shed, the fleet absorbs it
    rate_qps = 1.0 / per_q
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_req))

    # background compactor over the fleet-shared data plane: seal-only
    # policy (huge max_segments) — the original segment is never
    # re-trained, so the oracle check below stays exact
    write_batch = 16
    compactor = Compactor(
        fleet.data, fleet,
        CompactionConfig(delta_threshold=4 * write_batch,
                         max_segments=10_000, poll_s=0.01),
    )

    stop_writer = threading.Event()
    writer_log = {"upserts": 0, "deletes": 0}

    def writer(fe):
        """Stream upserts/deletes while clients query: fresh keys far from
        the query distribution (they never perturb read results), with a
        trailing delete of every 4th key."""
        wrng = np.random.default_rng(7)
        next_id = 1_000_000
        while not stop_writer.is_set():
            ids = np.arange(next_id, next_id + write_batch)
            vecs = (50.0 + wrng.standard_normal((write_batch, dim))
                    ).astype(np.float32)
            # tag the writer's rows so a filtered query can isolate them
            fe.upsert(ids, vecs, meta={"source": [7] * write_batch})
            writer_log["upserts"] += write_batch
            writer_log["deletes"] += fe.delete(ids[::4])
            next_id += write_batch
            stop_writer.wait(0.02)

    print(f"live serving: {len(caps)} replicas, offered {rate_qps:.0f} q/s, "
          f"{n_req} requests on the wall clock + writer thread")
    t0 = time.monotonic()
    with compactor, ServingFrontend(fleet, sched_cfg, k=cfg.topk) as fe:
        wt = threading.Thread(target=writer, args=(fe,), daemon=True)
        wt.start()
        futs = []
        for i in range(n_req):
            # absolute-time pacing: open-loop arrivals don't drift when a
            # sleep overshoots or the submitter contends with workers
            dt = t0 + arrivals[i] - time.monotonic()
            if dt > 0:
                time.sleep(dt)
            futs.append(fe.submit(SearchRequest(vector=q[i])))
        fe.drain(timeout=120.0)
        stop_writer.set()
        wt.join(timeout=10.0)

        # a filtered query through the same live front-end: the predicate
        # restricts the scan to the writer's tagged rows, so only
        # streamed-in ids can come back
        fres = fe.submit(SearchRequest(
            vector=np.full(dim, 50.0, np.float32), k=5,
            filter=TagIn("source", (7,)),
        )).result(timeout=30.0)
        fhits = fres.ids[fres.ids >= 0]
        assert len(fhits) > 0 and (fhits >= 1_000_000).all()
        print(f"   filtered query returned {len(fhits)} writer-tagged ids")

        # an asyncio client rides the same front-end
        async def aclient():
            outs = await asyncio.gather(
                *(fe.asubmit(SearchRequest(vector=q[i])) for i in range(8))
            )
            return [o.req_id for o in outs]

        async_ids = asyncio.run(aclient())
    wall = time.monotonic() - t0

    served = [f.result() for f in futs if f.exception() is None]
    shed = len(futs) - len(served)
    oracle = search_oracle(index, q[[r.req_id for r in served]], k=cfg.topk)
    got = np.stack([r.scores for r in served])
    assert np.allclose(got, oracle.scores, rtol=1e-3, atol=1e-3)
    print(f"   {len(served)} results verified against oracle "
          f"({shed} shed by backpressure), asyncio client got "
          f"{len(async_ids)} more")

    s = fe.summary()
    print(f"wall {wall:.2f}s | served QPS {s['served_qps']:.0f} | "
          f"p50 latency {s['p50_request_latency_ms']:.1f}ms "
          f"p99 {s['p99_request_latency_ms']:.1f}ms | "
          f"batches full={s['full_batches']} deadline={s['deadline_batches']} "
          f"capacity={s['capacity_batches']}")
    fs = fleet.summary()
    hedge = fs["hedge"]
    print(f"fleet: per-replica batches="
          f"{'/'.join(str(r['batches']) for r in fs['replicas'])} | "
          f"busy Gini={fs['load_balance_gini']:.3f} | "
          f"hedged={hedge['hedged']} (wins={hedge['hedge_wins']}, "
          f"win rate {hedge['win_rate']:.2f})")
    assert hedge["hedged"] >= 1, "straggling replica 3 should trip the hedge"

    data = fleet.data
    print(f"data plane: {writer_log['upserts']} upserts / "
          f"{writer_log['deletes']} deletes streamed | "
          f"generation {data.generation} | {data.n_segments} segments | "
          f"delta buffer {data.delta_len} rows | live {data.nb_live}")
    for e in compactor.events:
        print(f"   compaction[{e['reason']}] → gen {e['generation']}: "
              f"sealed {e['sealed_rows']} rows into "
              f"{e['new_segments']} segment(s) in {e['wall_s'] * 1e3:.0f}ms")
    assert writer_log["upserts"] > 0, "writer thread should have streamed"
    assert compactor.events, "the delta should have been sealed mid-traffic"
    print("OK")


if __name__ == "__main__":
    main()
