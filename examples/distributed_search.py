"""Distributed HARMONY search on a multi-device mesh (SPMD ring pipeline).

Runs the TPU-target shard_map engine on 8 host devices (data=4 × model=2),
validates exactness against the single-node oracle, and prints tile-skip
(pruning) statistics. This is both a runnable example and the target of
tests/test_pipeline_spmd.py.

Usage:  python examples/distributed_search.py [--pallas] [--int8]

``--int8`` runs the two-stage path: the ring scores scalar-quantized
int8 codes (stage 1 keeps K' = k·rerank_factor candidates in the
quantized metric), then the exact fp32 re-rank reduces them to the
final top-K — still validated against the single-node fp32 oracle.
"""

# The device-count override must precede any jax import.
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import numpy as np
import jax

from repro.config import HarmonyConfig
from repro.core import (
    assign_queries,
    build_ivf,
    harmony_search,
    preassign,
    prewarm_tau,
    search_oracle,
)
from repro.core.pipeline import SpmdConfig, build_spmd_inputs, input_shardings, make_spmd_search
from repro.core.types import PartitionPlan
from repro.core.router import load_aware_assignment, ring_offsets
from repro.data import make_dataset, make_queries


TINY = os.environ.get("HARMONY_BENCH_TINY", "") not in ("", "0")


def main(use_pallas: bool = False, int8: bool = False) -> int:
    V, B = 4, 2
    mesh = jax.make_mesh((V, B), ("data", "model"))

    nb, nq = (2000, 16) if TINY else (4000, 32)
    ds = make_dataset(nb=nb, dim=64, n_components=16, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=64, nlist=32, nprobe=6, topk=5, kmeans_iters=6)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=nq, skew=0.2, noise=0.2, seed=1)

    plan = PartitionPlan(
        v_shards=V,
        d_blocks=B,
        cluster_to_shard=load_aware_assignment(index.sizes, None, V),
        ring_offsets=ring_offsets(V, B),
    )
    corpus = preassign(index, plan)

    chunk = 256
    cap = -(-corpus.cap // chunk) * chunk
    kp = cfg.topk * cfg.rerank_factor if int8 else cfg.topk
    scfg = SpmdConfig(
        v_shards=V, d_blocks=B, qb=32, cap=cap, dim=cfg.dim,
        nprobe=cfg.nprobe, k=kp, chunk=chunk, use_pallas=use_pallas,
        precision="int8" if int8 else "fp32",
        tile_m=64, tile_n=64, tile_k=32,
    )
    probes = assign_queries(index, q)
    # int8 stage 1 scores in the quantized metric — an fp32 τ seed is not
    # a valid bound there, so the travelling τ starts at +inf
    tau0 = (
        np.full((q.shape[0],), np.inf, np.float32) if int8
        else prewarm_tau(index, q, probes, cfg.topk, cfg.prewarm_samples)
    )
    arrays = build_spmd_inputs(index, corpus, q, scfg, probes, tau0)

    shardings = input_shardings(scfg, mesh)
    placed = {k: jax.device_put(v, shardings[k]) for k, v in arrays.items()}

    step = make_spmd_search(scfg, mesh)
    operands = [placed["x_blocks"], placed["xn2_blocks"],
                placed["cluster_ids"], placed["row_ids"]]
    if int8:
        operands.append(placed["scale2"])
    scores, ids, stats = step(
        *operands, placed["queries"], placed["probes"], placed["tau0"],
    )
    scores, ids, stats = map(np.asarray, (scores, ids, stats))
    scores, ids = scores[: q.shape[0]], ids[: q.shape[0]]  # drop qb padding

    if int8:
        # stage 2: exact fp32 re-rank of the K' quantized-metric survivors
        order = np.argsort(index.ids, kind="stable")
        sids = index.ids[order]
        valid = np.isfinite(scores) & (ids >= 0)
        rows = order[np.searchsorted(sids, np.where(valid, ids, sids[0]))]
        d = (
            np.sum(q * q, axis=1)[:, None]
            - 2.0 * np.einsum("md,mkd->mk", q, index.x[rows])
            + index.xnorm2[rows]
        ).astype(np.float32)
        d = np.where(valid, d, np.inf)
        sel = np.argpartition(d, kth=cfg.topk - 1, axis=1)[:, : cfg.topk]
        sc = np.take_along_axis(d, sel, axis=1)
        o = np.argsort(sc, axis=1, kind="stable")
        sel = np.take_along_axis(sel, o, axis=1)
        scores = np.take_along_axis(sc, o, axis=1)
        ids = np.take_along_axis(ids, sel, axis=1)
        ids[~np.isfinite(scores)] = -1

    oracle = search_oracle(index, q)
    ok = True
    finite = np.isfinite(oracle.scores)
    if not np.allclose(scores[finite], oracle.scores[finite], rtol=1e-3, atol=1e-3):
        print("SCORE MISMATCH", file=sys.stderr)
        ok = False
    # ids equal except across fp ties
    diff = ids.astype(np.int64) != oracle.ids
    if diff.any():
        rows = np.nonzero(diff.any(axis=1))[0]
        for r in rows:
            if set(ids[r].tolist()) != set(oracle.ids[r].tolist()) and not np.allclose(
                np.sort(scores[r]), np.sort(oracle.scores[r]), rtol=1e-3, atol=1e-3
            ):
                print(f"ID MISMATCH row {r}: {ids[r]} vs {oracle.ids[r]}", file=sys.stderr)
                ok = False

    skipped, total = int(stats[0]), int(stats[1])
    host = harmony_search(index, corpus, q)
    print(f"devices={len(jax.devices())} mesh=({V}x{B})")
    print(f"tile_skip={skipped}/{total} ({skipped / max(total,1):.1%})")
    print(f"host-engine slice pruning: {np.round(host.stats['slice_pruned_ratio'], 3)}")
    print("EXACTNESS_OK" if ok else "EXACTNESS_FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(use_pallas="--pallas" in sys.argv,
                  int8="--int8" in sys.argv))
