"""Fig. 7: query-skew stability. Claims: vector-mode QPS degrades heavily
(paper: −56% avg, down to 26%); dimension/harmony stay flat; harmony beats
pure dimension (paper: up to +91% at extreme skew)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, emit, query_set, run_mode
from repro.core import assign_queries
from repro.data import make_queries


def make_hot_queries(ds, skew, nq=256):
    """Skewed workloads concentrate on very few components (paper Fig. 7
    manipulates query sets until single nodes saturate)."""
    from benchmarks.common import TINY

    if TINY:
        nq = min(nq, 64)
    return make_queries(ds, nq=nq, skew=skew, hot_fraction=0.04, noise=0.2, seed=11)


MODES = (
    # (label, mode, load-aware?)  "vector" = the traditional baseline:
    # cluster-id round-robin, workload-oblivious — what the paper compares
    # against. harmony/dimension use the cost-model planner.
    ("harmony", "harmony", True),
    ("vector_traditional", "vector", False),
    ("vector_loadaware", "vector", True),
    ("dimension", "dimension", True),
)


def main():
    ds, cfg, index = corpus()
    print("# fig7: skew sweep, 4 nodes")
    base = {}
    for skew in (0.0, 0.5, 0.75, 0.9):
        q = make_hot_queries(ds, skew)
        probes = assign_queries(index, q)
        for label, mode, aware in MODES:
            res, qps, _ = run_mode(
                index, cfg, q, mode, 4,
                probes_sample=probes if aware else None,
                balanced=aware,
            )
            if skew == 0.0:
                base[label] = qps
            rel = qps / base[label]
            loads = np.asarray(res.stats["shard_pair_flops"], float)
            imb = loads.std() / max(loads.mean(), 1)
            emit(
                f"fig7.{label}.skew{skew}",
                1e6 / max(qps, 1e-9),
                f"qps={qps:.0f};rel_to_uniform={rel:.2f};load_imbalance={imb:.2f}",
            )
    # claim: at skew 0.9 harmony ≥ traditional vector, ≥ dimension
    q = make_hot_queries(ds, 0.9)
    probes = assign_queries(index, q)
    qh = run_mode(index, cfg, q, "harmony", 4, probes_sample=probes)[1]
    qv = run_mode(index, cfg, q, "vector", 4, balanced=False)[1]
    qd = run_mode(index, cfg, q, "dimension", 4, probes_sample=probes)[1]
    emit("fig7.claim.skew0.9", 0.0,
         f"harmony/vector_trad={qh/qv:.2f};harmony/dimension={qh/qd:.2f}")


if __name__ == "__main__":
    main()
