"""Host engine vs device-resident executor: batched serving throughput.

Measures the same batches through both ``HarmonyServer.search_batch``
backends — the staged numpy engine ("host") and the jit'd SPMD pipeline
with static-shape bucketing ("spmd") — across batch sizes and workload
skew, with executor compiles excluded via a per-bucket warmup pass. The
realized tile-level pruning saving comes from the kernel's skip map.

Emits the usual CSV rows and folds a JSON summary into
``benchmarks/serving_results.json`` (written earlier in the run by
``bench_serving``) so the perf trajectory is tracked across PRs:

    "executor": {
      "config":    {"chunk": int, "qb_buckets": [int, ...],
                    "use_pallas": bool},
      "sweep": [   one entry per (batch size, workload) cell
        {"qb": int, "workload": "uniform" | "skewed", "n_queries": int,
         "host_qps": float, "exec_qps": float, "speedup": float,
         "tile_skip_frac": float}
      ],
      "executor_stats": SpmdExecutor.stats_summary()   # buckets compiled,
                        # dispatch/compile counts, cumulative tile skips
      "claim_exec_ge_host_qb64_skewed": bool           # acceptance claim
    }
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.bench_skew import make_hot_queries
from benchmarks.common import TINY, corpus, emit
from repro.data import make_queries
from repro.serve import ExecutorConfig, HarmonyServer

QBS = (16, 64) if TINY else (16, 64, 128)
BATCHES_PER_CELL = 3
N_NODES = 4


def _time_backend(srv, batches, backend, reps=2):
    """Best-of-``reps`` wall (both backends are warmed by the caller, so
    this measures steady-state serving, not compiles or cold caches)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for b in batches:
            srv.search_batch(b, backend=backend)
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    ds, cfg, index = corpus()
    ex_cfg = ExecutorConfig(chunk=512, qb_buckets=QBS)
    srv = HarmonyServer(index, n_nodes=N_NODES, executor_cfg=ex_cfg)
    ex = srv.executor

    nq = max(QBS) * BATCHES_PER_CELL
    workloads = {
        "uniform": make_queries(ds, nq=nq, skew=0.0, noise=0.2, seed=31),
        "skewed": make_hot_queries(ds, skew=0.9, nq=nq),
    }

    print(f"# executor: host vs device-resident spmd backend, "
          f"{BATCHES_PER_CELL} batches/cell, buckets={list(ex.qb_buckets)}")
    sweep = []
    for qb in QBS:
        for name, q in workloads.items():
            batches = [q[i * qb : (i + 1) * qb] for i in range(BATCHES_PER_CELL)]
            srv.search_batch(batches[0], backend="spmd")   # warm the bucket
            srv.search_batch(batches[0], backend="host")   # warm host caches
            skipped0, total0 = ex.tile_skipped, ex.tile_total
            exec_s = _time_backend(srv, batches, "spmd")
            host_s = _time_backend(srv, batches, "host")
            host_qps = len(batches) * qb / max(host_s, 1e-9)
            exec_qps = len(batches) * qb / max(exec_s, 1e-9)
            skip_frac = (ex.tile_skipped - skipped0) / max(
                ex.tile_total - total0, 1
            )
            cell = {
                "qb": qb,
                "workload": name,
                "n_queries": len(batches) * qb,
                "host_qps": host_qps,
                "exec_qps": exec_qps,
                "speedup": exec_qps / max(host_qps, 1e-9),
                "tile_skip_frac": skip_frac,
            }
            sweep.append(cell)
            emit(
                f"executor.{name}.qb{qb}",
                1e6 / max(exec_qps, 1e-9),
                f"exec_qps={exec_qps:.0f};host_qps={host_qps:.0f};"
                f"speedup={cell['speedup']:.2f};tile_skip={skip_frac:.2f}",
            )

    ok = all(
        c["exec_qps"] >= c["host_qps"]
        for c in sweep
        if c["workload"] == "skewed" and c["qb"] >= 64
    )
    emit("executor.claim.exec_ge_host_qb64_skewed", 0.0, f"ok={ok}")

    report = {
        "config": {
            "chunk": ex_cfg.chunk,
            "qb_buckets": list(ex.qb_buckets),
            "use_pallas": ex_cfg.use_pallas,
        },
        "sweep": sweep,
        "executor_stats": ex.stats_summary(),
        "claim_exec_ge_host_qb64_skewed": bool(ok),
    }
    # fold into the serving results blob (bench_serving writes it earlier in
    # the run; create it if this bench runs standalone)
    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["executor"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))
    print(json.dumps({"executor": report}, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
