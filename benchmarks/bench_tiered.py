"""Tiered memory hierarchy: corpus scale per device byte vs QPS.

The paper's device-resident executor caps corpus size at device memory.
The tiered plane lifts that cap: cold sealed segments live host-side and
stream only their probed-cluster rows through the executor's
double-buffered upload path, while the hotness-driven placement policy
(:mod:`repro.serve.placement`) keeps the probe-heavy segments resident.

This bench replays a Zipfian segment-popularity trace (segment heat
falls off as rank^-1.5, the classic multi-tenant corpus shape) against
the same 4-segment corpus under device budgets of {100, 50, 25, 12.5}%
of the all-resident footprint, and measures:

* ``device_MB`` — actual HBM the placement packed (memory_report);
* ``recall@10`` — vs exact brute force. The host tier streams the same
  packed rows through the same kernels, so recall is *tier-invariant*;
  any drop would be a bug, not a tradeoff;
* ``qps`` — measured wall throughput of the executed batches, with the
  lookahead prefetch staging batch i+1's cold uploads while batch i
  computes (the scheduler's ``prefetch`` hook, driven inline here).

Acceptance claims (ISSUE 10):

* ≥ 4× corpus per device byte at < 2 recall@10 points lost (the 25%
  cell: ¼ the HBM, identical results);
* the 25%-budget cell keeps ≥ 60% of all-device QPS on this trace;
* ``prefetch_hits > 0`` — the double buffer actually gets hit.

Results fold into ``serving_results.json`` under ``"tiered"`` (schema in
``benchmarks/README.md``), plus the usual CSV rows.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import TINY, emit
from repro.config import HarmonyConfig
from repro.core import SegmentedIndex
from repro.data import brute_force_topk, make_dataset, recall_at_k
from repro.serve import HarmonyServer, PlacementConfig
from repro.serve.placement import (
    apply_placement,
    device_bytes_by_segment,
    plan_placement,
)

SEGMENTS = 4
PER_SEG = 800 if TINY else 6000
DIM = 64
BATCH = 16 if TINY else 32
N_BATCHES = 12 if TINY else 48
WARM_BATCHES = 6
FRACTIONS = (1.0, 0.5, 0.25, 0.125)
ZIPF_EXP = 1.5          # segment heat ~ rank^-1.5


def build_plane(cfg: HarmonyConfig):
    """4 equal sealed segments over one Gaussian-mixture corpus; external
    ids equal global row positions, so brute-force row indices are the
    ground-truth id space."""
    ds = make_dataset(nb=SEGMENTS * PER_SEG, dim=DIM, n_components=32,
                      spread=0.6, seed=17)
    x = ds.x.astype(np.float32)
    data = SegmentedIndex.build(x[:PER_SEG], cfg)
    for s in range(1, SEGMENTS):
        lo = s * PER_SEG
        data.upsert(np.arange(lo, lo + PER_SEG), x[lo: lo + PER_SEG])
        data.compact_inline()
    return x, data


def zipf_queries(x: np.ndarray, n: int, seed: int) -> np.ndarray:
    """Queries anchored on corpus rows with Zipfian *segment* popularity:
    segment s is hit with weight (s+1)^-ZIPF_EXP, so one segment carries
    most of the probe mass and the tail segments are cold."""
    rng = np.random.default_rng(seed)
    w = (1.0 + np.arange(SEGMENTS)) ** -ZIPF_EXP
    w /= w.sum()
    segs = rng.choice(SEGMENTS, size=n, p=w)
    rows = segs * PER_SEG + rng.integers(0, PER_SEG, size=n)
    noise = 0.15 * rng.standard_normal((n, x.shape[1])).astype(np.float32)
    return x[rows] + noise


def run_cell(srv, data, queries, gt, k):
    """Timed batch loop with inline lookahead: prefetch batch i+1's cold
    uploads, then execute batch i (exactly what the scheduler's
    ``prefetch`` hook does with the queued next batch)."""
    batches = [queries[i: i + BATCH]
               for i in range(0, len(queries), BATCH)]
    # untimed warm pass: compiles this placement's (qb, cap) buckets and
    # primes the prefetch pipeline so the timed loop measures steady state
    srv.prefetch_batch(batches[0])
    srv.search_batch(batches[0], k=k)
    st0 = srv.stats
    hits0, bytes0 = st0.prefetch_hits, st0.bytes_streamed
    ids = np.zeros((len(queries), k), np.int64)
    t0 = time.perf_counter()
    for i, qb in enumerate(batches):
        if i + 1 < len(batches):
            srv.prefetch_batch(batches[i + 1])
        res = srv.search_batch(qb, k=k)
        ids[i * BATCH: i * BATCH + len(qb)] = res.ids
    wall = time.perf_counter() - t0
    rep = data.memory_report()
    tiers = data.tiers()
    return {
        "device_bytes": rep["device_bytes"],
        "host_bytes": rep["host_bytes"],
        "host_segments": sum(1 for t in tiers.values() if t == "host"),
        "recall_at_10": recall_at_k(ids, gt),
        "qps": len(queries) / max(wall, 1e-9),
        "prefetch_hits": st0.prefetch_hits - hits0,
        "bytes_streamed": st0.bytes_streamed - bytes0,
    }


def main():
    cfg = HarmonyConfig(dim=DIM, nlist=32, nprobe=8, topk=10,
                        kmeans_iters=4 if TINY else 8)
    x, data = build_plane(cfg)
    queries = zipf_queries(x, N_BATCHES * BATCH, seed=23)
    gt, _ = brute_force_topk(x, queries, cfg.topk)
    srv = HarmonyServer(data, n_nodes=4, backend="spmd")
    srv.warmup_executors(k=cfg.topk)
    # feed the hotness EWMA before the first placement decision (the
    # compactor would have accrued this during normal serving)
    for i in range(WARM_BATCHES):
        srv.search_batch(queries[i * BATCH: (i + 1) * BATCH], k=cfg.topk)

    total = sum(device_bytes_by_segment(data).values())
    print(f"# tiered: {SEGMENTS}×{PER_SEG} rows, Zipf({ZIPF_EXP}) segment "
          f"trace, all-device footprint {total / 2**20:.1f} MB")
    report = {
        "segments": SEGMENTS,
        "rows_per_segment": PER_SEG,
        "zipf_exponent": ZIPF_EXP,
        "all_device_bytes": total,
        "cells": {},
    }
    for frac in FRACTIONS:
        tiers = plan_placement(
            data, PlacementConfig(device_budget_bytes=int(frac * total)))
        apply_placement(data, [srv], tiers)
        cell = run_cell(srv, data, queries, gt, cfg.topk)
        cell["budget_fraction"] = frac
        cell["corpus_per_device_byte_x"] = (
            total / max(cell["device_bytes"], 1))
        report["cells"][f"{frac:g}"] = cell
        emit(
            f"tiered.budget.{frac:g}",
            1e6 / max(cell["qps"], 1e-9),
            f"device_MB={cell['device_bytes'] / 2**20:.1f};"
            f"host_segs={cell['host_segments']};"
            f"recall={cell['recall_at_10']:.3f};qps={cell['qps']:.0f};"
            f"prefetch_hits={cell['prefetch_hits']};"
            f"streamed_MB={cell['bytes_streamed'] / 2**20:.1f}",
        )

    cells = report["cells"]
    full, quarter = cells["1"], cells["0.25"]
    # claim 1: ≥4× corpus per device byte, <2 recall points lost
    best = max(
        (c for c in cells.values()
         if full["recall_at_10"] - c["recall_at_10"] < 0.02),
        key=lambda c: c["corpus_per_device_byte_x"],
    )
    ok1 = best["corpus_per_device_byte_x"] >= 4.0 - 1e-9
    report["claim_4x_corpus_per_device_byte"] = {
        "best_x": best["corpus_per_device_byte_x"],
        "at_fraction": best["budget_fraction"],
        "recall_drop": full["recall_at_10"] - best["recall_at_10"],
        "ok": bool(ok1),
    }
    emit("tiered.claim.4x_corpus_per_device_byte", 0.0,
         f"ok={ok1};x={best['corpus_per_device_byte_x']:.1f};"
         f"recall_drop={full['recall_at_10'] - best['recall_at_10']:.4f}")
    # claim 2: 25% budget keeps ≥60% of all-device QPS
    ok2 = quarter["qps"] >= 0.6 * full["qps"]
    report["claim_qps_25pct_ge_60pct"] = {
        "full_qps": full["qps"], "quarter_qps": quarter["qps"],
        "ratio": quarter["qps"] / max(full["qps"], 1e-9), "ok": bool(ok2),
    }
    emit("tiered.claim.qps_25pct_ge_60pct", 0.0,
         f"ok={ok2};ratio={quarter['qps'] / max(full['qps'], 1e-9):.2f}")
    # claim 3: the double buffer is actually hit on cold cells
    cold_hits = sum(c["prefetch_hits"] for c in cells.values()
                    if c["host_segments"])
    ok3 = cold_hits > 0
    report["claim_prefetch_hits_positive"] = {
        "hits": cold_hits, "ok": bool(ok3)}
    emit("tiered.claim.prefetch_hits_positive", 0.0,
         f"ok={ok3};hits={cold_hits}")

    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["tiered"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
