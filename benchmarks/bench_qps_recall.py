"""Fig. 6: QPS–recall trade-off — Faiss-like single node vs the three
HARMONY distribution strategies on 4 nodes. Claims checked: distributed
speedup ≥ ~node count at high recall (paper: 4.63× avg); vector mode wins
at lower recall."""

from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, emit, faiss_like_qps, query_set, run_mode
from repro.data import brute_force_topk, recall_at_k


def main():
    ds, cfg, index = corpus()
    # boundary ("tail") queries give the gradual recall-vs-nprobe curve of
    # the paper's real datasets (see repro.data.make_queries)
    q = query_set(ds.nb, ds.dim, skew=0.0, noise=1.5, tail=0.02)
    true_idx, _ = brute_force_topk(ds.x, q, cfg.topk)
    n_nodes = 4
    print("# fig6: nprobe sweep, 4 nodes")
    best_speedup = 0.0
    for nprobe in (1, 2, 4, 8, 16, 32, 64):
        qps0, res0 = faiss_like_qps(index, cfg, q, nprobe=nprobe)
        rec = recall_at_k(res0.ids, true_idx)
        emit(f"fig6.faiss.nprobe{nprobe}", 1e6 / qps0, f"qps={qps0:.0f};recall={rec:.3f}")
        for mode in ("harmony", "vector", "dimension"):
            res, qps, serial = run_mode(index, cfg, q, mode, n_nodes, nprobe=nprobe)
            rec_m = recall_at_k(res.ids, true_idx)
            speed = qps / qps0
            emit(
                f"fig6.{mode}.nprobe{nprobe}",
                1e6 / qps,
                f"qps={qps:.0f};recall={rec_m:.3f};speedup_vs_faiss={speed:.2f}",
            )
            if mode == "harmony" and rec_m > 0.9:
                best_speedup = max(best_speedup, speed)
    emit("fig6.claim.high_recall_speedup", 0.0,
         f"harmony_speedup_at_recall>0.9={best_speedup:.2f};paper=4.63x_on_4nodes")

    # headline on a prunable (Sift-like core-query) workload — the paper's
    # >node-count speedups come from pruning-heavy datasets
    qe = query_set(ds.nb, ds.dim, skew=0.0)
    qps0, res0 = faiss_like_qps(index, cfg, qe, nprobe=32)
    res, qps, _ = run_mode(index, cfg, qe, "harmony", n_nodes, nprobe=32)
    from repro.data import brute_force_topk as _bf

    t_easy, _ = _bf(ds.x, qe, cfg.topk)
    rec_easy = recall_at_k(res.ids, t_easy)
    emit("fig6.claim.prunable_workload", 0.0,
         f"harmony_speedup={qps/qps0:.2f};recall={rec_easy:.3f};"
         f"flops_saved={1 - res.stats['pair_flops']/res.stats['dense_flops']:.2f}")


if __name__ == "__main__":
    main()
