"""Chaos benchmark: availability, tail latency, and acknowledged-write
survival under deterministic injected faults (PR 7's fault harness).

Every scenario replays the SAME virtual-clock trace on a modeled
(deterministic) per-query service time, with a seeded
:class:`repro.runtime.faults.FaultPlan` installed — so each cell is a
reproducible experiment, not a flaky stress test, and the harness can
assert exact re-run equality ("deterministic replay" claim).

Serving-plane scenarios (3-replica fleet, bounded idempotent-read
retries, circuit breaker + health probes):

* ``baseline``       — fault-free reference availability / p99;
* ``replica_crash``  — one replica throws on its first N batches: the
  scheduler retries onto its siblings, the breaker ejects the replica,
  a health probe readmits it.  Claim: availability stays 1.0 and every
  answer matches the fault-free run bit-for-bit;
* ``straggler``      — one replica is slowed by an injected delay on
  every batch: answers are unchanged, only the tail pays.

Write-path scenarios (WAL-journaled mutable plane, crash → recover):

* ``torn_wal``       — power cut mid-append of an *unacknowledged*
  record;
* ``compactor.<phase>`` / ``checkpoint.<site>`` — process kill between
  compaction phases / inside the checkpoint write or publish window.
  Claim: acknowledged-write survival is exactly 1.0 in every cell.

Results are folded into ``serving_results.json`` under the ``"chaos"``
key (schema in benchmarks/README.md), plus the usual CSV rows.
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

from benchmarks.bench_serving import bursty_trace
from benchmarks.common import TINY, corpus, emit
from repro.checkpoint import (
    Checkpointer,
    WriteAheadLog,
    checkpoint_segmented_index,
    recover_segmented_index,
)
from repro.config import HarmonyConfig
from repro.core import SegmentedIndex
from repro.data import make_queries
from repro.runtime.faults import FaultPlan, FaultSpec, InjectedFault, fault_scope
from repro.serve import (
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingScheduler,
)
from repro.serve.compactor import Compactor

N_REQ = 64 if TINY else 256
N_REPLICAS = 3
N_NODES = 4
MB = 8                      # dispatch batch
SVC_PER_QUERY_S = 1e-4      # modeled service rate (deterministic clock)

WRITE_OPS = 48 if TINY else 128
CRASHES = (
    "torn_wal",
    "compactor.begin", "compactor.seal",
    "compactor.prepare", "compactor.commit",
    "checkpoint.write", "checkpoint.publish",
)


# ------------------------------------------------------------- serving plane
def _fleet(index, cfg):
    return ReplicaFleet(
        index,
        replicas=[ReplicaSpec(backend="host", n_nodes=N_NODES)] * N_REPLICAS,
        cfg=cfg,
        # round-robin pins the batch→replica mapping, so the fault
        # window deterministically lands 6 hits on replica 0 — enough
        # to trip the breaker (threshold 3); the sub-millisecond
        # cooldown lets health probes readmit it within the trace
        routing="round_robin",
        seed=0,
        service_time_fn=lambda r, n: n * SVC_PER_QUERY_S,
        breaker_threshold=3,
        breaker_cooldown_s=5e-4,
    )


def _replay(index, cfg, trace, plan=None):
    """One trace replay under an optional fault plan. Returns the report
    cell plus the raw result ids (for answer-parity checks) and the
    plan's fire log (the determinism witness)."""
    fleet = _fleet(index, cfg)
    sched = ServingScheduler(
        fleet,
        SchedulerConfig(max_batch=MB, max_wait_s=2e-3, max_retries=2,
                        retry_backoff_s=1e-4, request_deadline_s=1.0),
    )
    if plan is not None:
        with fault_scope(plan):
            results = sched.run_trace(trace)
    else:
        results = sched.run_trace(trace)
    st, fl = sched.stats, fleet.stats
    total = len(trace)
    lat = st.request_latency_ms
    cell = {
        "requests": total,
        "served": total - st.failed_requests,
        "availability": (total - st.failed_requests) / total,
        "qps": sched.served_qps,
        "p50_ms": float(np.percentile(lat, 50)) if lat else 0.0,
        "p99_ms": float(np.percentile(lat, 99)) if lat else 0.0,
        "retried_batches": st.retried_batches + fl.retried_batches,
        "failed_requests": st.failed_requests,
        "replica_failures": fl.replica_failures,
        "breaker_opens": fl.breaker_opens,
        "breaker_closes": fl.breaker_closes,
        "health_probes": fl.health_probes,
        "faults_fired": plan.fired if plan is not None else 0,
    }
    ids = np.stack([r.ids for r in results]) if results else np.zeros((0,))
    log = list(plan.log) if plan is not None else []
    return cell, ids, log


def _crash_plan():
    # replica 0 throws on its first 3 executions — exactly the breaker
    # threshold, so the breaker opens mid-burst and the first health
    # probe after the cooldown finds it healthy and readmits it
    return FaultPlan(
        FaultSpec("replica.execute", where={"replica": 0}, count=3),
        seed=0,
    )


def _straggler_plan():
    return FaultPlan(
        FaultSpec("replica.execute", kind="delay", delay_s=20 * MB * SVC_PER_QUERY_S,
                  where={"replica": 1}, count=1_000_000),
        seed=0,
    )


# --------------------------------------------------------------- write path
def _write_survival(crash: str) -> dict:
    """Apply WRITE_OPS acknowledged writes to a WAL-journaled plane,
    crash at ``crash``, recover from disk, and count survivors."""
    dim = 16
    nb = 128 if TINY else 256
    cfg = HarmonyConfig(dim=dim, nlist=8, nprobe=8, topk=4,
                        kmeans_iters=2)
    rng = np.random.default_rng(11)
    x = rng.standard_normal((nb, dim)).astype(np.float32)
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        data = SegmentedIndex.build(x, cfg)
        ckpt = Checkpointer(root / "ckpt", keep=3)
        wal = WriteAheadLog(root / "wal", sync=False)
        data.attach_wal(wal)
        checkpoint_segmented_index(ckpt, data, wal)

        model = {i: x[i] for i in range(nb)}
        deleted: set = set()
        next_id = nb
        # periodic checkpoints NOT aligned with the end of the stream:
        # the ops after the last one are exactly what WAL-tail replay
        # must bring back
        for i in range(WRITE_OPS):
            if i % 16 == 8:
                checkpoint_segmented_index(ckpt, data, wal)
            elif i % 4 == 2:                            # deletes
                tid = sorted(model)[int(rng.integers(0, len(model)))]
                data.delete(np.array([tid], np.int64))
                del model[tid]
                deleted.add(tid)
            else:                                       # inserts
                v = rng.standard_normal((1, dim)).astype(np.float32)
                data.upsert(np.array([next_id], np.int64), v)
                model[next_id] = v[0]
                next_id += 1

        torn = crash == "torn_wal"
        try:
            with fault_scope(
                FaultSpec("wal.append", kind="torn") if torn
                else FaultSpec(crash, kind="crash")
            ):
                if torn:
                    # this append never returns: the write is torn
                    # mid-frame and therefore never acknowledged
                    data.upsert(
                        np.array([next_id], np.int64),
                        rng.standard_normal((1, dim)).astype(np.float32),
                    )
                elif crash.startswith("compactor."):
                    Compactor(data).run_once(merge_all=True)
                else:
                    checkpoint_segmented_index(ckpt, data, wal)
        except InjectedFault:
            pass                                        # the "kill -9"
        acked_seq = data.wal_seq
        wal.close()

        import warnings as _w

        with _w.catch_warnings():
            _w.simplefilter("ignore")   # interrupted-overwrite repair note
            data2, wal2, report = recover_segmented_index(
                ckpt, root / "wal", cfg=cfg, sync=False
            )
        wal2.close()
        lost = [i for i in model if not data2.has(i)]
        phantom = [i for i in deleted if i not in model and data2.has(i)]
        phantom += [next_id] if torn and data2.has(next_id) else []
        acked = len(model) + len(deleted)
        return {
            "acked_ops": WRITE_OPS,
            "acked_live_ids": acked,
            "lost": len(lost),
            "phantom": len(phantom),
            "survival": 1.0 - len(lost) / max(acked, 1),
            "wal_seq_match": bool(data2.wal_seq == acked_seq),
            "replayed": report["replayed"],
            "torn_tail": bool(report["torn_tail"]),
        }


def main():
    ds, cfg, index = corpus()
    q = make_queries(ds, nq=N_REQ, skew=0.8, hot_fraction=0.05, noise=0.2,
                     seed=17)
    # bursts at ~2x one replica's modeled capacity: the fleet absorbs
    # them fault-free, so degradation below is attributable to the plan
    trace = bursty_trace(q, burst=2 * MB, gap_s=MB * SVC_PER_QUERY_S)

    print(f"# chaos: {N_REQ} reqs x {N_REPLICAS} replicas, "
          f"modeled {SVC_PER_QUERY_S * 1e6:.0f}us/query, "
          f"{WRITE_OPS} write ops per crash cell")
    report = {"scenarios": {}, "write_survival": {}}

    base, base_ids, _ = _replay(index, cfg, trace)
    report["scenarios"]["baseline"] = base
    emit("chaos.baseline", 1e6 / max(base["qps"], 1e-9),
         f"avail={base['availability']:.3f};p99_ms={base['p99_ms']:.2f}")

    crash, crash_ids, log1 = _replay(index, cfg, trace, _crash_plan())
    report["scenarios"]["replica_crash"] = crash
    emit("chaos.replica_crash", 1e6 / max(crash["qps"], 1e-9),
         f"avail={crash['availability']:.3f};p99_ms={crash['p99_ms']:.2f};"
         f"retried={crash['retried_batches']};"
         f"breaker={crash['breaker_opens']}/{crash['breaker_closes']};"
         f"probes={crash['health_probes']}")

    slow, slow_ids, _ = _replay(index, cfg, trace, _straggler_plan())
    report["scenarios"]["straggler"] = slow
    emit("chaos.straggler", 1e6 / max(slow["qps"], 1e-9),
         f"avail={slow['availability']:.3f};p99_ms={slow['p99_ms']:.2f};"
         f"p99_inflation={slow['p99_ms'] / max(base['p99_ms'], 1e-9):.2f}x")

    # --- claim: full availability + bit-identical answers under the
    # replica crash (reads are idempotent; retries must not change them)
    ok_avail = (
        crash["availability"] == 1.0
        and crash_ids.shape == base_ids.shape
        and bool(np.array_equal(crash_ids, base_ids))
        and np.array_equal(slow_ids, base_ids)
    )
    report["claim_available_under_replica_crash"] = {
        "availability": crash["availability"],
        "answers_match_baseline": bool(np.array_equal(crash_ids, base_ids)),
        "ok": bool(ok_avail),
    }
    emit("chaos.claim.available_under_replica_crash", 0.0,
         f"ok={ok_avail};avail={crash['availability']:.3f}")

    # --- claim: the chaos replay is deterministic — a second run of the
    # same seeded plan fires identically and serves identical answers
    crash2, crash2_ids, log2 = _replay(index, cfg, trace, _crash_plan())
    ok_det = (log1 == log2 and np.array_equal(crash_ids, crash2_ids)
              and crash == crash2)
    report["claim_deterministic_replay"] = {
        "fires": len(log1), "ok": bool(ok_det),
    }
    emit("chaos.claim.deterministic_replay", 0.0,
         f"ok={ok_det};fires={len(log1)}")

    # --- write path: acknowledged-write survival across the crash matrix
    ok_writes = True
    for crash_site in CRASHES:
        cell = _write_survival(crash_site)
        report["write_survival"][crash_site] = cell
        ok_writes = ok_writes and (
            cell["survival"] == 1.0 and cell["phantom"] == 0
            and cell["wal_seq_match"]
        )
        emit(f"chaos.write.{crash_site}", 0.0,
             f"survival={cell['survival']:.3f};lost={cell['lost']};"
             f"phantom={cell['phantom']};replayed={cell['replayed']}")
    report["claim_zero_acked_write_loss"] = {"ok": bool(ok_writes)}
    emit("chaos.claim.zero_acked_write_loss", 0.0, f"ok={ok_writes}")

    # --- fold into the serving report
    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["chaos"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
