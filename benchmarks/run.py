"""Benchmark driver: one harness per paper table/figure (DESIGN.md §6),
plus the dry-run/roofline summary when benchmarks/dryrun_results.json is
present. Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import json
import time
from pathlib import Path


def main() -> None:
    t0 = time.time()
    from benchmarks import (
        bench_ablation,
        bench_breakdown,
        bench_build,
        bench_cache,
        bench_chaos,
        bench_executor,
        bench_filtered,
        bench_fleet,
        bench_frontend,
        bench_ingest,
        bench_memory,
        bench_pruning_ratio,
        bench_qps_recall,
        bench_quantization,
        bench_scaling,
        bench_serving,
        bench_skew,
        bench_tiered,
    )

    print("name,us_per_call,derived")
    for mod in (
        bench_qps_recall,
        bench_skew,
        bench_serving,
        bench_fleet,
        bench_frontend,
        bench_cache,
        bench_chaos,
        bench_executor,
        bench_tiered,
        bench_quantization,
        bench_filtered,
        bench_ingest,
        bench_breakdown,
        bench_ablation,
        bench_pruning_ratio,
        bench_build,
        bench_memory,
        bench_scaling,
    ):
        mod.main()

    # dry-run/roofline summary (produced by repro.launch.dryrun + roofline)
    dr = Path(__file__).resolve().parent / "dryrun_results.json"
    if dr.exists():
        cells = json.loads(dr.read_text())
        ok = sum(1 for c in cells if c.get("ok"))
        print(f"dryrun.cells,{0.0:.1f},ok={ok}/{len(cells)}")
    rf = Path(__file__).resolve().parent / "roofline.json"
    if rf.exists():
        rows = json.loads(rf.read_text())
        for r in rows:
            if r.get("mesh") != "pod16x16":
                continue
            print(
                f"roofline.{r['arch']}.{r['shape']},0.0,"
                f"bound={r['dominant']};compute_s={r['compute_s']:.3g};"
                f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
                f"model_flops_ratio={r.get('model_flops_ratio', 0):.2f}"
            )
    # §Perf: optimized-variant deltas (EXPERIMENTS.md hillclimb)
    opt = Path(__file__).resolve().parent / "dryrun_results_opt.json"
    if rf.exists() and opt.exists():
        from repro.launch.roofline import analyze

        base = {(r["arch"], r["shape"]): r for r in json.loads(rf.read_text())
                if r["mesh"] == "pod16x16"}
        for r in analyze(json.loads(opt.read_text()), "pod16x16"):
            b = base.get((r["arch"], r["shape"]))
            if not b:
                continue
            dom = b["dominant"]
            key = f"{dom}_s"
            print(
                f"perf.{r['arch']}.{r['shape']},0.0,"
                f"dominant_term[{dom}]={b[key]:.3g}->{r[key]:.3g}s"
                f";x{b[key]/max(r[key], 1e-12):.1f}"
                f";MF_HLO={b['model_flops_ratio']:.2f}->{r['model_flops_ratio']:.2f}"
            )
    print(f"# total bench wall: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
