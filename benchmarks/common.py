"""Shared benchmark scaffolding.

One corpus family at CPU-measurable scale (the paper's billion-scale
shapes live in the dry-run/roofline, not here). All benches emit CSV rows
``name,us_per_call,derived`` via :func:`emit`.

Throughput convention: this container has ONE core, so the distributed
engine runs its 4–16 "nodes" serially. ``modeled_qps`` converts the
per-(stage, shard) compute walls into the cluster's critical path
(max-over-shards per stage, plus the comm model) — the standard simulation
methodology when reproducing a cluster paper on one box; measured serial
walls are reported alongside.
"""

from __future__ import annotations

import functools
import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import HarmonyConfig
from repro.core import HardwareModel, build_ivf, harmony_search, plan_search, preassign, search_oracle
from repro.core.search import SearchStats
from repro.data import brute_force_topk, make_dataset, make_queries, recall_at_k

ROWS = []

# CI smoke switch: HARMONY_BENCH_TINY=1 clamps every corpus/query-set size
# so the whole bench suite runs in minutes (numbers are meaningless at this
# scale — the job only guards the scripts against rot).
TINY = os.environ.get("HARMONY_BENCH_TINY", "") not in ("", "0")


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@functools.lru_cache(maxsize=8)
def corpus(nb: int = 40_000, dim: int = 128, ncomp: int = 64, spread: float = 0.6,
           nlist: int = 256, nprobe: int = 16, seed: int = 7):
    if TINY:
        nb, nlist, nprobe = min(nb, 4000), min(nlist, 32), min(nprobe, 8)
    kmeans_iters = 4 if TINY else 8
    ds = make_dataset(nb=nb, dim=dim, n_components=ncomp, spread=spread, seed=seed)
    cfg = HarmonyConfig(dim=dim, nlist=nlist, nprobe=nprobe, topk=10,
                        kmeans_iters=kmeans_iters)
    index = build_ivf(ds.x, cfg)
    return ds, cfg, index


@functools.lru_cache(maxsize=16)
def query_set(nb: int, dim: int, skew: float, nq: int = 256, seed: int = 3,
              noise: float = 0.2, tail: float = 0.0):
    if TINY:
        nq = min(nq, 64)
    ds, cfg, index = corpus(nb=nb, dim=dim)
    return make_queries(ds, nq=nq, skew=skew, noise=noise, seed=seed,
                        tail_fraction=tail)


_CAL = {}


def calibrated_rate(index, cfg, q) -> float:
    """Effective node flops rate, calibrated once per corpus from the
    measured single-node scan (pair_flops / measured compute wall). All
    modes are then modeled on this same per-node hardware rate."""
    key = (id(index), q.shape)
    if key not in _CAL:
        decision = plan_search(index, 1, cfg.replace(mode="vector"))
        corpus_ = preassign(index, decision.plan)
        res = harmony_search(index, corpus_, q, enable_pruning=False,
                             pipeline=False)
        _CAL[key] = res.stats["pair_flops"] / max(res.stats["wall_comp_s"], 1e-9)
    return _CAL[key]


def modeled_qps(stats: dict, nq: int, rate: float,
                net_bw: float = 12.5e9, latency: float = 15e-6,
                pipelined: bool = True) -> float:
    """Critical-path throughput from per-(stage, machine) flops.

    pipelined=True → steady-state pipelining (Fig. 5): every machine works
    continuously on its slice of successive batches, so throughput is
    limited by the busiest machine's TOTAL flops. pipelined=False →
    stage-barriered ("synchronous execution" ablation): each stage waits
    for its slowest machine, cost = Σ_stages max_machine."""
    from collections import defaultdict

    agg = defaultdict(dict)
    totals = defaultdict(float)
    for key, fl in stats["machine_flops"].items():
        stage, machine = key.split(":")
        agg[stage][machine] = agg[stage].get(machine, 0.0) + fl
        totals[machine] += fl
    if not agg:
        comp = 0.0
    elif pipelined:
        comp = max(totals.values()) / rate
    else:
        comp = sum(max(m.values()) for m in agg.values()) / rate
    comm = sum(stats["comm_bytes"].values()) / net_bw + latency * stats["visits"]
    return nq / max(comp + comm, 1e-12)


def faiss_like_qps(index, cfg, q, nprobe=None):
    """Single-node IVF baseline: same engine, one shard, no pruning or
    pipeline (cost proportional to probed candidates, like Faiss)."""
    rate = calibrated_rate(index, cfg, q)
    decision = plan_search(index, 1, cfg.replace(mode="vector"))
    corpus_ = preassign(index, decision.plan)
    res = harmony_search(index, corpus_, q, nprobe=nprobe,
                         enable_pruning=False, pipeline=False)
    return modeled_qps(res.stats, q.shape[0], rate), res


def run_mode(
    index,
    cfg: HarmonyConfig,
    q: np.ndarray,
    mode: str,
    n_nodes: int,
    nprobe: Optional[int] = None,
    balanced: bool = True,
    stagger: bool = True,
    enable_pruning: Optional[bool] = None,
    pipeline: bool = True,
    probes_sample: Optional[np.ndarray] = None,
):
    """Plan + preassign + search one mode; returns (result, modeled_qps,
    serial_wall_s)."""
    cfg2 = cfg.replace(mode=mode)
    if enable_pruning is not None:
        cfg2 = cfg2.replace(enable_pruning=enable_pruning)
    # the planner's cost model runs on the same hardware model the
    # throughput model evaluates on (calibrated per-node flops rate)
    hw = HardwareModel(flops_rate=calibrated_rate(index, cfg, q))
    decision = plan_search(
        index, n_nodes, cfg2, probes_sample=probes_sample,
        balanced=balanced, stagger=stagger, hw=hw,
    )
    corpus_ = preassign(index, decision.plan)
    t0 = time.perf_counter()
    res = harmony_search(
        index, corpus_, q, nprobe=nprobe,
        enable_pruning=enable_pruning, pipeline=pipeline,
    )
    serial = time.perf_counter() - t0
    rate = calibrated_rate(index, cfg, q)
    qps = modeled_qps(res.stats, q.shape[0], rate, pipelined=pipeline)
    return res, qps, serial


def oracle_qps(index, q: np.ndarray, nprobe: Optional[int] = None) -> Tuple[float, object]:
    res = search_oracle(index, q, nprobe=nprobe)
    return q.shape[0] / max(res.stats["wall_s"], 1e-9), res
