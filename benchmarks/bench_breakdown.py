"""Fig. 8: time breakdown (computation / communication / other) per mode,
under the paper's hardware model (Xeon-class nodes, 100 Gb/s links) so
communication shares are visible. Claims: only dimension-touching modes
pay partial-result communication; comm share dimension > harmony > vector;
comm share shrinks as dimensionality grows."""

from __future__ import annotations

from benchmarks.common import corpus, emit, query_set, run_mode

PAPER_RATE = 2.0e11   # effective per-node f32 FLOP/s (56-thread Xeon + MKL)
NET_BW = 12.5e9       # 100 Gb/s


def _shares(res, n_nodes=4):
    st = res.stats
    comp_s = st["pair_flops"] / n_nodes / PAPER_RATE
    comm_s = sum(st["comm_bytes"].values()) / NET_BW / n_nodes
    other_s = 0.1 * comp_s + 2e-5 * st["visits"]   # scheduling/merge overhead
    tot = comp_s + comm_s + other_s
    return comp_s / tot, comm_s / tot, other_s / tot, tot


def main():
    print("# fig8: comp/comm/other under the paper's hardware model")
    comm_share = {}
    for dim in (128, 256):
        ds, cfg, index = corpus(dim=dim)
        q = query_set(ds.nb, dim, skew=0.25)
        for mode in ("harmony", "vector", "dimension"):
            res, _, _ = run_mode(index, cfg, q, mode, 4)
            comp, comm, other, tot = _shares(res)
            comm_share[(dim, mode)] = comm
            emit(
                f"fig8.d{dim}.{mode}",
                1e6 * tot / q.shape[0],
                f"comp={comp:.2f};comm={comm:.2f};other={other:.2f};"
                f"partial_result_bytes={res.stats['comm_bytes'].get('partial_results', 0)}",
            )
    ok_order = comm_share[(128, "dimension")] >= comm_share[(128, "harmony")] >= comm_share[(128, "vector")]
    ok_dim = comm_share[(256, "dimension")] <= comm_share[(128, "dimension")]
    emit("fig8.claim.comm_order", 0.0, f"dim>=harmony>=vector:{ok_order}")
    emit("fig8.claim.comm_dilutes_with_dim", 0.0, f"{ok_dim}")


if __name__ == "__main__":
    main()
