"""Two-stage int8 tier: the recall / memory / throughput frontier.

Claims guarded here (the PR's acceptance bounds):

* recall@10 of the int8 + fp32-re-rank path stays within 2 points of the
  fp32 path at the same nprobe (the exact re-rank recovers everything
  stage 1 keeps — recall only drops when a true neighbour falls outside
  the quantized top ``k·rerank_factor``);
* the resident stage-1 corpus is ≥4× smaller per vector than fp32;
* QPS of the int8 executor is reported next to fp32 across a
  rerank_factor sweep (the frontier: bigger K' → higher recall, more
  stage-2 gather work).

Results fold into ``benchmarks/serving_results.json`` under the
``"quantization"`` key (schema in benchmarks/README.md).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import TINY, corpus, emit, query_set
from repro.core import search_oracle, two_stage_search
from repro.serve import ExecutorConfig, SpmdExecutor


def _recall(ids, ref_ids):
    k = ref_ids.shape[1]
    return float(np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids, ref_ids)
    ]))


def main():
    print("# quantization: int8 stage-1 + exact fp32 re-rank")
    ds, cfg, index = corpus()
    q = query_set(ds.nb, cfg.dim, skew=0.3)
    k = cfg.topk
    oracle = search_oracle(index, q, k=k)

    # resident bytes per vector: fp32 corpus vs int8 codes (+O(1) grid)
    quant = index.int8_quant(cfg.quant_blocks)
    bpv_fp32 = index.x.nbytes / index.nb
    bpv_int8 = quant.codes.nbytes / index.nb
    ratio = bpv_fp32 / bpv_int8
    emit("quant.memory", 0.0,
         f"bytes_per_vec_fp32={bpv_fp32:.0f};bytes_per_vec_int8={bpv_int8:.0f};"
         f"ratio={ratio:.2f}")

    # fp32 executor baseline at the config nprobe
    ex_kw = dict(chunk=256, qb_buckets=(8, 32, 128), use_pallas=False)
    reps = 1 if TINY else 3
    ex32 = SpmdExecutor(index, ExecutorConfig(**ex_kw))
    ex32.warmup()
    t0 = time.perf_counter()
    for _ in range(reps):
        r32 = ex32.search_batch(q, k=k)
    fp32_wall = (time.perf_counter() - t0) / reps
    fp32_recall = _recall(r32.ids, oracle.ids)
    fp32_qps = q.shape[0] / fp32_wall
    emit("quant.fp32_baseline", fp32_wall / q.shape[0] * 1e6,
         f"recall={fp32_recall:.4f};qps={fp32_qps:.0f}")

    # the frontier: rerank_factor sweep on the int8 executor
    sweep = []
    for rf in (1, 2, 4, 8):
        ex8 = SpmdExecutor(index, ExecutorConfig(
            precision="int8", rerank_factor=rf, **ex_kw))
        ex8.warmup()
        t0 = time.perf_counter()
        for _ in range(reps):
            r8 = ex8.search_batch(q, k=k)
        wall = (time.perf_counter() - t0) / reps
        rec = _recall(r8.ids, oracle.ids)
        qps = q.shape[0] / wall
        sweep.append({
            "rerank_factor": rf,
            "recall_at_10": rec,
            "recall_drop_vs_fp32": fp32_recall - rec,
            "qps": qps,
            "us_per_query": wall / q.shape[0] * 1e6,
        })
        emit(f"quant.int8.rf{rf}", wall / q.shape[0] * 1e6,
             f"recall={rec:.4f};drop={fp32_recall - rec:.4f};qps={qps:.0f}")

    # host two-stage path (the engine's backend="host" int8 dispatch)
    t0 = time.perf_counter()
    rh = two_stage_search(index, q, k=k)
    host_wall = time.perf_counter() - t0
    emit("quant.int8.host_two_stage", host_wall / q.shape[0] * 1e6,
         f"recall={_recall(rh.ids, oracle.ids):.4f};"
         f"survivors={rh.stats['stage1_survivors']}")

    at_cfg = next(s for s in sweep
                  if s["rerank_factor"] == cfg.rerank_factor)
    ok_recall = at_cfg["recall_drop_vs_fp32"] <= 0.02
    ok_memory = ratio >= 4.0
    emit("quant.claim.recall_within_2pts", 0.0, f"ok={ok_recall}")
    emit("quant.claim.memory_4x", 0.0, f"ok={ok_memory}")

    report = {
        "bytes_per_vec_fp32": bpv_fp32,
        "bytes_per_vec_int8": bpv_int8,
        "memory_ratio": ratio,
        "fp32_recall_at_10": fp32_recall,
        "fp32_qps": fp32_qps,
        "rerank_sweep": sweep,
        "host_two_stage_us_per_query": host_wall / q.shape[0] * 1e6,
        "claim_recall_within_2pts": bool(ok_recall),
        "claim_memory_4x": bool(ok_memory),
    }
    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["quantization"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))
    print(json.dumps({"quantization": report}, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
