"""Mixed read/write ingest benchmark: streaming upserts/deletes into the
segmented data plane while queries are served, with background
compaction.

Drives the new workload axis (ISSUE 5): the server starts from a sealed
index over 70% of the corpus; the trace then interleaves query batches
with upsert bursts (the remaining 30% plus overwrites) and deletes while
a background :class:`repro.serve.compactor.Compactor` thread seals the
delta / merges segments concurrently with the reads.

Claims (folded into ``serving_results.json`` under ``"ingest"``; schema
in ``benchmarks/README.md``):

* **recall parity** — after the trace and a full merge, segmented search
  recall@10 against the live-set ground truth equals a from-scratch
  ``build_ivf`` rebuild's recall (the full merge *is* a from-scratch
  rebuild, so the difference must be ~0);
* **bounded read p99 during compaction** — reads issued while a
  compaction cycle is in flight complete in a small fraction of the
  cycle wall (compaction runs off the serving path and the swap is
  O(1), so no read is ever serialized behind a rebuild — the
  stop-the-world alternative stalls reads for the whole cycle).
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import TINY, corpus, emit
from repro.core import SegmentedIndex, build_ivf
from repro.data import brute_force_topk, make_queries, recall_at_k
from repro.serve import CompactionConfig, Compactor, HarmonyServer

K = 10
READ_BATCH = 32
WRITE_BATCH = 32 if TINY else 64
N_STEPS = 16 if TINY else 48
DELETES_PER_STEP = 4 if TINY else 8


def live_ground_truth(data: SegmentedIndex, q: np.ndarray, k: int):
    ids, x = data.live_vectors()
    idx, _ = brute_force_topk(x, q, k, metric=data.cfg.metric)
    return ids[np.asarray(idx)]


def main():
    ds, cfg, _ = corpus()
    nb = ds.nb
    n0 = int(0.7 * nb)
    data = SegmentedIndex.build(ds.x[:n0], cfg)
    srv = HarmonyServer(data, n_nodes=4)
    comp = Compactor(
        data, srv,
        CompactionConfig(delta_threshold=4 * WRITE_BATCH, max_segments=3),
    )
    rng = np.random.default_rng(17)
    q_pool = make_queries(ds, nq=256 if TINY else 512, skew=0.3, noise=0.2,
                          seed=23)

    # --- streaming phase: reads in this thread, compactions in background
    compacting = threading.Event()
    bg: list = []

    def compact_bg(reason: str):
        compacting.set()
        try:
            comp.run_once(merge_all=(reason != "delta_full"), reason=reason)
        finally:
            compacting.clear()

    walls_quiet, walls_during = [], []
    next_insert = n0
    t0 = time.perf_counter()
    for step in range(N_STEPS):
        # writes: a fresh-insert burst (wrapping ids past nb are new keys)
        ins = np.arange(next_insert, next_insert + WRITE_BATCH)
        vecs = ds.x[ins % nb] + 0.01 * rng.standard_normal(
            (WRITE_BATCH, ds.dim)).astype(np.float32)
        srv.upsert(ins, vecs)
        next_insert += WRITE_BATCH
        dele = rng.integers(0, n0, size=DELETES_PER_STEP)
        srv.delete(dele)
        # maybe kick a background compaction (never blocks reads)
        reason = comp.should_compact()
        if reason and not compacting.is_set():
            th = threading.Thread(target=compact_bg, args=(reason,), daemon=True)
            bg.append(th)
            th.start()
        # reads
        qb = q_pool[rng.integers(0, len(q_pool), size=READ_BATCH)]
        tb = time.perf_counter()
        srv.search_batch(qb, k=K)
        wall_ms = (time.perf_counter() - tb) * 1e3
        (walls_during if compacting.is_set() else walls_quiet).append(wall_ms)
    for th in bg:
        th.join()
    stream_wall = time.perf_counter() - t0

    # --- recall parity: full merge == from-scratch rebuild
    q_eval = q_pool[:64]
    truth = live_ground_truth(data, q_eval, K)
    rec_stream = recall_at_k(srv.search_batch(q_eval, k=K).ids, truth)
    comp.run_once(merge_all=True, reason="final")
    rec_merged = recall_at_k(srv.search_batch(q_eval, k=K).ids, truth)
    live_ids, live_x = data.live_vectors()
    fresh = HarmonyServer(build_ivf(live_x, cfg), n_nodes=4)
    fresh_ids = fresh.search_batch(q_eval, k=K).ids
    rec_fresh = recall_at_k(
        np.where(fresh_ids >= 0, live_ids[fresh_ids], -1), truth)

    pct = lambda a, p: float(np.percentile(a, p)) if a else None
    p99_quiet = pct(walls_quiet, 99)
    p99_during = pct(walls_during, 99)
    ok_recall = abs(rec_merged - rec_fresh) < 1e-6
    # zero-downtime bound: reads issued while a compaction cycle is in
    # flight complete in a small fraction of the cycle wall — a
    # stop-the-world rebuild would stall them for the whole cycle. (On a
    # 1-core container the background k-means still steals CPU from
    # concurrent reads, so a pure quiet-vs-during latency factor is not
    # the right invariant; never-serialized-behind-the-swap is.)
    cycle_ms = [1e3 * e["wall_s"] for e in comp.events]
    mean_cycle_ms = float(np.mean(cycle_ms)) if cycle_ms else None
    ok_p99 = (
        p99_during is None or p99_quiet is None or mean_cycle_ms is None
        or p99_during <= max(3.0 * p99_quiet, 0.5 * mean_cycle_ms)
    )

    report = {
        "steps": N_STEPS,
        "reads": N_STEPS * READ_BATCH,
        "upserts": int(srv.stats.upserts),
        "deletes": int(srv.stats.deletes),
        "compactions": len(comp.events),
        "compaction_reasons": [e["reason"] for e in comp.events],
        "generation": data.generation,
        "stream_wall_s": stream_wall,
        "read_p50_quiet_ms": pct(walls_quiet, 50),
        "read_p99_quiet_ms": p99_quiet,
        "read_p50_during_compaction_ms": pct(walls_during, 50),
        "read_p99_during_compaction_ms": p99_during,
        "reads_during_compaction": len(walls_during),
        "recall_streaming": rec_stream,
        "recall_after_merge": rec_merged,
        "recall_fresh_rebuild": rec_fresh,
        "claim_recall_parity": {
            "recall_after_merge": rec_merged,
            "recall_fresh_rebuild": rec_fresh,
            "ok": bool(ok_recall),
        },
        "claim_bounded_p99_during_compaction": {
            "p99_quiet_ms": p99_quiet,
            "p99_during_ms": p99_during,
            "mean_compaction_cycle_ms": mean_cycle_ms,
            "ok": bool(ok_p99),
        },
    }
    fmt = lambda v: f"{v:.2f}" if v is not None else "na"
    emit(
        "ingest.stream",
        1e6 * stream_wall / max(N_STEPS * READ_BATCH, 1),
        f"compactions={len(comp.events)};gen={data.generation};"
        f"p99_quiet_ms={fmt(p99_quiet)};p99_during_ms={fmt(p99_during)};"
        f"recall_stream={rec_stream:.3f}",
    )
    emit("ingest.claim.recall_parity_vs_rebuild", 0.0,
         f"ok={ok_recall};merged={rec_merged:.4f};fresh={rec_fresh:.4f}")
    emit("ingest.claim.bounded_p99_during_compaction", 0.0,
         f"ok={ok_p99};quiet={fmt(p99_quiet)}ms;during={fmt(p99_during)}ms;"
         f"cycle={fmt(mean_cycle_ms)}ms")

    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["ingest"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
