"""Fleet benchmark: replica count × skew × backend sweep over the
multi-replica serving fleet (`repro.serve.fleet.ReplicaFleet`).

The paper's headline claim is throughput scaling across nodes under
skewed load; this harness replays the same virtual-clock traces as
``bench_serving`` but scales *out* — 1/2/4 ``HarmonyServer`` replicas
behind one admission queue with load-estimate routing. The arrival rate
is calibrated from a measured batch wall so a single replica is
``OVERSUBSCRIBE``x oversubscribed: served QPS then scales with replica
count (the acceptance claim: ≥1.5x at 4 replicas vs 1 on the bursty
skewed trace).

A second sweep compares routing policies on a heterogeneous fleet (two
half-speed replicas): power-of-two-choices with load estimates must
spread work-seconds more evenly than capacity-blind round-robin (fleet
Gini < round-robin Gini under skew).

Results are folded into ``serving_results.json`` under the "fleet" key
(the file ``bench_serving`` emits), plus the usual CSV rows.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.bench_serving import bursty_trace, poisson_trace
from benchmarks.common import TINY, corpus, emit
from repro.data import make_queries
from repro.serve import (
    HarmonyServer,
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingScheduler,
)

N_REQ = 128 if TINY else 512
N_NODES = 4
OVERSUBSCRIBE = 4.0     # single-replica demand/capacity on the bursty trace


def calibrate_batch_wall(index, cfg, mb: int) -> float:
    """Measured wall of one scheduled batch (size ``mb``) on one replica."""
    srv = HarmonyServer(index, n_nodes=N_NODES)
    rng = np.random.default_rng(0)
    qb = rng.standard_normal((mb, index.dim)).astype(np.float32)
    srv.search_batch(qb, cfg.topk)                  # warm caches
    t0 = time.perf_counter()
    srv.search_batch(qb, cfg.topk)
    return max(time.perf_counter() - t0, 1e-5)


def replay(trace, fleet, sched_cfg):
    sched = ServingScheduler(fleet, sched_cfg)
    sched.run_trace(trace)
    s = fleet.summary()
    return {
        "qps": sched.served_qps,
        "makespan_s": sched.makespan_s,
        "served": len(sched.done),
        "gini": s["load_balance_gini"],
        "hedge_win_rate": s["hedge"]["win_rate"],
        "per_replica_batches": [r["batches"] for r in s["replicas"]],
        "per_replica_busy_s": [r["busy_s"] for r in s["replicas"]],
        "shed": s["shed"],
    }


def specs(n: int, backend_mix: str):
    """Replica specs for one sweep cell. "host" = homogeneous host fleet;
    "mixed" = alternating host / device-resident spmd replicas."""
    if backend_mix == "host":
        return [ReplicaSpec(backend="host", n_nodes=N_NODES)] * n
    return [
        ReplicaSpec(backend="spmd" if i % 2 else "host", n_nodes=N_NODES)
        for i in range(n)
    ]


def main():
    ds, cfg, index = corpus()
    # dispatch batches smaller than query_block so every replay makes
    # enough routing decisions for balance statistics to mean something
    mb = max(8, cfg.query_block // 4)
    wall = calibrate_batch_wall(index, cfg, mb)

    # built directly (make_hot_queries clamps nq under TINY; the fleet
    # sweep controls its own trace length via N_REQ)
    q_skew = make_queries(ds, nq=N_REQ, skew=0.9, hot_fraction=0.04,
                          noise=0.2, seed=11)
    q_uni = make_queries(ds, nq=N_REQ, skew=0.0, noise=0.2, seed=11)

    # bursts of 4 batches, gap sized so one replica runs at
    # OVERSUBSCRIBE-times its capacity
    burst = 4 * mb
    gap_s = (burst / mb) * wall / OVERSUBSCRIBE
    rate_qps = OVERSUBSCRIBE * mb / wall
    traces = {
        "bursty_skewed": (q_skew, bursty_trace(q_skew, burst=burst, gap_s=gap_s)),
        "bursty_uniform": (q_uni, bursty_trace(q_uni, burst=burst, gap_s=gap_s)),
        "poisson_skewed": (q_skew, poisson_trace(q_skew, rate_qps, seed=3)),
    }
    sched_cfg = SchedulerConfig(max_batch=mb, max_wait_s=2e-3)

    print(f"# fleet: replica count x skew x backend sweep "
          f"(batch {mb} wall {wall * 1e3:.1f}ms, burst {burst} / gap {gap_s * 1e3:.1f}ms)")
    report = {"batch_wall_s": wall, "scenarios": {}}

    for tname, (q, trace) in traces.items():
        for backend_mix in ("host", "mixed"):
            for n_rep in (1, 2, 4):
                if backend_mix == "mixed" and n_rep != 2:
                    continue        # one mixed cell keeps the smoke wall sane
                fleet = ReplicaFleet(
                    index, replicas=specs(n_rep, backend_mix), cfg=cfg, seed=0
                )
                r = replay(trace, fleet, sched_cfg)
                key = f"{tname}.{backend_mix}.r{n_rep}"
                report["scenarios"][key] = r
                emit(
                    f"fleet.{key}",
                    1e6 / max(r["qps"], 1e-9),
                    f"qps={r['qps']:.0f};gini={r['gini']:.3f};"
                    f"batches={'/'.join(map(str, r['per_replica_batches']))};"
                    f"shed={r['shed']}",
                )

    # --- scaling claim: >=1.5x served QPS at 4 replicas vs 1 (bursty
    # skewed). The claim runs on the calibrated service model (per-query
    # rate from the measured wall) so it measures fleet mechanics on the
    # virtual clock, not per-replay OS noise — the sweep rows above keep
    # raw measured walls.
    svc = lambda r, n: n * wall / mb
    claim_qps = {}
    for n_rep in (1, 4):
        fleet = ReplicaFleet(index, replicas=specs(n_rep, "host"), cfg=cfg,
                             service_time_fn=svc, seed=0)
        claim_qps[n_rep] = replay(
            traces["bursty_skewed"][1], fleet, sched_cfg
        )["qps"]
    q1, q4 = claim_qps[1], claim_qps[4]
    ok_scale = q4 >= 1.5 * q1
    report["claim_qps_4rep_ge_1p5x"] = {
        "r1_qps": q1, "r4_qps": q4, "speedup": q4 / max(q1, 1e-9),
        "ok": bool(ok_scale),
    }
    emit("fleet.claim.qps_4rep_ge_1p5x_1rep", 0.0,
         f"ok={ok_scale};speedup={q4 / max(q1, 1e-9):.2f}")

    # --- routing claim: load-aware Gini < round-robin Gini on a
    # heterogeneous fleet (two half-speed replicas) under skew
    caps = (1.0, 1.0, 0.5, 0.5)
    het = [ReplicaSpec(backend="host", capacity=c, n_nodes=N_NODES)
           for c in caps]
    # longer trace at 2x the whole fleet's capacity (the paper's heavy-
    # traffic regime): balance statistics need enough routing decisions,
    # and deep backlog is where busy-second balance is won or lost
    q_het = make_queries(ds, nq=4 * N_REQ, skew=0.9, hot_fraction=0.04,
                         noise=0.2, seed=13)
    trace = bursty_trace(q_het, burst=burst, gap_s=gap_s / 2.0)
    routed = {}
    for routing in ("p2c", "round_robin"):
        fleet = ReplicaFleet(index, replicas=het, cfg=cfg, routing=routing,
                             seed=0)
        routed[routing] = replay(trace, fleet, sched_cfg)
        r = routed[routing]
        emit(f"fleet.hetero_skewed.{routing}", 1e6 / max(r["qps"], 1e-9),
             f"qps={r['qps']:.0f};gini={r['gini']:.3f}")
    ok_gini = routed["p2c"]["gini"] < routed["round_robin"]["gini"]
    report["claim_gini_p2c_lt_rr"] = {
        "p2c_gini": routed["p2c"]["gini"],
        "rr_gini": routed["round_robin"]["gini"],
        "ok": bool(ok_gini),
    }
    emit("fleet.claim.gini_p2c_lt_rr", 0.0,
         f"ok={ok_gini};p2c={routed['p2c']['gini']:.3f};"
         f"rr={routed['round_robin']['gini']:.3f}")

    # --- fold into the serving report
    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["fleet"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
