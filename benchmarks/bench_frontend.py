"""Real-clock front-end benchmark: wall-clock QPS vs offered load ×
replica count under an open-loop Poisson driver.

Unlike every other serving bench (virtual-clock replays), this one runs
the live :class:`repro.serve.frontend.ServingFrontend`: requests are
submitted at their Poisson arrival times on the **wall clock**, batches
dispatch from a real thread pool, and fleet replicas genuinely overlap.

Service model: each replica runs the real ``search_batch`` and then
sleeps up to a calibrated per-query service time (measured single-replica
batch wall with head-room, floored at 1 ms/query) — the standard
one-box methodology for modelling N remote replicas (see
``benchmarks/common.py``): sleeping replicas overlap on any core count,
so the measured speedup isolates the front-end's overlap machinery from
host parallelism. The driver is open-loop (arrivals don't wait for
completions), offered at ``OVERSUBSCRIBE``× one replica's capacity, so a
single replica saturates and sheds while four replicas keep up.

Acceptance claim (ISSUE 4): ≥1.5× wall-clock served QPS at 4 host
replicas vs 1 on the Poisson trace.

Results fold into ``serving_results.json`` under the ``"frontend"`` key
(schema in ``benchmarks/README.md``), plus the usual CSV rows.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.bench_fleet import calibrate_batch_wall
from benchmarks.common import TINY, corpus, emit
from repro.data import make_queries
from repro.serve import (
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingFrontend,
)

N_REQ = 160 if TINY else 512
N_NODES = 4
OVERSUBSCRIBE = 3.0     # offered load / single-replica capacity


def poisson_arrivals(n: int, rate_qps: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def drive_open_loop(frontend: ServingFrontend, arrivals, queries):
    """Open-loop driver: submit each request at its arrival time on the
    front-end's wall clock, never waiting for completions."""
    clock = frontend.clock
    t0 = clock.now()
    futs = []
    for t, qv in zip(arrivals, queries):
        dt = (t0 + t) - clock.now()
        if dt > 0:
            clock.sleep(dt)
        futs.append(frontend.submit(qv))
    assert frontend.drain(timeout=300.0), "drain timed out"
    return futs


def run_cell(index, cfg, q, arrivals, n_rep: int, per_q_s: float,
             mb: int) -> dict:
    fleet = ReplicaFleet(
        index,
        replicas=[ReplicaSpec(backend="host", n_nodes=N_NODES)] * n_rep,
        cfg=cfg,
        service_time_fn=lambda r, n: n * per_q_s,
        seed=0,
    )
    sched_cfg = SchedulerConfig(
        max_batch=mb, max_wait_s=2e-3, queue_capacity=8 * mb
    )
    with ServingFrontend(fleet, sched_cfg, k=cfg.topk) as fe:
        drive_open_loop(fe, arrivals, q)
        summary = fe.summary()
    return {
        "wall_qps": fe.served_qps,
        "makespan_s": fe.makespan_s,
        "served": summary["served"],
        "shed": summary["shed"],
        "p50_request_latency_ms": summary["p50_request_latency_ms"],
        "p99_request_latency_ms": summary["p99_request_latency_ms"],
        "per_replica_batches": [r.batches for r in fleet.replicas],
        "max_inflight": summary["max_inflight"],
    }


def main():
    # a lighter corpus than the shared measurement one: this bench runs
    # real searches concurrently in threads, and the *sleep* model (not
    # host compute) must dominate the wall for overlap to be measurable
    ds, cfg, index = corpus(nb=10_000)
    mb = max(8, cfg.query_block // 4)
    wall = calibrate_batch_wall(index, cfg, mb)
    # head-room over the measured compute so the sleep padding (which is
    # what overlaps across replicas) dominates on any host: at 4 in-flight
    # replicas the *compute* slices contend for local cores/GIL and can
    # stretch ~4x (starving the dispatcher/submitter threads too), so the
    # model leaves 8x slack or the 4-replica cell measures host
    # parallelism instead of front-end overlap
    per_q_s = max(8.0 * wall / mb, 1e-3)
    rate_qps = OVERSUBSCRIBE / per_q_s
    arrivals = poisson_arrivals(N_REQ, rate_qps, seed=3)
    q = make_queries(ds, nq=N_REQ, skew=0.3, noise=0.2, seed=11)

    print(f"# frontend: open-loop Poisson x replica count "
          f"(service {per_q_s * 1e3:.2f}ms/q, offered {rate_qps:.0f} q/s, "
          f"{N_REQ} requests)")
    report = {
        "per_q_service_s": per_q_s,
        "offered_qps": rate_qps,
        "n_requests": N_REQ,
        "cells": {},
    }
    for n_rep in (1, 2, 4):
        cell = run_cell(index, cfg, q, arrivals, n_rep, per_q_s, mb)
        report["cells"][f"r{n_rep}"] = cell
        emit(
            f"frontend.poisson.r{n_rep}",
            1e6 / max(cell["wall_qps"], 1e-9),
            f"wall_qps={cell['wall_qps']:.0f};served={cell['served']};"
            f"shed={cell['shed']};"
            f"p99_ms={cell['p99_request_latency_ms']:.1f};"
            f"batches={'/'.join(map(str, cell['per_replica_batches']))}",
        )

    q1 = report["cells"]["r1"]["wall_qps"]
    q4 = report["cells"]["r4"]["wall_qps"]
    ok = q4 >= 1.5 * q1
    report["claim_wall_qps_4rep_ge_1p5x"] = {
        "r1_wall_qps": q1, "r4_wall_qps": q4,
        "speedup": q4 / max(q1, 1e-9), "ok": bool(ok),
    }
    emit("frontend.claim.wall_qps_4rep_ge_1p5x_1rep", 0.0,
         f"ok={ok};speedup={q4 / max(q1, 1e-9):.2f}")

    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["frontend"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
