"""Fig. 9: contribution of each optimization to throughput on a fixed 4-node
hybrid grid (V=2 × B=2), skewed workload. Paper: balanced load 1.75x,
pipeline+async 1.25x, pruning 1.51x; gains shrink on uniform workloads."""

from __future__ import annotations

import numpy as np

from benchmarks.bench_skew import make_hot_queries
from benchmarks.common import calibrated_rate, corpus, emit, modeled_qps
from repro.core import assign_queries, harmony_search, preassign
from repro.core.router import (
    estimate_cluster_hits,
    load_aware_assignment,
    ring_offsets,
    round_robin_assignment,
)
from repro.core.types import PartitionPlan


def _run(index, cfg, q, rate, *, balanced, stagger, pipeline, pruning, probes):
    V, B = 2, 2
    hits = estimate_cluster_hits(probes, index.nlist) if balanced else None
    assign = (
        load_aware_assignment(index.sizes, hits, V)
        if balanced
        else round_robin_assignment(index.nlist, V)
    )
    plan = PartitionPlan(v_shards=V, d_blocks=B, cluster_to_shard=assign,
                         ring_offsets=ring_offsets(V, B, stagger))
    corpus_ = preassign(index, plan)
    res = harmony_search(index, corpus_, q, enable_pruning=pruning,
                         pipeline=pipeline)
    return modeled_qps(res.stats, q.shape[0], rate, pipelined=pipeline)


def main():
    ds, cfg, index = corpus()
    print("# fig9: optimization ablations, fixed 2x2 grid, skewed workload")
    q = make_hot_queries(ds, 0.75)
    probes = assign_queries(index, q)
    rate = calibrated_rate(index, cfg, q)

    full = _run(index, cfg, q, rate, balanced=True, stagger=True,
                pipeline=True, pruning=True, probes=probes)
    no_bal = _run(index, cfg, q, rate, balanced=False, stagger=True,
                  pipeline=True, pruning=True, probes=probes)
    no_pipe = _run(index, cfg, q, rate, balanced=True, stagger=True,
                   pipeline=False, pruning=True, probes=probes)
    no_prune = _run(index, cfg, q, rate, balanced=True, stagger=True,
                    pipeline=True, pruning=False, probes=probes)
    emit("fig9.full", 1e6 / full, f"qps={full:.0f}")
    emit("fig9.balanced_load_gain", 0.0, f"x{full / no_bal:.2f};paper=1.75x")
    emit("fig9.pipeline_gain", 0.0, f"x{full / no_pipe:.2f};paper=1.25x")
    emit("fig9.pruning_gain", 0.0, f"x{full / no_prune:.2f};paper=1.51x")

    # uniform workload: balance/pipeline gains shrink (paper's Sift1M note)
    from benchmarks.common import query_set

    qu = query_set(ds.nb, ds.dim, skew=0.0)
    pu = assign_queries(index, qu)
    fu = _run(index, cfg, qu, rate, balanced=True, stagger=True,
              pipeline=True, pruning=True, probes=pu)
    nu = _run(index, cfg, qu, rate, balanced=False, stagger=True,
              pipeline=True, pruning=True, probes=pu)
    emit("fig9.uniform.balanced_load_gain", 0.0, f"x{fu / nu:.2f}")


if __name__ == "__main__":
    main()
