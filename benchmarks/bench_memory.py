"""Tables 4 + 5: index memory per node and peak query-time memory.
Claims: each distributed node holds ≈ 1/N of the single-node index;
dimension-touching modes add ≤ a few % overhead (per-block norms +
intermediate partial results), diluting as dimension grows.

Tiered extension: the ``table4.d*.tiered`` rows report the segmented
data plane's per-tier split (:meth:`repro.core.SegmentedIndex.
memory_report`) — device bytes at fp32 vs int8 residency (the int8 tier
buys ~4× corpus per HBM byte) and the host-side total (fp32 re-rank
source + metadata + BM25 + quant codes), which a demotion to the host
tier makes the *only* footprint."""

from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, emit, query_set, run_mode
from repro.core import SegmentedIndex, plan_search, preassign


def main():
    print("# table4/5: memory")
    for dim in (64, 128, 256):
        ds, cfg, index = corpus(dim=dim)
        faiss_bytes = index.memory_bytes()
        q = query_set(ds.nb, dim, skew=0.0)
        for mode, nodes in (("vector", 4), ("dimension", 4), ("harmony", 4)):
            d = plan_search(index, nodes, cfg.replace(mode=mode))
            c = preassign(index, d.plan)
            per_node = c.memory_bytes() / d.plan.v_shards / max(d.plan.d_blocks, 1)
            overhead = c.memory_bytes() / (index.x.nbytes + index.ids.nbytes) - 1.0
            res, _, _ = run_mode(index, cfg, q, mode, nodes)
            peak = per_node + res.stats["max_pair_buffer"] * 4
            emit(
                f"table4.d{dim}.{mode}",
                0.0,
                f"faiss_MB={faiss_bytes/2**20:.1f};per_node_MB={per_node/2**20:.1f};"
                f"overhead={overhead:.3f};peak_query_MB={peak/2**20:.1f}",
            )
        data = SegmentedIndex.from_static(index)
        rep32 = data.memory_report(precision="fp32")
        rep8 = data.memory_report(precision="int8")
        data.set_tiers({s.seg_id: "host" for s in data.segments})
        rep_cold = data.memory_report(precision="int8")
        emit(
            f"table4.d{dim}.tiered",
            0.0,
            f"device_fp32_MB={rep32['device_bytes']/2**20:.1f};"
            f"device_int8_MB={rep8['device_bytes']/2**20:.1f};"
            f"host_MB={rep32['host_bytes']/2**20:.1f};"
            f"device_demoted_MB={rep_cold['device_bytes']/2**20:.1f}",
        )


if __name__ == "__main__":
    main()
