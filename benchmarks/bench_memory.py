"""Tables 4 + 5: index memory per node and peak query-time memory.
Claims: each distributed node holds ≈ 1/N of the single-node index;
dimension-touching modes add ≤ a few % overhead (per-block norms +
intermediate partial results), diluting as dimension grows."""

from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, emit, query_set, run_mode
from repro.core import plan_search, preassign


def main():
    print("# table4/5: memory")
    for dim in (64, 128, 256):
        ds, cfg, index = corpus(dim=dim)
        faiss_bytes = index.memory_bytes()
        q = query_set(ds.nb, dim, skew=0.0)
        for mode, nodes in (("vector", 4), ("dimension", 4), ("harmony", 4)):
            d = plan_search(index, nodes, cfg.replace(mode=mode))
            c = preassign(index, d.plan)
            per_node = c.memory_bytes() / d.plan.v_shards / max(d.plan.d_blocks, 1)
            overhead = c.memory_bytes() / (index.x.nbytes + index.ids.nbytes) - 1.0
            res, _, _ = run_mode(index, cfg, q, mode, nodes)
            peak = per_node + res.stats["max_pair_buffer"] * 4
            emit(
                f"table4.d{dim}.{mode}",
                0.0,
                f"faiss_MB={faiss_bytes/2**20:.1f};per_node_MB={per_node/2**20:.1f};"
                f"overhead={overhead:.3f};peak_query_MB={peak/2**20:.1f}",
            )


if __name__ == "__main__":
    main()
