"""Serving-scheduler benchmark: synchronous request-at-a-time serving (the
seed drain loop) vs admission-controlled scheduled serving, replayed on
Poisson and bursty skewed arrival traces (the paper's "heavy traffic"
regime; batching/dispatch is where distributed-ANN QPS is won).

Both paths run under the same virtual-clock replay rules: arrivals come
from the trace, service time is the measured ``search_batch`` wall, and a
single server drains sequentially. Synchronous = a degenerate scheduler
(``max_batch=1``), i.e. every request is its own batch the moment the
server frees up — exactly the old ``HarmonyServer.serve`` list
comprehension. Scheduled = adaptive batches (size ``query_block`` or the
deadline), bounded queue, skew-drift re-planning.

Emits the usual CSV rows plus a JSON blob (stdout + serving_results.json)
with per-scenario QPS, p50/p99 queue wait, and shed counts.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.bench_skew import make_hot_queries
from benchmarks.common import TINY, corpus, emit
from repro.data import make_queries
from repro.serve import HarmonyServer, SchedulerConfig, ServingScheduler

N_REQ = 96 if TINY else 384
N_NODES = 4


def poisson_trace(queries: np.ndarray, rate_qps: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_qps, size=len(queries)))
    return [(float(t[i]), queries[i]) for i in range(len(queries))]


def bursty_trace(queries: np.ndarray, burst: int, gap_s: float):
    """Bursts of ``burst`` simultaneous arrivals every ``gap_s``."""
    return [
        (gap_s * (i // burst), queries[i]) for i in range(len(queries))
    ]


def replay(index, trace, sched_cfg, k=10):
    srv = HarmonyServer(index, n_nodes=N_NODES)
    sched = ServingScheduler(srv, sched_cfg, k=k)
    results = sched.run_trace(trace)
    return {
        "qps": sched.served_qps,
        "served": len(results),
        "makespan_s": sched.makespan_s,
        **srv.stats.summary(),
    }


def main():
    ds, cfg, index = corpus()
    sync_cfg = SchedulerConfig(max_batch=1, max_wait_s=0.0)
    sched_cfg = SchedulerConfig(
        max_batch=cfg.query_block,
        max_wait_s=2e-3,
        replan_drift=0.2,
        min_batches_between_replans=2,
    )
    bursty_cfg = SchedulerConfig(
        max_batch=cfg.query_block,
        max_wait_s=2e-3,
        queue_capacity=2 * cfg.query_block,
        replan_drift=0.2,
        min_batches_between_replans=2,
    )

    q_uniform = make_queries(ds, nq=N_REQ, skew=0.0, noise=0.2, seed=21)
    q_skewed = make_hot_queries(ds, skew=0.9, nq=N_REQ)

    scenarios = {
        "poisson_uniform": (q_uniform, poisson_trace(q_uniform, 2000.0, seed=1),
                            sched_cfg),
        "poisson_skewed": (q_skewed, poisson_trace(q_skewed, 2000.0, seed=2),
                           sched_cfg),
        "bursty_skewed": (q_skewed, bursty_trace(q_skewed, burst=128,
                                                 gap_s=0.05), bursty_cfg),
    }

    print("# serving: sync (request-at-a-time) vs scheduled "
          f"(adaptive batch ≤{cfg.query_block}, deadline 2ms), {N_NODES} nodes")
    report = {}
    for name, (q, trace, scfg) in scenarios.items():
        sync = replay(index, trace, sync_cfg)
        sched = replay(index, trace, scfg)
        report[name] = {"sync": sync, "scheduled": sched}
        # percentile fields are None when a replay completes zero requests
        pct = lambda v: f"{v:.2f}" if v is not None else "na"
        emit(
            f"serving.{name}",
            1e6 / max(sched["qps"], 1e-9),
            f"sched_qps={sched['qps']:.0f};sync_qps={sync['qps']:.0f};"
            f"speedup={sched['qps'] / max(sync['qps'], 1e-9):.2f};"
            f"p50_wait_ms={pct(sched['p50_queue_wait_ms'])};"
            f"p99_wait_ms={pct(sched['p99_queue_wait_ms'])};"
            f"shed={sched['shed']};skew_replans={sched['skew_replans']}",
        )

    ok = (report["poisson_skewed"]["scheduled"]["qps"]
          >= report["poisson_skewed"]["sync"]["qps"])
    emit("serving.claim.sched_ge_sync_skewed", 0.0, f"ok={ok}")
    blob = json.dumps(report, indent=2, sort_keys=True)
    out = Path(__file__).resolve().parent / "serving_results.json"
    out.write_text(blob)
    print(blob)


if __name__ == "__main__":
    main()
