"""Fig. 10: index build time breakdown — Train / Add / Pre-assign per
distribution mode. Claims: train+add identical across modes (the index
structure is unchanged); pre-assign grows with dimension splitting and
data size."""

from __future__ import annotations

import time

from benchmarks.common import corpus, emit
from repro.config import HarmonyConfig
from repro.core import build_ivf, plan_search, preassign
from repro.data import make_dataset


def main():
    print("# fig10: build time breakdown")
    for label, nb in (("1.2m_like", 20_000), ("2.2m_like", 40_000)):
        ds = make_dataset(nb=nb, dim=128, n_components=64, spread=0.6, seed=7)
        cfg = HarmonyConfig(dim=128, nlist=256, nprobe=16, topk=10, kmeans_iters=8)
        index = build_ivf(ds.x, cfg)
        for mode, nodes in (("vector", 4), ("dimension", 4), ("harmony", 4)):
            d = plan_search(index, nodes, cfg.replace(mode=mode))
            c = preassign(index, d.plan)
            emit(
                f"fig10.{label}.{mode}",
                1e6 * (index.build_times["train"] + index.build_times["add"] + c.preassign_time),
                f"train={index.build_times['train']:.2f}s;add={index.build_times['add']:.3f}s;"
                f"preassign={c.preassign_time:.3f}s",
            )


if __name__ == "__main__":
    main()
