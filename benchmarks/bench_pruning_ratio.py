"""Table 3 + Fig. 2(a): per-slice pruning ratios with a 4-way dimension
split. Paper averages: slice2 33.6%, slice3 66.1%, slice4 92.3% (per-
dataset range 1.5–81% at slice 2). Also the Fig. 2(a) motivation: ≥80%
pruned by the later slices on prunable corpora."""

from __future__ import annotations

import numpy as np

from benchmarks.common import corpus, emit, query_set, run_mode


def main():
    print("# table3: per-slice pruning, dimension split B=4")
    # vary spread like the paper varies datasets (Star ↔ Glove difficulty)
    for label, spread in (("tight_star_like", 0.4), ("mid_deep_like", 0.6),
                          ("loose_glove_like", 0.9)):
        ds, cfg, index = corpus(spread=spread, nprobe=32)
        q = query_set(ds.nb, ds.dim, skew=0.0)
        res, qps, _ = run_mode(index, cfg, q, "dimension", 4)
        ratios = res.stats["slice_pruned_ratio"]
        saved = 1 - res.stats["pair_flops"] / res.stats["dense_flops"]
        emit(
            f"table3.{label}",
            0.0,
            "slices=" + "/".join(f"{r:.2f}" for r in ratios)
            + f";flops_saved={saved:.2f}",
        )
    emit("table3.paper_avg", 0.0, "paper_slices=0.00/0.34/0.66/0.92")


if __name__ == "__main__":
    main()
