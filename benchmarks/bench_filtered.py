"""Filtered search: the recall / throughput cost of predicate pushdown.

Claims guarded here (the PR's acceptance bounds):

* recall@10 of filtered search (vs the *filtered* full-coverage ground
  truth) stays within 2 points of unfiltered recall (vs the unfiltered
  ground truth) at every selectivity ≥ 10% — pushdown re-fills probes
  from non-excluded clusters, so a predicate doesn't starve the scan;
* QPS under a filter degrades no worse than linearly with selectivity:
  at selectivity s the filtered path keeps ≥ s × the unfiltered QPS
  (×0.7 measurement slack) — masking is O(candidates), never a rescan.

The sweep runs the host engine through ``HarmonyServer.search_batch``
with a ``SearchRequest(filter=...)`` — the exact serve-path code, probe
pushdown and bitmap caches included. Results fold into
``benchmarks/serving_results.json`` under the ``"filtered"`` key (schema
in benchmarks/README.md).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import TINY, emit
from repro.config import HarmonyConfig
from repro.core import NumRange, SearchRequest, build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import HarmonyServer

SELECTIVITIES = (1.0, 0.5, 0.2, 0.1, 0.01)


def _recall(ids, ref_ids):
    """Mean fraction of the (possibly short) reference set recovered."""
    out = []
    for a, b in zip(ids, ref_ids):
        ref = set(b[b >= 0].tolist())
        if not ref:
            continue
        out.append(len(set(a[a >= 0].tolist()) & ref) / len(ref))
    return float(np.mean(out)) if out else 1.0


def main():
    print("# filtered: predicate pushdown recall/QPS frontier")
    nb, nlist, nprobe = (4000, 32, 8) if TINY else (40_000, 256, 16)
    dim, nq = 128, 64 if TINY else 256
    ds = make_dataset(nb=nb, dim=dim, n_components=64, spread=0.6, seed=7)
    rng = np.random.default_rng(11)
    cfg = HarmonyConfig(dim=dim, nlist=nlist, nprobe=nprobe, topk=10,
                        kmeans_iters=4 if TINY else 8)
    # one uniform numeric column: NumRange("u", 0, s) has selectivity s
    index = build_ivf(ds.x, cfg, meta={"u": rng.uniform(0.0, 1.0, size=nb)})
    q = make_queries(ds, nq=nq, skew=0.3, noise=0.2, seed=3)
    k = cfg.topk
    srv = HarmonyServer(index, n_nodes=4)
    reps = 1 if TINY else 3

    # unfiltered baseline through the same serve path
    srv.search_batch(q, k)                                 # warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        base = srv.search_batch(q, k)
    base_wall = (time.perf_counter() - t0) / reps
    base_qps = nq / base_wall
    base_recall = _recall(base.ids, search_oracle(index, q, k=k).ids)
    emit("filtered.unfiltered_baseline", base_wall / nq * 1e6,
         f"recall={base_recall:.4f};qps={base_qps:.0f}")

    sweep = []
    for s in SELECTIVITIES:
        flt = NumRange("u", 0.0, s)
        req = SearchRequest(vector=q, k=k, filter=flt)
        srv.search_batch(req)                              # warm bitmap
        t0 = time.perf_counter()
        for _ in range(reps):
            res = srv.search_batch(req)
        wall = (time.perf_counter() - t0) / reps
        qps = nq / wall
        truth = search_oracle(index, q, k=k, nprobe=cfg.nlist, flt=flt)
        rec = _recall(res.ids, truth.ids)
        sweep.append({
            "selectivity": s,
            "recall_at_10": rec,
            "recall_drop_vs_unfiltered": base_recall - rec,
            "qps": qps,
            "qps_linear_bound": s * base_qps,
            "us_per_query": wall / nq * 1e6,
        })
        emit(f"filtered.sel{s}", wall / nq * 1e6,
             f"recall={rec:.4f};drop={base_recall - rec:.4f};qps={qps:.0f}")

    ok_recall = all(r["recall_drop_vs_unfiltered"] <= 0.02
                    for r in sweep if r["selectivity"] >= 0.1)
    ok_qps = all(r["qps"] >= 0.7 * r["qps_linear_bound"] for r in sweep)
    emit("filtered.claim.recall_within_2pts_sel_ge_10pct", 0.0,
         f"ok={ok_recall}")
    emit("filtered.claim.qps_no_worse_than_linear", 0.0, f"ok={ok_qps}")

    report = {
        "nb": nb,
        "nprobe": nprobe,
        "unfiltered_recall_at_10": base_recall,
        "unfiltered_qps": base_qps,
        "selectivity_sweep": sweep,
        "claim_recall_within_2pts_sel_ge_10pct": bool(ok_recall),
        "claim_qps_no_worse_than_linear": bool(ok_qps),
    }
    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["filtered"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))
    print(json.dumps({"filtered": report}, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
