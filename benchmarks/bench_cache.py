"""Semantic-cache benchmark: effective served QPS on a Zipfian
repeat-heavy trace, cache off vs exact tier vs semantic tier.

Real query streams are heavily repetitive; this bench replays an
open-loop trace whose queries are drawn Zipf-distributed from a small
pool (rank-``1/r`` weights — a few hot queries dominate, the tail is
long) at an offered rate well above the service model's capacity. The
virtual-clock scheduler replays the identical trace three ways:

* ``off`` — ``cache=None``: every repeat executes; throughput is pinned
  at the service model's capacity and the makespan stretches far past
  the trace span;
* ``exact`` — exact-tier cache + in-batch coalescing: repeats are
  answered from cache at arrival, only (roughly) the distinct pool
  executes, and the makespan collapses toward the trace span;
* ``semantic`` — every request is its pool anchor plus a jitter inside
  half the semantic radius (so any two requests of one anchor are
  within the threshold of each other): the exact tier can never hit,
  the semantic tier serves the repeats.

Service model: ``service_time_fn = n_queries × 1 ms`` on the virtual
clock (the serving benches' standard one-box methodology — deterministic
and machine-independent). Offered load is ``OVERSUBSCRIBE×`` capacity.

Acceptance claim (ISSUE 9): the exact-tier cell serves **≥ 3×** the
cache-off effective QPS on this trace.

Results fold into ``serving_results.json`` under the ``"cache"`` key
(schema in ``benchmarks/README.md``), plus the usual CSV rows.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import TINY, corpus, emit
from repro.core import SearchRequest
from repro.data import make_queries
from repro.serve import (
    CacheConfig,
    HarmonyServer,
    SchedulerConfig,
    ServingScheduler,
)

N_REQ = 256 if TINY else 1024
POOL = 32 if TINY else 64
PER_Q_S = 1e-3          # virtual service model: 1 ms per query row
OVERSUBSCRIBE = 5.0     # offered load / service capacity
SEM_THRESHOLD = 1.0     # squared-L2 semantic radius (score space)


def zipf_trace(pool: np.ndarray, n: int, rate_qps: float, seed: int,
               jitter_r: float = 0.0):
    """Open-loop arrivals at ``rate_qps`` whose queries are drawn from
    ``pool`` with Zipf (1/rank) weights; ``jitter_r > 0`` perturbs every
    draw inside a ball of that radius (the semantic-tier workload)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, len(pool) + 1)
    p /= p.sum()
    picks = rng.choice(len(pool), size=n, p=p)
    trace = []
    for i, pick in enumerate(picks):
        v = pool[pick]
        if jitter_r > 0:
            d = rng.standard_normal(v.shape[0]).astype(np.float32)
            d *= (jitter_r * rng.uniform()) / max(float(np.linalg.norm(d)),
                                                  1e-9)
            v = (v + d).astype(np.float32)
        trace.append((i / rate_qps, SearchRequest(vector=v)))
    return trace


def run_cell(index, cfg, trace, cache) -> dict:
    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=32, max_wait_s=2e-3, cache=cache),
        k=cfg.topk, service_time_fn=lambda n: n * PER_Q_S,
    )
    sched.run_trace(trace)
    st = srv.stats
    # effective request latency: arrival → completion over EVERY served
    # request — cache hits complete at admission (≈0 wait), executed
    # requests pay queue + service, so the percentiles show the cache
    # collapsing the latency distribution, not just the throughput
    lat_ms = np.array([(r.done_s - r.arrival_s) * 1e3 for r in sched.done])
    return {
        "served": len(sched.done),
        "served_qps": sched.served_qps,
        "makespan_s": sched.makespan_s,
        "executed_queries": st.queries,
        "cache_hits_exact": st.cache_hits_exact,
        "cache_hits_semantic": st.cache_hits_semantic,
        "cache_misses": st.cache_misses,
        "coalesced": st.coalesced,
        "p50_latency_ms": float(np.percentile(lat_ms, 50)) if lat_ms.size else None,
        "p99_latency_ms": float(np.percentile(lat_ms, 99)) if lat_ms.size else None,
    }


def main():
    _, cfg, index = corpus(nb=10_000)
    rate_qps = OVERSUBSCRIBE / PER_Q_S
    ds, _, _ = corpus(nb=10_000)
    pool = make_queries(ds, nq=POOL, skew=0.3, noise=0.2, seed=11)

    print(f"# cache: Zipfian repeat trace ({N_REQ} requests over a "
          f"{POOL}-query pool, offered {rate_qps:.0f} q/s vs "
          f"{1.0 / PER_Q_S:.0f} q/s capacity)")
    exact_cfg = CacheConfig(enabled=True, exact_ttl_s=1e9)
    sem_cfg = CacheConfig(enabled=True, exact_ttl_s=1e9,
                          semantic_threshold=SEM_THRESHOLD)
    # same arrival process for every cell; the semantic cell jitters each
    # draw inside HALF the semantic radius, so any two requests of one
    # anchor stay within the threshold of each other
    exact_trace = zipf_trace(pool, N_REQ, rate_qps, seed=5)
    sem_trace = zipf_trace(pool, N_REQ, rate_qps, seed=5,
                           jitter_r=0.5 * float(np.sqrt(SEM_THRESHOLD)))
    report = {
        "n_requests": N_REQ,
        "pool": POOL,
        "offered_qps": rate_qps,
        "per_q_service_s": PER_Q_S,
        "semantic_threshold": SEM_THRESHOLD,
        "cells": {},
    }
    for name, trace, cache in (
        ("off", exact_trace, None),
        ("exact", exact_trace, exact_cfg),
        ("semantic", sem_trace, sem_cfg),
    ):
        cell = run_cell(index, cfg, trace, cache)
        report["cells"][name] = cell
        emit(
            f"cache.zipf.{name}",
            1e6 / max(cell["served_qps"], 1e-9),
            f"served_qps={cell['served_qps']:.0f};"
            f"executed={cell['executed_queries']};"
            f"hits={cell['cache_hits_exact']}+{cell['cache_hits_semantic']};"
            f"coalesced={cell['coalesced']};"
            f"p50_ms={cell['p50_latency_ms']:.2f};"
            f"p99_ms={cell['p99_latency_ms']:.2f}",
        )

    q_off = report["cells"]["off"]["served_qps"]
    q_on = report["cells"]["exact"]["served_qps"]
    p99_off = report["cells"]["off"]["p99_latency_ms"]
    p99_on = report["cells"]["exact"]["p99_latency_ms"]
    # the cache must buy throughput WITHOUT a tail-latency regression:
    # ≥3× effective QPS and cached p99 no worse than uncached p99
    ok = (q_on >= 3.0 * q_off) and (p99_on <= p99_off)
    report["claim_cached_qps_ge_3x_uncached"] = {
        "off_qps": q_off, "exact_qps": q_on,
        "speedup": q_on / max(q_off, 1e-9),
        "off_p99_ms": p99_off, "exact_p99_ms": p99_on,
        "ok": bool(ok),
    }
    emit("cache.claim.cached_qps_ge_3x_uncached", 0.0,
         f"ok={ok};speedup={q_on / max(q_off, 1e-9):.2f};"
         f"p99_off_ms={p99_off:.2f};p99_on_ms={p99_on:.2f}")

    out = Path(__file__).resolve().parent / "serving_results.json"
    blob = json.loads(out.read_text()) if out.exists() else {}
    blob["cache"] = report
    out.write_text(json.dumps(blob, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
