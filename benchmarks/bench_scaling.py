"""Fig. 11: (a) speedup vs dimension/dataset size (Gaussian corpora, like
the paper's §6.5.1); (b) node scaling 4→8→16. Claims: speedup grows with
D and NB; harmony ≥ node count at scale (pruning super-linearity); pure
dimension mode eventually flattens from comm overhead."""

from __future__ import annotations

from benchmarks.common import corpus, emit, oracle_qps, query_set, run_mode


def main():
    print("# fig11a: dims × size, 4 nodes")
    for dim in (64, 128, 256):
        for nb in (10_000, 40_000):
            ds, cfg, index = corpus(nb=nb, dim=dim)
            q = query_set(nb, dim, skew=0.0)
            qps0, _ = oracle_qps(index, q)
            res, qps, _ = run_mode(index, cfg, q, "harmony", 4)
            emit(f"fig11a.d{dim}.n{nb}", 1e6 / qps,
                 f"speedup={qps / qps0:.2f}")
    print("# fig11b: node scaling")
    ds, cfg, index = corpus()
    q = query_set(ds.nb, ds.dim, skew=0.0)
    qps0, _ = oracle_qps(index, q)
    for nodes in (4, 8, 16):
        for mode in ("harmony", "vector", "dimension"):
            res, qps, _ = run_mode(index, cfg, q, mode, nodes)
            emit(f"fig11b.{mode}.n{nodes}", 1e6 / qps,
                 f"speedup={qps / qps0:.2f}")


if __name__ == "__main__":
    main()
