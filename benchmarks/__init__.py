# Benchmark harnesses: one per paper table/figure. Run via
#   PYTHONPATH=src python -m benchmarks.run
