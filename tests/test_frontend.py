"""Real-clock front-end tests: live submit/drain/shutdown, deadline
shedding under a slow target, wall-clock trigger accounting, asyncio
submission, fleet overlap + EWMA thread-safety under concurrent dispatch,
and wall-clock cross-replica hedging.

Kept fast with stub targets wherever real search isn't the point; the
timing assertions are deliberately loose (they check *overlap happened*,
not exact walls) so the suite stays robust on loaded CI boxes."""

import threading
import time

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import (
    DispatchTarget,
    HarmonyServer,
    MonotonicClock,
    ReplicaFleet,
    SchedulerConfig,
    ServeStats,
    ServingFrontend,
    ShedError,
    VirtualClock,
)


class StubResult:
    def __init__(self, n, k):
        self.ids = np.tile(np.arange(k, dtype=np.int64), (n, 1))
        self.scores = np.zeros((n, k), np.float32)


class StubTarget(DispatchTarget):
    """Executes instantly (or after a fixed wall sleep) — isolates the
    front-end's queue/trigger/lifecycle logic from real search."""

    def __init__(self, service_s: float = 0.0, parallel: int = 1):
        self.stats = ServeStats()
        self.service_s = service_s
        self._parallel = parallel
        self.executed = []              # (batch_id, n) in completion order

    def configure(self, cfg, k):
        pass

    def next_free_s(self):
        return 0.0

    def execute(self, queries, k, dispatch_s, batch_id):
        if self.service_s:
            time.sleep(self.service_s)
        self.executed.append((batch_id, queries.shape[0]))
        return StubResult(queries.shape[0], k), dispatch_s + self.service_s

    @property
    def default_max_batch(self):
        return 8

    @property
    def default_k(self):
        return 5

    @property
    def replans(self):
        return 0

    @property
    def nlist(self):
        return 4

    @property
    def parallelism(self):
        return self._parallel


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=2000, dim=16, n_components=6, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=16, nlist=16, nprobe=4, topk=5, kmeans_iters=3)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=64, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


@pytest.fixture(scope="module")
def mini_anns():
    """Tiny corpus for wall-timing tests: real search compute must be
    negligible next to the injected wall service models, or GIL-serialized
    compute across 'replica' threads swamps the timing assertions."""
    ds = make_dataset(nb=512, dim=8, n_components=4, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=8, nlist=8, nprobe=2, topk=5, kmeans_iters=2)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=64, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


# -------------------------------------------------- lifecycle smoke


def test_submit_drain_shutdown_smoke():
    """Live submissions resolve, counters add up, shutdown is graceful
    and idempotent, and post-shutdown submits are refused."""
    target = StubTarget()
    fe = ServingFrontend(target, SchedulerConfig(max_batch=4, max_wait_s=1e-3))
    futs = fe.submit_many(np.zeros((10, 8), np.float32))
    assert fe.drain(timeout=10.0)
    results = [f.result(timeout=10) for f in futs]
    assert [r.req_id for r in results] == list(range(10))
    assert all(r.ids.shape == (5,) for r in results)
    assert fe.stats.offered == fe.stats.admitted == 10
    assert fe.stats.shed == 0
    assert sum(n for _, n in target.executed) == 10
    s = fe.summary()
    assert s["served"] == 10 and s["served_qps"] > 0
    assert s["full_batches"] + s["deadline_batches"] + s["capacity_batches"] \
        == len(target.executed)
    assert fe.shutdown() is True        # clean: drained, dispatcher down
    assert fe.shutdown() is True        # idempotent
    assert not fe._dispatcher.is_alive()
    assert fe.stats.shutdown_leaks == 0
    with pytest.raises(RuntimeError):
        fe.submit(np.zeros(8, np.float32))


def test_shutdown_reports_leaks_like_compactor_stop():
    """shutdown() returns a bool — same contract as Compactor.stop():
    True only when nothing was left running in the background. A drain
    timeout with work still in flight reports False (and the batch
    finishes in the background without being lost)."""
    target = StubTarget(service_s=0.3)
    fe = ServingFrontend(target, SchedulerConfig(max_batch=4, max_wait_s=1e-4))
    futs = fe.submit_many(np.zeros((4, 8), np.float32))
    deadline = time.monotonic() + 5.0
    while not fe._inflight and time.monotonic() < deadline:
        time.sleep(1e-3)                # wait for the batch to be in flight
    assert fe.shutdown(timeout=0.01) is False   # can't drain a 0.3s batch
    results = [f.result(timeout=10) for f in futs]
    assert len(results) == 4            # background completion, not loss
    assert fe.shutdown() is True        # second call finds it all down
    assert fe.stats.shutdown_leaks == 0  # dispatcher itself never leaked


def test_request_timeline_is_wall_ordered():
    """arrival ≤ dispatch ≤ done on the monotonic clock, and queue
    wait/latency accounting matches the future timeline."""
    target = StubTarget(service_s=0.01)
    with ServingFrontend(
        target, SchedulerConfig(max_batch=4, max_wait_s=1e-3)
    ) as fe:
        futs = fe.submit_many(np.zeros((8, 8), np.float32))
        results = [f.result(timeout=10) for f in futs]
    for r in results:
        assert r.arrival_s <= r.dispatch_s <= r.done_s
        assert r.latency_s >= 0.01 - 1e-4       # the stub's service sleep
    assert len(fe.stats.request_latency_ms) == 8


def test_deadline_trigger_fires_small_batches():
    """Arrivals slower than max_wait_s must fire deadline batches on the
    wall clock (the size trigger is never reached)."""
    target = StubTarget()
    with ServingFrontend(
        target, SchedulerConfig(max_batch=64, max_wait_s=5e-3)
    ) as fe:
        for i in range(4):
            fe.submit(np.zeros(8, np.float32)).result(timeout=10)
    assert fe.stats.deadline_batches == 4
    assert fe.stats.full_batches == 0


# -------------------------------------------------- backpressure / shedding


def test_slow_target_sheds_by_backpressure():
    """A burst into a tiny bounded queue behind a slow target sheds: shed
    futures fail with ShedError, counters add up, admitted all serve."""
    target = StubTarget(service_s=0.2)
    with ServingFrontend(
        target,
        SchedulerConfig(max_batch=4, queue_capacity=4, max_wait_s=1e-3),
    ) as fe:
        futs = fe.submit_many(np.zeros((32, 8), np.float32))
        fe.drain(timeout=30.0)
        shed = [f for f in futs if isinstance(f.exception(timeout=10),
                                              ShedError)]
        served = [f for f in futs if f.exception(timeout=10) is None]
    assert fe.stats.offered == 32
    assert fe.stats.shed == len(shed) > 0
    assert fe.stats.admitted == len(served) == 32 - len(shed)
    assert all(f.result().ids.shape == (5,) for f in served)


# -------------------------------------------------- asyncio surface


def test_asubmit_asyncio_roundtrip():
    import asyncio

    target = StubTarget()

    async def drive(fe):
        results = await asyncio.gather(
            *(fe.asubmit(np.zeros(8, np.float32)) for _ in range(6))
        )
        return results

    with ServingFrontend(
        target, SchedulerConfig(max_batch=4, max_wait_s=1e-3)
    ) as fe:
        results = asyncio.run(drive(fe))
    assert sorted(r.req_id for r in results) == list(range(6))


# -------------------------------------------------- fleet: overlap + safety


def test_fleet_overlaps_replica_execution_on_wall_clock(mini_anns):
    """4 replicas with an 8ms/query wall service model must serve a
    saturating burst with real overlap: wall makespan well below the
    serial sum of service times (the whole point of the real-clock
    front-end), and every result stays exact."""
    ds, cfg, index, q = mini_anns
    # service model well above the mini corpus's real per-batch compute,
    # so the sleeps (which overlap on any core count) dominate the wall
    # and the assertion isn't at the mercy of CI compute contention
    per_q = 8e-3
    # least_loaded (not p2c) so the spread is deterministic given the
    # in-flight reservations — the test measures overlap machinery, not
    # p2c's sampling variance
    fleet = ReplicaFleet(index, replicas=4, cfg=cfg, routing="least_loaded",
                         service_time_fn=lambda r, n: n * per_q, seed=0)
    with ServingFrontend(
        fleet, SchedulerConfig(max_batch=8, max_wait_s=1e-3), k=5
    ) as fe:
        assert fe.max_inflight == 4     # target.parallelism default
        futs = fe.submit_many(q)
        results = [f.result(timeout=60) for f in futs]
    serial_s = len(q) * per_q           # one replica, back to back
    assert fe.makespan_s < 0.6 * serial_s, (
        f"no overlap: makespan {fe.makespan_s:.3f}s vs serial "
        f"{serial_s:.3f}s"
    )
    assert sum(r.batches for r in fleet.replicas) == len(q) // 8
    assert sum(1 for r in fleet.replicas if r.batches > 0) >= 2
    oracle = search_oracle(index, q, k=5)
    got = np.stack(
        [r.scores for r in sorted(results, key=lambda r: r.req_id)]
    )
    np.testing.assert_allclose(got, oracle.scores, rtol=1e-3, atol=1e-3)


def test_fleet_ewma_accounting_safe_under_concurrent_dispatch(anns):
    """Hammer the fleet's shared accounting from many threads directly:
    counters must come out exact (no lost increments) and both EWMAs
    converge to the injected service model."""
    ds, cfg, index, q = anns
    fleet = ReplicaFleet(index, replicas=4, cfg=cfg, seed=0)
    per_q = 1e-3
    n_threads, per_thread, n_q = 8, 50, 4

    def hammer(tid):
        rep = fleet.replicas[tid % 4]
        for _ in range(per_thread):
            fleet._record_service(rep, n_q, n_q * per_q,
                                  done_s=fleet._last_done_s + n_q * per_q)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * per_thread
    assert sum(r.batches for r in fleet.replicas) == total
    assert sum(r.queries for r in fleet.replicas) == total * n_q
    for rep in fleet.replicas:
        assert rep.batches == (n_threads // 4) * per_thread
        assert rep.ewma_per_q_s == pytest.approx(per_q)
        assert rep.busy_s == pytest.approx(rep.batches * n_q * per_q)
    assert fleet._fleet_ewma_norm_per_q == pytest.approx(per_q)


def test_fleet_wall_hedge_fires_and_preserves_results(mini_anns):
    """A replica whose wall service model straggles past the hedge
    deadline gets hedged for real: the batch re-runs on another replica,
    the first finisher wins, and results stay exact."""
    ds, cfg, index, q = mini_anns
    # replica 0's wall service model straggles 0.4s; the 50ms hedge
    # deadline sits well above the fast replicas' contended real compute
    # (so only genuine stragglers hedge) and well below the straggle (so
    # the hedge target always finishes first)
    fleet = ReplicaFleet(
        index, replicas=3, cfg=cfg, routing="least_loaded",
        service_time_fn=lambda r, n: 0.4 if r == 0 else 1e-3, seed=0,
    )
    with ServingFrontend(
        fleet,
        SchedulerConfig(max_batch=8, max_wait_s=1e-3, hedge_deadline_s=0.05),
        k=5,
    ) as fe:
        futs = fe.submit_many(q[:32])
        results = [f.result(timeout=60) for f in futs]
    hs = fleet._hedge.stats
    assert hs.hedged >= 1
    assert hs.hedge_wins >= 1           # the 1ms replicas beat the 250ms one
    assert fleet.stats.hedged_batches == hs.hedged
    oracle = search_oracle(index, q[:32], k=5)
    got = np.stack(
        [r.scores for r in sorted(results, key=lambda r: r.req_id)]
    )
    np.testing.assert_allclose(got, oracle.scores, rtol=1e-3, atol=1e-3)


# -------------------------------------------------- single real server


def test_single_server_frontend_matches_oracle(anns):
    """The front-end over one real HarmonyServer returns oracle-exact
    results for live submissions."""
    ds, cfg, index, q = anns
    srv = HarmonyServer(index, n_nodes=4)
    with ServingFrontend(
        srv, SchedulerConfig(max_batch=16, max_wait_s=1e-3), k=5
    ) as fe:
        futs = fe.submit_many(q)
        results = [f.result(timeout=60) for f in futs]
    assert fe.stats.admitted == len(q)
    oracle = search_oracle(index, q, k=5)
    got = np.stack(
        [r.scores for r in sorted(results, key=lambda r: r.req_id)]
    )
    np.testing.assert_allclose(got, oracle.scores, rtol=1e-3, atol=1e-3)


# -------------------------------------------------- clock unit behaviour


def test_clocks():
    v = VirtualClock()
    assert v.now() == 0.0
    v.advance_to(2.0)
    v.advance_to(1.0)                   # never backwards
    assert v.now() == 2.0
    v.sleep(10.0)                       # no-op: virtual time is trace-driven
    assert v.now() == 2.0
    m = MonotonicClock()
    t0 = m.now()
    m.sleep(0.005)
    assert m.now() - t0 >= 0.004
    m.advance_to(1e9)                   # no-op on a wall clock
    assert m.now() < 1e6
