"""Crash-safe write path: WAL framing/repair, checkpoint rotation, and
recovery that loses zero acknowledged writes.

The contract under test (PR 7's tentpole): every write acknowledged by
the data plane is durable — a crash at *any* instant (mid-WAL-record,
mid-checkpoint, between the two) recovers to exactly the acknowledged
prefix. The only record a crash may drop is one that tore mid-write,
which by construction was never acknowledged.
"""

import numpy as np
import pytest

from repro.checkpoint import (
    Checkpointer,
    WriteAheadLog,
    checkpoint_segmented_index,
    read_wal,
    recover_segmented_index,
    replay_wal_into,
)
from repro.config import HarmonyConfig
from repro.core import SegmentedIndex
from repro.runtime.faults import FaultSpec, InjectedFault, fault_scope

CFG = HarmonyConfig(dim=8, nlist=4, nprobe=4, topk=4, kmeans_iters=2)


def _plane(seed=0, nb=64):
    rng = np.random.default_rng(seed)
    return SegmentedIndex.build(
        rng.standard_normal((nb, 8)).astype(np.float32), CFG
    ), rng


def _assert_same_live_set(data, model: dict, deleted: set):
    for i in model:
        assert data.has(i), f"acknowledged id {i} lost"
    for i in deleted:
        if i not in model:
            assert not data.has(i), f"deleted id {i} resurfaced"


# ------------------------------------------------------------------ framing
def test_wal_roundtrip(tmp_path):
    wal = WriteAheadLog(tmp_path, sync=False)
    v = np.arange(12, dtype=np.float32).reshape(3, 4)
    assert wal.append_upsert(np.array([5, 6, 7]), v) == 1
    assert wal.append_delete(np.array([6])) == 2
    wal.close()
    r = read_wal(wal.path)
    assert not r.torn_tail and r.last_seq == 2
    up, de = r.records
    assert up.kind == "upsert" and de.kind == "delete"
    np.testing.assert_array_equal(up.ids, [5, 6, 7])
    np.testing.assert_array_equal(up.vecs, v)
    np.testing.assert_array_equal(de.ids, [6])
    assert de.vecs is None


def test_wal_torn_tail_at_every_byte(tmp_path):
    """Truncating the file anywhere inside the final record yields the
    intact prefix — never garbage, never a lost *earlier* record."""
    wal = WriteAheadLog(tmp_path, sync=False)
    wal.append_upsert(np.array([1]), np.ones((1, 4), np.float32))
    wal.append_delete(np.array([2, 3]))
    wal.append_upsert(np.array([4]), np.full((1, 4), 2, np.float32))
    wal.close()
    blob = wal.path.read_bytes()
    full = read_wal(wal.path)
    assert [rec.seq for rec in full.records] == [1, 2, 3]
    second_end = full.records[1].end_offset
    for cut in range(second_end, len(blob)):
        wal.path.write_bytes(blob[:cut])
        r = read_wal(wal.path)
        assert [rec.seq for rec in r.records] == [1, 2]
        assert r.torn_tail == (cut > second_end)
        assert r.valid_bytes == second_end


def test_wal_reopen_repairs_and_continues_seq(tmp_path):
    wal = WriteAheadLog(tmp_path, sync=False)
    wal.append_upsert(np.array([1]), np.ones((1, 4), np.float32))
    wal.append_upsert(np.array([2]), np.ones((1, 4), np.float32))
    wal.close()
    # tear the tail (crash mid-write of record 2)
    blob = wal.path.read_bytes()
    wal.path.write_bytes(blob[:-5])
    wal2 = WriteAheadLog(tmp_path, sync=False)
    assert wal2.last_seq == 1                   # torn record dropped
    assert wal2.append_delete(np.array([9])) == 2   # seq continues
    wal2.close()
    r = read_wal(wal2.path)
    assert not r.torn_tail
    assert [(rec.seq, rec.kind) for rec in r.records] == [
        (1, "upsert"), (2, "delete")
    ]


def test_wal_torn_write_injection(tmp_path):
    """A kind="torn" fault persists a partial frame then dies — the op
    is unacknowledged, and recovery must treat it as never written."""
    wal = WriteAheadLog(tmp_path, sync=False)
    wal.append_upsert(np.array([1]), np.ones((1, 4), np.float32))
    with fault_scope(FaultSpec("wal.append", kind="torn")):
        with pytest.raises(InjectedFault):
            wal.append_upsert(np.array([2]), np.ones((1, 4), np.float32))
    wal.close()
    r = read_wal(wal.path)
    assert r.torn_tail and [rec.seq for rec in r.records] == [1]
    # reopening repairs the tear and the next append lands cleanly
    wal2 = WriteAheadLog(tmp_path, sync=False)
    assert wal2.append_delete(np.array([1])) == 2
    wal2.close()
    r2 = read_wal(wal2.path)
    assert not r2.torn_tail and r2.last_seq == 2


# ----------------------------------------------------------------- rotation
def test_rotation_prunes_only_covered_files(tmp_path):
    wal = WriteAheadLog(tmp_path, sync=False)
    wal.append_upsert(np.array([1]), np.ones((1, 4), np.float32))
    wal.append_upsert(np.array([2]), np.ones((1, 4), np.float32))
    wal.rotate(step=1, prune_up_to_seq=1)       # record 2 NOT covered
    assert len(wal.files()) == 2                # old file kept
    wal.append_delete(np.array([2]))
    wal.rotate(step=2, prune_up_to_seq=3)       # everything covered now
    assert [p.name for p in wal.files()] == ["wal_000000002.log"]
    wal.close()


def test_checkpoint_and_recover_equals_oracle(tmp_path):
    data, rng = _plane()
    ckpt = Checkpointer(tmp_path / "ckpt", keep=3)
    wal = WriteAheadLog(tmp_path / "wal", sync=False)
    data.attach_wal(wal)

    model = {i: None for i in range(64)}
    deleted = set()

    def upsert(ids):
        vecs = rng.standard_normal((len(ids), 8)).astype(np.float32)
        data.upsert(np.asarray(ids, np.int64), vecs)
        for j, i in enumerate(ids):
            model[i] = vecs[j]
            deleted.discard(i)

    def delete(ids):
        data.delete(np.asarray(ids, np.int64))
        for i in ids:
            model.pop(i, None)
            deleted.add(i)

    upsert([100, 101])
    delete([0, 1])
    checkpoint_segmented_index(ckpt, data, wal)     # durable point
    upsert([102])
    delete([100, 2])
    upsert([2])                                     # resurrect id 2
    wal.close()                                     # crash here

    data2, wal2, report = recover_segmented_index(
        ckpt, tmp_path / "wal", cfg=CFG, sync=False
    )
    assert report["replayed"] == 3 and not report["torn_tail"]
    assert data2.wal_seq == data.wal_seq
    _assert_same_live_set(data2, model, deleted)
    # recovered vectors are the acknowledged ones: the resurrected id 2
    # answers a query for its (new) vector at distance ~0
    from repro.serve import HarmonyServer

    srv = HarmonyServer(data2, n_nodes=2)
    res = srv.search_batch(model[2][None], k=1)
    assert int(res.ids[0, 0]) == 2
    assert float(res.scores[0, 0]) < 1e-6
    # journaling continues on the recovered plane
    data2.upsert(np.array([500]), rng.standard_normal((1, 8)).astype(np.float32))
    assert wal2.last_seq == data2.wal_seq
    wal2.close()


def test_recover_without_checkpoint_cold_start(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal", sync=False)
    wal.append_upsert(np.array([7]), np.ones((1, 8), np.float32))
    wal.close()
    ckpt = Checkpointer(tmp_path / "ckpt")
    with pytest.warns(UserWarning, match="recovering from WAL alone"):
        data, wal2, report = recover_segmented_index(
            ckpt, tmp_path / "wal", cfg=CFG, sync=False
        )
    assert report["replayed"] == 1 and data.has(7)
    wal2.close()
    with pytest.raises(FileNotFoundError):
        recover_segmented_index(Checkpointer(tmp_path / "ckpt2"),
                                tmp_path / "wal")


def test_replay_refuses_attached_wal(tmp_path):
    data, _ = _plane()
    wal = WriteAheadLog(tmp_path, sync=False)
    data.attach_wal(wal)
    with pytest.raises(RuntimeError, match="detach"):
        replay_wal_into(data, tmp_path)
    wal.close()


# ----------------------------------------------------- checkpointer atomics
def test_checkpointer_crash_atomic_write_and_publish(tmp_path):
    """A crash inside the checkpoint write or in the publish window never
    leaves a corrupt step dir — recovery falls back to the previous
    step, and the next save of the same step sweeps the litter."""
    ckpt = Checkpointer(tmp_path, keep=3)
    tree0 = {"w": np.arange(4, dtype=np.float32)}
    ckpt.save(0, tree0)

    for site in ("checkpoint.write", "checkpoint.publish"):
        with fault_scope(FaultSpec(site, kind="crash", where={"step": 1})):
            with pytest.raises(InjectedFault):
                ckpt.save(1, {"w": np.full(4, 9, np.float32)})
        assert ckpt.all_steps() == [0], site     # no torn step published
        _, arrays = ckpt.load_arrays()
        np.testing.assert_array_equal(arrays["w"], tree0["w"])

    # the interrupted save left .tmp litter; a clean save sweeps it
    ckpt.save(1, {"w": np.full(4, 7, np.float32)})
    assert ckpt.all_steps() == [0, 1]
    assert not list(tmp_path.glob(".tmp_step_*"))
    assert not list(tmp_path.glob(".old_step_*"))
    _, arrays = ckpt.load_arrays()
    np.testing.assert_array_equal(arrays["w"], np.full(4, 7, np.float32))


def test_checkpointer_overwrite_publish_crash_keeps_old_copy(tmp_path):
    """Re-saving an existing step crashes between the two renames: the
    old copy was moved aside, not deleted — recovery renames it back
    (it is the previously *published* step 1, complete and fsynced),
    so the newest step survives its own interrupted overwrite."""
    ckpt = Checkpointer(tmp_path, keep=3)
    ckpt.save(0, {"w": np.zeros(2, np.float32)})
    ckpt.save(1, {"w": np.ones(2, np.float32)})
    with fault_scope(FaultSpec("checkpoint.publish", kind="crash",
                               where={"step": 1})):
        with pytest.raises(InjectedFault):
            ckpt.save(1, {"w": np.full(2, 5, np.float32)})
    assert ckpt.all_steps() == [0]          # step 1 is mid-publish
    with pytest.warns(UserWarning, match="interrupted overwrite"):
        _, arrays = ckpt.load_arrays()
    np.testing.assert_array_equal(arrays["w"], np.ones(2, np.float32))
    assert ckpt.all_steps() == [0, 1]       # repair is durable


def test_checkpointer_publish_crash_on_only_step_is_recoverable(tmp_path):
    """Found by P9: overwriting the ONLY step (step = generation, which
    never changes without compaction) and crashing mid-publish used to
    leave no step dir at all — unrecoverable, even though the WAL had
    already pruned records that checkpoint covered. The moved-aside
    copy must be restored, not swept as litter."""
    ckpt = Checkpointer(tmp_path, keep=3)
    ckpt.save(0, {"w": np.zeros(2, np.float32)})
    with fault_scope(FaultSpec("checkpoint.publish", kind="crash")):
        with pytest.raises(InjectedFault):
            ckpt.save(0, {"w": np.ones(2, np.float32)})
    assert ckpt.all_steps() == []           # the crash window, verbatim
    with pytest.warns(UserWarning, match="interrupted overwrite"):
        _, arrays = ckpt.load_arrays()
    np.testing.assert_array_equal(arrays["w"], np.zeros(2, np.float32))
    # a later clean save must not have its _gc destroy the restored copy
    ckpt.save(0, {"w": np.full(2, 7, np.float32)})
    _, arrays = ckpt.load_arrays()
    np.testing.assert_array_equal(arrays["w"], np.full(2, 7, np.float32))


def test_load_arrays_skips_unreadable_step_with_warning(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=3)
    ckpt.save(0, {"w": np.zeros(2, np.float32)})
    ckpt.save(1, {"w": np.ones(2, np.float32)})
    # externally corrupt the newest step (models pre-atomic damage)
    (tmp_path / "step_000000001" / "arrays.npz").write_bytes(b"garbage")
    with pytest.warns(UserWarning, match="skipping unreadable"):
        _, arrays = ckpt.load_arrays()
    np.testing.assert_array_equal(arrays["w"], np.zeros(2, np.float32))
    # an explicit step still raises — the caller asked for that one
    with pytest.raises(Exception):
        ckpt.load_arrays(step=1)
    # restore() takes the same fallback
    with pytest.warns(UserWarning, match="skipping unreadable"):
        out = ckpt.restore({"w": np.zeros(2, np.float32)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(2))


def test_async_checkpoint_crash_is_surfaced(tmp_path):
    ckpt = Checkpointer(tmp_path, keep=3, async_write=True)
    ckpt.save(0, {"w": np.zeros(2, np.float32)})
    ckpt.wait()
    with fault_scope(FaultSpec("checkpoint.write", kind="crash")):
        with pytest.warns(UserWarning, match="async checkpoint write failed"):
            ckpt.save(1, {"w": np.ones(2, np.float32)})
            ckpt.wait()
    assert ckpt.errors and "InjectedFault" in ckpt.errors[0]
    assert ckpt.all_steps() == [0]
