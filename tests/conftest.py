"""Test-suite conftest.

Besides the usual pytest hook point, its presence puts ``tests/`` on
``sys.path`` (rootdir conftest, prepend import mode), so shared test
helpers — :mod:`cache_invariants`, the body of invariant P11 used by
both ``tests/test_cache.py`` and ``tests/properties/test_props.py`` —
import as plain top-level modules from any test directory.
"""
