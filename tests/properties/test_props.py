"""Property-based tests (hypothesis) for the system's invariants.

The invariants that make HARMONY's pruning *exact* rather than heuristic:

  P1  partial L2 sums over disjoint dimension blocks are non-decreasing;
  P2  any τ ≥ final kth-best distance never prunes a true top-K member —
      the full engine equals the oracle for arbitrary corpora/plans;
  P3  the distributed heap-merge of per-shard top-Ks equals global top-K;
  P4  the kernel's block accumulation reconstructs exact distances for
      any dimension split;
  P5  cost-model sanity: loads are non-negative, uniform workloads have
      zero imbalance, adding dimension blocks never increases the
      (pruning-discounted) per-node compute;
  P6  int8 error-feedback compression drift stays bounded by one
      quantization step;
  P7  arbitrary interleavings of upsert/delete/seal/merge on the mutable
      segmented data plane match a brute-force oracle over the live
      vector set on both serving backends — deleted ids never resurface,
      upserted ids are always reachable;
  P8  the fused-kernel ``merge_topk`` equals the host heap merge for any
      part layout — including external ids at the int32 boundary, where
      the fused path must fall back to the heap instead of wrapping;
  P9  crash safety of the write path: killing the process at an
      arbitrary WAL record boundary, mid-checkpoint, or at any
      compaction phase, then recovering (checkpoint + WAL-tail replay),
      reproduces exactly the brute-force oracle of *acknowledged*
      upserts/deletes on both serving backends — acknowledged writes
      never lost, unacknowledged (torn) writes never resurrected;
  P10 filtered search is exact: for random per-row metadata and random
      filter expression trees (TagIn/NumRange under And/Or), serving at
      full coverage equals the brute-force oracle restricted to the
      filter's allowed set — on both backends, under both precisions
      (the int8 two-stage re-rank included), across seal/merge, and
      interacting correctly with tombstones; rows without metadata and
      disallowed/deleted ids never appear;
  P11 staleness-bounded cache correctness: under arbitrary
      interleavings of search/upsert/delete/compaction with the
      semantic cache enabled (staleness budget 0), exact-tier hits and
      misses are bit-identical to a cache-off twin execution, semantic
      hits stay within the distance threshold of the fresh answer and
      never serve a deleted id, and no hit is ever served across a
      generation swap — on both serving backends, fp32 and int8 (body
      shared with tests/test_cache.py via tests/cache_invariants.py).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis extra")
from hypothesis import given, settings, strategies as st

from repro.config import HarmonyConfig
from repro.core import (
    TopKHeap,
    build_ivf,
    harmony_search,
    plan_search,
    preassign,
    search_oracle,
)
from repro.core.cost_model import HardwareModel, WorkloadStats, per_node_loads, plan_cost
from repro.core.index import dim_block_bounds
from repro.core.types import PartitionPlan

SETTINGS = dict(max_examples=15, deadline=None)


@given(
    d=st.integers(4, 96),
    blocks=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_p1_partial_sums_monotone(d, blocks, seed):
    rng = np.random.default_rng(seed)
    p = rng.normal(size=(d,)).astype(np.float32)
    q = rng.normal(size=(d,)).astype(np.float32)
    bounds = dim_block_bounds(d, blocks)
    running = 0.0
    prev = 0.0
    for lo, hi in bounds:
        running += float(np.sum((p[lo:hi] - q[lo:hi]) ** 2))
        assert running >= prev - 1e-6
        prev = running
    assert np.isclose(running, float(np.sum((p - q) ** 2)), rtol=1e-4, atol=1e-4)


@given(
    nb=st.integers(300, 1200),
    dim=st.sampled_from([16, 32, 48]),
    nodes=st.sampled_from([2, 4, 6]),
    mode=st.sampled_from(["harmony", "vector", "dimension"]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=8, deadline=None)
def test_p2_engine_equals_oracle_any_plan(nb, dim, nodes, mode, seed):
    from repro.data import make_dataset, make_queries

    ds = make_dataset(nb=nb, dim=dim, n_components=6, spread=0.7, seed=seed)
    cfg = HarmonyConfig(dim=dim, nlist=8, nprobe=3, topk=5, kmeans_iters=3)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=12, skew=0.5, noise=0.4, seed=seed + 1)
    decision = plan_search(index, nodes, cfg.replace(mode=mode))
    corpus = preassign(index, decision.plan)
    got = harmony_search(index, corpus, q)
    want = search_oracle(index, q)
    finite = np.isfinite(want.scores)
    np.testing.assert_allclose(got.scores[finite], want.scores[finite],
                               rtol=1e-3, atol=1e-3)


@given(
    n_shards=st.integers(1, 6),
    per_shard=st.integers(1, 30),
    k=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_p3_shard_merge_equals_global_topk(n_shards, per_shard, k, seed):
    rng = np.random.default_rng(seed)
    nq = 5
    all_scores, all_ids = [], []
    heap = TopKHeap.empty(nq, k)
    next_id = 0
    for _ in range(n_shards):
        sc = rng.uniform(0, 100, size=(nq, per_shard)).astype(np.float32)
        ids = np.arange(next_id, next_id + per_shard, dtype=np.int64)
        next_id += per_shard
        all_scores.append(sc)
        all_ids.append(np.broadcast_to(ids, sc.shape))
        heap.merge_rows(np.arange(nq), sc, np.broadcast_to(ids, sc.shape))
    cat_s = np.concatenate(all_scores, axis=1)
    cat_i = np.concatenate(all_ids, axis=1)
    order = np.argsort(cat_s, axis=1, kind="stable")[:, :k]
    want_s = np.take_along_axis(cat_s, order, axis=1)
    kk = min(k, cat_s.shape[1])
    np.testing.assert_allclose(heap.scores[:, :kk], want_s[:, :kk], rtol=1e-6)


@given(
    m=st.integers(1, 20),
    n=st.integers(1, 40),
    d=st.sampled_from([8, 24, 64]),
    blocks=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=10, deadline=None)
def test_p4_kernel_block_accumulation_exact(m, n, d, blocks, seed):
    import jax.numpy as jnp

    from repro.kernels.ref import partial_distance_update_ref

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(m, d)).astype(np.float32)
    acc = jnp.zeros((m, n), jnp.float32)
    tau = jnp.full((m,), jnp.inf, jnp.float32)
    for lo, hi in dim_block_bounds(d, blocks):
        xb, qb = x[:, lo:hi], q[:, lo:hi]
        acc = partial_distance_update_ref(
            jnp.asarray(xb), jnp.asarray((xb ** 2).sum(1)),
            jnp.asarray(qb), jnp.asarray((qb ** 2).sum(1)), acc, tau,
        )
    want = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(acc), want, rtol=5e-4, atol=5e-4)


@given(
    nlist=st.integers(2, 32),
    v=st.integers(1, 8),
    b=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_p5_cost_model_sanity(nlist, v, b, seed):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 100, size=nlist).astype(np.float64)
    w = WorkloadStats(
        cluster_sizes=sizes,
        cluster_hits=np.ones(nlist),
        dim=64, nq=16, topk=5,
    )
    plan = PartitionPlan(
        v_shards=v, d_blocks=b,
        cluster_to_shard=(np.arange(nlist) % v).astype(np.int32),
    )
    loads = per_node_loads(plan, w)
    assert (loads >= 0).all()
    assert len(loads) == v * b
    c = plan_cost(plan, w, HardwareModel())
    assert c["cost"] > 0 and c["comp_s"] >= 0 and c["comm_s"] >= 0
    # uniform load across a single shard ⇒ zero imbalance
    if v == 1:
        assert np.isclose(c["imbalance_s"], 0.0)
    # pruning never increases compute
    c_noprune = plan_cost(plan, w, HardwareModel(), enable_pruning=False)
    assert c["comp_s"] <= c_noprune["comp_s"] + 1e-12


@given(
    n=st.integers(8, 256),
    steps=st.integers(1, 40),
    scale=st.floats(0.01, 10.0),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_p6_error_feedback_bounded_drift(n, steps, scale, seed):
    import jax.numpy as jnp

    from repro.train.compression import compress_with_feedback, dequantize_int8

    rng = np.random.default_rng(seed)
    err = jnp.zeros((n,), jnp.float32)
    sent = np.zeros(n, np.float32)
    true = np.zeros(n, np.float32)
    max_scale = 0.0
    for _ in range(steps):
        g = jnp.asarray((scale * rng.normal(size=(n,))).astype(np.float32))
        qv, s, err = compress_with_feedback(g, err)
        sent += np.asarray(dequantize_int8(qv, s))
        true += np.asarray(g)
        max_scale = max(max_scale, float(s))
    # drift = current residual, bounded by one quantization step
    assert np.abs(sent - true).max() <= max_scale * 0.5 + 1e-5


@given(
    data_seed=st.integers(0, 50),
    backend=st.sampled_from(["host", "spmd"]),
    ops=st.lists(
        st.tuples(
            st.sampled_from(["insert", "overwrite", "delete", "seal", "merge"]),
            st.integers(0, 10_000),
        ),
        min_size=1, max_size=10,
    ),
)
@settings(max_examples=6, deadline=None)
def test_p7_mutable_interleavings_match_bruteforce(data_seed, backend, ops):
    from repro.core import SegmentedIndex
    from repro.core.pruning import exact_scores
    from repro.serve import HarmonyServer
    from repro.serve.executor import ExecutorConfig

    nb, dim, k = 96, 8, 4
    rng0 = np.random.default_rng(data_seed)
    x = rng0.standard_normal((nb, dim)).astype(np.float32)
    # nprobe = nlist: probe everything, so IVF search is exact and the
    # clustering-independent brute-force oracle applies at every step
    cfg = HarmonyConfig(dim=dim, nlist=4, nprobe=4, topk=k, kmeans_iters=2)
    data = SegmentedIndex.build(x, cfg)
    srv = HarmonyServer(
        data, n_nodes=2, backend=backend,
        executor_cfg=ExecutorConfig(qb_buckets=(8,), chunk=64,
                                    use_pallas=False),
    )
    model = {i: x[i].copy() for i in range(nb)}
    deleted: set = set()
    next_id = nb
    for kind, s in ops:
        r = np.random.default_rng(s)
        if kind == "insert":
            v = r.standard_normal((1, dim)).astype(np.float32)
            srv.upsert([next_id], v)
            model[next_id] = v[0]
            deleted.discard(next_id)
            next_id += 1
        elif kind == "overwrite" and model:
            tid = sorted(model)[int(r.integers(0, len(model)))]
            v = r.standard_normal((1, dim)).astype(np.float32)
            srv.upsert([tid], v)
            model[tid] = v[0]
        elif kind == "delete" and model:
            tid = sorted(model)[int(r.integers(0, len(model)))]
            srv.delete([tid])
            del model[tid]
            deleted.add(tid)
        elif kind == "seal":
            data.compact_inline(merge_all=False)    # lazy adopt next batch
        elif kind == "merge":
            data.compact_inline(merge_all=True)

    q = rng0.standard_normal((4, dim)).astype(np.float32)
    if model:
        # every upserted id is reachable: query its own vector exactly
        probe_id = sorted(model)[-1]
        q[0] = model[probe_id]
    res = srv.search_batch(q, k=k)
    if not model:
        assert (res.ids == -1).all()
        return
    ids_m = np.array(sorted(model), np.int64)
    xs = np.stack([model[i] for i in ids_m])
    sc = exact_scores(xs, q, cfg.metric)
    order = np.argsort(sc, axis=1, kind="stable")[:, :k]
    want_s = np.full((4, k), np.inf, np.float32)
    kk = min(k, len(model))
    want_s[:, :kk] = np.take_along_axis(sc, order, axis=1)[:, :kk]
    finite = np.isfinite(want_s)
    np.testing.assert_allclose(res.scores[finite], want_s[finite],
                               rtol=1e-3, atol=1e-3)
    assert not np.isin(res.ids, list(deleted) or [-999]).any()
    # the upserted id is reachable by its own vector (distance 0; a
    # duplicate vector may tie, but the id must be in the top-k)
    assert probe_id in res.ids[0]


@given(
    nq=st.integers(1, 6),
    k=st.integers(1, 8),
    widths=st.lists(st.integers(1, 12), min_size=1, max_size=5),
    huge_ids=st.booleans(),
    dup_scores=st.booleans(),
    seed=st.integers(0, 10_000),
)
@settings(**SETTINGS)
def test_p8_fused_merge_topk_equals_heap(nq, k, widths, huge_ids,
                                         dup_scores, seed):
    from repro.core import merge_topk

    rng = np.random.default_rng(seed)
    i32max = np.iinfo(np.int32).max
    parts = []
    next_id = 0
    for w in widths:
        sc = rng.uniform(0, 10, size=(nq, w)).astype(np.float32)
        if dup_scores:
            # quantize scores to force ties across and within parts
            sc = np.round(sc).astype(np.float32)
        ids = np.arange(next_id, next_id + w, dtype=np.int64)
        next_id += w
        parts.append((sc, np.broadcast_to(ids, sc.shape).copy()))
    if huge_ids:
        # ids straddling the int32 boundary must force the host fallback
        # (an int32 cast would wrap them into valid-looking ids)
        parts[-1][1][:, -1] = i32max + 1
        if parts[-1][1].shape[1] > 1:
            parts[-1][1][:, -2] = i32max - 1
    fused_s, fused_i = merge_topk(parts, k, fused=True)
    host_s, host_i = merge_topk(parts, k, fused=False)
    np.testing.assert_allclose(fused_s, host_s, rtol=1e-6, atol=1e-7)
    assert (fused_i[~np.isfinite(fused_s)] == -1).all()
    assert np.abs(fused_i).max(initial=0) <= max(
        1,  # -1 padding sentinel
        max(np.abs(np.asarray(ids)).max() for _, ids in parts),
    )
    # both paths agree exactly on ids except across equal-score ties,
    # where each id they disagree on must carry the same score
    total = np.concatenate([s for s, _ in parts], axis=1)
    id_cat = np.concatenate([i for _, i in parts], axis=1)
    score_of = [
        dict(zip(id_cat[r].tolist(), total[r].tolist())) for r in range(nq)
    ]
    for r in range(nq):
        for a, b, s in zip(fused_i[r], host_i[r], host_s[r]):
            if a != b:
                assert np.isfinite(s)
                np.testing.assert_allclose(score_of[r][int(a)], s, rtol=1e-6)
                np.testing.assert_allclose(score_of[r][int(b)], s, rtol=1e-6)
    # determinism: the same parts merge to the same result, both paths
    f2 = merge_topk(parts, k, fused=True)
    h2 = merge_topk(parts, k, fused=False)
    assert np.array_equal(f2[1], fused_i) and np.array_equal(h2[1], host_i)


@given(
    data_seed=st.integers(0, 50),
    backend=st.sampled_from(["host", "spmd"]),
    ops=st.lists(
        st.tuples(
            st.sampled_from(["insert", "overwrite", "delete",
                             "checkpoint", "compact"]),
            st.integers(0, 10_000),
        ),
        min_size=1, max_size=8,
    ),
    crash=st.sampled_from([
        "clean", "torn_wal",
        "compactor.begin", "compactor.seal",
        "compactor.prepare", "compactor.commit",
        "checkpoint.write", "checkpoint.publish",
    ]),
)
@settings(max_examples=8, deadline=None)
def test_p9_crash_recovery_equals_acknowledged_oracle(data_seed, backend,
                                                      ops, crash):
    import tempfile
    from pathlib import Path

    from repro.checkpoint import (
        Checkpointer,
        WriteAheadLog,
        checkpoint_segmented_index,
        recover_segmented_index,
    )
    from repro.core import SegmentedIndex
    from repro.core.pruning import exact_scores
    from repro.runtime.faults import FaultSpec, InjectedFault, fault_scope
    from repro.serve import HarmonyServer
    from repro.serve.compactor import Compactor
    from repro.serve.executor import ExecutorConfig

    nb, dim, k = 64, 8, 4
    rng0 = np.random.default_rng(data_seed)
    x = rng0.standard_normal((nb, dim)).astype(np.float32)
    # nprobe = nlist: probe everything, so recovered-plane search is
    # exact and the brute-force oracle over acknowledged writes applies
    cfg = HarmonyConfig(dim=dim, nlist=4, nprobe=4, topk=k, kmeans_iters=2)
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        data = SegmentedIndex.build(x, cfg)
        ckpt = Checkpointer(root / "ckpt", keep=3)
        wal = WriteAheadLog(root / "wal", sync=False)
        data.attach_wal(wal)
        # the base build predates the WAL: one durable point makes it
        # recoverable (cold-start from the WAL alone only sees journaled
        # writes — that path is covered by test_wal)
        checkpoint_segmented_index(ckpt, data, wal)

        model = {i: x[i].copy() for i in range(nb)}
        deleted: set = set()
        next_id = nb
        for kind, s in ops:
            r = np.random.default_rng(s)
            if kind == "insert":
                v = r.standard_normal((1, dim)).astype(np.float32)
                data.upsert(np.array([next_id], np.int64), v)
                model[next_id] = v[0]
                deleted.discard(next_id)
                next_id += 1
            elif kind == "overwrite" and model:
                tid = sorted(model)[int(r.integers(0, len(model)))]
                v = r.standard_normal((1, dim)).astype(np.float32)
                data.upsert(np.array([tid], np.int64), v)
                model[tid] = v[0]
            elif kind == "delete" and model:
                tid = sorted(model)[int(r.integers(0, len(model)))]
                data.delete(np.array([tid], np.int64))
                del model[tid]
                deleted.add(tid)
            elif kind == "checkpoint":
                checkpoint_segmented_index(ckpt, data, wal)
            elif kind == "compact":
                data.compact_inline(merge_all=bool(s % 2))

        # ---- the crash: every branch leaves the disk state a real
        # process kill could have left, then we recover from disk only
        if crash == "torn_wal":
            # power cut mid-append: a partial frame reaches disk but the
            # write is never acknowledged, so the model must NOT see it
            v = rng0.standard_normal((1, dim)).astype(np.float32)
            with fault_scope(FaultSpec("wal.append", kind="torn")):
                with pytest.raises(InjectedFault):
                    data.upsert(np.array([next_id], np.int64), v)
        elif crash.startswith("compactor."):
            comp = Compactor(data)
            with fault_scope(FaultSpec(crash, kind="crash")):
                with pytest.raises(InjectedFault):
                    comp.run_once(merge_all=True)
        elif crash.startswith("checkpoint."):
            with fault_scope(FaultSpec(crash, kind="crash")):
                with pytest.raises(InjectedFault):
                    checkpoint_segmented_index(ckpt, data, wal)
        acked_seq = data.wal_seq
        wal.close()

        data2, wal2, report = recover_segmented_index(
            ckpt, root / "wal", cfg=cfg, sync=False
        )
        try:
            # zero acknowledged-write loss, zero phantom writes
            assert data2.wal_seq == acked_seq
            if crash == "torn_wal":
                assert report["torn_tail"]
            for i in model:
                assert data2.has(i), f"acknowledged id {i} lost"
            for i in deleted:
                if i not in model:
                    assert not data2.has(i), f"deleted id {i} resurfaced"
            if crash == "torn_wal":
                assert not data2.has(next_id), "unacknowledged write resurrected"

            srv = HarmonyServer(
                data2, n_nodes=2, backend=backend,
                executor_cfg=ExecutorConfig(qb_buckets=(8,), chunk=64,
                                            use_pallas=False),
            )
            q = rng0.standard_normal((4, dim)).astype(np.float32)
            probe_id = sorted(model)[-1]
            q[0] = model[probe_id]
            res = srv.search_batch(q, k=k)
            ids_m = np.array(sorted(model), np.int64)
            xs = np.stack([model[i] for i in ids_m])
            sc = exact_scores(xs, q, cfg.metric)
            order = np.argsort(sc, axis=1, kind="stable")[:, :k]
            want_s = np.full((4, k), np.inf, np.float32)
            kk = min(k, len(model))
            want_s[:, :kk] = np.take_along_axis(sc, order, axis=1)[:, :kk]
            finite = np.isfinite(want_s)
            np.testing.assert_allclose(res.scores[finite], want_s[finite],
                                       rtol=1e-3, atol=1e-3)
            assert probe_id in res.ids[0]
            assert not np.isin(res.ids, list(deleted) or [-999]).any()
        finally:
            wal2.close()


def _random_filter(r: np.random.Generator):
    """A small random expression tree over the "color" tag column and
    the "price" numeric column (the shapes the engine compiles to
    per-segment bitmaps)."""
    from repro.core import NumRange, TagIn

    def leaf():
        if r.integers(2):
            n_vals = int(r.integers(1, 4))
            vals = tuple(int(v) for v in r.integers(0, 5, size=n_vals))
            return TagIn("color", vals)
        lo, hi = sorted(float(v) for v in r.uniform(0.0, 1.0, size=2))
        return NumRange("price", lo, hi)

    flt = leaf()
    for _ in range(int(r.integers(0, 3))):
        flt = (flt & leaf()) if r.integers(2) else (flt | leaf())
    return flt


@given(
    data_seed=st.integers(0, 50),
    backend=st.sampled_from(["host", "spmd"]),
    precision=st.sampled_from(["fp32", "int8"]),
    flt_seed=st.integers(0, 10_000),
    n_delete=st.integers(0, 8),
    lifecycle=st.sampled_from(["delta", "seal", "merge"]),
)
@settings(max_examples=8, deadline=None)
def test_p10_filtered_search_matches_filtered_bruteforce(
        data_seed, backend, precision, flt_seed, n_delete, lifecycle):
    from repro.core import TAG_MISSING, SearchRequest, SegmentedIndex
    from repro.core.pruning import exact_scores
    from repro.serve import HarmonyServer
    from repro.serve.executor import ExecutorConfig

    nb, dim, k = 96, 8, 4
    rng0 = np.random.default_rng(data_seed)
    x = rng0.standard_normal((nb, dim)).astype(np.float32)
    colors = rng0.integers(0, 5, size=nb)
    prices = rng0.uniform(0.0, 1.0, size=nb).astype(np.float32)
    # nprobe = nlist (exact IVF) and rerank_factor large enough that the
    # int8 stage 1 keeps every probed candidate — both tiers are exact,
    # so the clustering-independent filtered brute force is the oracle
    cfg = HarmonyConfig(dim=dim, nlist=4, nprobe=4, topk=k, kmeans_iters=2,
                        rerank_factor=32)
    data = SegmentedIndex.build(x, cfg)
    srv = HarmonyServer(
        data, n_nodes=2, backend=backend,
        executor_cfg=ExecutorConfig(qb_buckets=(8,), chunk=64,
                                    use_pallas=False),
    )
    # overwrite every row with itself + metadata (replacement attaches
    # meta to the delta copy and tombstones the sealed original)
    srv.upsert(np.arange(nb), x, meta={"color": colors, "price": prices})
    # a few rows with *no* metadata: a predicate can never admit them
    rng1 = np.random.default_rng(data_seed + 1)
    xe = rng1.standard_normal((4, dim)).astype(np.float32)
    bare_ids = np.arange(200, 204)
    srv.upsert(bare_ids, xe)
    if lifecycle == "seal":
        data.compact_inline(merge_all=False)
    elif lifecycle == "merge":
        data.compact_inline(merge_all=True)

    model = {int(i): x[i].copy() for i in range(nb)}
    meta = {int(i): (int(colors[i]), float(prices[i])) for i in range(nb)}
    for j, i in enumerate(bare_ids):
        model[int(i)] = xe[j]
    rng2 = np.random.default_rng(flt_seed)
    deleted = sorted(model)
    rng2.shuffle(deleted)
    deleted = deleted[:n_delete]
    if deleted:
        srv.delete(deleted)
        for i in deleted:
            del model[i]
    flt = _random_filter(rng2)

    # oracle allowed set: evaluate the same filter over columnarized
    # model metadata (TAG_MISSING / NaN for rows upserted without meta)
    ids_m = np.array(sorted(model), np.int64)
    tag_col = np.array([meta.get(int(i), (TAG_MISSING, np.nan))[0]
                        for i in ids_m], np.int64)
    num_col = np.array([meta.get(int(i), (TAG_MISSING, np.nan))[1]
                        for i in ids_m], np.float32)
    allowed = flt.evaluate({"color": tag_col}, {"price": num_col},
                           len(ids_m))
    live = ids_m[allowed]

    q = rng0.standard_normal((4, dim)).astype(np.float32)
    probe_id = None
    if live.size:
        # a filtered row is reachable by its own vector
        probe_id = int(live[-1])
        q[0] = model[probe_id]
    res = srv.search_batch(
        SearchRequest(vector=q, k=k, filter=flt, precision=precision))
    if not live.size:
        assert (res.ids == -1).all()
        return
    xs = np.stack([model[int(i)] for i in live])
    sc = exact_scores(xs, q, cfg.metric)
    order = np.argsort(sc, axis=1, kind="stable")[:, :k]
    want_s = np.full((4, k), np.inf, np.float32)
    kk = min(k, live.size)
    want_s[:, :kk] = np.take_along_axis(sc, order, axis=1)[:, :kk]
    finite = np.isfinite(want_s)
    np.testing.assert_allclose(res.scores[finite], want_s[finite],
                               rtol=1e-3, atol=1e-3)
    assert (res.ids[~finite] == -1).all()
    # every returned id satisfies the filter; deleted/bare never leak
    got = res.ids[res.ids >= 0]
    assert np.isin(got, live).all()
    assert not np.isin(got, deleted or [-999]).any()
    assert not np.isin(got, bare_ids).any()
    assert probe_id in res.ids[0]


@given(
    data_seed=st.integers(0, 50),
    backend=st.sampled_from(["host", "spmd"]),
    precision=st.sampled_from(["fp32", "int8"]),
    ops=st.lists(
        st.tuples(st.sampled_from([
            "fresh", "repeat", "near", "upsert", "delete", "compact",
        ]), st.integers(0, 10_000)),
        min_size=1, max_size=10,
    ),
)
@settings(max_examples=6, deadline=None)
def test_p11_cached_serving_matches_cache_off_twin(data_seed, backend,
                                                   precision, ops):
    # shared P11 body (tests/ is on sys.path via tests/conftest.py);
    # tests/test_cache.py runs the same body on a fixed grid
    from cache_invariants import run_cache_interleaving

    run_cache_interleaving(data_seed, backend, precision, ops)
