"""Quantized int8 scoring tier + exact fp32 re-rank (two-stage search).

Covers the full tier stack: the affine per-dimension-block grid
(``Int8Quant``), the int8 Pallas kernel vs its jnp reference, the host
``two_stage_search`` path, the device-resident executor with
``precision="int8"``, the serving engine's int8 dispatch on both
backends across the mutable-plane lifecycle (seal → compact → swap →
checkpoint-restore), and the three serving-plane bugfix regressions
that ride along (compactor stop, executor warmup probe widths, the
dead-mask cache in ``harmony_search``).
"""

import os
import subprocess
import sys
import tempfile
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import (
    SegmentedIndex,
    build_ivf,
    harmony_search,
    plan_search,
    preassign,
    quantize_vectors,
    search_oracle,
    two_stage_search,
)
from repro.core.index import dim_block_bounds
from repro.data import make_dataset, make_queries
from repro.serve import ExecutorConfig, HarmonyServer, SpmdExecutor

ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=3000, dim=32, n_components=8, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=32, nlist=32, nprobe=8, topk=10, kmeans_iters=4)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=48, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


def _recall(ids, ref_ids):
    k = ref_ids.shape[1]
    return np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k
        for a, b in zip(ids, ref_ids)
    ])


def assert_matches_oracle(res, oracle):
    finite = np.isfinite(oracle.scores)
    assert np.array_equal(np.isfinite(res.scores), finite)
    np.testing.assert_allclose(
        res.scores[finite], oracle.scores[finite], rtol=1e-3, atol=1e-3
    )
    diff = (res.ids != oracle.ids) & finite
    for r in np.unique(np.nonzero(diff)[0]):
        assert np.allclose(
            np.sort(res.scores[r]), np.sort(oracle.scores[r]),
            rtol=1e-3, atol=1e-3,
        ), (res.ids[r], oracle.ids[r])


# ------------------------------------------------------------ quantizer


def test_quant_roundtrip_and_memory(anns):
    _, cfg, index, _ = anns
    quant = quantize_vectors(index.x, cfg.quant_blocks)
    assert quant.codes.dtype == np.int8
    dec = quant.decode()
    # the grid is fit to the corpus range, so the corpus never clips and
    # the decode error is bounded by half a quantization step per dim
    for b, (lo, hi) in enumerate(dim_block_bounds(index.dim, quant.d_blocks)):
        err = np.abs(dec[:, lo:hi] - index.x[:, lo:hi])
        assert err.max() <= quant.scale[b] / 2 + 1e-6
    # ≥4× lower bytes-per-vector than the fp32 corpus (the acceptance
    # bound); the per-block grid itself is O(1), not per-vector
    assert index.x.nbytes / quant.codes.nbytes >= 4.0
    assert index.x.nbytes / quant.memory_bytes() >= 3.99


def test_quant_scores_are_decoded_l2(anns):
    """The zero-point-cancelled score formula equals plain L2 between
    the decoded corpus and decoded queries (the quantized metric)."""
    _, cfg, index, q = anns
    quant = index.int8_quant(cfg.quant_blocks)
    qc = quant.encode(q[:8])
    got = quant.scores(qc, rows=np.arange(64))
    bounds = dim_block_bounds(index.dim, quant.d_blocks)
    dec_q = np.zeros_like(q[:8])
    for b, (lo, hi) in enumerate(bounds):
        dec_q[:, lo:hi] = qc[:, lo:hi] * quant.scale[b] + quant.zero[b]
    dec_x = quant.decode()[:64]
    want = (
        np.sum(dec_q * dec_q, axis=1)[:, None]
        - 2.0 * dec_q @ dec_x.T
        + np.sum(dec_x * dec_x, axis=1)[None, :]
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_int8_kernel_matches_ref():
    from repro.kernels.distance_int8 import int8_partial_distance_update
    from repro.kernels.ref import int8_partial_distance_update_ref

    rng = np.random.default_rng(2)
    m, n, kdim = 16, 48, 24
    x = rng.integers(-127, 128, (n, kdim)).astype(np.int8)
    q = rng.integers(-127, 128, (m, kdim)).astype(np.int8)
    s2 = np.float32(0.01)
    xn2 = (s2 * (x.astype(np.int64) ** 2).sum(1)).astype(np.float32)
    qn2 = (s2 * (q.astype(np.int64) ** 2).sum(1)).astype(np.float32)
    acc = np.zeros((m, n), np.float32)
    acc[3] = np.inf                       # a pruned query row stays +inf
    tau = np.full((m,), np.inf, np.float32)
    tau[5] = 0.5                          # a tight τ prunes row 5
    got, skip = int8_partial_distance_update(
        x, xn2, q, qn2, s2, acc, tau, tile_m=8, tile_n=16, tile_k=8,
        interpret=True,
    )
    want = int8_partial_distance_update_ref(x, xn2, q, qn2, s2, acc, tau)
    inf = ~np.isfinite(np.asarray(want))
    assert np.array_equal(~np.isfinite(np.asarray(got)), inf)
    np.testing.assert_allclose(
        np.asarray(got)[~inf], np.asarray(want)[~inf], rtol=1e-5, atol=1e-4
    )


# ----------------------------------------------------- host two-stage


def test_two_stage_recall_and_exact_scores(anns):
    _, cfg, index, q = anns
    oracle = search_oracle(index, q, k=cfg.topk)
    res = two_stage_search(index, q, k=cfg.topk, nprobe=cfg.nlist)
    assert res.stats["precision"] == "int8"
    assert _recall(res.ids, oracle.ids) >= 0.98
    # any id the two paths agree on carries the *exact* fp32 score
    for i in range(q.shape[0]):
        m = dict(zip(oracle.ids[i].tolist(), oracle.scores[i].tolist()))
        for j, e in enumerate(res.ids[i].tolist()):
            if e in m:
                np.testing.assert_allclose(res.scores[i, j], m[e],
                                           rtol=1e-4, atol=1e-5)


def test_two_stage_full_coverage_is_oracle(anns):
    """With every cluster probed and K' = nb, stage 1 cannot drop a true
    neighbour — the result is the oracle, bit-for-bit in score."""
    _, cfg, index, q = anns
    res = two_stage_search(
        index, q[:16], k=cfg.topk, nprobe=cfg.nlist,
        rerank_factor=-(-index.nb // cfg.topk),
    )
    assert_matches_oracle(res, search_oracle(index, q[:16], k=cfg.topk))


def test_two_stage_dead_rows(anns):
    _, cfg, index, q = anns
    base = two_stage_search(index, q[:4], k=cfg.topk, nprobe=cfg.nlist)
    dead = np.zeros(index.nb, bool)
    order = np.argsort(index.ids, kind="stable")
    top = base.ids[0, 0]
    dead[order[np.searchsorted(index.ids[order], top)]] = True
    res = two_stage_search(index, q[:4], k=cfg.topk, nprobe=cfg.nlist,
                           dead_rows=dead)
    assert top not in res.ids[0]


# ---------------------------------------------------- device executor


def _executor(index, **kw):
    kw.setdefault("chunk", 128)
    kw.setdefault("qb_buckets", (8, 32))
    return SpmdExecutor(index, ExecutorConfig(**kw))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_executor_int8_recall_and_exact_scores(anns, use_pallas):
    _, cfg, index, q = anns
    ex32 = _executor(index, use_pallas=use_pallas)
    ex8 = _executor(index, precision="int8", use_pallas=use_pallas)
    r32 = ex32.search_batch(q)
    r8 = ex8.search_batch(q)
    assert r8.stats["precision"] == "int8"
    assert r8.stats["rerank_k"] == cfg.topk * ex8.cfg.rerank_factor
    assert _recall(r8.ids, r32.ids) >= 0.98
    for i in range(q.shape[0]):
        m = dict(zip(r32.ids[i].tolist(), r32.scores[i].tolist()))
        for j, e in enumerate(r8.ids[i].tolist()):
            if e in m:
                np.testing.assert_allclose(
                    r8.scores[i, j], m[e], rtol=1e-3, atol=1e-3
                )


def test_executor_int8_dead_rows_and_split(anns):
    _, cfg, index, q = anns
    ex = _executor(index, precision="int8", qb_buckets=(8,))
    base = ex.search_batch(q[:1])
    dead = np.zeros(index.nb, bool)
    order = np.argsort(index.ids, kind="stable")
    top = base.ids[0, 0]
    dead[order[np.searchsorted(index.ids[order], top)]] = True
    res = ex.search_batch(q[:1], dead_rows=dead)
    assert top not in res.ids[0]
    # batch > biggest bucket splits and still re-ranks each part
    big = ex.search_batch(q)       # 48 queries through qb=8 buckets
    assert big.stats["splits"] == 6
    assert big.stats["precision"] == "int8"
    assert _recall(big.ids, _executor(index).search_batch(q).ids) >= 0.98


# ---------------------------------- engine lifecycle (both backends)


@pytest.mark.parametrize("backend", ["host", "spmd"])
def test_engine_int8_lifecycle(anns, backend):
    """int8 serving through seal → compact → generation swap →
    checkpoint-restore, with deletes masked throughout."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.checkpoint.index_io import (
        load_segmented_index,
        save_segmented_index,
    )
    from repro.serve.compactor import CompactionConfig, Compactor

    ds, cfg, _, q = anns
    data = SegmentedIndex.from_static(build_ivf(ds.x, cfg))
    srv = HarmonyServer(data, n_nodes=2, backend=backend, precision="int8")
    ref = HarmonyServer(SegmentedIndex.from_static(build_ivf(ds.x, cfg)),
                        n_nodes=2, backend=backend)

    r0 = srv.search_batch(q, k=cfg.topk)
    assert _recall(r0.ids, ref.search_batch(q, k=cfg.topk).ids) >= 0.98

    # streaming writes + a tombstone, then a full compaction cycle
    rng = np.random.default_rng(7)
    new_x = rng.standard_normal((64, cfg.dim)).astype(np.float32) + 30.0
    new_ids = np.arange(500_000, 500_064)
    srv.upsert(new_ids, new_x)
    killed = int(r0.ids[0, 0])
    srv.delete([killed])
    comp = Compactor(data, srv, CompactionConfig(delta_threshold=1))
    assert comp.maybe_compact() is not None
    assert srv.generation == data.generation

    r1 = srv.search_batch(np.concatenate([q[:8], new_x[:4]]), k=cfg.topk)
    assert killed not in r1.ids[:8]
    assert all(int(r1.ids[8 + i, 0]) == 500_000 + i for i in range(4))
    # every sealed segment of the swapped-in generation carries its tier
    for seg in data.snapshot().segments:
        assert cfg.quant_blocks in seg.index.__dict__.get("_int8_quants", {})

    # checkpoint roundtrip: the restored plane serves int8 immediately
    with tempfile.TemporaryDirectory() as d:
        save_segmented_index(Checkpointer(d), data)
        data2 = load_segmented_index(Checkpointer(d))
    for seg in data2.snapshot().segments:
        q2 = seg.index.__dict__.get("_int8_quants", {}).get(cfg.quant_blocks)
        assert q2 is not None          # attached, not re-derived
    srv2 = HarmonyServer(data2, n_nodes=2, backend=backend, precision="int8")
    r2 = srv2.search_batch(np.concatenate([q[:8], new_x[:4]]), k=cfg.topk)
    np.testing.assert_allclose(r2.scores, r1.scores, rtol=1e-3, atol=1e-3)
    assert killed not in r2.ids[:8]


def test_checkpoint_persists_quant_tier(anns):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.checkpoint.index_io import (
        load_segmented_index,
        save_segmented_index,
    )

    ds, cfg, index, _ = anns
    data = SegmentedIndex.from_static(index)
    want = data.segments[0].index.int8_quant(cfg.quant_blocks)
    with tempfile.TemporaryDirectory() as d:
        save_segmented_index(Checkpointer(d), data)
        data2 = load_segmented_index(Checkpointer(d))
    got = data2.segments[0].index.__dict__["_int8_quants"][cfg.quant_blocks]
    assert np.array_equal(got.codes, want.codes)
    assert np.array_equal(got.scale, want.scale)
    assert np.array_equal(got.zero, want.zero)


def test_multi_device_int8_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["HARMONY_BENCH_TINY"] = "1"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "distributed_search.py"),
         "--int8"],
        env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "EXACTNESS_OK" in proc.stdout, proc.stdout + proc.stderr


# ------------------------------------------------- bugfix regressions


def test_compactor_stop_keeps_handle_on_timeout(anns):
    """stop() must not drop a still-alive thread's handle: a dropped
    handle lets start() spawn a duplicate loop and clear the stop event
    the zombie still polls."""
    from repro.serve.compactor import CompactionConfig, Compactor

    ds, cfg, *_ = anns
    data = SegmentedIndex.from_static(build_ivf(ds.x, cfg))
    comp = Compactor(data, None, CompactionConfig(poll_s=0.01))
    comp.start()
    assert comp.stop() is True and comp._thread is None
    assert comp.stop() is True             # idempotent once down

    release = threading.Event()
    stuck = threading.Thread(target=release.wait, daemon=True)
    stuck.start()
    comp._thread = stuck
    try:
        assert comp.stop(timeout=0.05) is False
        assert comp._thread is stuck       # handle kept, not leaked
        assert any("still alive" in e for e in comp.errors)
        # start() must refuse to double-spawn while the zombie lives
        comp.start()
        assert comp._thread is stuck
    finally:
        release.set()
        stuck.join(timeout=5.0)
    assert comp.stop() is True and comp._thread is None


@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_warmup_covers_explicit_probe_widths(anns, precision):
    """The compile cache keys on probes.shape[1]; warmup must cover the
    widths search_batch will see, and narrower explicit probe tables get
    padded up to a warmed width instead of compiling a new step."""
    from repro.core import assign_queries

    _, cfg, index, q = anns
    ex = _executor(index, precision=precision)
    ex.warmup(nprobe=[4, cfg.nprobe])
    warmed = ex.compiles
    assert warmed > 0
    # width == a warmed width: no compile
    ex.search_batch(q, probes=assign_queries(index, q, 4))
    assert ex.compiles == warmed
    # width < smallest warmed width: padded up, still no compile
    probes2 = assign_queries(index, q, 2)
    res = ex.search_batch(q, probes=probes2)
    assert ex.compiles == warmed
    # padding must not change results (pad columns match no cluster, and
    # τ prewarm ran on the unpadded table)
    fresh = _executor(index, precision=precision)
    want = fresh.search_batch(q, probes=probes2)
    assert np.array_equal(res.ids, want.ids)
    np.testing.assert_allclose(res.scores, want.scores, rtol=1e-5)


def test_dead_mask_cache_on_sharded_corpus(anns):
    _, cfg, index, _ = anns
    dec = plan_search(index, n_nodes=4)
    corpus = preassign(index, dec.plan)
    dead = np.zeros(index.nb, bool)
    dead[::7] = True
    m1 = corpus.dead_shard_mask(dead, key=(0, 1))
    m2 = corpus.dead_shard_mask(dead, key=(0, 1))
    assert m1 is m2                        # cache hit on same key
    # the mask maps packed rows to their (shard, slot) exactly
    naive = np.zeros_like(m1)
    for c in range(index.nlist):
        v, lo, hi = corpus.cluster_slices[c]
        plo, phi = index.cluster_rows(c)
        naive[v, lo:hi] = dead[plo:phi]
    assert np.array_equal(m1, naive)
    dead2 = dead.copy()
    dead2[1] = not dead2[1]
    m3 = corpus.dead_shard_mask(dead2, key=(0, 2))
    assert m3 is not m1                    # new key recomputes
    assert not np.array_equal(m3, m1)


def test_dead_version_bumps_only_on_sealed_tombstones(anns):
    """(generation, dead_version) must change whenever sealed tombstones
    change — deletes don't bump the generation, so a generation-only
    cache key would serve stale masks."""
    ds, cfg, index, q = anns
    data = SegmentedIndex.from_static(build_ivf(ds.x, cfg))
    v0 = data.snapshot().dead_version
    # delta-only ops don't touch sealed tombstones
    data.upsert(np.array([900_000]), np.ones((1, cfg.dim), np.float32))
    data.delete(np.array([900_000]))
    assert data.snapshot().dead_version == v0
    # tombstoning a sealed row must bump it
    data.delete(np.array([0]))             # ext id 0 = ds.x[0], sealed
    snap = data.snapshot()
    assert snap.dead_version == v0 + 1

    # end to end: harmony_search with the snapshot key returns the
    # post-delete result (a stale cached mask would resurrect the row)
    seg_index = data.segments[0].index
    dec = plan_search(seg_index, n_nodes=2)
    corpus = preassign(seg_index, dec.plan)
    qx = ds.x[:1]
    r1 = harmony_search(seg_index, corpus, qx, k=1,
                        dead_rows=None, dead_key=(snap.generation, v0))
    assert int(r1.ids[0, 0]) == 0          # self-NN while alive
    dead = snap.dead_rows[data.segments[0].seg_id]
    r2 = harmony_search(seg_index, corpus, qx, k=1, dead_rows=dead,
                        dead_key=(snap.generation, snap.dead_version))
    assert int(r2.ids[0, 0]) != 0
