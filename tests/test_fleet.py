"""Multi-replica fleet tests: load-aware routing, cross-replica hedging,
replica fail/join elasticity, heterogeneous host+spmd fleets, and the
degenerate-summary fix.

All timing runs on the scheduler's virtual clock with injected
deterministic service models, and the fleet's power-of-two-choices
sampling is seeded — every assertion (batch placement, Gini, hedge
counts, shed counts) depends only on the trace."""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import (
    HarmonyServer,
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServeStats,
    ServingScheduler,
    gini,
)


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=4000, dim=32, n_components=8, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=32, nlist=32, nprobe=6, topk=5, kmeans_iters=4)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=96, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


def burst_trace(q, spacing=1e-5):
    return [(i * spacing, q[i]) for i in range(len(q))]


# -------------------------------------------------------------- exactness


def test_fleet_matches_oracle_and_single_server(anns):
    """A homogeneous fleet behind the scheduler returns exactly what one
    server returns (every replica serves the full corpus)."""
    ds, cfg, index, q = anns
    fleet = ReplicaFleet(index, replicas=3, cfg=cfg, seed=0)
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=16), k=5)
    results = sched.run_trace(burst_trace(q))
    assert len(results) == len(q)
    assert [r.req_id for r in results] == list(range(len(q)))
    oracle = search_oracle(index, q, k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )
    # work actually spread: more than one replica served batches
    served_by = [r.batches for r in fleet.replicas]
    assert sum(served_by) == len(q) // 16
    assert sum(1 for b in served_by if b > 0) >= 2
    assert fleet.stats.admitted == len(q) and fleet.stats.shed == 0


# ------------------------------------------------- load balance under skew


def test_load_balance_gini_under_skew_beats_round_robin(anns):
    """On a heterogeneous fleet (two half-speed replicas) under a skewed
    burst, load-estimate routing must spread *work-seconds* strictly more
    evenly than round-robin — the fleet's Gini is bounded well below the
    capacity-blind baseline's."""
    ds, cfg, index, q = anns
    qh = make_queries(ds, nq=192, skew=0.9, hot_fraction=0.05, noise=0.1,
                      seed=4)
    caps = [1.0, 1.0, 0.5, 0.5]
    specs = [ReplicaSpec(capacity=c) for c in caps]
    # deterministic service: 1ms per query on a full-speed replica
    service = lambda r, n: n * 1e-3 / caps[r]
    trace = burst_trace(qh, spacing=1e-5)

    def run(routing):
        fleet = ReplicaFleet(index, replicas=specs, cfg=cfg, routing=routing,
                             service_time_fn=service, seed=0)
        sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=5)
        sched.run_trace(trace)
        return fleet

    rr = run("round_robin")
    p2c = run("p2c")
    g_rr, g_p2c = rr.load_balance_gini, p2c.load_balance_gini
    # round-robin is balanced in counts but not in seconds: the slow
    # replicas carry ~2x busy time
    assert g_p2c < g_rr
    assert g_p2c < 0.10
    # every admitted request served under both policies
    assert len(rr.stats.request_latency_ms) == 192
    assert len(p2c.stats.request_latency_ms) == 192


def test_fleet_scales_served_qps(anns):
    """4 replicas must serve a saturating burst ≥1.5x faster than 1
    replica on the virtual clock (the bench_fleet acceptance claim, in
    deterministic miniature)."""
    ds, cfg, index, q = anns
    service = lambda r, n: n * 1e-3
    trace = burst_trace(q, spacing=1e-5)

    def qps(n_rep):
        fleet = ReplicaFleet(index, replicas=n_rep, cfg=cfg,
                             service_time_fn=service, seed=0)
        sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=5)
        sched.run_trace(trace)
        return sched.served_qps

    assert qps(4) >= 1.5 * qps(1)


# ------------------------------------------------------ replica elasticity


def test_replica_fail_join_mid_trace_no_lost_requests(anns):
    """Failing a replica mid-trace removes it from routing; joining a new
    one adds capacity — no admitted request is lost and every result
    stays exact."""
    ds, cfg, index, q = anns
    fleet = ReplicaFleet(index, replicas=2, cfg=cfg, routing="least_loaded",
                         service_time_fn=lambda r, n: n * 1e-3, seed=0)

    def churn(batch_idx, sched):
        if batch_idx == 2:
            fleet.fail_replica(1)
        elif batch_idx == 5:
            fleet.join_replica(ReplicaSpec())

    sched = ServingScheduler(
        fleet, SchedulerConfig(max_batch=8), k=5, on_batch=churn
    )
    results = sched.run_trace(burst_trace(q))
    assert len(results) == len(q)                 # nothing lost
    assert fleet.stats.shed == 0
    assert len(fleet.replicas) == 3 and fleet.cluster.n_live == 2
    assert not fleet.cluster.live[1]
    # the failed replica stopped taking batches; the joiner started
    assert fleet.replicas[1].batches <= 3
    assert fleet.replicas[2].batches > 0
    oracle = search_oracle(index, q, k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# -------------------------------------------------- cross-replica hedging


def test_cross_replica_hedge_fires_and_preserves_results(anns):
    """A straggling primary replica trips the hedge deadline; the batch
    re-runs on the second-least-loaded *replica* and results are
    identical to the unhedged fleet (and the oracle)."""
    ds, cfg, index, q = anns

    def build(hedge_s):
        # replica 0 straggles 0.5s; the others answer in 10us
        return ReplicaFleet(
            index, replicas=3, cfg=cfg, routing="least_loaded",
            service_time_fn=lambda r, n: n * 1e-4,
            latency_fn=lambda r, t: 0.5 if r == 0 else 1e-5,
            seed=0,
        ), SchedulerConfig(max_batch=8, hedge_deadline_s=hedge_s)

    hedged_fleet, hedged_cfg = build(0.01)
    sched = ServingScheduler(hedged_fleet, hedged_cfg, k=5)
    results = sched.run_trace(burst_trace(q))

    plain_fleet, _ = build(0.01)
    plain = ServingScheduler(plain_fleet, SchedulerConfig(max_batch=8), k=5)
    plain_results = plain.run_trace(burst_trace(q))

    hs = hedged_fleet._hedge.stats
    assert hs.hedged >= 1
    assert hs.hedge_wins >= 1                     # the hedge target won
    assert 0.0 < hs.win_rate <= 1.0
    assert hedged_fleet.stats.hedged_batches == hs.hedged
    # the hedge wait is charged to the virtual clock: a batch whose hedge
    # won cannot complete before dispatch + deadline (10ms >> the 0.8ms
    # injected service time)
    assert max(hedged_fleet.stats.request_latency_ms) >= 10.0
    # parity: hedging changes placement/latency, never answers
    np.testing.assert_array_equal(
        np.stack([r.ids for r in results]),
        np.stack([r.ids for r in plain_results]),
    )
    oracle = search_oracle(index, q, k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ------------------------------------------- heterogeneous host+spmd fleet


def test_heterogeneous_host_spmd_fleet_matches_oracle(anns):
    """A mixed fleet — one host replica, one device-resident spmd replica
    — serves through the same queue and matches the oracle."""
    ds, cfg, index, q = anns
    fleet = ReplicaFleet(
        index,
        replicas=[ReplicaSpec(backend="host"), ReplicaSpec(backend="spmd")],
        cfg=cfg,
        routing="round_robin",      # force both backends to serve batches
        seed=0,
    )
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=16), k=5)
    results = sched.run_trace(burst_trace(q[:64]))
    assert len(results) == 64
    assert fleet.replicas[0].batches > 0 and fleet.replicas[1].batches > 0
    assert fleet.replicas[1].server.stats.spmd_batches > 0
    oracle = search_oracle(index, q[:64], k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ------------------------------------------------- degenerate summaries


def test_shed_heavy_trace_summary_none_percentiles(anns):
    """A saturating trace behind a tiny bounded queue sheds nearly
    everything; replicas that never served report None percentiles (not a
    numpy empty-quantile raise, not a misleading 0.0), and the fleet
    summary stays JSON-clean."""
    ds, cfg, index, q = anns
    fleet = ReplicaFleet(
        index, replicas=2, cfg=cfg, routing="least_loaded",
        service_time_fn=lambda r, n: 1000.0,      # one batch pins a replica
        seed=0,
    )
    fleet.fail_replica(1)                          # replica 1 never serves
    sched = ServingScheduler(
        fleet,
        SchedulerConfig(max_batch=4, queue_capacity=4, max_wait_s=1e-3),
        k=5,
    )
    for i in range(64):                            # no flush: trace tail only
        sched.submit(q[i % len(q)], i * 1e-6)
    s = fleet.summary()                            # must not raise
    assert fleet.stats.shed > 0
    assert fleet.stats.offered == 64
    idle = [r for r in s["replicas"] if r["batches"] == 0]
    assert idle, "expected at least one replica with zero served batches"
    for r in idle:
        assert r["p50_service_ms"] is None and r["p99_service_ms"] is None
        assert r["server"]["p50_queue_wait_ms"] is None
    # a fresh stats object reports all-None percentiles and never raises
    empty = ServeStats().summary()
    for key in ("p50_queue_wait_ms", "p99_queue_wait_ms",
                "p50_request_latency_ms", "p99_request_latency_ms"):
        assert empty[key] is None


def test_gini_helper():
    assert gini([1.0, 1.0, 1.0, 1.0]) == pytest.approx(0.0)
    assert gini([]) == 0.0
    assert gini([0.0, 0.0]) == 0.0
    assert gini([0.0, 0.0, 0.0, 1.0]) == pytest.approx(0.75)
    assert gini([1.0, 1.0, 2.0, 2.0]) == pytest.approx(1 / 6, abs=1e-9)
