"""Device-resident batched executor: oracle parity (pruning on/off, both
metrics), static-shape bucketing edges, compile-count bounds, and the
scheduler/serve integration (backend="spmd", arrival-timestamp streams).

Everything runs on CPU — the jnp scoring path (use_pallas=False) plus one
interpret-mode Pallas case keep the BlockSpec logic covered without a TPU.
"""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import (
    ExecutorConfig,
    HarmonyServer,
    SchedulerConfig,
    ServingScheduler,
    SpmdExecutor,
)


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=4000, dim=32, n_components=8, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=32, nlist=32, nprobe=6, topk=5, kmeans_iters=4)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=64, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


def _executor(index, **kw):
    kw.setdefault("chunk", 128)
    kw.setdefault("qb_buckets", (8, 32))
    return SpmdExecutor(index, ExecutorConfig(**kw))


def assert_matches_oracle(res, oracle):
    """Scores equal (tie order may permute ids); inf/valid pattern equal."""
    finite = np.isfinite(oracle.scores)
    assert np.array_equal(np.isfinite(res.scores), finite)
    np.testing.assert_allclose(
        res.scores[finite], oracle.scores[finite], rtol=1e-3, atol=1e-3
    )
    # ids may differ only across equal-score ties
    diff = (res.ids != oracle.ids) & finite
    for r in np.unique(np.nonzero(diff)[0]):
        assert np.allclose(
            np.sort(res.scores[r]), np.sort(oracle.scores[r]),
            rtol=1e-3, atol=1e-3,
        ), (res.ids[r], oracle.ids[r])


# ----------------------------------------------------------------- parity


@pytest.mark.parametrize("prune", [True, False])
def test_parity_vs_oracle(anns, prune):
    ds, cfg, index, q = anns
    ex = _executor(index, prune=prune)
    res = ex.search_batch(q[:32])
    assert_matches_oracle(res, search_oracle(index, q[:32]))


def test_parity_pallas_interpret(anns):
    """Interpret-mode Pallas kernels under the executor (tile-skip map and
    BlockSpec logic validated end to end on CPU)."""
    ds, cfg, index, q = anns
    ex = _executor(index, use_pallas=True, tile_m=32, tile_n=64, tile_k=32)
    res = ex.search_batch(q[:8])
    assert_matches_oracle(res, search_oracle(index, q[:8]))
    assert res.stats["tile_total"] > 0


def test_parity_metric_ip():
    ds = make_dataset(nb=3000, dim=24, n_components=6, spread=0.6, seed=2)
    cfg = HarmonyConfig(dim=24, nlist=24, nprobe=5, topk=5, kmeans_iters=4,
                        metric="ip")
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=24, seed=3)
    ex = _executor(index)
    # -dot partial sums are not monotone → executor must not prune for ip
    assert ex.prune is False
    assert_matches_oracle(ex.search_batch(q), search_oracle(index, q))


# ------------------------------------------------------- bucketing edges


def test_batch_larger_than_biggest_bucket_splits(anns):
    ds, cfg, index, q = anns
    ex = _executor(index)            # biggest qb bucket = 32 < 64 queries
    res = ex.search_batch(q)
    assert res.ids.shape == (64, 5)
    assert res.stats["splits"] == 2
    assert_matches_oracle(res, search_oracle(index, q))


def test_singleton_batch(anns):
    ds, cfg, index, q = anns
    ex = _executor(index)
    res = ex.search_batch(q[:1])
    assert res.ids.shape == (1, 5)
    assert res.stats["pad_queries"] == ex.qb_buckets[0] - 1
    assert_matches_oracle(res, search_oracle(index, q[:1]))


def test_empty_probe_set(anns):
    ds, cfg, index, q = anns
    ex = _executor(index)
    res = ex.search_batch(q[:4], nprobe=0)
    assert (res.ids == -1).all()
    assert np.isinf(res.scores).all()
    assert ex.compiles == 0          # no candidates → no device dispatch


# ------------------------------------------------------ compile bounds


def test_mixed_batch_sizes_compile_each_bucket_at_most_once(anns):
    ds, cfg, index, q = anns
    ex = _executor(index)
    sizes = [3, 8, 20, 32, 1, 17, 32, 8]
    off = 0
    for n in sizes:
        ex.search_batch(q[off % 32 : off % 32 + n])
        off += 7
    assert all(n == 1 for n in ex.trace_counts.values()), ex.trace_counts
    compiled = ex.compiles
    # replaying the same mix must be served entirely from the compile cache
    off = 0
    for n in sizes:
        ex.search_batch(q[off % 32 : off % 32 + n])
        off += 7
    assert ex.compiles == compiled
    assert set(ex.trace_counts) == set(ex._steps)


# ------------------------------------------------- scheduler integration


def test_scheduled_spmd_backend_matches_oracle(anns):
    ds, cfg, index, q = anns
    srv = HarmonyServer(index, n_nodes=4,
                        executor_cfg=ExecutorConfig(chunk=128, qb_buckets=(16,)))
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=16, backend="spmd"), k=5
    )
    results = sched.run_trace([(0.0, q[i]) for i in range(len(q))])
    assert len(results) == len(q)
    assert srv.stats.spmd_batches == len(q) // 16
    res_scores = np.stack([r.scores for r in results])
    oracle = search_oracle(index, q, k=5)
    finite = np.isfinite(oracle.scores)
    np.testing.assert_allclose(
        res_scores[finite], oracle.scores[finite], rtol=1e-3, atol=1e-3
    )


def test_serve_arrival_stream_drives_batch_formation(anns):
    """Per-batch arrival timestamps must reach the scheduler: far-apart
    arrivals form one deadline batch each instead of one merged batch, and
    queue-wait percentiles stop degenerating to the all-at-t0 answer."""
    ds, cfg, index, q = anns
    batches = [q[0:4], q[4:8], q[8:12]]

    srv0 = HarmonyServer(index, n_nodes=4)
    srv0.serve(batches, k=5)                       # legacy: all arrive at t=0
    assert srv0.stats.batches == 1

    srv = HarmonyServer(index, n_nodes=4)
    outs = srv.serve(batches, k=5, arrivals=[0.0, 10.0, 20.0])
    assert srv.stats.batches == 3
    assert srv.stats.deadline_batches == 3
    oracle = search_oracle(index, q[:12], k=5)
    np.testing.assert_allclose(
        np.concatenate([o.scores for o in outs]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


def test_serve_per_row_arrivals(anns):
    ds, cfg, index, q = anns
    srv = HarmonyServer(index, n_nodes=4)
    outs = srv.serve(
        [q[0:4]], k=5, arrivals=[np.array([0.0, 0.1, 0.2, 0.3])],
    )
    assert outs[0].ids.shape == (4, 5)
    # spaced arrivals + 2ms deadline → multiple batches, nonzero makespan
    assert srv.stats.batches >= 2
    assert outs[0].stats["wall_s"] > 0.0
