"""Semantic cache + request coalescing front door (PR 9).

Covers the :mod:`repro.serve.cache` tiers directly (TTL expiry, the
inclusive semantic threshold boundary, LRU eviction), the virtual-clock
scheduler integration (in-batch coalescing, invalidation on
upsert/delete/compaction-adopt, the staleness budget, per-request
deadline enforcement — the PR 9 bugfix), the wall-clock front-end
(in-flight coalescing, drain/shutdown with no leaked futures), and the
fixed grid of invariant P11 (:mod:`cache_invariants` — the hypothesis
twin lives in ``tests/properties/test_props.py``).
"""

import numpy as np
import pytest

from cache_invariants import retry_flaky, run_cache_interleaving
from repro.config import HarmonyConfig
from repro.core import SearchRequest, SegmentedIndex, build_ivf
from repro.serve import (
    CacheConfig,
    HarmonyServer,
    QueryCache,
    SchedulerConfig,
    ServingFrontend,
    ServingScheduler,
)


def _plane(nb=256, dim=8, seed=0, **over):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((nb, dim)).astype(np.float32)
    cfg = HarmonyConfig(dim=dim, nlist=4, nprobe=4, topk=3, kmeans_iters=2,
                        **over)
    return x, cfg, SegmentedIndex.build(x, cfg)


# --------------------------------------------------------------- cache unit
def test_exact_tier_ttl_expiry():
    c = QueryCache(CacheConfig(enabled=True, exact_ttl_s=10.0))
    q = np.arange(4, dtype=np.float32)
    opts = (None, None, None)
    c.insert(q, 3, opts, np.array([1, 2, 3]), np.array([0.1, 0.2, 0.3]),
             now_s=0.0)
    assert c.lookup(q, 3, opts, now_s=9.9).tier == "exact"
    assert c.lookup(q, 3, opts, now_s=10.1) is None      # TTL bound
    assert c.stats.cache_invalidations == 1
    assert len(c) == 0                                   # expired entry dropped
    assert c.lookup(q, 3, opts, now_s=0.0) is None       # gone for good


def test_semantic_threshold_boundary_inclusive():
    c = QueryCache(CacheConfig(enabled=True, exact_ttl_s=1e9,
                               semantic_threshold=4.0))
    q = np.zeros(4, np.float32)
    opts = (None, None, None)
    ids = np.array([7, 8, -1])
    c.insert(q, 3, opts, ids, np.array([0.5, 0.6, np.inf]), now_s=0.0)
    at = q.copy()
    at[0] = 2.0                     # squared L2 distance exactly 4.0
    hit = c.lookup(at, 3, opts, now_s=1.0)
    assert hit is not None and hit.tier == "semantic"
    assert np.array_equal(hit.ids, ids)
    beyond = q.copy()
    beyond[0] = np.float32(2.001)   # just past the boundary
    assert c.lookup(beyond, 3, opts, now_s=1.0) is None
    # k/options partition the semantic space: same vector, different k
    assert c.lookup(at, 5, opts, now_s=1.0) is None
    assert (c.stats.cache_hits_semantic, c.stats.cache_misses) == (1, 2)


def test_semantic_tier_rejects_non_l2_metric():
    with pytest.raises(AssertionError):
        QueryCache(CacheConfig(enabled=True, semantic_threshold=1.0),
                   metric="ip")


def test_lru_eviction_with_refresh():
    c = QueryCache(CacheConfig(enabled=True, exact_ttl_s=1e9, max_entries=2))
    opts = (None, None, None)
    qs = [np.full(4, i, np.float32) for i in range(3)]
    ids = np.array([1, 2, 3])
    sc = np.array([0.1, 0.2, 0.3])
    c.insert(qs[0], 3, opts, ids, sc, now_s=0.0)
    c.insert(qs[1], 3, opts, ids, sc, now_s=0.0)
    assert c.lookup(qs[0], 3, opts, now_s=0.0) is not None  # LRU refresh
    c.insert(qs[2], 3, opts, ids, sc, now_s=0.0)            # evicts qs[1]
    assert c.lookup(qs[1], 3, opts, now_s=0.0) is None
    assert c.lookup(qs[0], 3, opts, now_s=0.0) is not None
    assert c.lookup(qs[2], 3, opts, now_s=0.0) is not None
    assert len(c) == 2


# ------------------------------------------- virtual-clock scheduler paths
def _sched(data, cache, **kw):
    srv = HarmonyServer(data, n_nodes=2)
    return srv, ServingScheduler(
        srv, SchedulerConfig(max_batch=8, cache=cache, **kw), k=3,
        service_time_fn=lambda n: 0.0,
    )


def test_scheduler_coalesces_duplicates_to_one_execution():
    x, cfg, data = _plane()
    srv, sched = _sched(data, CacheConfig(enabled=True, exact_ttl_s=1e9))
    req = SearchRequest(vector=x[0], k=3)
    n = 6
    for i in range(n):
        sched.submit(req, i * 1e-6)
    res = sched.flush()
    # one batch, one executed row, the answer fanned out to all n
    assert len(res) == n
    assert srv.stats.queries == 1
    assert srv.stats.coalesced == n - 1
    for r in res[1:]:
        assert r.batch_id == res[0].batch_id
        assert np.array_equal(r.ids, res[0].ids)
        assert np.array_equal(r.scores, res[0].scores)
    # the executed answer was cached: a later duplicate is an exact hit
    rid = sched.submit(req, 1.0)
    assert srv.stats.cache_hits_exact == 1
    late = [r for r in sched.done if r.req_id == rid]
    assert late and np.array_equal(late[0].ids, res[0].ids)
    assert srv.stats.queries == 1               # still one execution total
    st = srv.stats
    assert st.offered == (st.admitted + st.shed + st.expired_requests
                          + st.cache_hits_exact + st.cache_hits_semantic)


def test_scheduler_semantic_hit_replays_neighbor_answer():
    x, cfg, data = _plane()
    srv, sched = _sched(data, CacheConfig(enabled=True, exact_ttl_s=1e9,
                                          semantic_threshold=4.0))
    sched.submit(SearchRequest(vector=x[0], k=3), 0.0)
    sched.advance(0.5)
    first = sched.done[-1]
    near = x[0].copy()
    near[0] += 1.0                  # squared L2 distance 1.0 < 4.0
    sched.submit(SearchRequest(vector=near, k=3), 1.0)
    assert srv.stats.cache_hits_semantic == 1
    assert np.array_equal(sched.done[-1].ids, first.ids)
    assert np.array_equal(sched.done[-1].scores, first.scores)
    assert srv.stats.queries == 1


def test_scheduler_cache_invalidation_on_writes_and_adopt():
    x, cfg, data = _plane()
    srv, sched = _sched(data, CacheConfig(enabled=True, exact_ttl_s=1e9))
    req = SearchRequest(vector=x[0], k=3)

    def probe(t):
        h0 = srv.stats.cache_hits_exact
        sched.submit(req, t)
        sched.advance(t + 0.5)
        return srv.stats.cache_hits_exact > h0

    assert not probe(1.0)                       # cold: executes + caches
    assert probe(2.0)                           # warm: exact hit
    srv.upsert([500], x[:1] + 1.0)              # op_count moved, budget 0
    assert not probe(3.0)
    assert probe(4.0)
    srv.delete([500])                           # delete invalidates too
    assert not probe(5.0)
    assert probe(6.0)
    gen0 = data.generation
    data.compact_inline(merge_all=True)         # the PR 5 adoption path
    assert data.generation > gen0
    assert not probe(7.0)                       # never across a swap
    assert srv.stats.cache_invalidations >= 3


def test_scheduler_staleness_budget_bounds_serving_across_writes():
    x, cfg, data = _plane()
    srv, sched = _sched(data, CacheConfig(enabled=True, exact_ttl_s=1e9,
                                          staleness_s=10.0))
    req = SearchRequest(vector=x[0], k=3)
    sched.submit(req, 1.0)
    sched.advance(1.5)                          # entry stamped ~t=1
    srv.upsert([501], x[:1] - 1.0)              # op_count moves
    sched.submit(req, 5.0)                      # age ~4 s <= budget: served
    assert srv.stats.cache_hits_exact == 1
    sched.submit(req, 30.0)                     # age ~29 s > budget: stale
    assert srv.stats.cache_hits_exact == 1
    assert srv.stats.cache_invalidations == 1


# --------------------------------------------- per-request deadline (bugfix)
def test_scheduler_deadline_expired_at_submit_is_shed_with_sentinel():
    x, cfg, data = _plane()
    srv, sched = _sched(data, CacheConfig(enabled=True, exact_ttl_s=1e9))
    req = SearchRequest(vector=x[0], k=3)
    sched.submit(req, 1.0)
    sched.advance(1.5)                          # answer now cached
    hits0 = srv.stats.cache_hits_exact
    rid = sched.submit(
        SearchRequest(vector=x[0], k=3, deadline=2.5), 3.0)
    # expired at submission: sentinel degradation, and even the cached
    # answer is refused (a blown deadline is a blown deadline)
    assert srv.stats.expired_requests == 1
    assert srv.stats.cache_hits_exact == hits0
    r = [d for d in sched.done if d.req_id == rid][0]
    assert (r.ids == -1).all() and np.isinf(r.scores).all()
    assert r.batch_id == -1


def test_scheduler_deadline_expired_in_queue_degrades_not_executes():
    x, cfg, data = _plane()
    srv = HarmonyServer(data, n_nodes=2)
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=8, max_wait_s=1.0), k=3,
        service_time_fn=lambda n: 0.0,
    )
    sched.submit(SearchRequest(vector=x[0], k=3, deadline=0.3), 0.0)
    sched.submit(SearchRequest(vector=x[1], k=3), 0.01)
    res = sched.flush()                         # deadline trigger at t=1.0
    assert srv.stats.expired_requests == 1
    assert srv.stats.queries == 1               # only the live row executed
    dead, live = res[0], res[1]
    assert (dead.ids == -1).all() and np.isinf(dead.scores).all()
    assert dead.batch_id == live.batch_id == 0
    assert (live.ids >= 0).any()
    assert srv.stats.deadline_batches == 1


def test_scheduler_all_expired_batch_consumes_id_without_trigger():
    x, cfg, data = _plane()
    srv = HarmonyServer(data, n_nodes=2)
    seen = []
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=8, max_wait_s=1.0), k=3,
        service_time_fn=lambda n: 0.0,
        on_batch=lambda bid, s: seen.append(bid),
    )
    sched.submit(SearchRequest(vector=x[0], k=3, deadline=0.3), 0.0)
    res = sched.flush()
    assert srv.stats.expired_requests == 1
    assert srv.stats.queries == 0               # nothing executed
    # mirrors the failed-batch path: the batch id is consumed, no
    # size/deadline/capacity trigger is recorded, on_batch still fires
    assert (srv.stats.full_batches + srv.stats.deadline_batches
            + srv.stats.capacity_batches) == 0
    assert seen == [0]
    assert (res[0].ids == -1).all()


# ----------------------------------------------- wall-clock front-end paths
def _frontend_stack():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 8)).astype(np.float32)
    cfg = HarmonyConfig(dim=8, nlist=4, nprobe=2, topk=3, kmeans_iters=2)
    return x, HarmonyServer(build_ivf(x, cfg), n_nodes=2)


@retry_flaky(times=3)
def test_frontend_inflight_coalescing_and_clean_shutdown():
    x, srv = _frontend_stack()
    fe = ServingFrontend(
        srv,
        SchedulerConfig(max_batch=4, max_wait_s=1.0,
                        cache=CacheConfig(enabled=True, exact_ttl_s=60.0)),
        k=3, service_time_fn=lambda n: 0.05,
    )
    try:
        req = SearchRequest(vector=x[0], k=3)
        n = 5
        futs = [fe.submit(req) for _ in range(n)]   # 1 leader + 4 followers
        assert fe.drain(timeout=30.0)               # fire the queued leader
        res = [f.result(timeout=30.0) for f in futs]
        assert srv.stats.coalesced == n - 1
        assert srv.stats.queries == 1               # one execution for all n
        for r in res[1:]:
            assert np.array_equal(r.ids, res[0].ids)
            assert np.array_equal(r.scores, res[0].scores)
        # the answer was cached before followers detached: the next
        # duplicate (no in-flight leader anymore) is an exact hit
        late = fe.submit(req).result(timeout=30.0)
        assert srv.stats.cache_hits_exact == 1
        assert late.batch_id == -1
        assert np.array_equal(late.ids, res[0].ids)
        st = srv.stats
        assert st.offered == (st.admitted + st.shed + st.expired_requests
                              + st.coalesced + st.cache_hits_exact
                              + st.cache_hits_semantic)
    finally:
        assert fe.shutdown(wait=True)
    assert srv.stats.shutdown_leaks == 0
    assert not fe._futures and not fe._followers and not fe._leaders


def test_frontend_shutdown_nowait_drops_queued_leader_and_followers():
    x, srv = _frontend_stack()
    fe = ServingFrontend(
        srv,
        SchedulerConfig(max_batch=64, max_wait_s=5.0,
                        cache=CacheConfig(enabled=True, exact_ttl_s=60.0)),
        k=3,
    )
    req = SearchRequest(vector=x[0], k=3)
    futs = [fe.submit(req) for _ in range(3)]       # leader + 2 followers
    assert srv.stats.coalesced == 2
    fe.shutdown(wait=False)
    for f in futs:
        assert f.cancelled(), "queued work must be cancelled, not leaked"
    assert not fe._futures and not fe._followers and not fe._leaders
    assert srv.stats.shutdown_leaks == 0


def test_frontend_deadline_expired_at_submit():
    x, srv = _frontend_stack()
    with ServingFrontend(srv, SchedulerConfig(max_batch=4), k=3) as fe:
        r = fe.submit(
            SearchRequest(vector=x[0], k=3, deadline=-1.0)
        ).result(timeout=30.0)
    assert (r.ids == -1).all() and np.isinf(r.scores).all()
    assert r.batch_id == -1
    assert srv.stats.expired_requests == 1


# ------------------------------------------------------- P11 (fixed grid)
P11_OPS = [
    ("fresh", 1), ("repeat", 2), ("near", 3), ("upsert", 4), ("repeat", 5),
    ("compact", 6), ("repeat", 7), ("delete", 8), ("near", 9), ("fresh", 10),
    ("repeat", 11), ("compact", 13), ("repeat", 14),
]


@pytest.mark.parametrize("backend", ["host", "spmd"])
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_p11_cached_serving_matches_cache_off_twin_grid(backend, precision):
    run_cache_interleaving(0, backend, precision, P11_OPS)
