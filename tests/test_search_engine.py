"""End-to-end exactness of the HARMONY staged engine vs the single-node
oracle, across modes/plans/metrics. Pruning must never change results."""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import (
    build_ivf,
    harmony_search,
    plan_search,
    preassign,
    search_oracle,
)
from repro.data import make_dataset, make_queries


def _compare(oracle, got, rtol=1e-4, atol=1e-4):
    """Scores must match; ids must match except across near-ties."""
    assert oracle.scores.shape == got.scores.shape
    np.testing.assert_allclose(got.scores, oracle.scores, rtol=rtol, atol=atol)
    nq, k = oracle.ids.shape
    for i in range(nq):
        if not np.array_equal(oracle.ids[i], got.ids[i]):
            # permit permutations among (near-)tied scores only
            assert set(oracle.ids[i ].tolist()) == set(got.ids[i].tolist()) or np.allclose(
                np.sort(oracle.scores[i]), np.sort(got.scores[i]), rtol=rtol, atol=atol
            ), f"query {i}: ids diverge beyond ties"


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset(nb=6000, dim=96, n_components=24, seed=3)
    cfg = HarmonyConfig(dim=96, nlist=32, nprobe=6, topk=10, kmeans_iters=8)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=64, skew=0.3, seed=7)
    return ds, cfg, index, q


@pytest.mark.parametrize("mode,n_nodes", [("harmony", 8), ("vector", 4), ("dimension", 4)])
def test_engine_matches_oracle(setup, mode, n_nodes):
    ds, cfg, index, q = setup
    cfg2 = cfg.replace(mode=mode)
    decision = plan_search(index, n_nodes, cfg2)
    corpus = preassign(index, decision.plan)
    oracle = search_oracle(index, q)
    got = harmony_search(index, corpus, q)
    _compare(oracle, got)


def test_pruning_is_exact(setup):
    """enable_pruning on/off must give identical result sets."""
    ds, cfg, index, q = setup
    # pin a plan with dimension blocks so intermediate pruning is exercised
    decision = plan_search(index, 8, cfg.replace(mode="dimension"))
    corpus = preassign(index, decision.plan)
    on = harmony_search(index, corpus, q, enable_pruning=True)
    off = harmony_search(index, corpus, q, enable_pruning=False)
    _compare(off, on)
    # and pruning actually skipped work
    assert on.stats["pair_flops"] < off.stats["pair_flops"]


def test_pipeline_off_matches(setup):
    ds, cfg, index, q = setup
    decision = plan_search(index, 8, cfg)
    corpus = preassign(index, decision.plan)
    oracle = search_oracle(index, q)
    got = harmony_search(index, corpus, q, pipeline=False)
    _compare(oracle, got)


def test_pruning_ratio_increases_by_slice(setup):
    """Paper Table 3: later slices prune more."""
    ds, cfg, index, q = setup
    cfg2 = cfg.replace(mode="dimension")
    decision = plan_search(index, 4, cfg2)
    corpus = preassign(index, decision.plan)
    res = harmony_search(index, corpus, q)
    ratios = res.stats["slice_pruned_ratio"]
    assert ratios[0] == 0.0
    assert all(ratios[i] <= ratios[i + 1] + 1e-9 for i in range(len(ratios) - 1))
    assert ratios[-1] > 0.2  # meaningful pruning by the last slice


def test_recall_against_brute_force(setup):
    """IVF with nprobe=6/32 should give decent recall on clustered data."""
    from repro.data import brute_force_topk, recall_at_k

    ds, cfg, index, q = setup
    decision = plan_search(index, 8, cfg)
    corpus = preassign(index, decision.plan)
    got = harmony_search(index, corpus, q)
    true_idx, _ = brute_force_topk(ds.x, q, cfg.topk)
    rec = recall_at_k(got.ids, true_idx)
    assert rec > 0.8, rec
