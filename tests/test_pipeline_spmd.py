"""SPMD ring-pipeline engine: multi-device runs go through a subprocess so
the main pytest process keeps a single CPU device (per the dry-run rules);
a 1×1-mesh in-process test covers the degenerate geometry."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("extra", [[], ["--pallas"]])
def test_multi_device_spmd_matches_oracle(extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "distributed_search.py"), *extra],
        env=env,
        capture_output=True,
        text=True,
        timeout=540,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "EXACTNESS_OK" in proc.stdout, proc.stdout + proc.stderr


def test_single_device_mesh_in_process():
    from repro.config import HarmonyConfig
    from repro.core import assign_queries, build_ivf, preassign, prewarm_tau, search_oracle
    from repro.core.pipeline import (
        SpmdConfig,
        build_spmd_inputs,
        make_spmd_search,
    )
    from repro.core.types import PartitionPlan
    from repro.data import make_dataset, make_queries

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ds = make_dataset(nb=1000, dim=32, n_components=8, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=32, nlist=16, nprobe=4, topk=5, kmeans_iters=4)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=16, seed=1)
    plan = PartitionPlan(
        v_shards=1, d_blocks=1, cluster_to_shard=np.zeros(16, np.int32)
    )
    corpus = preassign(index, plan)
    chunk = 128
    cap = -(-corpus.cap // chunk) * chunk
    scfg = SpmdConfig(
        v_shards=1, d_blocks=1, qb=16, cap=cap, dim=32, nprobe=4, k=5,
        chunk=chunk, use_pallas=False,
    )
    probes = assign_queries(index, q)
    tau0 = prewarm_tau(index, q, probes, 5)
    arrays = build_spmd_inputs(index, corpus, q, scfg, probes, tau0)
    step = make_spmd_search(scfg, mesh)
    scores, ids, stats = step(
        arrays["x_blocks"], arrays["xn2_blocks"], arrays["cluster_ids"],
        arrays["row_ids"], arrays["queries"], arrays["probes"], arrays["tau0"],
    )
    oracle = search_oracle(index, q)
    finite = np.isfinite(oracle.scores)
    np.testing.assert_allclose(
        np.asarray(scores)[finite], oracle.scores[finite], rtol=1e-3, atol=1e-3
    )
