"""Running-top-K Pallas kernel vs the sort-based oracle: shape sweep +
duplicate/invalid handling. Interpret mode on CPU."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.ref import running_topk_ref
from repro.kernels.topk_update import running_topk_update


def _mk(m, c, k, seed=0, frac_invalid=0.2, run_filled=True):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0, 100, size=(m, c)).astype(np.float32)
    scores[rng.random((m, c)) < frac_invalid] = np.inf
    ids = rng.integers(0, 10_000, size=(m, c)).astype(np.int32)
    if run_filled:
        run_s = np.sort(rng.uniform(0, 100, size=(m, k)).astype(np.float32), axis=1)
        run_i = rng.integers(10_000, 20_000, size=(m, k)).astype(np.int32)
    else:
        run_s = np.full((m, k), np.inf, np.float32)
        run_i = np.full((m, k), -1, np.int32)
    return map(jnp.asarray, (scores, ids, run_s, run_i))


@pytest.mark.parametrize("m,c,k", [(1, 8, 4), (8, 64, 10), (13, 100, 5), (4, 16, 16)])
@pytest.mark.parametrize("run_filled", [True, False])
def test_matches_oracle(m, c, k, run_filled):
    scores, ids, run_s, run_i = _mk(m, c, k, seed=m * c + k,
                                    run_filled=run_filled)
    got_s, got_i = running_topk_update(scores, ids, run_s, run_i, k=k,
                                       tile_m=4, interpret=True)
    want_s, want_i = running_topk_ref(scores, ids, run_s, run_i, k)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-6)
    # ids must match except across exact score ties
    gs, ws = np.asarray(got_s), np.asarray(want_s)
    gi, wi = np.asarray(got_i), np.asarray(want_i)
    diff = gi != wi
    if diff.any():
        r, c_ = np.nonzero(diff)
        assert np.allclose(gs[r, c_], ws[r, c_]), "id mismatch beyond ties"


def test_all_invalid_chunk_keeps_running():
    scores = jnp.full((3, 10), jnp.inf, jnp.float32)
    ids = jnp.full((3, 10), -1, jnp.int32)
    run_s = jnp.asarray(np.sort(np.random.default_rng(0).uniform(0, 1, (3, 5)), axis=1),
                        jnp.float32)
    run_i = jnp.arange(15, dtype=jnp.int32).reshape(3, 5)
    got_s, got_i = running_topk_update(scores, ids, run_s, run_i, k=5,
                                       tile_m=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(run_s))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(run_i))
