"""Shape/dtype sweep of the Pallas partial-distance kernel vs the pure-jnp
oracle, plus semantic checks (pruning exactness, inf propagation, skip map).
Kernels run in interpret mode on CPU."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.distance import partial_distance_update
from repro.kernels.ref import partial_distance_update_ref


def _mk(m, n, d, dtype, seed=0, frac_pruned=0.3):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(dtype)
    q = rng.normal(size=(m, d)).astype(dtype)
    xn2 = (x.astype(np.float32) ** 2).sum(1)
    qn2 = (q.astype(np.float32) ** 2).sum(1)
    acc = rng.uniform(0, 5, size=(m, n)).astype(np.float32)
    acc[rng.random((m, n)) < frac_pruned] = np.inf
    tau = rng.uniform(d * 0.5, d * 3.0, size=(m,)).astype(np.float32)
    return map(jnp.asarray, (x, xn2, q, qn2, acc, tau))


SHAPES = [
    (8, 16, 32),      # all smaller than tiles → single padded tile
    (128, 128, 128),  # exact tile multiples
    (130, 257, 96),   # ragged everything
    (1, 300, 64),     # single query
    (64, 1, 128),     # single candidate
]


@pytest.mark.parametrize("m,n,d", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_kernel_matches_ref(m, n, d, dtype, metric):
    x, xn2, q, qn2, acc, tau = _mk(m, n, d, dtype, seed=m * 31 + n)
    got, skip = partial_distance_update(
        x, xn2, q, qn2, acc, tau, metric=metric, interpret=True,
        tile_m=64, tile_n=64, tile_k=64,
    )
    want = partial_distance_update_ref(x, xn2, q, qn2, acc, tau, metric=metric)
    # compare finite entries with tolerance; inf pattern must match exactly
    # except at the pruning boundary (|value − τ| within fp noise).
    gf, wf = np.asarray(got), np.asarray(want)
    tau_np = np.asarray(tau)[:, None]
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    boundary = np.abs(np.where(np.isfinite(wf), wf, tau_np) - tau_np) <= tol * (
        1 + np.abs(tau_np)
    )
    mismatch_inf = np.isfinite(gf) != np.isfinite(wf)
    assert not (mismatch_inf & ~boundary).any(), "inf pattern diverges beyond fp ties"
    both = np.isfinite(gf) & np.isfinite(wf)
    np.testing.assert_allclose(gf[both], wf[both], rtol=tol, atol=tol)


def test_prune_false_keeps_everything_finite():
    x, xn2, q, qn2, acc, tau = _mk(32, 48, 64, np.float32, frac_pruned=0.0)
    got, _ = partial_distance_update(
        x, xn2, q, qn2, acc, tau * 0, prune=False, interpret=True,
        tile_m=32, tile_n=32, tile_k=32,
    )
    assert np.isfinite(np.asarray(got)).all()


def test_inf_never_resurrects():
    x, xn2, q, qn2, acc, tau = _mk(32, 48, 64, np.float32, frac_pruned=0.5)
    got, _ = partial_distance_update(
        x, xn2, q, qn2, acc, tau + 1e9, interpret=True,
        tile_m=32, tile_n=32, tile_k=32,
    )
    was_inf = ~np.isfinite(np.asarray(acc))
    assert (~np.isfinite(np.asarray(got)))[was_inf].all()


def test_skip_map_marks_dead_tiles():
    m, n, d, t = 64, 128, 32, 32
    x, xn2, q, qn2, acc, tau = _mk(m, n, d, np.float32, frac_pruned=0.0)
    acc = np.array(acc)            # writable copy
    acc[:, :t] = np.inf            # first candidate-tile column fully dead
    got, skip = partial_distance_update(
        jnp.asarray(x), xn2, q, qn2, jnp.asarray(acc), tau + 1e9,
        interpret=True, tile_m=t, tile_n=t, tile_k=t,
    )
    skip = np.asarray(skip)
    assert skip.shape == (m // t, n // t)
    assert (skip[:, 0] == 1).all()
    assert (skip[:, 1:] == 0).all()
    # skipped tiles must still carry +inf in the output
    assert (~np.isfinite(np.asarray(got)[:, :t])).all()


def test_accumulation_reconstructs_exact_distance():
    """Summing the kernel over disjoint dim blocks == exact squared L2."""
    rng = np.random.default_rng(0)
    m, n, d, B = 16, 40, 96, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(m, d)).astype(np.float32)
    acc = jnp.zeros((m, n), jnp.float32)
    tau = jnp.full((m,), jnp.inf, jnp.float32)
    per = d // B
    for b in range(B):
        sl = slice(b * per, (b + 1) * per)
        xb, qb = x[:, sl], q[:, sl]
        acc, _ = partial_distance_update(
            jnp.asarray(xb), jnp.asarray((xb ** 2).sum(1)),
            jnp.asarray(qb), jnp.asarray((qb ** 2).sum(1)),
            acc, tau, interpret=True, tile_m=32, tile_n=32, tile_k=32,
        )
    want = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(acc), want, rtol=2e-4, atol=2e-4)
