"""Per-architecture smoke tests: reduced configs of the same family run one
forward + backward (train) step and a few decode steps on CPU, asserting
output shapes and finiteness. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct lowering, no allocation)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfgs
from repro.models import RunCtx, decode_step, init_cache, init_params, loss_fn, unit_layout

ARCHS = cfgs.arch_names()

# the heaviest smoke configs on CPU (20s+ per case); excluded from the
# default tier-1 run via the registered `slow` marker
SLOW_ARCHS = {"gemma3-27b", "zamba2-2.7b"}


def _params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS else a
        for a in archs
    ]


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        frames = rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        targets = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
        mask = (rng.random((B, S)) < 0.3).astype(np.float32)
        return {"frames": jnp.asarray(frames), "targets": jnp.asarray(targets),
                "loss_mask": jnp.asarray(mask)}
    toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}
    if cfg.family == "vlm":
        pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S)).copy()
        pos[1, :, : S // 4] += 3     # fake 2D patch positions for a prefix
        pos[2, :, : S // 4] += 5
        batch["positions"] = jnp.asarray(pos)
    return batch


@pytest.mark.parametrize("arch", _params(ARCHS))
def test_forward_backward_smoke(arch):
    cfg = cfgs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    ctx = RunCtx(q_chunk=16, rec_chunk=8)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, ctx
    )
    assert np.isfinite(float(loss)), (arch, float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), arch
    # at least some gradient signal reaches the input/output embedding
    probe = grads["embed"] if cfg.frontend == "none" else grads["lm_head"]
    assert float(jnp.abs(probe.astype(jnp.float32)).sum()) > 0


@pytest.mark.parametrize("arch", _params(
    [a for a in ARCHS if cfgs.get_config(a).supports_decode]))
def test_decode_smoke(arch):
    cfg = cfgs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, max_len = 2, 16
    cache = init_cache(cfg, B, max_len)
    tok = jnp.zeros((B,), jnp.int32)
    logits = None
    for t in range(4):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = decode_step(params, cfg, tok, pos, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch


@pytest.mark.parametrize("arch", _params(["xlstm-1.3b", "zamba2-2.7b"]))
def test_recurrent_decode_matches_forward(arch):
    """Teacher-forced decode logits must match the parallel forward —
    validates the chunkwise/recurrent state equivalence."""
    from repro.models import forward

    cfg = cfgs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    full_logits, _ = forward(params, cfg, {"tokens": toks}, RunCtx(rec_chunk=4))
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = decode_step(params, cfg, toks[:, t], pos, cache)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_gemma3_unit_layout_covers_62_layers():
    cfg = cfgs.get_config("gemma3-27b")
    lo = unit_layout(cfg)
    assert lo["n_units"] * lo["unit_layers"] + lo["tail_locals"] == 62
