"""Deterministic fault-injection harness + graceful degradation.

Chaos scenarios as ordinary tests: a seeded :class:`FaultPlan` fires at
named sites on exact hits, so every failure here is replayable — and the
serving plane must degrade (retry, breaker-eject, recover), never lose
an acknowledged request or return a wrong answer.
"""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import SegmentedIndex, build_ivf
from repro.runtime.faults import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    fault_point,
    fault_scope,
)
from repro.serve import (
    HarmonyServer,
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingFrontend,
    ServingScheduler,
)
from repro.serve.compactor import CompactionConfig, Compactor

CFG = HarmonyConfig(dim=8, nlist=4, nprobe=4, topk=3, kmeans_iters=2)


def _data(seed=0, nb=256):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((nb, 8)).astype(np.float32)


# --------------------------------------------------------------- the plan
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("x", kind="explode")
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec("x", at=0)


def test_fault_plan_counting_where_and_delay():
    plan = FaultPlan(
        FaultSpec("a", at=2, count=2, where={"node": 1}),
        FaultSpec("b", kind="delay", delay_s=0.25),
    )
    with fault_scope(plan):
        assert fault_point("a", node=0) == 0.0      # where mismatch
        assert fault_point("a", node=1) == 0.0      # hit 1, armed at 2
        for expect_hit in (2, 3):                   # hits 2 and 3 fire
            with pytest.raises(InjectedFault) as ei:
                fault_point("a", node=1)
            assert ei.value.hit == expect_hit
        assert fault_point("a", node=1) == 0.0      # window exhausted
        assert fault_point("b") == 0.25             # delay returns seconds
    assert plan.fired == 3
    assert [e["site"] for e in plan.log] == ["a", "a", "b"]


def test_fault_plan_probability_is_seeded():
    def run(seed):
        plan = FaultPlan(
            FaultSpec("s", at=1, count=100, kind="delay", delay_s=1.0, p=0.5),
            seed=seed,
        )
        with fault_scope(plan):
            return [fault_point("s") for _ in range(50)], list(plan.log)

    d1, l1 = run(7)
    d2, l2 = run(7)
    assert d1 == d2 and l1 == l2                    # replayable
    assert 0 < sum(d1) < 50                         # actually thinned


def test_fault_scope_restores_previous_plan():
    outer = FaultPlan(FaultSpec("o"))
    with fault_scope(outer):
        with fault_scope(FaultSpec("i")):
            with pytest.raises(InjectedFault):
                fault_point("i")
        with pytest.raises(InjectedFault):
            fault_point("o")                        # outer plan restored
    assert fault_point("o") == 0.0                  # nothing installed


# -------------------------------------------------- replica crash + breaker
def _trace(x, n=64, spacing=1e-3):
    return [(i * spacing, x[i]) for i in range(n)]


def test_replica_crash_served_by_retry_matches_oracle():
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=2, cfg=CFG, routing="round_robin",
        service_time_fn=lambda r, n: n * 1e-3, seed=0,
        breaker_threshold=2, breaker_cooldown_s=0.005,
    )
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=3)
    with fault_scope(FaultSpec("replica.execute", at=1, count=4,
                               where={"replica": 0})) as plan:
        res = sched.run_trace(_trace(x))
    assert len(res) == 64                           # zero requests lost
    assert plan.fired >= 4
    s = fleet.stats
    assert s.replica_failures >= 4 and s.retried_batches >= 1
    assert s.breaker_opens >= 1                     # 2 consec failures trip
    assert s.breaker_closes >= 1                    # …and it healed
    assert s.failed_batches == 0

    # answer parity with a fault-free single server over the same trace
    srv = HarmonyServer(build_ivf(x, CFG), n_nodes=2)
    oracle = ServingScheduler(
        srv, SchedulerConfig(max_batch=8), k=3
    ).run_trace(_trace(x))
    for a, b in zip(res, oracle):
        np.testing.assert_array_equal(a.ids, b.ids)


def test_chaos_replay_is_deterministic():
    """Same seeded plan + same trace ⇒ identical fault log and identical
    resilience counters — the harness's whole reason to exist."""
    def run():
        x = _data()
        fleet = ReplicaFleet(
            build_ivf(x, CFG), replicas=3, cfg=CFG, routing="p2c",
            service_time_fn=lambda r, n: n * 1e-3, seed=0,
            breaker_threshold=2, breaker_cooldown_s=0.01,
        )
        sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=3)
        plan = FaultPlan(
            FaultSpec("replica.execute", at=2, count=3, where={"replica": 1}),
            FaultSpec("replica.execute", at=5, count=2, kind="delay",
                      delay_s=0.02, where={"replica": 0}),
            seed=11,
        )
        with fault_scope(plan):
            res = sched.run_trace(_trace(x))
        ids = np.concatenate([r.ids for r in res])
        return list(plan.log), fleet.stats.summary(), ids

    log1, sum1, ids1 = run()
    log2, sum2, ids2 = run()
    assert log1 == log2
    assert sum1 == sum2
    np.testing.assert_array_equal(ids1, ids2)


def test_breaker_open_ejects_then_probe_readmits_with_adoption():
    x = _data()
    data = SegmentedIndex.build(x, CFG)
    fleet = ReplicaFleet(
        data, replicas=2, cfg=CFG, routing="least_loaded",
        service_time_fn=lambda r, n: n * 1e-3, seed=0,
        breaker_threshold=1, breaker_cooldown_s=0.5,
    )
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=3)
    rng = np.random.default_rng(1)

    # one failure trips replica 0's breaker (threshold=1)
    with fault_scope(FaultSpec("replica.execute", where={"replica": 0})):
        sched.run_trace(_trace(x, n=8, spacing=1e-4))
    rep0 = fleet.replicas[0]
    assert rep0.open_until is not None
    assert fleet.stats.breaker_opens == 1

    # while ejected, the data plane moves on: a write + a compaction the
    # replica never adopted (no servers wired to the inline compaction)
    fleet.upsert(np.array([999]),
                 rng.standard_normal((1, 8)).astype(np.float32))
    data.compact_inline(merge_all=True)
    assert rep0.server.generation != data.generation

    # routing while open avoids replica 0 entirely
    ranked = fleet._rank_replicas(8, now=0.1, batch_id=0)
    assert ranked[0] == 1 and ranked[-1] == 0

    # past the cooldown the automatic health probe readmits it — and
    # adoption catches it up on the generation it missed
    sched.advance(0.1)          # still open: no probe
    res2 = sched.run_trace([(0.7 + i * 1e-4, x[i]) for i in range(8)])
    assert len(res2) == 16      # run_trace returns cumulative results
    assert fleet.stats.health_probes >= 1
    assert fleet.stats.breaker_closes == 1
    assert rep0.open_until is None
    assert rep0.server.generation == data.generation


def test_breaker_fail_open_when_all_replicas_tripped():
    """Every breaker open ⇒ availability wins: the fleet routes through
    open breakers rather than refusing to serve."""
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=2, cfg=CFG, routing="least_loaded",
        service_time_fn=lambda r, n: n * 1e-3, seed=0,
        breaker_threshold=1, breaker_cooldown_s=100.0,
    )
    sched = ServingScheduler(
        fleet, SchedulerConfig(max_batch=8, max_retries=2), k=3
    )
    with fault_scope(FaultSpec("replica.execute", at=1, count=4)):
        res = sched.run_trace(_trace(x, n=32))
    assert len(res) == 32
    assert fleet.stats.breaker_opens == 2
    served = [r for r in res if r.ids[0] != -1]
    assert len(served) >= 24                # at most one degraded batch
    assert fleet.next_free_s() >= 0.0       # fail-open covers this too


def test_injected_straggler_delay_charges_the_virtual_clock():
    x = _data()

    def build():
        fleet = ReplicaFleet(
            build_ivf(x, CFG), replicas=2, cfg=CFG, routing="round_robin",
            service_time_fn=lambda r, n: n * 1e-3, seed=0,
        )
        return fleet, ServingScheduler(
            fleet, SchedulerConfig(max_batch=8), k=3
        )

    fleet0, sched0 = build()
    base = sched0.run_trace(_trace(x, n=32))
    fleet1, sched1 = build()
    with fault_scope(FaultSpec("replica.execute", at=1, count=2,
                               kind="delay", delay_s=0.5)) as plan:
        slow = sched1.run_trace(_trace(x, n=32))
    assert plan.fired == 2
    # same answers, slower clock: the injected second is in busy_s and
    # in the affected batches' latency
    for a, b in zip(base, slow):
        np.testing.assert_array_equal(a.ids, b.ids)
    extra = sum(r.busy_s for r in fleet1.replicas) - sum(
        r.busy_s for r in fleet0.replicas
    )
    assert extra == pytest.approx(1.0, rel=1e-6)
    assert sched1.makespan_s > sched0.makespan_s


# ------------------------------------------------------- scheduler retries
def test_scheduler_retry_exhaustion_degrades_with_sentinels():
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=1, cfg=CFG,
        service_time_fn=lambda r, n: n * 1e-3, seed=0,
        breaker_threshold=0,            # isolate retry behaviour
    )
    sched = ServingScheduler(
        fleet, SchedulerConfig(max_batch=8, max_retries=1), k=3
    )
    # first batch fails twice (attempt + retry); later batches clean.
    # tight spacing keeps every batch on the size trigger (a deadline
    # fire would shrink the first batch and with it failed_requests)
    with fault_scope(FaultSpec("replica.execute", at=1, count=2)):
        res = sched.run_trace(_trace(x, n=24, spacing=1e-5))
    assert len(res) == 24               # degraded, not dropped
    s = fleet.stats
    assert s.failed_batches == 1 and s.failed_requests == 8
    assert s.retried_batches >= 1
    failed = [r for r in res if r.req_id < 8]
    for r in failed:
        assert (r.ids == -1).all() and np.isinf(r.scores).all()
    for r in res:
        if r.req_id >= 8:
            assert (r.ids != -1).any()


def test_scheduler_default_config_still_raises():
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=1, cfg=CFG,
        service_time_fn=lambda r, n: n * 1e-3, seed=0, breaker_threshold=0,
    )
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=3)
    with fault_scope(FaultSpec("replica.execute")):
        with pytest.raises(InjectedFault):
            sched.run_trace(_trace(x, n=8))


# ------------------------------------------------------- compactor crashes
@pytest.mark.parametrize(
    "site", ["compactor.begin", "compactor.seal", "compactor.prepare",
             "compactor.commit"]
)
def test_compactor_crash_then_recover(site):
    x = _data(nb=128)
    rng = np.random.default_rng(3)
    data = SegmentedIndex.build(x, CFG)
    srv = HarmonyServer(data, n_nodes=2)
    comp = Compactor(data, srv, CompactionConfig(delta_threshold=4))
    srv.upsert(np.arange(300, 306),
               rng.standard_normal((6, 8)).astype(np.float32))
    with fault_scope(FaultSpec(site, kind="crash")):
        with pytest.raises(InjectedFault):
            comp.run_once(reason="chaos")
    report = comp.recover()
    if site == "compactor.commit":
        # committed: roll forward — the replica adopts the generation
        assert not report["rolled_back"] and report["generation"] == 1
    else:
        # not committed: roll back — nothing was lost (begin snapshots)
        assert report["rolled_back"] and report["generation"] == 0
    assert not data.compaction_in_flight
    assert srv.generation == data.generation
    for i in range(300, 306):
        assert data.has(i)              # acknowledged writes all survive
    # the plane compacts normally afterwards
    ev = comp.run_once(reason="after")
    assert ev["generation"] == data.generation
    # queries are right after recovery + compaction
    res = srv.search_batch(x[:1], k=1)
    assert np.isfinite(res.scores[0, 0])


def test_compactor_recover_is_noop_when_clean():
    data = SegmentedIndex.build(_data(nb=64), CFG)
    srv = HarmonyServer(data, n_nodes=2)
    comp = Compactor(data, srv)
    report = comp.recover()
    assert report == {"rolled_back": False, "adopted": [],
                      "generation": 0}


def test_background_compactor_survives_injected_crash():
    """An InjectedFault inside the background loop is recorded like any
    failed cycle; recover() then clears the wreckage and the loop keeps
    going."""
    x = _data(nb=128)
    data = SegmentedIndex.build(x, CFG)
    srv = HarmonyServer(data, n_nodes=2)
    comp = Compactor(data, srv, CompactionConfig(delta_threshold=4,
                                                 poll_s=0.005))
    rng = np.random.default_rng(5)
    with fault_scope(FaultSpec("compactor.seal", kind="crash")):
        with pytest.warns(UserWarning, match="background compaction failed"):
            with comp:
                srv.upsert(np.arange(300, 310),
                           rng.standard_normal((10, 8)).astype(np.float32))
                deadline = 200
                while not comp.errors and deadline:
                    deadline -= 1
                    comp._stop.wait(0.01)
    assert comp.errors and "InjectedFault" in comp.errors[0]
    comp.recover()
    ev = comp.maybe_compact()
    assert ev is not None and data.delta_len == 0


# ------------------------------------------------------ wall-clock serving
def test_frontend_retries_idempotent_reads_under_faults():
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=1, cfg=CFG, seed=0, breaker_threshold=0,
    )
    cfg = SchedulerConfig(max_batch=4, max_wait_s=1e-3, max_retries=3,
                          retry_backoff_s=1e-4)
    with fault_scope(FaultSpec("replica.execute", at=1, count=1)) as plan:
        with ServingFrontend(fleet, cfg, k=3) as fe:
            futs = fe.submit_many(x[:8])
            ids = [f.result(timeout=30).ids for f in futs]
    assert len(ids) == 8 and plan.fired == 1
    assert fleet.stats.retried_batches >= 1
    assert fleet.stats.failed_batches == 0


def test_frontend_failed_batch_fails_futures_but_keeps_serving():
    x = _data()
    fleet = ReplicaFleet(
        build_ivf(x, CFG), replicas=1, cfg=CFG, seed=0, breaker_threshold=0,
    )
    cfg = SchedulerConfig(max_batch=4, max_wait_s=1e-3)     # retries off
    with ServingFrontend(fleet, cfg, k=3) as fe:
        with fault_scope(FaultSpec("replica.execute", at=1, count=1)):
            doomed = fe.submit_many(x[:4])
            errs = []
            for f in doomed:
                try:
                    f.result(timeout=30)
                except InjectedFault as e:
                    errs.append(e)
        # the first formed batch fails (deadline races may split the 4
        # submissions into several batches — only the first is doomed)
        assert len(errs) >= 1               # answered with the error…
        ok = [f.result(timeout=30) for f in fe.submit_many(x[4:8])]
        assert len(ok) == 4                 # …and the front-end lives on
    assert fleet.stats.failed_batches == 1
    assert fleet.stats.failed_requests == len(errs)
