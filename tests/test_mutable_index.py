"""Mutable segmented data plane: streaming upserts/deletes, tombstone
masking, background compaction, zero-downtime swap, checkpoint restore.

The exactness bar (ISSUE 5 acceptance): after N upserts + M deletes + a
compaction cycle, segmented search matches a fresh ``build_ivf`` over
the live set at equal recall settings, on both backends, with queries
served continuously (zero shed attributable to the swap) in the
virtual-clock harness. Brute-force comparisons use ``nprobe = nlist``
(probe everything) so IVF search is exact and the oracle is clustering-
independent."""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import SegmentedIndex, build_ivf
from repro.core.pruning import exact_scores
from repro.data import make_dataset
from repro.serve import (
    CompactionConfig,
    Compactor,
    HarmonyServer,
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingScheduler,
)
from repro.serve.executor import ExecutorConfig

DIM = 16
TINY_EXEC = ExecutorConfig(qb_buckets=(8,), chunk=64, use_pallas=False)


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=600, dim=DIM, n_components=6, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=DIM, nlist=8, nprobe=8, topk=5, kmeans_iters=3)
    return ds, cfg


def brute_topk(data: SegmentedIndex, q: np.ndarray, k: int):
    """Ground truth: exact top-k over the live vector set."""
    ids, x = data.live_vectors()
    sc = exact_scores(x, q, data.cfg.metric)
    order = np.argsort(sc, axis=1, kind="stable")[:, :k]
    out_s = np.take_along_axis(sc, order, axis=1)
    out_i = ids[order]
    out_i[~np.isfinite(out_s)] = -1
    return out_s, out_i


def apply_writes(target, rng, ds, n_upsert=40, n_delete=25, id_base=10_000):
    """A deterministic mixed write burst: fresh inserts, overwrites of
    existing ids, and deletes (some of freshly written ids)."""
    new_ids = np.arange(id_base, id_base + n_upsert)
    target.upsert(new_ids, rng.standard_normal((n_upsert, DIM)).astype(np.float32))
    overwrite = rng.choice(ds.x.shape[0], size=n_upsert // 2, replace=False)
    target.upsert(overwrite,
                  rng.standard_normal((len(overwrite), DIM)).astype(np.float32))
    dele = np.concatenate([
        rng.choice(ds.x.shape[0], size=n_delete, replace=False),
        new_ids[:5],
    ])
    target.delete(dele)
    return new_ids, dele


# --------------------------------------------------------------- exactness


@pytest.mark.parametrize("backend", ["host", "spmd"])
def test_upsert_delete_compact_matches_fresh_build(anns, backend):
    """The acceptance bar: writes + compaction, then segmented search ==
    a from-scratch ``build_ivf`` over the live set, on both backends."""
    ds, cfg = anns
    rng = np.random.default_rng(42)
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=4, backend=backend,
                        executor_cfg=TINY_EXEC)
    q = (ds.x[:12] + 0.05 * rng.standard_normal((12, DIM))).astype(np.float32)

    new_ids, dele = apply_writes(srv, rng, ds)

    # pre-compaction: delta scan + tombstone masking already exact
    res = srv.search_batch(q, k=5)
    bs, bi = brute_topk(data, q, 5)
    np.testing.assert_allclose(res.scores, bs, rtol=1e-3, atol=1e-3)
    assert not np.isin(res.ids, dele).any()

    # compact (seal then full merge) and compare against a fresh build
    comp = Compactor(data, srv, CompactionConfig(delta_threshold=1))
    ev = comp.maybe_compact()
    assert ev is not None and data.generation >= 1
    comp.run_once(merge_all=True, reason="test")
    assert data.n_segments == 1 and data.delta_len == 0
    assert srv.generation == data.generation

    live_ids, live_x = data.live_vectors()
    fresh = HarmonyServer(build_ivf(live_x, cfg), n_nodes=4, backend=backend,
                          executor_cfg=TINY_EXEC)
    res = srv.search_batch(q, k=5)
    want = fresh.search_batch(q, k=5)
    np.testing.assert_allclose(res.scores, want.scores, rtol=1e-3, atol=1e-3)
    # fresh ids are live-set positions; map them to external ids
    mapped = np.where(want.ids >= 0, live_ids[want.ids], -1)
    same = (mapped == res.ids) | ~np.isfinite(res.scores)
    assert same.mean() > 0.9          # identical modulo float tie order
    # and both equal brute force (nprobe = nlist)
    bs, _ = brute_topk(data, q, 5)
    np.testing.assert_allclose(res.scores, bs, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["host", "spmd"])
def test_deleted_never_resurface_upserted_reachable(anns, backend):
    ds, cfg = anns
    rng = np.random.default_rng(7)
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=2, backend=backend,
                        executor_cfg=TINY_EXEC)
    new_vec = rng.standard_normal((1, DIM)).astype(np.float32)
    srv.upsert([9999], new_vec)
    srv.delete([0, 1, 2])
    # across every lifecycle stage (delta, sealed, merged)...
    comp = Compactor(data, srv, CompactionConfig())
    for stage in ("delta", "sealed", "merged"):
        res = srv.search_batch(np.concatenate([new_vec, ds.x[:3]]), k=5)
        assert int(res.ids[0, 0]) == 9999          # exact hit, distance 0
        assert res.scores[0, 0] == pytest.approx(0.0, abs=1e-5)
        assert not np.isin(res.ids, [0, 1, 2]).any()
        if stage == "delta":
            comp.run_once(reason="seal")           # delta → sealed segment
        elif stage == "sealed":
            comp.run_once(merge_all=True, reason="merge")
    assert data.n_segments == 1 and not data.has(0) and data.has(9999)


def test_upsert_overwrites_old_version(anns):
    """The newest version wins immediately — the sealed copy of an
    overwritten id must never be returned."""
    ds, cfg = anns
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=2)
    old_vec = ds.x[5:6]
    new_vec = (old_vec + 3.0).astype(np.float32)
    srv.upsert([5], new_vec)
    res = srv.search_batch(np.concatenate([old_vec, new_vec]), k=3)
    # querying the OLD vector: id 5 may only appear with the NEW distance
    hit = res.ids[0] == 5
    if hit.any():
        d_new = float(np.sum((old_vec - new_vec) ** 2))
        assert res.scores[0][hit][0] == pytest.approx(d_new, rel=1e-3)
    # querying the NEW vector: exact hit at distance 0
    assert int(res.ids[1, 0]) == 5
    assert res.scores[1, 0] == pytest.approx(0.0, abs=1e-5)


# ------------------------------------------- continuous serving during swap


def test_zero_downtime_swap_in_virtual_clock_harness(anns):
    """Queries are served continuously through a mid-trace write burst +
    full compaction: nothing shed, every result exact for the data state
    its batch was dispatched against."""
    ds, cfg = anns
    rng = np.random.default_rng(3)
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=4)
    comp = Compactor(data, srv, CompactionConfig(delta_threshold=1))
    q = (ds.x[:64] + 0.05 * rng.standard_normal((64, DIM))).astype(np.float32)

    pre_truth = brute_topk(data, q, 5)
    mutated = {}

    def hook(batch_idx, sched):
        if batch_idx == 3:          # after batch 3 completes: write + swap
            apply_writes(srv, rng, ds)
            ev = comp.run_once(merge_all=True, reason="mid-trace")
            assert ev["segments_after"] == 1
            mutated["post_truth"] = brute_topk(data, q, 5)

    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=8, queue_capacity=0), k=5,
        on_batch=hook,
    )
    results = sched.run_trace([(i * 1e-5, q[i]) for i in range(64)])
    assert len(results) == 64 and srv.stats.shed == 0
    assert srv.stats.generation_swaps >= 1
    got = np.stack([r.scores for r in results])
    # batches 0–3 (requests 0–31) saw the pre-write corpus; 4–7 the
    # post-compaction one
    np.testing.assert_allclose(got[:32], pre_truth[0][:32], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got[32:], mutated["post_truth"][0][32:],
                               rtol=1e-3, atol=1e-3)


def test_background_compactor_thread_live_writes(anns):
    """Real-thread compactor: writes stream in while batches are served;
    the thread seals/merges in the background and the final state is
    exact."""
    ds, cfg = anns
    rng = np.random.default_rng(11)
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=2)
    q = ds.x[:8]
    comp = Compactor(data, srv,
                     CompactionConfig(delta_threshold=16, poll_s=0.005))
    with comp:
        for i in range(12):
            srv.upsert(np.arange(20_000 + 8 * i, 20_000 + 8 * (i + 1)),
                       rng.standard_normal((8, DIM)).astype(np.float32))
            srv.delete([int(rng.integers(0, 600))])
            srv.search_batch(q, k=5)
    assert data.generation >= 1 and comp.events
    res = srv.search_batch(q, k=5)
    bs, _ = brute_topk(data, q, 5)
    np.testing.assert_allclose(res.scores, bs, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------- fleet churn


def test_fleet_fail_mutate_join_gets_current_generation(anns):
    """The membership-churn regression: a replica that joins after
    fail → upsert/delete → compact serves the *current* generation, not
    the boot-time index."""
    ds, cfg = anns
    rng = np.random.default_rng(5)
    fleet = ReplicaFleet(build_ivf(ds.x, cfg), replicas=2, cfg=cfg,
                         routing="least_loaded",
                         service_time_fn=lambda r, n: n * 1e-3, seed=0)
    comp = Compactor(fleet.data, fleet, CompactionConfig(delta_threshold=1))
    q = ds.x[:48]

    def churn(batch_idx, sched):
        if batch_idx == 1:
            fleet.fail_replica(1)
            apply_writes(fleet, rng, ds)          # mutate through the fleet
            comp.run_once(merge_all=True, reason="churn")
        elif batch_idx == 3:
            fleet.join_replica(ReplicaSpec())

    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=5,
                             on_batch=churn)
    results = sched.run_trace([(i * 1e-5, q[i]) for i in range(48)])
    assert len(results) == 48 and fleet.stats.shed == 0
    joiner = fleet.replicas[2].server
    assert joiner.generation == fleet.data.generation >= 1
    # the joiner serves the post-mutation corpus exactly
    res = joiner.search_batch(q[:8], k=5)
    bs, _ = brute_topk(fleet.data, q[:8], 5)
    np.testing.assert_allclose(res.scores, bs, rtol=1e-3, atol=1e-3)
    # and the post-churn trace results match the post-mutation truth
    post = np.stack([r.scores for r in results[16:]])
    bs_all, _ = brute_topk(fleet.data, q, 5)
    np.testing.assert_allclose(post, bs_all[16:], rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------- checkpointing


def test_checkpoint_roundtrip_search_identical(anns, tmp_path):
    ds, cfg = anns
    rng = np.random.default_rng(9)
    from repro.checkpoint import (
        Checkpointer,
        load_segmented_index,
        save_segmented_index,
    )

    data = SegmentedIndex.build(ds.x, cfg)
    apply_writes(data, rng, ds)
    data.compact_inline()                       # seal → 2 segments, gen 1
    data.delete([40])                           # post-seal tombstone
    data.upsert([31_000], rng.standard_normal((1, DIM)).astype(np.float32))

    ck = Checkpointer(str(tmp_path / "ckpt"))
    save_segmented_index(ck, data)
    assert ck.latest_step() == data.generation  # generation-numbered
    back = load_segmented_index(ck)
    assert (back.generation, back.n_segments, back.nb_live) == (
        data.generation, data.n_segments, data.nb_live)

    q = ds.x[:10]
    res_a = HarmonyServer(data, n_nodes=4).search_batch(q, k=5)
    res_b = HarmonyServer(back, n_nodes=4).search_batch(q, k=5)
    np.testing.assert_array_equal(res_a.ids, res_b.ids)
    np.testing.assert_allclose(res_a.scores, res_b.scores)
    # the restored plane is fully mutable (delta, tombstones, compaction)
    back.delete([41])
    back.compact_inline(merge_all=True)
    assert back.n_segments == 1 and not back.has(41)


# ------------------------------------------------------- bookkeeping bits


def test_tombstone_aware_sizes_and_memory(anns):
    ds, cfg = anns
    data = SegmentedIndex.build(ds.x, cfg)
    seg = data.segments[0]
    assert data.live_sizes(seg).sum() == ds.x.shape[0]
    mem0 = data.memory_bytes()
    data.delete(np.arange(50))
    assert data.live_sizes(seg).sum() == ds.x.shape[0] - 50
    assert data.nb_live == ds.x.shape[0] - 50
    data.upsert([99_999], np.zeros((1, DIM), np.float32))
    assert data.memory_bytes() > mem0           # delta buffer counted
    assert data.delta_len == 1
    d = data.dead_count_by_segment()
    assert d[seg.seg_id] == 50


def test_compaction_journal_replays_concurrent_writes(anns):
    """Writes that land between begin and commit survive the swap."""
    ds, cfg = anns
    rng = np.random.default_rng(13)
    data = SegmentedIndex.build(ds.x, cfg)
    data.upsert([50_000], rng.standard_normal((1, DIM)).astype(np.float32))
    plan = data.begin_compaction(merge_all=True)
    # concurrent with the (here: deferred) seal:
    data.delete([0, 50_000])
    v = rng.standard_normal((1, DIM)).astype(np.float32)
    data.upsert([50_001], v)
    data.upsert([1], v + 1.0)                   # overwrite a sealed-in-plan id
    segs = data.seal(plan)
    data.commit_compaction(plan, segs)
    assert not data.has(0) and not data.has(50_000)
    assert data.has(50_001) and data.has(1)
    srv = HarmonyServer(data, n_nodes=2)
    res = srv.search_batch(np.concatenate([v, v + 1.0]), k=1)
    assert res.ids[:, 0].tolist() == [50_001, 1]
    assert np.allclose(res.scores[:, 0], 0.0, atol=1e-5)


def test_stale_snapshot_never_rolls_back_generation(anns):
    """A thread carrying a pre-swap snapshot must not roll the server
    back a generation (it would destroy the compactor's prepared state);
    `_sync` refuses and the serving loop re-snapshots."""
    ds, cfg = anns
    data = SegmentedIndex.build(ds.x, cfg)
    srv = HarmonyServer(data, n_nodes=2)
    stale = data.snapshot()
    data.upsert([77_000], np.ones((1, DIM), np.float32))
    data.compact_inline()                      # gen 1: delta sealed
    srv.adopt()
    gen = srv.generation
    assert gen == data.generation == 1
    assert srv._sync(stale) is False           # stale reader refused
    assert srv.generation == gen
    res = srv.search_batch(ds.x[:4], k=5)      # serving unaffected
    bs, _ = brute_topk(data, ds.x[:4], 5)
    np.testing.assert_allclose(res.scores, bs, rtol=1e-3, atol=1e-3)


def test_external_ids_beyond_int32_host_and_spmd_delta(anns):
    """Ids past the int32 range survive the host path end-to-end, and
    the spmd backend's fused cross-part merge falls back to the host
    merge instead of silently wrapping a delta id."""
    ds, cfg = anns
    big = 3_000_000_000                        # > 2^31 - 1
    vec = np.full((1, DIM), 4.0, np.float32)
    for backend in ("host", "spmd"):
        data = SegmentedIndex.build(ds.x, cfg)
        srv = HarmonyServer(data, n_nodes=2, backend=backend,
                            executor_cfg=TINY_EXEC)
        srv.upsert([big], vec)                 # lives in the delta
        res = srv.search_batch(vec, k=3)
        assert int(res.ids[0, 0]) == big
        assert res.scores[0, 0] == pytest.approx(0.0, abs=1e-5)
        # once sealed, the segment's ids no longer fit int32: the spmd
        # backend must serve that segment via the host engine rather
        # than upload wrapped ids to the device
        data.compact_inline()
        res = srv.search_batch(vec, k=3)
        assert int(res.ids[0, 0]) == big
        assert res.scores[0, 0] == pytest.approx(0.0, abs=1e-5)


def test_snapshot_is_point_in_time(anns):
    """A snapshot taken before an upsert of a sealed id must keep that
    id visible: the tombstone half of a later write may not leak into an
    in-flight batch that can't see the new delta row."""
    ds, cfg = anns
    data = SegmentedIndex.build(ds.x, cfg)
    snap = data.snapshot()
    data.upsert([5], np.ones((1, DIM), np.float32))   # tombstones sealed row 5
    data.delete([6])
    seg = snap.segments[0]
    assert not snap.dead_rows[seg.seg_id].any()       # snapshot unaffected
    from repro.core import search_oracle
    res = search_oracle(seg.index, ds.x[5:7], k=1,
                        dead_rows=snap.dead_rows[seg.seg_id])
    assert res.ids[:, 0].tolist() == [5, 6]           # both still visible
