"""Substrate tests: optimizer, train step, checkpoint (incl. resharding
restore semantics), gradient compression, data pipelines, elastic replan,
straggler hedging, serving engine."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfgs
from repro.checkpoint import Checkpointer
from repro.config import HarmonyConfig
from repro.core import build_ivf, harmony_search, plan_search, preassign, search_oracle
from repro.data import TokenPipeline, make_dataset, make_queries
from repro.models import RunCtx, init_params
from repro.runtime import ClusterState, HedgingExecutor, replan_on_failure
from repro.serve import HarmonyServer
from repro.train import OptConfig, init_opt_state, make_train_step, opt_update
from repro.train.compression import (
    compress_with_feedback,
    dequantize_int8,
    init_error_state,
    quantize_int8,
)


# ---------------------------------------------------------------- optimizer


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    ocfg = OptConfig(name=name, lr=0.05, weight_decay=0.0)
    params = {"w": jnp.ones((256, 256), jnp.float32) * 2.0}
    state = init_opt_state(params, ocfg)

    def loss(p):
        return jnp.mean(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(20):
        grads = jax.grad(loss)(params)
        params, state = opt_update(params, grads, state, ocfg)
    assert float(loss(params)) < l0 * 0.7
    assert int(state["step"]) == 20


@pytest.mark.slow
def test_train_step_microbatch_equivalence():
    """1 microbatch vs 4 must give (nearly) the same update."""
    cfg = cfgs.get_smoke_config("qwen1.5-4b").replace(remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ocfg = OptConfig(lr=1e-3)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(8, 17)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}

    s1 = make_train_step(cfg, ocfg, RunCtx(), microbatches=1)
    s4 = make_train_step(cfg, ocfg, RunCtx(), microbatches=4)
    p1, o1, m1 = s1(params, init_opt_state(params, ocfg), batch)
    p4, o4, m4 = s4(params, init_opt_state(params, ocfg), batch)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        p1, p4,
    )
    assert max(jax.tree.leaves(d)) < 5e-2  # bf16 params, microbatch fp noise


def test_training_reduces_loss():
    cfg = cfgs.get_smoke_config("qwen1.5-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    from repro.train import train_loop

    params, _, history = train_loop(cfg, params, pipe, steps=30,
                                    ocfg=OptConfig(lr=3e-3), log_every=0)
    assert np.mean(history[-5:]) < np.mean(history[:5]) - 0.2, history[:3] + history[-3:]


# --------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    cfg = cfgs.get_smoke_config("olmoe-1b-7b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params, OptConfig())
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(5, {"params": params, "opt": opt})
    ck.save(9, {"params": params, "opt": opt})
    assert ck.latest_step() == 9
    restored = ck.restore({"params": params, "opt": opt}, step=9)
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_write=True)
    tree = {"w": jnp.arange(8.0)}
    for s in [1, 2, 3, 4]:
        ck.save(s, tree)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_restore_resumes_training(tmp_path):
    """Failure-recovery path: restore mid-run, continue, identical stream."""
    cfg = cfgs.get_smoke_config("xlstm-1.3b")
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    ocfg = OptConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, ocfg, RunCtx(rec_chunk=8)))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, ocfg)
    ck = Checkpointer(tmp_path)
    for step in range(4):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_for_step(step).items()}
        params, opt, _ = step_fn(params, opt, batch)
        if step == 1:
            ck.save(2, {"params": params, "opt": opt})

    # crash + restore at step 2, replay steps 2..3
    restored = ck.restore({"params": params, "opt": opt}, step=2)
    p2, o2 = restored["params"], restored["opt"]
    for step in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_for_step(step).items()}
        p2, o2, _ = step_fn(p2, o2, batch)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


# -------------------------------------------------------------- compression


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Σ_t deq(q_t) must track Σ_t g_t (error feedback re-injects residual)."""
    rng = np.random.default_rng(1)
    err = jnp.zeros((64,), jnp.float32)
    total_sent = np.zeros(64, np.float32)
    total_true = np.zeros(64, np.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        q, s, err = compress_with_feedback(g, err)
        total_sent += np.asarray(dequantize_int8(q, s))
        total_true += np.asarray(g)
    # residual bounded by one quantization step → averages converge
    assert np.abs(total_sent - total_true).max() < 0.2


# ------------------------------------------------------- data / determinism


def test_token_pipeline_elastic_determinism():
    pipe = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=8)
    g = pipe.global_batch_at(3)
    # resharding: 2 ranks vs 4 ranks slice the same global stream
    two = np.concatenate([pipe.shard_at(3, r, 2) for r in range(2)])
    four = np.concatenate([pipe.shard_at(3, r, 4) for r in range(4)])
    np.testing.assert_array_equal(two, g)
    np.testing.assert_array_equal(four, g)


# ------------------------------------------------------- elastic / hedging


@pytest.fixture(scope="module")
def anns():
    # exactness-vs-oracle assertions don't need a big corpus; keep it small
    # so tier-1 stays fast
    ds = make_dataset(nb=4000, dim=64, n_components=16, spread=0.6, seed=2)
    cfg = HarmonyConfig(dim=64, nlist=32, nprobe=6, topk=5, kmeans_iters=6)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=48, skew=0.4, noise=0.2, seed=3)
    return ds, cfg, index, q


def test_elastic_replan_preserves_results(anns):
    ds, cfg, index, q = anns
    state = ClusterState.fresh(8)
    oracle = search_oracle(index, q)
    for dead in [3, 5, 0]:
        state.fail(dead)
        decision, corpus = replan_on_failure(index, state, cfg)
        assert decision.plan.n_nodes <= state.n_live
        res = harmony_search(index, corpus, q)
        np.testing.assert_allclose(res.scores, oracle.scores, rtol=1e-3, atol=1e-3)


def test_hedging_beats_straggler():
    results = lambda t: t * 2
    workers = [results, results]
    # worker 0 straggles on every task
    lat = lambda w, t: 5.0 if w == 0 else 0.001
    ex = HedgingExecutor(workers, deadline_s=0.1, latency_fn=lat)
    out, served_by = ex.run(21, primary=0, replica=1)
    assert out == 42 and served_by == 1
    assert ex.stats.hedged == 1


# ------------------------------------------------------------------ serving


def test_server_end_to_end(anns):
    ds, cfg, index, q = anns
    srv = HarmonyServer(index, n_nodes=8, replan_every=2)
    oracle = search_oracle(index, q)
    for lo in range(0, 48, 16):
        res = srv.search_batch(q[lo : lo + 16])
        np.testing.assert_allclose(
            res.scores, oracle.scores[lo : lo + 16], rtol=1e-3, atol=1e-3
        )
    assert srv.stats.queries == 48
    assert srv.stats.qps > 0
    # kill a node mid-serve; results must not change
    srv.fail_node(2)
    res = srv.search_batch(q[:16])
    np.testing.assert_allclose(res.scores, oracle.scores[:16], rtol=1e-3, atol=1e-3)
