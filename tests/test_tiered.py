"""Tiered memory hierarchy: placement policy, host-tier streaming, and
the tier lifecycle (demote/promote, compaction, checkpoint, crashes).

The load-bearing invariant everywhere: results are *tier-invariant*. The
host tier gathers the same packed rows into the same static (qb, cap)
buckets and runs the same ring kernels, so a tier move may change pacing
but never a single returned id or score.
"""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import SegmentedIndex, TagIn, build_ivf, search_oracle
from repro.core.search import filtered_assign_queries
from repro.runtime.faults import FaultPlan, FaultSpec, InjectedFault, fault_scope
from repro.serve import (
    HarmonyServer,
    PlacementConfig,
    SchedulerConfig,
    apply_placement,
    device_bytes_by_segment,
    plan_placement,
)
from repro.serve.compactor import CompactionConfig, Compactor

CFG = HarmonyConfig(dim=16, nlist=8, nprobe=4, topk=5, kmeans_iters=3)


def _plane(seed=0, nb=384, extra=192, cfg=CFG):
    """Two sealed segments (build + sealed delta) with ids = row order."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((nb + extra, cfg.dim)).astype(np.float32)
    data = SegmentedIndex.build(x[:nb], cfg)
    if extra:
        data.upsert(np.arange(nb, nb + extra), x[nb:])
        data.compact_inline()
    return x, data


def _queries(x, n=12, seed=3):
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(x), n)
    return x[picks] + 0.05 * rng.standard_normal((n, x.shape[1])).astype(
        np.float32
    )


# ------------------------------------------------------------------ policy
def test_plan_placement_no_budget_is_all_device():
    _, data = _plane()
    tiers = plan_placement(data, PlacementConfig())
    assert set(tiers.values()) == {"device"}


def test_plan_placement_budget_keeps_hottest():
    _, data = _plane()
    sids = [s.seg_id for s in data.segments]
    # heat segment 1 only
    data.note_probes(sids[1], np.array([[0, 1, 2, 3]]))
    costs = device_bytes_by_segment(data)
    budget = costs[sids[1]]  # room for exactly the hot segment
    tiers = plan_placement(data, PlacementConfig(device_budget_bytes=budget))
    assert tiers[sids[1]] == "device"
    assert tiers[sids[0]] == "host"


def test_plan_placement_hysteresis_is_sticky():
    _, data = _plane(nb=192, extra=192)      # equal-size → equal cost
    s0, s1 = [s.seg_id for s in data.segments]
    costs = device_bytes_by_segment(data)
    assert costs[s0] == costs[s1]
    data.set_tiers({s0: "device", s1: "host"})
    # s1 is 5% hotter — inside the incumbent's 10% bonus, so the device
    # set must NOT flap; beyond it (2× hotter) the move must happen
    data.note_probes(s0, np.zeros((1, 20), np.int64))
    data.note_probes(s1, np.zeros((1, 21), np.int64))
    cfg = PlacementConfig(device_budget_bytes=costs[s0])
    tiers = plan_placement(data, cfg)
    assert tiers == {s0: "device", s1: "host"}
    data.note_probes(s1, np.zeros((1, 200), np.int64))
    assert plan_placement(data, cfg) == {s0: "host", s1: "device"}


def test_set_tiers_validates_and_bumps_version():
    _, data = _plane()
    v0 = data.placement_version
    sid = data.segments[0].seg_id
    assert data.set_tiers({sid: "host"}) == v0 + 1
    assert data.tier_of(sid) == "host"
    data.set_tiers({9999: "host"})       # unknown id ignored
    assert data.tiers().get(9999) is None
    with pytest.raises(ValueError, match="unknown tier"):
        data.set_tiers({sid: "warm"})


def test_memory_report_per_tier():
    _, data = _plane()
    rep = data.memory_report()
    assert rep["device_bytes"] > 0 and rep["host_bytes"] > 0
    assert data.memory_bytes() == rep["host_bytes"] + rep["device_bytes"]
    # int8 residency: device cost collapses toward d + overhead per row
    rep8 = data.memory_report(precision="int8")
    assert rep8["device_bytes"] < rep["device_bytes"]
    # demoting everything frees all device bytes; host side is unchanged
    data.set_tiers({s.seg_id: "host" for s in data.segments})
    cold = data.memory_report()
    assert cold["device_bytes"] == 0
    assert cold["host_bytes"] == rep["host_bytes"]


def test_memory_report_counts_metadata_and_bm25():
    cfg = CFG
    rng = np.random.default_rng(5)
    x = rng.standard_normal((128, cfg.dim)).astype(np.float32)
    data = SegmentedIndex.build(x, cfg)
    base = data.memory_report()["host_bytes"]
    data2 = SegmentedIndex.build(x, cfg)
    data2.upsert(
        np.arange(128, 192),
        rng.standard_normal((64, cfg.dim)).astype(np.float32),
        meta={"color": np.arange(64) % 3,
              "text": [f"doc number {i}" for i in range(64)]},
    )
    data2.compact_inline()
    rep = data2.memory_report()
    assert rep["host_bytes"] > base
    # force the lazy BM25 build, then the report must grow again
    from repro.core.fusion import segment_bm25
    bm = segment_bm25(data2.segments[-1].index)
    assert bm is not None
    assert data2.memory_report()["host_bytes"] == rep["host_bytes"] + \
        bm.memory_bytes()


# ----------------------------------------------------- tier-invariant serving
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_demote_promote_bit_identical_roundtrip(precision):
    x, data = _plane()
    srv = HarmonyServer(data, n_nodes=2, backend="spmd", precision=precision)
    q = _queries(x)
    hot = srv.search_batch(q)
    assert hot.stats["cold_segments"] == 0
    # demote everything
    apply_placement(data, [srv],
                    {s.seg_id: "host" for s in data.segments})
    cold = srv.search_batch(q)
    assert cold.stats["cold_segments"] == data.n_segments
    assert cold.stats["bytes_streamed"] > 0
    assert np.array_equal(hot.ids, cold.ids)
    assert np.array_equal(hot.scores, cold.scores)
    # promote back: again bit-identical, nothing streamed
    apply_placement(data, [srv],
                    {s.seg_id: "device" for s in data.segments})
    hot2 = srv.search_batch(q)
    assert hot2.stats["cold_segments"] == 0
    assert np.array_equal(hot.ids, hot2.ids)
    assert np.array_equal(hot.scores, hot2.scores)


@pytest.mark.parametrize("backend", ["host", "spmd"])
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_host_tier_matches_oracle(backend, precision):
    cfg = CFG.replace(nprobe=8)              # all clusters: exact
    x, data = _plane(cfg=cfg, extra=0)       # single segment vs oracle
    data.set_tiers({data.segments[0].seg_id: "host"})
    srv = HarmonyServer(data, n_nodes=2, backend=backend,
                        precision=precision)
    q = _queries(x)
    res = srv.search_batch(q)
    ref = search_oracle(data.segments[0].index, q, k=cfg.topk)
    assert np.array_equal(res.ids, ref.ids)
    np.testing.assert_allclose(res.scores, ref.scores, rtol=1e-5, atol=1e-5)


def test_tier_moves_do_not_bump_generation():
    x, data = _plane()
    srv = HarmonyServer(data, n_nodes=2, backend="spmd")
    gen = srv.generation
    swaps = srv.stats.generation_swaps
    apply_placement(data, [srv],
                    {s.seg_id: "host" for s in data.segments})
    srv.search_batch(_queries(x))
    assert srv.generation == gen
    assert srv.stats.generation_swaps == swaps
    assert srv.stats.placement_swaps == 1


# ------------------------------------------------------------- lifecycles
def test_placement_survives_compaction():
    x, data = _plane()
    srv = HarmonyServer(data, n_nodes=2, backend="spmd")
    sids = [s.seg_id for s in data.segments]
    budget = device_bytes_by_segment(data)[sids[0]]
    comp = Compactor(data, srv, CompactionConfig(
        delta_threshold=16,
        placement=PlacementConfig(device_budget_bytes=budget),
    ))
    # heat segment 0, install the placement
    data.note_probes(sids[0], np.array([[0, 1, 2, 3]]))
    assert comp.maybe_place() is not None
    assert data.tier_of(sids[1]) == "host"
    # seal a delta: commit prunes retired tiers, re-plans, and the server
    # keeps serving correct results across the whole cycle
    rng = np.random.default_rng(9)
    data.upsert(np.arange(2000, 2032),
                rng.standard_normal((32, CFG.dim)).astype(np.float32))
    ev = comp.maybe_compact()
    assert ev is not None and ev["placed"] in (True, False)
    assert set(data.tiers()) == {s.seg_id for s in data.segments}
    q = _queries(x)
    res = srv.search_batch(q)
    ref = srv.search_batch(q, backend="host")
    assert np.array_equal(res.ids, ref.ids)


def test_placement_survives_checkpoint_restore(tmp_path):
    from repro.checkpoint import Checkpointer
    from repro.checkpoint.index_io import (
        load_segmented_index,
        save_segmented_index,
    )

    x, data = _plane()
    sids = [s.seg_id for s in data.segments]
    data.note_probes(sids[0], np.array([[0, 1], [2, 3]]))
    data.set_tiers({sids[0]: "device", sids[1]: "host"})
    save_segmented_index(Checkpointer(tmp_path), data)
    data2 = load_segmented_index(Checkpointer(tmp_path))
    assert data2.tiers() == data.tiers()
    assert data2.placement_version == data.placement_version
    for sid in sids:
        np.testing.assert_allclose(data2.hotness(sid), data.hotness(sid))
    # the restored plane serves the host tier bit-identically
    srv = HarmonyServer(data, n_nodes=2, backend="spmd")
    srv2 = HarmonyServer(data2, n_nodes=2, backend="spmd")
    q = _queries(x)
    a, b = srv.search_batch(q), srv2.search_batch(q)
    assert np.array_equal(a.ids, b.ids)
    assert np.array_equal(a.scores, b.scores)


def test_crash_at_tier_swap_never_loses_a_segment():
    x, data = _plane()
    srv = HarmonyServer(data, n_nodes=2, backend="spmd")
    q = _queries(x)
    before = srv.search_batch(q)
    tiers = {s.seg_id: "host" for s in data.segments}
    # die between set_tiers and the replica adopt — the worst boundary
    with fault_scope(FaultPlan(FaultSpec("placement.swap"))):
        with pytest.raises(InjectedFault):
            apply_placement(data, [srv], tiers)
    assert data.tiers() == tiers                 # swap itself committed
    assert srv._placement_version != data.placement_version
    # next batch lazily re-syncs residency; every segment stays
    # reachable and the answers don't move
    after = srv.search_batch(q)
    assert np.array_equal(before.ids, after.ids)
    assert np.array_equal(before.scores, after.scores)
    assert after.stats["cold_segments"] == data.n_segments
    # crash at prepare: nothing committed, placement unchanged
    with fault_scope(FaultPlan(FaultSpec("placement.prepare"))):
        with pytest.raises(InjectedFault):
            apply_placement(data, [srv],
                            {s.seg_id: "device" for s in data.segments})
    assert data.tiers() == tiers
    again = srv.search_batch(q)
    assert np.array_equal(before.ids, again.ids)


# ------------------------------------------------------------- prefetch
def test_prefetch_hits_and_lookahead():
    x, data = _plane()
    data.set_tiers({s.seg_id: "host" for s in data.segments})
    srv = HarmonyServer(data, n_nodes=2, backend="spmd")
    q = _queries(x, n=8)
    srv.prefetch_batch(q)
    res = srv.search_batch(q)
    assert res.stats["prefetch_hits"] == data.n_segments
    assert srv.stats.prefetch_hits == data.n_segments
    # scheduler lookahead: queued next batch is prefetched automatically
    hits0 = srv.stats.prefetch_hits
    srv.serve([q[i: i + 2] for i in range(0, 8, 2)],
              sched=SchedulerConfig(backend="spmd", max_batch=2))
    assert srv.stats.prefetch_hits > hits0


def test_engine_feeds_hotness():
    x, data = _plane()
    srv = HarmonyServer(data, n_nodes=2)
    assert all(v == 0.0 for v in data.segment_hotness().values())
    srv.search_batch(_queries(x))
    heat = data.segment_hotness()
    assert any(v > 0.0 for v in heat.values())


# ------------------------------------- selectivity-aware probe widening
def _meta_corpus(nb=2048, sel_mod=100):
    """1-in-``sel_mod`` rows carry the target tag (selectivity 0.01).
    The 21 allowed rows scatter across clusters, so a sel=0.01 filter
    needs probes ∝ 1/sel to see its candidate set — the widen cap is
    raised so the threshold/selectivity ratio (~20×) binds at nlist."""
    cfg = HarmonyConfig(dim=16, nlist=32, nprobe=2, topk=5, kmeans_iters=3,
                        filter_widen_cap=16.0)
    rng = np.random.default_rng(11)
    x = rng.standard_normal((nb, cfg.dim)).astype(np.float32)
    meta = {"bucket": np.arange(nb) % sel_mod}
    return cfg, x, meta


def test_filtered_widening_recovers_recall_at_low_selectivity():
    cfg, x, meta = _meta_corpus()
    flt = TagIn("bucket", (0,))
    narrow_cfg = cfg.replace(filter_widen_threshold=0.0)   # widening off
    idx_wide = build_ivf(x, cfg, meta=meta)
    idx_narrow = build_ivf(x, narrow_cfg, meta=meta)
    q = _queries(x, n=24, seed=7)
    truth = search_oracle(idx_wide, q, nprobe=cfg.nlist, flt=flt)

    def recall(idx):
        srv = HarmonyServer(idx, n_nodes=2)
        res = srv.search_batch(q, flt=flt)
        hits = sum(
            len(set(res.ids[i].tolist()) & set(truth.ids[i].tolist())
                - {-1})
            for i in range(len(q))
        )
        denom = int((truth.ids >= 0).sum())
        return hits / max(denom, 1)

    r_narrow, r_wide = recall(idx_narrow), recall(idx_wide)
    assert r_wide > r_narrow
    # widened to every live cluster → exact filtered results; the fixed
    # 2-probe budget sees only a sliver of the 21-row candidate set
    assert r_wide >= 0.99
    assert r_narrow <= 0.5


def test_filtered_widening_math_and_override():
    cfg, x, meta = _meta_corpus()
    idx = build_ivf(x, cfg, meta=meta)
    excluded = np.asarray(meta["bucket"] != 0)[np.argsort(idx.ids)]
    # packed order: recompute the mask in row order
    excluded = np.zeros(idx.nb, bool)
    excluded[:] = True
    excluded[np.isin(idx.ids, np.nonzero(
        np.asarray(meta["bucket"]) == 0)[0])] = False
    q = x[:4]
    probes = filtered_assign_queries(idx, q, excluded)
    # sel≈0.0103 < threshold 0.2 → widen by min(cap, thr/sel)≈16×,
    # clamped to nlist
    assert probes.shape[1] == min(cfg.nlist, cfg.nprobe * 16)
    # an explicit nprobe is a caller override: never widened
    assert filtered_assign_queries(idx, q, excluded, nprobe=3).shape[1] == 3
    # high selectivity: untouched
    assert filtered_assign_queries(
        idx, q, np.zeros(idx.nb, bool)).shape[1] == cfg.nprobe
