"""Request-API regression tests.

The SearchRequest/SearchResult redesign must be a strict superset of the
old surface: every pre-existing call shape — positional
``search_batch(q, k)``, bare-ndarray ``submit``/``run_trace``, dispatch
targets written against the old positional ``execute`` signature — still
runs and returns bit-identical results (the virtual-clock goldens pin
the same contract end-to-end). The deprecation shim must warn on bare
arrays at the public admission points and stay silent on the canonical
:class:`SearchRequest` path."""

import warnings

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import SegmentedIndex, TagIn, build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import (
    DispatchTarget,
    HarmonyServer,
    SchedulerConfig,
    SearchRequest,
    ServeStats,
    ServingScheduler,
)


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=1500, dim=16, n_components=6, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=16, nlist=8, nprobe=8, topk=5, kmeans_iters=3)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=32, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


# ------------------------------------------- engine: old positional call


def test_search_batch_positional_equals_request(anns):
    ds, cfg, index, q = anns
    srv = HarmonyServer(index, n_nodes=2)
    old = srv.search_batch(q, 5)
    new = srv.search_batch(SearchRequest(vector=q, k=5))
    assert np.array_equal(old.ids, new.ids)
    assert np.array_equal(old.scores, new.scores)


# --------------------------------------- scheduler: deprecation shim


def test_bare_ndarray_submit_warns_and_matches(anns):
    ds, cfg, index, q = anns

    def run(wrap):
        srv = HarmonyServer(index, n_nodes=2)
        sched = ServingScheduler(srv, SchedulerConfig(max_batch=8), k=5)
        trace = [(0.0, wrap(q[i]) if wrap else q[i]) for i in range(16)]
        return sched.run_trace(trace)

    with pytest.warns(DeprecationWarning, match="bare ndarray"):
        old = run(None)
    with warnings.catch_warnings():
        # the canonical path must be warning-free
        warnings.simplefilter("error", DeprecationWarning)
        new = run(lambda v: SearchRequest(vector=v))
    assert len(old) == len(new) == 16
    for a, b in zip(old, new):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.scores, b.scores)


# ------------------------------- old-style dispatch targets still work


class LegacyTarget(DispatchTarget):
    """A dispatch target written against the pre-request positional
    ``execute`` signature — knob-free batches must reach it unchanged."""

    def __init__(self):
        self.stats = ServeStats()
        self.calls = []

    def configure(self, cfg, k):
        pass

    def next_free_s(self):
        return 0.0

    def execute(self, queries, k, dispatch_s, batch_id):  # no options arg
        self.calls.append((batch_id, queries.shape[0], k))
        ids = np.tile(np.arange(k, dtype=np.int64), (queries.shape[0], 1))
        scores = np.zeros((queries.shape[0], k), np.float32)

        class R:
            pass

        r = R()
        r.ids, r.scores = ids, scores
        return r, dispatch_s

    @property
    def default_max_batch(self):
        return 8

    @property
    def default_k(self):
        return 5

    @property
    def replans(self):
        return 0

    @property
    def nlist(self):
        return 4

    @property
    def parallelism(self):
        return 1


def test_legacy_positional_target_unchanged():
    target = LegacyTarget()
    sched = ServingScheduler(target, SchedulerConfig(max_batch=4), k=5)
    q = np.zeros((8, 8), np.float32)
    results = sched.run_trace(
        [(0.0, SearchRequest(vector=q[i])) for i in range(8)])
    assert len(results) == 8
    assert [c[1] for c in target.calls] == [4, 4]
    assert all(c[2] == 5 for c in target.calls)


# -------------------------- mixed per-request knobs in one formed batch


def test_mixed_option_batch_splits_and_matches(anns):
    ds, cfg, index, q = anns
    data = SegmentedIndex.from_static(index)
    srv = HarmonyServer(data, n_nodes=2)
    srv.upsert(np.arange(8) + 10_000, ds.x[:8] + 3.0,
               meta={"color": [1, 2] * 4})
    flt = TagIn("color", (2,))
    sched = ServingScheduler(srv, SchedulerConfig(max_batch=8), k=5)
    trace = [
        (0.0, SearchRequest(vector=q[0])),
        (0.0, SearchRequest(vector=q[1], filter=flt)),
        (0.0, SearchRequest(vector=q[2], k=3)),
        (0.0, SearchRequest(vector=q[3], filter=flt)),
    ]
    results = sched.run_trace(trace)
    assert len(results) == 4
    # per-request k honoured without inflating the others
    assert results[2].ids.shape == (3,)
    assert results[0].ids.shape == (5,)
    # filtered rows equal the filtered synchronous call, row for row
    want = srv.search_batch(np.stack([q[1], q[3]]), 5, flt=flt)
    assert np.array_equal(results[1].ids, want.ids[0])
    assert np.array_equal(results[3].ids, want.ids[1])
    # unfiltered row equals the plain engine result
    plain = srv.search_batch(q[:1], 5)
    assert np.array_equal(results[0].ids, plain.ids[0])


# ----------------------------------------- DataPlane forwarder contract


def test_dataplane_forwarders_count_writes(anns):
    ds, cfg, index, q = anns
    data = SegmentedIndex.from_static(index)
    srv = HarmonyServer(data, n_nodes=2)
    srv.upsert([50_000, 50_001], ds.x[:2])
    assert srv.stats.upserts == 2
    removed = srv.delete([50_000, 99_999])   # one hit, one miss
    assert removed == 1
    # deletes count submitted ids (the historical, golden-pinned metric)
    assert srv.stats.deletes == 2
