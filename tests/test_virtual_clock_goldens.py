"""Golden regression: virtual-clock replay stats must be bit-identical
across serving-stack refactors.

The scheduler's virtual-clock replay is the repo's test oracle for the
queue/deadline/shed logic — PR 4 factors the clock out of
``ServingScheduler`` (``Clock`` protocol, real-clock ``ServingFrontend``)
and these goldens pin the replay behaviour across that refactor: every
admission counter, trigger counter, queue-wait/latency percentile,
makespan, hedge counter, and per-replica placement below was captured
from the pre-refactor scheduler and must not move.

All scenarios inject deterministic service/latency models and fixed
seeds, so the numbers depend only on the trace — any drift is a real
behaviour change, not noise.

Regenerate (only when a behaviour change is *intended* and reviewed):

    PYTHONPATH=src python tests/test_virtual_clock_goldens.py --regen
"""

import json
from pathlib import Path

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf
from repro.data import make_dataset, make_queries
from repro.serve import (
    ReplicaFleet,
    ReplicaSpec,
    SchedulerConfig,
    ServingScheduler,
)

GOLDEN_PATH = Path(__file__).parent / "goldens" / "serving_virtual_clock.json"


def _fixture():
    ds = make_dataset(nb=2000, dim=16, n_components=6, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=16, nlist=16, nprobe=4, topk=5, kmeans_iters=3)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=96, skew=0.3, noise=0.2, seed=1)
    qh = make_queries(ds, nq=64, skew=0.95, hot_fraction=0.06, noise=0.1,
                      seed=3)
    return ds, cfg, index, q, qh


def _burst(q, spacing=1e-5, t0=0.0):
    return [(t0 + i * spacing, q[i]) for i in range(len(q))]


def _digest(sched, target) -> dict:
    """Every counter the replay oracle guarantees, JSON-normalized.

    Floats are rounded to 9 decimals purely for stable JSON round-trips;
    the comparison below is exact equality on the rounded values."""
    stats = target.stats
    out = {
        "served": len(sched.done),
        "req_ids_sum": int(sum(r.req_id for r in sched.done)),
        "batch_ids": [r.batch_id for r in sorted(sched.done,
                                                 key=lambda r: r.req_id)],
        "makespan_s": round(sched.makespan_s, 9),
        "queue_wait_sum_ms": round(float(np.sum(stats.queue_wait_ms)), 9),
        "latency_sum_ms": round(float(np.sum(stats.request_latency_ms)), 9),
        "summary": {
            k: (round(v, 9) if isinstance(v, float) else v)
            for k, v in stats.summary().items()
            # execution-side and data-plane-side counters are not replay
            # state (the data plane grew upsert/delete/swap counters in
            # PR 5, resilience counters in PR 7, cache/coalescing/
            # deadline counters in PR 9, and tiered-placement counters
            # in PR 10 — always 0 in these read-only, fault-free,
            # cache-off, all-device scenarios)
            if k not in ("batches", "queries",
                         "upserts", "deletes", "generation_swaps",
                         "replica_failures", "breaker_opens",
                         "breaker_closes", "health_probes",
                         "retried_batches", "failed_batches",
                         "failed_requests", "shutdown_leaks",
                         "cache_hits_exact", "cache_hits_semantic",
                         "cache_misses", "cache_invalidations",
                         "coalesced", "expired_requests",
                         "cold_batches", "bytes_streamed",
                         "prefetch_hits", "placement_swaps")
        },
    }
    hedge = getattr(target, "_hedge", None) or getattr(
        sched, "_hedge", None
    )
    if hedge is not None:
        hs = hedge.stats
        out["hedge"] = {
            "dispatched": hs.dispatched, "hedged": hs.hedged,
            "wasted": hs.wasted, "hedge_wins": hs.hedge_wins,
        }
    if isinstance(target, ReplicaFleet):
        out["per_replica_batches"] = [r.batches for r in target.replicas]
        out["per_replica_queries"] = [r.queries for r in target.replicas]
        out["per_replica_busy_s"] = [round(r.busy_s, 9)
                                     for r in target.replicas]
        out["gini"] = round(target.load_balance_gini, 9)
    return out


def _scenarios():
    """name -> digest for every deterministic virtual-clock scenario."""
    ds, cfg, index, q, qh = _fixture()
    out = {}

    # -- single server: size-trigger batches on a same-instant burst
    from repro.serve import HarmonyServer

    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=16), k=5,
        service_time_fn=lambda n: n * 1e-3,
    )
    sched.run_trace(_burst(q, spacing=0.0))
    out["single_full"] = _digest(sched, sched.target)

    # -- single server: deadline-trigger batches under slow arrivals
    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=32, max_wait_s=2e-3), k=5,
        service_time_fn=lambda n: 0.0,
    )
    sched.run_trace([(0.01 * i, q[i]) for i in range(16)])
    out["single_deadline"] = _digest(sched, sched.target)

    # -- single server: backpressure shed behind a 1s-per-batch server
    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=4, queue_capacity=8, max_wait_s=1e-3),
        k=5, service_time_fn=lambda n: 1.0,
    )
    sched.run_trace([(i * 1e-6, q[i % len(q)]) for i in range(64)])
    out["single_backpressure"] = _digest(sched, sched.target)

    # -- single server: hedged dispatch with a deterministic straggler
    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=8, hedge_deadline_s=0.01), k=5,
        service_time_fn=lambda n: n * 1e-4,
        latency_fn=lambda w, t: 0.5 if w == 0 else 1e-5,
    )
    sched.run_trace(_burst(q[:32]))
    out["single_hedged"] = _digest(sched, sched.target)

    # -- single server: hot-mass drift triggers a skew re-plan
    srv = HarmonyServer(index, n_nodes=4)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=8, replan_drift=0.15,
                        min_batches_between_replans=2),
        k=5, service_time_fn=lambda n: n * 1e-4,
    )
    trace = _burst(q[:32], spacing=1e-4) + _burst(qh, spacing=1e-4, t0=0.01)
    sched.run_trace(trace)
    out["single_skew_replan"] = _digest(sched, sched.target)

    # -- fleet: heterogeneous p2c routing under a skewed burst
    caps = [1.0, 1.0, 0.5, 0.5]
    fleet = ReplicaFleet(
        index, replicas=[ReplicaSpec(capacity=c) for c in caps], cfg=cfg,
        routing="p2c", service_time_fn=lambda r, n: n * 1e-3 / caps[r],
        seed=0,
    )
    sched = ServingScheduler(fleet, SchedulerConfig(max_batch=8), k=5)
    sched.run_trace(_burst(qh))
    out["fleet_p2c_hetero"] = _digest(sched, fleet)

    # -- fleet: cross-replica hedging with a straggling replica 0
    fleet = ReplicaFleet(
        index, replicas=3, cfg=cfg, routing="least_loaded",
        service_time_fn=lambda r, n: n * 1e-4,
        latency_fn=lambda r, t: 0.5 if r == 0 else 1e-5,
        seed=0,
    )
    sched = ServingScheduler(
        fleet, SchedulerConfig(max_batch=8, hedge_deadline_s=0.01), k=5
    )
    sched.run_trace(_burst(q))
    out["fleet_hedged"] = _digest(sched, fleet)

    # -- fleet: replica fail/join mid-trace
    fleet = ReplicaFleet(
        index, replicas=2, cfg=cfg, routing="least_loaded",
        service_time_fn=lambda r, n: n * 1e-3, seed=0,
    )

    def churn(batch_idx, sched):
        if batch_idx == 2:
            fleet.fail_replica(1)
        elif batch_idx == 5:
            fleet.join_replica(ReplicaSpec())

    sched = ServingScheduler(
        fleet, SchedulerConfig(max_batch=8), k=5, on_batch=churn
    )
    sched.run_trace(_burst(q))
    out["fleet_churn"] = _digest(sched, fleet)

    return out


def test_virtual_clock_replay_matches_goldens():
    """Every admission/trigger/hedge/placement counter of the virtual-clock
    replay is unchanged from the pre-clock-refactor goldens."""
    assert GOLDEN_PATH.exists(), (
        f"missing {GOLDEN_PATH}; regenerate with "
        "PYTHONPATH=src python tests/test_virtual_clock_goldens.py --regen"
    )
    golden = json.loads(GOLDEN_PATH.read_text())
    got = _scenarios()
    assert sorted(got) == sorted(golden), "scenario set changed"
    for name in golden:
        assert got[name] == golden[name], (
            f"virtual-clock replay drifted in scenario {name!r}:\n"
            f"  golden: {json.dumps(golden[name], sort_keys=True)}\n"
            f"  got:    {json.dumps(got[name], sort_keys=True)}"
        )


def test_cache_off_replay_is_byte_identical_to_golden():
    """PR 9's cache/coalescing front door is default-off; this pins that
    the *new code paths themselves* leave the replay byte-identical to the
    stored pre-cache golden: (a) an explicit ``CacheConfig(enabled=False)``
    must be fully inert, and (b) an *enabled* cache on a repeat-free trace
    (exact tier only — distinct queries can't hit) must not move a single
    admission counter, trigger classification, wait, or makespan either —
    lookups/inserts happen off the accounting path."""
    from repro.serve import HarmonyServer
    from repro.serve.cache import CacheConfig

    golden = json.loads(GOLDEN_PATH.read_text())["single_full"]
    ds, cfg, index, q, qh = _fixture()
    for ccfg in (CacheConfig(enabled=False),
                 CacheConfig(enabled=True, semantic_threshold=0.0)):
        srv = HarmonyServer(index, n_nodes=4)
        sched = ServingScheduler(
            srv, SchedulerConfig(max_batch=16, cache=ccfg), k=5,
            service_time_fn=lambda n: n * 1e-3,
        )
        sched.run_trace(_burst(q, spacing=0.0))
        assert _digest(sched, sched.target) == golden, (
            f"cache config {ccfg} perturbed the replay"
        )


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN_PATH.write_text(
            json.dumps(_scenarios(), indent=2, sort_keys=True) + "\n"
        )
        print(f"wrote {GOLDEN_PATH}")
    else:
        test_virtual_clock_replay_matches_goldens()
        print("goldens match")
