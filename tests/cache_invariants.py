"""Shared body of invariant **P11** — staleness-bounded cache
correctness under arbitrary interleavings.

Two identical serving stacks replay the same interleaving of
search / upsert / delete / compaction on the virtual clock — one with
the semantic cache enabled (staleness budget 0), one cache-off (the
twin). For every search the pair must agree:

* an **exact-tier hit** (and every **miss**) is *bit-identical* to the
  cache-off execution — with a zero staleness budget a hit is only
  served while the data plane is unchanged since the entry was stored,
  so replaying the stored answer equals re-executing;
* a **semantic hit** is the exact answer of a cached neighbor query
  within ``sqrt(threshold)`` (L2), so by the 1-Lipschitz property of
  k-th-neighbor distances every returned distance is within
  ``sqrt(threshold)`` of the fresh answer's — and no deleted id may
  appear;
* **no hit is ever served across a generation swap** — immediately
  after a compaction commit, a repeat of a cached query must miss.

``tests/test_cache.py`` runs a fixed grid (both backends × fp32/int8);
``tests/properties/test_props.py`` drives the same body from hypothesis.
"""

import functools

import numpy as np

from repro.config import HarmonyConfig
from repro.core import SearchRequest, SegmentedIndex
from repro.serve import (
    CacheConfig,
    HarmonyServer,
    SchedulerConfig,
    ServingScheduler,
)
from repro.serve.executor import ExecutorConfig

# the op alphabet hypothesis samples from (seed-parameterized)
OPS = ("fresh", "repeat", "near", "upsert", "delete", "compact")
THRESHOLD = 4.0                     # semantic tier, squared-L2 score space


def retry_flaky(times: int = 3):
    """Re-run a test body on AssertionError up to ``times`` attempts —
    the flake guard for wall-clock thread-timing tests (the frontend
    coalescing test races real threads against real sleeps; a loaded CI
    box can starve the window). Genuine failures still fail: the last
    attempt's AssertionError propagates."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **k):
            for attempt in range(times):
                try:
                    return fn(*a, **k)
                except AssertionError:
                    if attempt == times - 1:
                        raise
        return wrapper
    return deco


def _mk_stack(x, cfg, backend, cache):
    data = SegmentedIndex.build(x, cfg)
    srv = HarmonyServer(
        data, n_nodes=2, backend=backend,
        executor_cfg=ExecutorConfig(qb_buckets=(8,), chunk=64,
                                    use_pallas=False),
    )
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=1, cache=cache), k=cfg.topk,
        service_time_fn=lambda n: 0.0,
    )
    return data, srv, sched


def run_cache_interleaving(data_seed, backend, precision, ops):
    """Replay one interleaving on the cached stack and its cache-off
    twin, asserting the P11 invariants after every search."""
    nb, dim, k = 64, 8, 4
    rng0 = np.random.default_rng(data_seed)
    x = rng0.standard_normal((nb, dim)).astype(np.float32)
    # nprobe = nlist (exact IVF) + a rerank factor that keeps every int8
    # stage-1 candidate: both precisions are exact, so the twin's fresh
    # answer is the oracle for the cached answer at staleness 0
    cfg = HarmonyConfig(dim=dim, nlist=4, nprobe=4, topk=k, kmeans_iters=2,
                        rerank_factor=32)
    ccfg = CacheConfig(enabled=True, exact_ttl_s=1e9,
                       semantic_threshold=THRESHOLD, staleness_s=0.0)
    data_a, srv_a, sa = _mk_stack(x, cfg, backend, ccfg)
    data_b, srv_b, sb = _mk_stack(x, cfg, backend, None)

    history = []                    # every query vector submitted so far
    live = set(range(nb))
    deleted: set = set()
    next_id = nb
    t = 0.0

    def ask(v):
        """Submit v to both stacks at the same virtual instant; returns
        (cached result, twin result, tier served: exact|semantic|miss)."""
        nonlocal t
        t += 1.0
        st = srv_a.stats
        before = (st.cache_hits_exact, st.cache_hits_semantic)
        req = SearchRequest(vector=v, k=k, precision=precision)
        results = []
        for sched in (sa, sb):
            n0 = len(sched.done)
            sched.submit(req, t)
            sched.advance(t + 0.5)
            new = sched.done[n0:]
            assert len(new) == 1, "one submission must yield one result"
            results.append(new[0])
        if st.cache_hits_exact > before[0]:
            tier = "exact"
        elif st.cache_hits_semantic > before[1]:
            tier = "semantic"
        else:
            tier = "miss"
        history.append(v)
        return results[0], results[1], tier

    def check(v):
        ra, rb, tier = ask(v)
        if tier == "semantic":
            # the cached answer is the exact top-k of a neighbor query
            # q' with ||q - q'|| <= sqrt(THRESHOLD) over the *same*
            # plane state (staleness 0): j-th-neighbor distance is
            # 1-Lipschitz in the query, so every served distance is
            # within sqrt(THRESHOLD) of the fresh twin's
            fin_a, fin_b = np.isfinite(ra.scores), np.isfinite(rb.scores)
            assert np.array_equal(fin_a, fin_b), (
                "semantic hit padded differently than the fresh answer"
            )
            r = np.sqrt(THRESHOLD)
            gap = np.abs(np.sqrt(ra.scores[fin_a]) - np.sqrt(rb.scores[fin_b]))
            assert gap.max(initial=0.0) <= r + 1e-3, (
                f"semantic hit drifted past the threshold: {gap.max()}"
            )
            got = ra.ids[ra.ids >= 0]
            assert not np.isin(got, sorted(deleted) or [-999]).any(), (
                "semantic hit served a deleted id"
            )
        else:
            # exact hits and misses are bit-identical to the twin
            assert np.array_equal(ra.ids, rb.ids), (
                f"{tier}: ids diverged from the cache-off twin"
            )
            assert np.array_equal(ra.scores, rb.scores), (
                f"{tier}: scores diverged from the cache-off twin"
            )
        return tier

    for kind, s in ops:
        r = np.random.default_rng(s)
        if kind == "fresh":
            check(r.standard_normal(dim).astype(np.float32))
        elif kind == "repeat":
            if not history:
                check(r.standard_normal(dim).astype(np.float32))
            else:
                v = history[int(r.integers(0, len(history)))]
                check(v.copy())
        elif kind == "near":
            if not history:
                check(r.standard_normal(dim).astype(np.float32))
            else:
                v = history[int(r.integers(0, len(history)))]
                jit = r.standard_normal(dim).astype(np.float32)
                # jitter scaled inside the threshold ball (not asserted
                # to hit — the anchor may be stale/evicted by now)
                jit *= np.sqrt(0.8 * THRESHOLD) / max(
                    float(np.linalg.norm(jit)), 1e-9)
                check((v + jit).astype(np.float32))
        elif kind == "upsert":
            v = r.standard_normal((1, dim)).astype(np.float32)
            if live and r.integers(2):
                tid = sorted(live)[int(r.integers(0, len(live)))]
            else:
                tid = next_id
                next_id += 1
            for srv in (srv_a, srv_b):
                srv.upsert([tid], v)
            live.add(tid)
            deleted.discard(tid)
        elif kind == "delete":
            if live:
                tid = sorted(live)[int(r.integers(0, len(live)))]
                for srv in (srv_a, srv_b):
                    srv.delete([tid])
                live.discard(tid)
                deleted.add(tid)
        elif kind == "compact":
            gen0 = data_a.generation
            for data in (data_a, data_b):
                data.compact_inline(merge_all=bool(s % 2))
            if history and data_a.generation != gen0:
                # no hit may ever be served across a generation swap:
                # a repeat of an already-cached query must miss now
                v = history[int(r.integers(0, len(history)))]
                assert check(v.copy()) == "miss", (
                    "cache hit served across a generation swap"
                )

    # the cached stack never lost or duplicated an answer: every offered
    # request was served exactly once, from cache or from execution
    st = srv_a.stats
    assert st.offered == len(sa.done)
    assert st.offered == (st.admitted + st.shed + st.expired_requests
                          + st.cache_hits_exact + st.cache_hits_semantic)
