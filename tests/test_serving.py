"""Serving-scheduler tests: admission control, adaptive batch forming,
backpressure, skew-triggered re-planning, hedged dispatch, and the elastic
invariant under scheduled serving.

The scheduler runs on a virtual clock driven by arrival timestamps, so
every assertion here is deterministic: batch composition, trigger type,
and shed counts depend only on the trace (service time is injected where
the test needs backlog)."""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf, search_oracle
from repro.data import make_dataset, make_queries
from repro.serve import HarmonyServer, SchedulerConfig, ServingScheduler


@pytest.fixture(scope="module")
def anns():
    ds = make_dataset(nb=4000, dim=32, n_components=8, spread=0.6, seed=0)
    cfg = HarmonyConfig(dim=32, nlist=32, nprobe=6, topk=5, kmeans_iters=4)
    index = build_ivf(ds.x, cfg)
    q = make_queries(ds, nq=64, skew=0.3, noise=0.2, seed=1)
    return ds, cfg, index, q


def _server(index, n_nodes=4):
    return HarmonyServer(index, n_nodes=n_nodes)


# ------------------------------------------------------------- (a) exactness


def test_scheduled_results_bitwise_equal_synchronous(anns):
    """Scheduled serving with the same batch composition must be BITWISE
    identical to the synchronous search_batch drain loop."""
    ds, cfg, index, q = anns
    srv_sched = _server(index)
    srv_sync = _server(index)
    B = 16
    sched = ServingScheduler(srv_sched, SchedulerConfig(max_batch=B), k=5)
    results = sched.run_trace([(0.0, q[i]) for i in range(len(q))])
    assert len(results) == len(q)
    assert [r.req_id for r in results] == list(range(len(q)))
    got_scores = np.stack([r.scores for r in results])
    got_ids = np.stack([r.ids for r in results])

    want_scores, want_ids = [], []
    for lo in range(0, len(q), B):
        res = srv_sync.search_batch(q[lo : lo + B], 5)
        want_scores.append(res.scores)
        want_ids.append(res.ids)
    assert np.array_equal(got_scores, np.concatenate(want_scores))
    assert np.array_equal(got_ids, np.concatenate(want_ids))
    assert srv_sched.stats.full_batches == len(q) // B
    assert srv_sched.stats.deadline_batches == 0
    assert srv_sched.stats.shed == 0


def test_serve_stream_is_scheduled_and_aligned(anns):
    """HarmonyServer.serve (now scheduler-backed) returns one result per
    input batch, aligned with the stream, matching the oracle."""
    ds, cfg, index, q = anns
    srv = _server(index)
    outs = srv.serve([q[0:16], q[16:48], q[48:64]], k=5)
    assert [o.ids.shape[0] for o in outs] == [16, 32, 16]
    oracle = search_oracle(index, q, k=5)
    np.testing.assert_allclose(
        np.concatenate([o.scores for o in outs]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )
    assert srv.stats.admitted == 64 and srv.stats.shed == 0


# -------------------------------------------------------- (b) deadline fires


def test_deadline_triggers_batches_under_slow_arrivals(anns):
    """Arrivals slower than max_wait_s must fire (small) deadline batches;
    queue waits are bounded by the deadline on the virtual clock."""
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=32, max_wait_s=0.002), k=5,
        service_time_fn=lambda n: 0.0,   # keep the virtual clock deterministic
    )
    n = 8
    results = sched.run_trace([(0.010 * i, q[i]) for i in range(n)])
    assert len(results) == n
    assert srv.stats.deadline_batches == n      # every batch fired by deadline
    assert srv.stats.full_batches == 0
    for w in srv.stats.queue_wait_ms:
        assert 0.0 <= w <= 2.0 + 1e-6
    oracle = search_oracle(index, q[:n], k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ------------------------------------------------------- (c) backpressure


def test_backpressure_sheds_and_counts(anns):
    """Once the bounded queue fills behind a slow server, arrivals are shed
    and accounted; admitted requests are all served."""
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=4, queue_capacity=8, max_wait_s=0.001),
        k=5,
        service_time_fn=lambda n: 1.0,        # 1s virtual service → backlog
    )
    n = 64
    results = sched.run_trace([(i * 1e-6, q[i % len(q)]) for i in range(n)])
    st = srv.stats
    # batch 1 (4 reqs) fires during the burst; the queue then fills to its
    # bound (8); everything else is shed.
    assert st.offered == n
    assert st.admitted == 12
    assert st.shed == n - 12
    assert st.offered == st.admitted + st.shed
    assert len(results) == st.admitted
    served_ids = {r.req_id for r in results}
    assert len(served_ids) == st.admitted     # shed requests have no result


def test_capacity_fire_drains_bounded_queue_early(anns):
    """When queue_capacity < max_batch the size trigger is unreachable; the
    queue hitting its bound must fire the batch (counted separately) rather
    than shedding behind an idle server until the deadline."""
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=8, queue_capacity=2, max_wait_s=1.0),
        k=5,
        service_time_fn=lambda n: 0.0,
    )
    results = sched.run_trace([(i * 1e-4, q[i]) for i in range(8)])
    st = srv.stats
    assert len(results) == 8 and st.shed == 0     # nothing shed: drained early
    assert st.capacity_batches == 4               # 4 pairs, all capacity-fired
    assert st.full_batches == 0 and st.deadline_batches == 0
    oracle = search_oracle(index, q[:8], k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ------------------------------------------- (d) elastic invariant mid-stream


def test_fail_node_mid_stream_preserves_results(anns):
    """Killing a node between scheduled batches re-plans but must not change
    any result (extends the runtime/elastic invariant to the scheduler)."""
    ds, cfg, index, q = anns
    srv = _server(index)
    oracle = search_oracle(index, q, k=5)

    def killer(batch_idx, sched):
        if batch_idx == 1:
            sched.server.fail_node(1)

    sched = ServingScheduler(
        srv, SchedulerConfig(max_batch=16), k=5, on_batch=killer
    )
    results = sched.run_trace([(0.0, q[i]) for i in range(len(q))])
    assert srv.cluster.n_live == 3
    assert srv.stats.replans >= 1
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ----------------------------------------------------- skew-aware re-planning


def test_skew_drift_triggers_replan(anns):
    """A workload that drifts from uniform to hot must push the live-window
    hot-mass past the drift threshold and trigger a cost-model re-plan."""
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(
            max_batch=8, replan_drift=0.15, min_batches_between_replans=2
        ),
        k=5,
    )
    qu = make_queries(ds, nq=32, skew=0.0, noise=0.2, seed=2)
    qh = make_queries(ds, nq=64, skew=0.95, hot_fraction=0.04, noise=0.1, seed=3)
    trace = [(i * 1e-4, qu[i]) for i in range(32)]
    trace += [(0.01 + i * 1e-4, qh[i]) for i in range(64)]
    results = sched.run_trace(trace)
    assert len(results) == 96
    assert srv.stats.skew_replans >= 1
    # results stay exact across the re-plan
    oracle = search_oracle(index, np.concatenate([qu, qh]), k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ----------------------------------------------------------- hedged dispatch


def test_hedged_dispatch_fires_and_preserves_results(anns):
    """A straggling primary makes the hedge fire; results are unchanged and
    the effective latency is charged to the virtual clock."""
    ds, cfg, index, q = anns
    srv = _server(index)
    lat = lambda w, t: 0.5 if w == 0 else 1e-5      # node 0 straggles
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=8, hedge_deadline_s=0.01),
        k=5,
        latency_fn=lat,
    )
    results = sched.run_trace([(0.0, q[i]) for i in range(32)])
    assert srv.stats.hedged_batches >= 1
    assert sched._hedge.stats.hedged >= 1
    oracle = search_oracle(index, q[:32], k=5)
    np.testing.assert_allclose(
        np.stack([r.scores for r in results]), oracle.scores,
        rtol=1e-3, atol=1e-3,
    )


# ------------------------------------------------------------------ plumbing


def test_summary_none_percentiles_with_zero_completions(anns):
    """With requests offered (and shed) but none completed, summary()
    must report None percentile fields instead of raising on an empty
    quantile or fabricating 0.0."""
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(
        srv,
        SchedulerConfig(max_batch=64, max_wait_s=10.0, queue_capacity=8),
        k=5,
    )
    for i in range(4):          # same-instant burst: nothing fires pre-flush
        sched.submit(q[i], 0.0)
    s = srv.stats.summary()     # must not raise
    assert srv.stats.admitted == 4 and srv.stats.batches == 0
    for key in ("p50_queue_wait_ms", "p99_queue_wait_ms",
                "p50_request_latency_ms", "p99_request_latency_ms"):
        assert s[key] is None
    results = sched.flush()     # the deadline fires on drain
    assert len(results) == 4
    assert srv.stats.summary()["p50_queue_wait_ms"] is not None


def test_stats_summary_and_percentiles(anns):
    ds, cfg, index, q = anns
    srv = _server(index)
    sched = ServingScheduler(srv, SchedulerConfig(max_batch=16), k=5)
    sched.run_trace([(0.0, q[i]) for i in range(32)])
    s = srv.stats.summary()
    for key in (
        "p50_queue_wait_ms", "p99_queue_wait_ms", "shed", "admitted",
        "full_batches", "deadline_batches", "skew_replans",
    ):
        assert key in s
    assert s["admitted"] == 32
    assert srv.stats.queue_wait_pct(50) <= srv.stats.queue_wait_pct(99) + 1e-9
    assert sched.served_qps > 0
